"""Cursor-loop UDFs end to end: parse → verdict → LoopScan plan → execute.

    PYTHONPATH=src python examples/cursor_loops.py

The PR-6 loop frontend in three acts:

  1. Parse a T-SQL UDF containing DECLARE CURSOR / OPEN / FETCH NEXT /
     WHILE @@fetch_status / CLOSE / DEALLOCATE into loop IR
     (`repro.core.parse_udf`), including the line/column diagnostics a
     bad source gets.
  2. Classify each loop (`repro.loops.classify`): commutative folds
     rewrite to masked reductions ("reduce"), order-dependent bodies to
     a predicated `lax.scan` ("scan"), and anything else gets an
     explicit non-rewritable verdict — NOT a parse error.
  3. Prepare under FROID: the rewritten loop shows up as a `LoopScan`
     operator in `explain()`, the UDF call is gone from the plan, and
     FROID / INTERPRETED / HEKATON agree element-wise.  Non-rewritable
     loops stay as a UdfCall and run on the per-row interpreter.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (
    FROID, INTERPRETED, HEKATON, Session, UnsupportedConstructError,
    col, explain, param, parse_udf, scan, udf,
)
from repro.loops import classify

# ---------------------------------------------------------------- act 1
CURSOR_TOTAL = """
create function cursor_total(@x float) returns float as
begin
    declare @t float = 0.0;
    declare @v float;
    declare c cursor for select val from facts where fk <= @x;
    open c;
    fetch next from c into @v;
    while @@fetch_status = 0
    begin
        set @t = @t * 0.5 + @v;
        if @t > 75.0 break;
        fetch next from c into @v;
    end
    close c;
    deallocate c;
    return @t;
end
"""

fn = parse_udf(CURSOR_TOTAL)
print(f"parsed UDF {fn.name!r}: {len(fn.body)} top-level statements")

BAD = CURSOR_TOTAL.replace("open c;", "open missing;")
try:
    parse_udf(BAD)
except UnsupportedConstructError as e:
    print(f"diagnostic demo -> {e}")

# ---------------------------------------------------------------- act 2
from repro.core import CursorLoop  # noqa: E402  (narrative ordering)

loop = next(s for s in fn.body if isinstance(s, CursorLoop))
print(f"verdict: {classify(loop)}")

# a loop the rewrite refuses: plain WHILE with no driving relation
PLAIN = """
create function countdown(@x float) returns float as
begin
    declare @i float = 0.0;
    while @i < @x
    begin
        set @i = @i + 1.0;
    end
    return @i;
end
"""
plain_fn = parse_udf(PLAIN)
from repro.core import While  # noqa: E402

wloop = next(s for s in plain_fn.body if isinstance(s, While))
print(f"verdict: {classify(wloop)}")

# ---------------------------------------------------------------- act 3
db = Session()
rng = np.random.default_rng(0)
db.create_table("facts",
                fk=rng.integers(0, 8, 64),
                val=np.round(rng.uniform(-10, 10, 64), 2).astype(np.float32))
db.create_table("keys", k=np.arange(5))
db.create_function(fn)
db.create_function(plain_fn)

q = (scan("keys")
     .filter(col("k") < param("cut"))
     .compute(out=udf("cursor_total", col("k") * 1.0))
     .project("k", "out"))

stmt = db.prepare(q, FROID)
plan_text = explain(stmt.plan)
print("\nFROID plan (loop rewritten into the relational operator):")
print(plan_text)
assert "LoopScan[" in plan_text and "UdfCall" not in plan_text

p = {"cut": 4}
r_froid = stmt.execute(params=p)
m = np.asarray(r_froid.masked.mask)  # values on masked-out rows are undefined
for policy, tag in ((INTERPRETED, "INTERPRETED"), (HEKATON, "HEKATON")):
    r_other = db.prepare(q, policy).execute(params=p)
    np.testing.assert_array_equal(m, np.asarray(r_other.masked.mask))
    np.testing.assert_allclose(
        np.asarray(r_other.masked.table.columns["out"].data)[m],
        np.asarray(r_froid.masked.table.columns["out"].data)[m],
        rtol=2e-3, atol=1e-3)
    print(f"{tag} agrees with FROID")

q2 = (scan("keys")
      .compute(out=udf("countdown", col("k") * 1.0))
      .project("k", "out"))
stmt2 = db.prepare(q2, FROID)
from repro.core import relalg as R  # noqa: E402
from repro.core import scalar as S  # noqa: E402

calls = [e for n in R.walk_plan_deep(stmt2.plan) for ex in n.exprs()
         for e in S.walk(ex) if isinstance(e, S.UdfCall)]
assert calls, "expected the non-rewritable loop's UdfCall to survive"
r2 = stmt2.execute()
print("\nnon-rewritable loop fell back to the interpreter:",
      np.asarray(r2.masked.table.columns["out"].data).tolist())
