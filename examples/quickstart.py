"""Quickstart: the paper's Figure 1 example on the prepare/execute API.

    PYTHONPATH=src python examples/quickstart.py

Defines the `total_price` UDF (imperative: declarations, SELECT-assigns,
IF/ELSE, nested UDF call), opens a Session, prepares the query once under
each ExecutionPolicy preset and executes it warm:

  * FROID        — algebrized + inlined + set-oriented compiled plan
  * INTERPRETED  — per-tuple statement-at-a-time interpretation (classic)
  * HEKATON      — natively-compiled but still iterative (Table 5)

The prepared FROID statement is the paper's headline: cold `prepare` pays
bind + optimize + jit once; every warm `execute` reuses the cached plan
and compiled callable (`QueryResult.cache_hit`).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (
    FROID, INTERPRETED, Session, UdfBuilder,
    col, lit, param, scalar_subquery, scan, sum_, udf, var,
)

session = Session()
rng = np.random.default_rng(0)
n_cust, n_ord = 2_000, 20_000
session.create_table("customer", c_custkey=np.arange(n_cust))
session.create_table("orders",
                     o_custkey=rng.integers(0, n_cust, n_ord),
                     o_totalprice=rng.uniform(10, 1000, n_ord).astype(np.float32))
session.create_table("customer_prefs", custkey=np.arange(n_cust),
                     currency=np.array(["USD" if i % 3 else "EUR" for i in range(n_cust)]))
session.create_table("xchg", from_cur=np.array(["USD"]), to_cur=np.array(["EUR"]),
                     rate=np.array([0.9], dtype=np.float32))

# dbo.xchg_rate
u = UdfBuilder("xchg_rate", [("frm", "str"), ("to", "str")], "float32")
u.return_(scalar_subquery(
    scan("xchg")
    .filter((col("from_cur") == param("frm")) & (col("to_cur") == param("to")))
    .compute(r=col("rate")).project("r"), "r"))
session.create_function(u.build())

# dbo.total_price (Figure 1)
u = UdfBuilder("total_price", [("key", "int32")], "float32")
u.declare("price", "float32")
u.declare("rate", "float32")
u.declare("pref_currency", "str")
u.declare("default_currency", "str", lit("USD"))
u.select({"price": sum_(col("o_totalprice"))},
         frm=scan("orders"), where=col("o_custkey") == param("key"))
u.select({"pref_currency": col("currency")},
         frm=scan("customer_prefs"), where=col("custkey") == param("key"))
with u.if_(var("pref_currency") != var("default_currency")):
    u.set("rate", udf("xchg_rate", var("default_currency"), var("pref_currency")))
    u.set("price", var("price") * var("rate"))
u.return_(var("price"))
session.create_function(u.build())

q = scan("customer").compute(total=udf("total_price", col("c_custkey"))) \
                    .project("c_custkey", "total")

# prepare once: bind-time inlining + rewrites happen here
stmt = session.prepare(q, FROID)
print("=== Froid ON: algebrized + inlined + optimized plan ===")
print(stmt.explain())

r_cold = stmt.execute()            # pays whole-plan jit
r_warm = stmt.execute()            # cached compiled plan
assert not r_cold.cache_hit and r_warm.cache_hit

# iterative baseline on a subset (it is slow — that is the point)
sub = scan("customer").filter(col("c_custkey") < 100) \
    .compute(total=udf("total_price", col("c_custkey")))
r_off = session.execute(sub, INTERPRETED)
t_off = r_off.elapsed_s * n_cust / 100

a = np.asarray(r_warm.table.columns["total"].data)
print(f"\nfirst totals: {a[:5]}")
print(f"froid ON  cold (prepare+jit, {n_cust} rows): {r_cold.elapsed_s*1e3:9.1f} ms")
print(f"froid ON  warm (cache_hit={r_warm.cache_hit}):          "
      f"{r_warm.elapsed_s*1e3:9.1f} ms")
print(f"froid OFF (interpreted, extrap.):       {t_off*1e3:9.1f} ms")
print(f"speedup (warm vs interpreted): {t_off/r_warm.elapsed_s:.0f}x")
print(f"session cache stats: {session.cache_stats}")
