"""Mamba-2 (SSD) mixer block: fused in-projection, short causal conv,
SSD selective scan (Pallas kernel on TPU), gated RMSNorm, out-projection.
Sequence form for train/prefill + single-token decode with (conv, ssd)
state for serving.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ops import ssd_decode_step, ssd_scan
from repro.models.config import ArchConfig
from repro.models.layers import _dense_init, init_rmsnorm, rmsnorm


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    return s, d_in, H, conv_dim


def init_mamba(key, cfg: ArchConfig):
    s, d_in, H, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        # fused in-proj: [z (gate), x, B, C, dt]
        "w_in": _dense_init(
            ks[0], (cfg.d_model, 2 * d_in + 2 * s.n_groups * s.state_dim + H)
        ),
        "conv_w": _dense_init(ks[1], (s.conv_kernel, conv_dim), scale=0.5),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": init_rmsnorm(d_in),
        "w_out": _dense_init(ks[2], (d_in, cfg.d_model)),
    }


def _split_proj(cfg, proj):
    s, d_in, H, _ = _dims(cfg)
    gN = s.n_groups * s.state_dim
    z, xBC_dt = jnp.split(proj, [d_in], axis=-1)
    xBC, dt_raw = jnp.split(xBC_dt, [d_in + 2 * gN], axis=-1)
    return z, xBC, dt_raw


def mamba_seq(params, x_in, cfg: ArchConfig):
    """Sequence form.  Returns (out, (conv_state, ssd_state)) — final
    states for cache handoff after prefill."""
    s, d_in, H, conv_dim = _dims(cfg)
    B, S, D = x_in.shape
    dt_ = x_in.dtype
    gN = s.n_groups * s.state_dim

    proj = jnp.einsum("bsd,dh->bsh", x_in, params["w_in"].astype(dt_))
    z, xBC, dt_raw = _split_proj(cfg, proj)

    # short causal depthwise conv over sequence
    k = s.conv_kernel
    xBC_pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(
        xBC_pad[:, i : i + S, :] * params["conv_w"][i][None, None, :].astype(dt_)
        for i in range(k)
    )
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(dt_)
    xs, Bm, Cm = jnp.split(conv, [d_in, d_in + gN], axis=-1)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )  # (B, S, H)
    A = -jnp.exp(params["A_log"])  # (H,) negative
    xh = xs.reshape(B, S, H, s.head_dim)
    Bm = Bm.reshape(B, S, s.n_groups, s.state_dim)
    Cm = Cm.reshape(B, S, s.n_groups, s.state_dim)

    y = ssd_scan(xh.astype(jnp.float32), dt, A, Bm.astype(jnp.float32),
                 Cm.astype(jnp.float32))
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(dt_)

    y = rmsnorm(y, params["gate_norm"], cfg.norm_eps) * jax.nn.silu(
        z.astype(jnp.float32)
    ).astype(dt_)
    out = jnp.einsum("bsh,hd->bsd", y, params["w_out"].astype(dt_))

    conv_state = xBC[:, -(k - 1) :, :] if k > 1 else jnp.zeros((B, 0, conv_dim), dt_)
    # exact final SSD state for the prefill->decode handoff:
    #   S = Σ_s exp(cumA_S - cumA_s) · B_s ⊗ (dt_s x_s)
    dtA = dt * A[None, None, :]  # (B, S, H)
    cum = jnp.cumsum(dtA, axis=1)
    decay_end = jnp.exp(cum[:, -1:, :] - cum)  # (B, S, H)
    n_rep = H // s.n_groups
    B_rep = jnp.repeat(Bm.astype(jnp.float32), n_rep, axis=2)  # (B, S, H, N)
    xdt = xh.astype(jnp.float32) * dt[..., None]  # (B, S, H, P)
    ssd_state = jnp.einsum(
        "bsh,bshn,bshp->bhnp", decay_end, B_rep, xdt
    )  # (B, H, N, P)
    return out, (conv_state, ssd_state)


def mamba_decode(params, x_in, state, cfg: ArchConfig):
    """Single-token decode.  state = (conv_state (B, k-1, conv_dim),
    ssd_state (B, H, N, P))."""
    s, d_in, H, conv_dim = _dims(cfg)
    B, _, D = x_in.shape
    dt_ = x_in.dtype
    gN = s.n_groups * s.state_dim
    conv_state, ssd_state = state

    proj = jnp.einsum("bsd,dh->bsh", x_in, params["w_in"].astype(dt_))
    z, xBC, dt_raw = _split_proj(cfg, proj)  # (B, 1, ·)

    k = s.conv_kernel
    window = jnp.concatenate([conv_state, xBC], axis=1)  # (B, k, conv_dim)
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32))
    conv = jax.nn.silu(conv)[:, None, :].astype(dt_)
    new_conv_state = window[:, 1:, :]

    xs, Bm, Cm = jnp.split(conv[:, 0], [d_in, d_in + gN], axis=-1)
    dt = jax.nn.softplus(
        dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"][None, :]
    )  # (B, H)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B, H, s.head_dim).astype(jnp.float32)
    Bt = Bm.reshape(B, s.n_groups, s.state_dim).astype(jnp.float32)
    Ct = Cm.reshape(B, s.n_groups, s.state_dim).astype(jnp.float32)

    new_ssd, y = ssd_decode_step(ssd_state, xh, dt, A, Bt, Ct)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(B, 1, d_in).astype(dt_)
    y = rmsnorm(y, params["gate_norm"], cfg.norm_eps) * jax.nn.silu(
        z.astype(jnp.float32)
    ).astype(dt_)
    out = jnp.einsum("bsh,hd->bsd", y, params["w_out"].astype(dt_))
    return out, (new_conv_state, new_ssd)
