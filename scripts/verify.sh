#!/usr/bin/env bash
# Tier-1 verify: the repo's test suite, then the perf smoke CI runs.
# pyproject.toml sets pythonpath=src, so no PYTHONPATH export is needed for
# pytest — this script exists so `scripts/verify.sh` is the one canonical
# spelling (extra pytest args pass through, e.g.
# `scripts/verify.sh -m "not slow"`).
#
# VERIFY_BENCH=0 skips the perf smoke (tests only).
set -euo pipefail
cd "$(dirname "$0")/.."
python -m pytest -x -q "$@"

# mirrors the CI sharded-8dev job: sharded parity tests + perf smoke on a
# forced 8-device CPU mesh (VERIFY_SHARDED=0 skips)
if [ "${VERIFY_SHARDED:-1}" != "0" ]; then
  echo "--- sharded parity: pytest on a forced 8-device host mesh"
  XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -q tests/test_sharded_many.py \
      tests/test_conformance_oracle.py tests/test_execute_many.py \
      tests/test_fused.py tests/test_fuse_cse.py
fi

# multi-statement fusion: fused-drain parity + perf smoke (the in-bench
# asserts are the parity check; the speedup bar is host-aware — see the CI
# fused gate).  VERIFY_FUSED=0 skips.
if [ "${VERIFY_FUSED:-1}" != "0" ]; then
  echo "--- fused drain parity + perf smoke: benchmarks.run --quick --only fused"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --quick --only fused \
      --run-id verify-fused --json-dir /tmp
fi

# cursor-loop rewrite: interpreted-vs-rewritten parity (in-bench assert)
# plus the loop-to-scan perf smoke — the CI gate requires >= 20x at N=1024.
# VERIFY_CURSORLOOP=0 skips.
if [ "${VERIFY_CURSORLOOP:-1}" != "0" ]; then
  echo "--- cursor-loop parity + perf smoke: benchmarks.run --quick --only cursorloop"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --quick --only cursorloop \
      --run-id verify-cursorloop --json-dir /tmp
fi

# decorrelation: the decorrelation conformance oracle (fixed grid, plain
# + forced 8-device mesh for the sharded execute_many axis) plus the
# correlated-subquery perf smoke — the CI gate requires the rewritten
# plan >= 10x over the compiled per-row apply at N=1024 with three-way
# parity asserted in-bench.  VERIFY_DECORR=0 skips.
if [ "${VERIFY_DECORR:-1}" != "0" ]; then
  echo "--- decorrelation oracle: pytest tests/test_decorrelate.py"
  python -m pytest -q tests/test_decorrelate.py
  echo "--- decorrelation oracle (8-device mesh): sharded decorrelated drains"
  XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -q tests/test_decorrelate.py
  echo "--- decorrelation perf smoke: benchmarks.run --quick --only decorr"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --quick --only decorr \
      --run-id verify-decorr --json-dir /tmp
fi

# resilience: chaos smoke on a forced 8-device mesh (ladder, breakers,
# deadlines, chaos conformance oracle) + the ladder-overhead perf smoke —
# the CI gate requires fault-free overhead <= 1.05 with in-bench parity.
# VERIFY_RESILIENCE=0 skips.
if [ "${VERIFY_RESILIENCE:-1}" != "0" ]; then
  echo "--- chaos smoke: pytest tests/test_resilience.py on a forced 8-device host mesh"
  XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -q tests/test_resilience.py
  echo "--- resilience overhead + demotion smoke: benchmarks.run --quick --only resilience"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --quick --only resilience \
      --run-id verify-resilience --json-dir /tmp
fi

# cost routing: routed-vs-oracle parity tests (plain + forced 8-device
# mesh for the sharded/fused-sharding regressions) + the routing perf
# smoke — the CI gates hold routed within the host-aware bars and the
# cache-resident bookkeeping overhead <= 1.05.  VERIFY_ROUTING=0 skips.
if [ "${VERIFY_ROUTING:-1}" != "0" ]; then
  echo "--- cost routing: pytest tests/test_cost_routing.py"
  python -m pytest -q tests/test_cost_routing.py
  echo "--- cost routing (8-device mesh): routing + fused-sharding regressions"
  XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -q tests/test_cost_routing.py tests/test_fused.py
  echo "--- routing perf smoke: benchmarks.run --quick --only routing"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --quick --only routing \
      --run-id verify-routing --json-dir /tmp
fi

# persistent plan tier + fleet serving: store/key/session persistence
# tests, the fleet conformance oracle (plain + forced 8-device mesh for
# sharded-entry round-trips), and the cold-vs-warm startup smoke — the CI
# gate requires warm first-call >= 10x faster than cold with every
# statement served from the store.  VERIFY_PERSIST=0 skips.
if [ "${VERIFY_PERSIST:-1}" != "0" ]; then
  echo "--- persistent tier: pytest tests/test_persist.py tests/test_fleet.py"
  python -m pytest -q tests/test_persist.py tests/test_fleet.py
  echo "--- fleet oracle (8-device mesh): sharded persistent-entry round-trips"
  XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -q tests/test_persist.py tests/test_fleet.py
  echo "--- fleet startup + drain smoke: benchmarks.run --quick --only fleet"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --quick --only fleet \
      --run-id verify-fleet --json-dir /tmp
fi

if [ "${VERIFY_BENCH:-1}" != "0" ]; then
  echo "--- perf smoke: benchmarks.run --quick --only prepared,table4,execmany"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --quick --only prepared,table4,execmany \
      --run-id verify --json-dir /tmp
  echo "--- sharded perf smoke: benchmarks.run --quick --only shardmany (8 devices)"
  XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --quick --only shardmany \
      --run-id verify-sharded --json-dir /tmp
fi
