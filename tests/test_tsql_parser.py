"""T-SQL-subset frontend (paper §7.3): parse real T-SQL UDF text, algebrize,
and check froid == interpreter."""
import numpy as np

from repro.core import Database, col, scan, udf
from repro.core.tsql import parse_udf

GETVAL = """
create function dbo.getVal(@x int) returns char(10) as
begin
  declare @val float;
  if (@x > 1000)
    set @val = 10.0;
  else
    set @val = 1.0;
  return @val + 5.0;
end
"""

TOTAL = """
create function dbo.total_price(@key int) returns float as
begin
  declare @price float;
  select @price = sum(o_totalprice) from orders where o_custkey = @key;
  if @price is null
    return 0.0;
  if (@price > 1000.0)
    begin
      set @price = @price * 0.9;  -- bulk discount
    end
  return @price;
end
"""

BRACKET = """
create function dbo.RptBracket(@MyDiff int, @NDays int) returns int as
begin
  if (@MyDiff >= 5 * @NDays)
  begin
    return 5 * @NDays;
  end
  return (@MyDiff / @NDays) * @NDays;
end
"""


def _db(rng):
    db = Database()
    db.create_table("customer", c_custkey=np.arange(30))
    db.create_table(
        "orders",
        o_custkey=rng.integers(0, 30, 200),
        o_totalprice=rng.uniform(10, 200, 200).astype(np.float32),
    )
    return db


def _compare(db, q):
    r_on = db.run(q, froid=True)
    r_off = db.run(q, froid=False, mode="python")
    for name in r_on.table.names():
        a = np.asarray(r_on.table.columns[name].data, np.float64)
        av = np.asarray(r_on.table.columns[name].validity())
        b = np.asarray(r_off.table.columns[name].data, np.float64)
        bv = np.asarray(r_off.table.columns[name].validity())
        assert (av == bv).all()
        np.testing.assert_allclose(a[av], b[bv], rtol=1e-4)


def test_parse_getval(rng):
    db = _db(rng)
    f = parse_udf(GETVAL)
    assert f.name == "getval" or f.name == "getVal".lower() or f.name
    db.create_function(f)
    q = scan("customer").compute(v=udf(f.name, col("c_custkey") * 100))
    _compare(db, q)


def test_parse_total_price_with_inner_query(rng):
    db = _db(rng)
    f = parse_udf(TOTAL)
    db.create_function(f)
    assert f.statement_count() >= 4
    q = scan("customer").compute(t=udf(f.name, col("c_custkey")))
    _compare(db, q)
    # spot-check semantics against numpy
    r = db.run(q, froid=True)
    ck = np.asarray(db.catalog["orders"].columns["o_custkey"].data)
    tp = np.asarray(db.catalog["orders"].columns["o_totalprice"].data)
    got = np.asarray(r.table.columns["t"].data)
    for k in range(30):
        s = float(tp[ck == k].sum())
        exp = 0.0 if s == 0 else (s * 0.9 if s > 1000 else s)
        np.testing.assert_allclose(got[k], exp, rtol=1e-4)


def test_parse_rpt_bracket(rng):
    db = _db(rng)
    f = parse_udf(BRACKET)
    db.create_function(f)
    q = scan("customer").compute(b=udf(f.name, col("c_custkey"), 7))
    _compare(db, q)
