"""Figure 13 (CPU time) + Figure 14 / §8.3 (logical reads).

CPU time: process-CPU seconds for froid ON vs interpreted OFF (sampled).
Logical reads: bytes scanned by the storage layer — froid's set-oriented
plan reads each table once; iterative evaluation re-reads the inner table
per invocation (the paper's 3300 vs 5M logical reads example, Figure 14).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import (FROID, INTERPRETED, Session, UdfBuilder, col,
                        param, scan, sum_, udf, var)
from repro.core.executor import Executor
from repro.core.interpreter import Interpreter

N_CUST = 2_000
N_ORD = 20_000
N_INTERP = 200


def _db():
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table("customer", c_custkey=np.arange(N_CUST))
    db.create_table(
        "orders",
        o_custkey=rng.integers(0, N_CUST, N_ORD),
        o_totalprice=rng.uniform(10, 1000, N_ORD).astype(np.float32),
    )
    u = UdfBuilder("total_price", [("key", "int32")], "float32")
    u.declare("price", "float32")
    u.select({"price": sum_(col("o_totalprice"))}, frm=scan("orders"),
             where=col("o_custkey") == param("key"))
    u.return_(var("price"))
    db.create_function(u.build())
    return db


def run(quick: bool = False):
    db = _db()
    q = scan("customer").compute(total=udf("total_price", col("c_custkey")))

    # --- fig 13: CPU time (warm plan cache, as in the paper) ---------------
    fn_on = db.prepare(q, FROID)
    fn_on()  # warm
    t0 = time.process_time()
    fn_on()
    cpu_on = time.process_time() - t0
    emit("fig13/total_price/froid_on_cpu", cpu_on * 1e6, "")

    # interpreted CPU time on a sample, extrapolated (jit disabled: pure
    # statement-at-a-time interpretation like classic T-SQL)
    sub_q = scan("customer").filter(col("c_custkey") < N_INTERP).compute(
        total=udf("total_price", col("c_custkey"))
    )
    t0 = time.process_time()
    import dataclasses as _dc

    db.execute(sub_q, _dc.replace(INTERPRETED, jit_statements=not quick))
    cpu_off = (time.process_time() - t0) * N_CUST / N_INTERP
    emit("fig13/total_price/froid_off_cpu", cpu_off * 1e6,
         f"reduction={cpu_off/max(cpu_on, 1e-9):.0f}x (extrapolated)")

    # --- fig 14: logical reads (bytes scanned) ----------------------------
    plan = db.prepare(q, FROID).plan
    ex = Executor(db.catalog)
    ex.execute(plan)
    bytes_on = ex.stats["bytes_scanned"]
    emit("fig14/total_price/froid_on_bytes", bytes_on, "one scan per table")

    # iterative: inner table re-scanned once per invocation
    interp = Interpreter(db.catalog, db.registry, mode="python",
                         jit_statements=False)
    ex_off = Executor(db.catalog, udf_column_evaluator=interp.eval_udf_call)
    plan_off = db.prepare(sub_q, INTERPRETED).plan
    ex_off.execute(plan_off)
    measured = ex_off.stats["bytes_scanned"] + interp.stats["bytes_scanned"]
    bytes_off = measured * N_CUST / N_INTERP
    emit("fig14/total_price/froid_off_bytes", bytes_off,
         f"{bytes_off/bytes_on:.0f}x more logical reads (extrapolated)")


if __name__ == "__main__":
    run()
