"""A T-SQL-subset parser frontend (paper §7.3: the framework is
language-agnostic — adding a surface language is a parser plus calls into
the construct classes).

Supported grammar (enough for the paper's §9 example shapes)::

    CREATE FUNCTION name(@p TYPE, ...) RETURNS TYPE AS
    BEGIN
        DECLARE @v TYPE [= expr];
        SET @v = expr;
        SELECT @v = AGG(col) FROM table WHERE pred;
        IF (pred) BEGIN ... END [ELSE BEGIN ... END]
        RETURN expr;
    END

Expressions: numbers, 'strings', @vars, identifiers (columns), + - * /,
comparisons (= <> < <= > >=), AND/OR/NOT, parentheses, CASE WHEN ... THEN
... ELSE ... END, and function calls (intrinsics).  Types: INT, FLOAT,
BIT, DATE, VARCHAR/CHAR(n).

Loops (the Aggify surface — see :mod:`repro.loops`)::

    WHILE (pred) BEGIN ... END                       [BREAK inside]
    DECLARE c CURSOR FOR SELECT col, ... FROM t [WHERE pred];
    OPEN c;
    FETCH NEXT FROM c INTO @a, @b;
    WHILE @@fetch_status = 0 [AND guard] BEGIN
        ...body...
        FETCH NEXT FROM c INTO @a, @b;
    END
    CLOSE c; DEALLOCATE c;

The priming FETCH / trailing FETCH pair is folded into one
:class:`repro.core.ir.CursorLoop`; anything off that shape raises
:class:`UnsupportedConstructError` with the offending line/column.
"""
from __future__ import annotations

import re

from repro.core import frontend as F
from repro.core import ir as IR
from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.ir import UdfDef

#: the parsed name of the T-SQL ``@@fetch_status`` builtin (``@`` stripped
#: like every other variable token)
FETCH_STATUS = "@fetch_status"

_TOKEN = re.compile(
    r"\s*(?:(?P<num>\d+\.\d+|\d+)|(?P<str>'[^']*')|(?P<var>@@?\w+)"
    r"|(?P<id>[A-Za-z_][\w.]*)|(?P<op><=|>=|<>|!=|[=<>+\-*/(),;]))"
)


class UnsupportedConstructError(SyntaxError):
    """A construct outside the supported T-SQL subset, with location.

    Carries ``construct`` (short name of the offending construct),
    ``line`` and ``col`` (1-based) so frontends can point at the source."""

    def __init__(self, construct: str, detail: str, line: int = 0, col: int = 0):
        self.construct = construct
        self.line = line
        self.col = col
        super().__init__(
            f"unsupported construct {construct!r} at line {line}, col {col}: "
            f"{detail}")

_TYPES = {
    "int": "int32", "bigint": "int32", "bit": "bool", "float": "float32",
    "real": "float32", "decimal": "float32", "money": "float32",
    "date": "date", "datetime": "date", "varchar": "str", "char": "str",
    "nvarchar": "str",
}

_AGGS = {"sum": F.sum_, "count": F.count_, "min": F.min_, "max": F.max_,
         "avg": F.avg_}


def _line_col(src: str, offset: int) -> tuple[int, int]:
    line = src.count("\n", 0, offset) + 1
    col = offset - src.rfind("\n", 0, offset)
    return line, col


def _tokenize(src: str):
    """Returns (tokens, positions): parallel lists, positions[i] = (line,
    col) of tokens[i].  Comments are blanked (not stripped) so offsets stay
    true to the original source."""
    out, positions, pos = [], [], 0
    src = re.sub(r"--[^\n]*", lambda m: " " * len(m.group(0)), src)
    while pos < len(src):
        m = _TOKEN.match(src, pos)
        if not m:
            if src[pos:].strip() == "":
                break
            line, col = _line_col(src, pos + len(src[pos:]) - len(src[pos:].lstrip()))
            raise UnsupportedConstructError(
                "token", f"cannot tokenize {src[pos:pos+40].strip()!r}",
                line, col)
        pos = m.end()
        for kind in ("num", "str", "var", "id", "op"):
            v = m.group(kind)
            if v is not None:
                out.append((kind, v.lower() if kind == "id" else v))
                positions.append(_line_col(src, m.start(kind)))
                break
    out.append(("eof", ""))
    positions.append(_line_col(src, len(src)))
    return out, positions


class _Parser:
    def __init__(self, tokens, positions=None):
        self.toks = tokens
        self.positions = positions or [(0, 0)] * len(tokens)
        self.i = 0
        self._cursors: dict[str, tuple[R.RelNode, list[str]]] = {}

    def peek(self, k=0):
        return self.toks[self.i + k]

    def next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def err(self, construct: str, detail: str, at: int | None = None):
        """Raise an UnsupportedConstructError at token ``at`` (default: the
        last consumed token)."""
        idx = self.i - 1 if at is None else at
        idx = max(0, min(idx, len(self.positions) - 1))
        line, col = self.positions[idx]
        raise UnsupportedConstructError(construct, detail, line, col)

    def expect(self, value=None, kind=None):
        k, v = self.next()
        if value is not None and v.lower() != value.lower():
            self.err("syntax", f"expected {value!r}, got {v!r}")
        if kind is not None and k != kind:
            self.err("syntax", f"expected a {kind} token, got {k}:{v!r}")
        return v

    def accept(self, value):
        if self.peek()[1].lower() == value.lower():
            self.next()
            return True
        return False

    # ---------------------------------------------------------------- types
    def parse_type(self) -> str:
        name = self.expect(kind="id")
        if self.accept("("):  # char(50), decimal(12,2)
            while not self.accept(")"):
                self.next()
        if name not in _TYPES:
            self.err("type", f"type {name!r} is outside the supported subset")
        return _TYPES[name]

    # ----------------------------------------------------------- expressions
    def parse_expr(self) -> S.Scalar:
        return self._or()

    def _or(self):
        left = self._and()
        while self.peek()[1].lower() == "or":
            self.next()
            left = S.BoolOp("or", [left, self._and()])
        return left

    def _and(self):
        left = self._not()
        while self.peek()[1].lower() == "and":
            self.next()
            left = S.BoolOp("and", [left, self._not()])
        return left

    def _not(self):
        if self.peek()[1].lower() == "not":
            self.next()
            return S.BoolOp("not", [self._not()])
        return self._cmp()

    def _cmp(self):
        left = self._add()
        k, v = self.peek()
        ops = {"=": "==", "<>": "!=", "!=": "!=", "<": "<", "<=": "<=",
               ">": ">", ">=": ">="}
        if v in ops:
            self.next()
            return S.Cmp(ops[v], left, self._add())
        if v.lower() == "is":
            self.next()
            neg = self.accept("not")
            self.expect("null")
            e = S.IsNull(left)
            return S.BoolOp("not", [e]) if neg else e
        if v.lower() == "between":
            self.next()
            lo = self._add()
            self.expect("and")
            return S.Between(left, lo, self._add())
        if v.lower() == "in":
            self.next()
            self.expect("(")
            opts = [self._literal_value()]
            while self.accept(","):
                opts.append(self._literal_value())
            self.expect(")")
            return S.InList(left, opts)
        if v.lower() == "like":
            self.next()
            pat = self.expect(kind="str")
            return S.Like(left, pat.strip("'"))
        return left

    def _literal_value(self):
        k, v = self.next()
        if k == "num":
            return float(v) if "." in v else int(v)
        if k == "str":
            return v.strip("'")
        self.err("literal", f"expected a literal, got {v!r}")

    def _add(self):
        left = self._mul()
        while self.peek()[1] in ("+", "-"):
            op = self.next()[1]
            left = S.BinOp(op, left, self._mul())
        return left

    def _mul(self):
        left = self._unary()
        while self.peek()[1] in ("*", "/"):
            op = self.next()[1]
            left = S.BinOp(op, left, self._unary())
        return left

    def _unary(self):
        if self.peek()[1] == "-":
            self.next()
            return S.BinOp("-", S.Const(0), self._unary())
        return self._atom()

    def _atom(self) -> S.Scalar:
        k, v = self.next()
        if k == "num":
            return S.Const(float(v) if "." in v else int(v))
        if k == "str":
            return S.Const(v.strip("'"))
        if k == "var":
            return S.Var(v[1:])
        if v == "(":
            e = self.parse_expr()
            self.expect(")")
            return e
        if k == "id":
            name = v
            if name == "null":
                return S.Const(None)
            if name == "case":
                return self._case()
            if self.peek()[1] == "(":  # function call
                self.next()
                args = []
                if self.peek()[1] != ")":
                    args.append(self.parse_expr())
                    while self.accept(","):
                        args.append(self.parse_expr())
                self.expect(")")
                base = name.split(".")[-1]
                if base in ("dateadd", "datepart"):
                    # first arg is a part keyword parsed as ColRef
                    part = args[0]
                    pname = part.name if isinstance(part, S.ColRef) else part.value
                    return S.Func(base, [S.Const(pname)] + args[1:])
                if "." in name:  # dbo.func -> UDF call
                    return S.UdfCall(base, args)
                return S.Func(base, args)
            return S.ColRef(name)
        self.err("expression", f"unexpected token {v!r}")

    def _case(self) -> S.Scalar:
        whens = []
        while self.accept("when"):
            p = self.parse_expr()
            self.expect("then")
            whens.append((p, self.parse_expr()))
        else_ = S.Const(None)
        if self.accept("else"):
            else_ = self.parse_expr()
        self.expect("end")
        return S.Case(whens, else_)

    # ------------------------------------------------------------ statements
    def parse_block(self, u: F.UdfBuilder):
        self.expect("begin")
        while not self.accept("end"):
            self.parse_statement(u)

    def parse_statement(self, u: F.UdfBuilder):
        k, v = self.peek()
        word = v.lower()
        if word == "declare":
            self.next()
            if self.peek()[0] == "id":  # DECLARE c CURSOR FOR ...
                self._parse_cursor_decl()
                return
            name = self.expect(kind="var")[1:]
            dtype = self.parse_type()
            init = None
            if self.accept("="):
                init = self.parse_expr()
            self.accept(";")
            u.declare(name, dtype, init)
        elif word == "set":
            self.next()
            name = self.expect(kind="var")[1:]
            self.expect("=")
            u.set(name, self.parse_expr())
            self.accept(";")
        elif word == "select":
            self.next()
            name = self.expect(kind="var")[1:]
            self.expect("=")
            expr = self.parse_expr()
            frm = None
            where = None
            if self.accept("from"):
                table = self.expect(kind="id").split(".")[-1]
                frm = F.scan(table)
                if self.accept("where"):
                    where = self.parse_expr()
            self.accept(";")
            if frm is None:
                u.set(name, expr)
            else:
                agg = self._as_agg(expr)
                u.select({name: agg}, frm=frm, where=where)
        elif word == "if":
            self.next()
            pred = self.parse_expr()
            with u.if_(pred):
                if self.peek()[1].lower() == "begin":
                    self.parse_block(u)
                else:
                    self.parse_statement(u)
            if self.accept("else"):
                with u.else_():
                    if self.peek()[1].lower() == "begin":
                        self.parse_block(u)
                    else:
                        self.parse_statement(u)
        elif word == "while":
            self.next()
            at = self.i
            pred = self.parse_expr()
            if self._uses_fetch_status(pred):
                self._parse_cursor_while(u, pred, at)
            else:
                with u.while_(pred):
                    self._parse_body(u)
        elif word == "break":
            self.next()
            self.accept(";")
            u.break_()
        elif word == "fetch":
            self._parse_fetch(u)
        elif word in ("open", "close", "deallocate"):
            # cursor lifecycle is implicit in the rewrite — consume as no-ops
            self.next()
            cname = self.expect(kind="id")
            if cname not in self._cursors:
                self.err("cursor", f"unknown cursor {cname!r}")
            self.accept(";")
        elif word == "return":
            self.next()
            u.return_(self.parse_expr())
            self.accept(";")
        elif v == ";":
            self.next()
        else:
            self.err("statement",
                     f"statement starting at {v!r} is outside the supported "
                     "subset", at=self.i)

    def _parse_body(self, u: F.UdfBuilder):
        if self.peek()[1].lower() == "begin":
            self.parse_block(u)
        else:
            self.parse_statement(u)

    # ------------------------------------------------------------- cursors
    def _parse_cursor_decl(self):
        name = self.expect(kind="id")
        self.expect("cursor")
        self.expect("for")
        self.expect("select")
        cols = []
        while True:
            if self.peek()[0] != "id":
                self.err("cursor-select",
                         "cursor SELECT list must be plain column names",
                         at=self.i)
            cols.append(self.next()[1])
            if not self.accept(","):
                break
        if self.peek()[1].lower() != "from":
            self.err("cursor-select",
                     "cursor SELECT list must be plain column names",
                     at=self.i)
        self.expect("from")
        table = self.expect(kind="id").split(".")[-1]
        plan: R.RelNode = R.Scan(table)
        if self.accept("where"):
            plan = R.Filter(plan, self.parse_expr())
        self.accept(";")
        self._cursors[name] = (plan, cols)

    def _parse_fetch(self, u: F.UdfBuilder):
        self.next()  # fetch
        self.expect("next")
        self.expect("from")
        cname = self.expect(kind="id")
        if cname not in self._cursors:
            self.err("fetch", f"unknown cursor {cname!r}")
        self.expect("into")
        tvars = [self.expect(kind="var")[1:]]
        while self.accept(","):
            tvars.append(self.expect(kind="var")[1:])
        self.accept(";")
        _, cols = self._cursors[cname]
        if len(tvars) != len(cols):
            self.err("fetch", f"FETCH INTO binds {len(tvars)} variables but "
                              f"cursor {cname!r} selects {len(cols)} columns")
        u.fetch_(cname, list(zip(tvars, cols)))

    @staticmethod
    def _uses_fetch_status(expr: S.Scalar) -> bool:
        return any(isinstance(n, S.Var) and n.name == FETCH_STATUS
                   for n in S.walk(expr))

    def _parse_cursor_while(self, u: F.UdfBuilder, pred: S.Scalar, at: int):
        """WHILE @@fetch_status = 0 [AND guard] over a primed cursor: fold
        the priming FETCH + trailing FETCH + body into one CursorLoop."""

        def conjuncts(e):
            if isinstance(e, S.BoolOp) and e.op == "and":
                out = []
                for a in e.args:
                    out.extend(conjuncts(a))
                return out
            return [e]

        def is_status_check(c):
            if not (isinstance(c, S.Cmp) and c.op == "=="):
                return False
            sides = (c.l, c.r)
            return any(isinstance(s, S.Var) and s.name == FETCH_STATUS
                       for s in sides) and any(
                isinstance(s, S.Const) and s.value == 0 for s in sides)

        rest, found = [], False
        for c in conjuncts(pred):
            if is_status_check(c):
                found = True
            elif self._uses_fetch_status(c):
                self.err("fetch-status",
                         "@@fetch_status may only appear as the conjunct "
                         "@@fetch_status = 0", at=at)
            else:
                rest.append(c)
        if not found:
            self.err("fetch-status",
                     "@@fetch_status must appear as the conjunct "
                     "@@fetch_status = 0", at=at)
        guard = None
        for c in rest:
            guard = c if guard is None else S.BoolOp("and", [guard, c])

        stmts = u._stack[-1]
        if not stmts or not isinstance(stmts[-1], IR.Fetch):
            self.err("cursor-while",
                     "WHILE @@fetch_status = 0 requires an immediately "
                     "preceding FETCH NEXT (the priming fetch)", at=at)
        prime = stmts.pop()

        with u._capture() as body:
            self._parse_body(u)
        if not body or not isinstance(body[-1], IR.Fetch):
            self.err("cursor-while",
                     "cursor WHILE body must end with FETCH NEXT", at=at)
        trailing = body.pop()
        if trailing.cursor != prime.cursor or trailing.targets != prime.targets:
            self.err("cursor-while",
                     "trailing FETCH NEXT must match the priming fetch "
                     "(same cursor, same INTO variables)", at=at)

        def has_fetch(stmts):
            for st in stmts:
                if isinstance(st, IR.Fetch):
                    return True
                if isinstance(st, IR.IfElse):
                    if has_fetch(st.then_body) or has_fetch(st.else_body):
                        return True
                if isinstance(st, (IR.While, IR.CursorLoop)):
                    if has_fetch(st.body):
                        return True
            return False

        if has_fetch(body):
            self.err("fetch",
                     "FETCH NEXT is only supported as the final statement "
                     "of a cursor WHILE body", at=at)

        plan, _ = self._cursors[prime.cursor]
        u._stack[-1].append(
            IR.CursorLoop(prime.cursor, plan, prime.targets, body, guard))
        u._last_if[-1] = None

    def _as_agg(self, expr: S.Scalar):
        if isinstance(expr, S.Func) and expr.name in _AGGS:
            arg = expr.args[0] if expr.args else None
            if expr.name == "count":
                return F.count_(arg)
            return _AGGS[expr.name](arg)
        return expr


def parse_udf(src: str) -> UdfDef:
    """Parse a CREATE FUNCTION statement into a UdfDef.

    In the UDF body, bare identifiers inside FROM/WHERE are table columns;
    @names are variables/parameters — matching T-SQL scoping."""
    p = _Parser(*_tokenize(src))
    p.expect("create")
    p.expect("function")
    name = p.expect(kind="id").split(".")[-1]
    p.expect("(")
    params = []
    while not p.accept(")"):
        pname = p.expect(kind="var")[1:]
        ptype = p.parse_type()
        params.append((pname, ptype))
        p.accept(",")
    p.expect("returns")
    rtype = p.parse_type()
    p.accept("as")
    u = F.UdfBuilder(name, params, rtype)
    p.parse_block(u)
    return u.build()
