"""Imperative UDF IR: statements, regions, and function definitions.

Mirrors the paper's supported constructs (§3.4, Table 1):
DECLARE / SET / SELECT-assign / IF-ELSE (arbitrary nesting) / RETURN
(single or multiple) / nested UDF calls / EXISTS / ISNULL — plus the loop
forms the paper disabled (§4.2.1): WHILE and cursor loops.  Cursor loops
go through the Aggify-style rewrite in :mod:`repro.loops`; loops the
rewrite rejects fall back to the per-row interpreter.

Region construction (§4.1): a statement list splits into a hierarchy of
*sequential* regions (maximal runs of straight-line statements) and
*conditional* regions (IF-ELSE), each of which the algebrizer turns into one
single-row derived table.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import scalar as S


# ---------------------------------------------------------------------------
# Statements
# ---------------------------------------------------------------------------


class Statement:
    pass


@dataclasses.dataclass
class Declare(Statement):
    name: str
    dtype: str = "float32"  # float32 | int32 | bool | str | date
    init: S.Scalar | None = None  # None => NULL (paper §4.2.1)


@dataclasses.dataclass
class Assign(Statement):
    """SET @name = expr  (also models single-variable SELECT-assign; the
    frontend lowers multi-assign SELECTs to several Assigns — paper §4.2.1
    notes Froid does exactly this and relies on CSE for the duplication)."""

    name: str
    expr: S.Scalar


@dataclasses.dataclass
class IfElse(Statement):
    pred: S.Scalar
    then_body: list[Statement]
    else_body: list[Statement]


@dataclasses.dataclass
class Return(Statement):
    expr: S.Scalar


@dataclasses.dataclass
class Break(Statement):
    """BREAK — exits the innermost enclosing loop."""


@dataclasses.dataclass
class While(Statement):
    """WHILE pred BEGIN body END — a general (non-cursor) loop.

    Never algebrizable (no driving relation): FROID falls back to the
    interpreter; the scan-mode interpreter lowers it to ``lax.while_loop``."""

    pred: S.Scalar
    body: list[Statement]


@dataclasses.dataclass
class Fetch(Statement):
    """FETCH NEXT FROM cursor INTO @a, @b — a frontend marker.

    The parser folds the priming FETCH plus the trailing in-loop FETCH into
    the enclosing :class:`CursorLoop`; a Fetch that survives into a UDF body
    (fetch outside a recognised loop shape) is rejected downstream."""

    cursor: str
    targets: list[tuple[str, str]]  # (variable, cursor column)


@dataclasses.dataclass
class CursorLoop(Statement):
    """A cursor-driven loop: iterate ``plan``'s rows in order, binding each
    row's columns to ``targets`` variables, then running ``body``.

    ``guard`` is an optional extra termination conjunct (beyond the implicit
    ``@@fetch_status = 0``): per row the semantics are *bind fetch vars,
    evaluate guard, stop the loop if not true, else run body*."""

    cursor: str
    plan: "object"  # R.RelNode — typed loosely to keep ir free of relalg
    targets: list[tuple[str, str]]  # (variable, cursor column)
    body: list[Statement]
    guard: S.Scalar | None = None


# ---------------------------------------------------------------------------
# Regions (paper §4.1)
# ---------------------------------------------------------------------------


class Region:
    pass


@dataclasses.dataclass
class SeqRegion(Region):
    """A maximal straight-line run of Declare/Assign/Return statements."""

    statements: list[Statement]


@dataclasses.dataclass
class CondRegion(Region):
    pred: S.Scalar
    then_regions: list[Region]
    else_regions: list[Region]


def build_regions(body: Sequence[Statement]) -> list[Region]:
    """Single pass over the UDF body (paper: 'Regions can be constructed in
    a single pass')."""
    out: list[Region] = []
    run: list[Statement] = []

    def flush():
        nonlocal run
        if run:
            out.append(SeqRegion(run))
            run = []

    for st in body:
        if isinstance(st, IfElse):
            flush()
            out.append(
                CondRegion(
                    st.pred, build_regions(st.then_body), build_regions(st.else_body)
                )
            )
        else:
            run.append(st)
            if isinstance(st, Return):
                # statements after an unconditional RETURN are unreachable —
                # drop them (dead-code elimination at region construction)
                flush()
                return out
    flush()
    return out


# ---------------------------------------------------------------------------
# Function definition
# ---------------------------------------------------------------------------

_DTYPES = {"float32", "int32", "bool", "str", "date"}


@dataclasses.dataclass
class UdfDef:
    name: str
    params: list[tuple[str, str]]  # (name, dtype)
    return_dtype: str
    body: list[Statement]

    def __post_init__(self):
        for _, dt in self.params:
            assert dt in _DTYPES, dt
        assert self.return_dtype in _DTYPES

    def regions(self) -> list[Region]:
        return build_regions(self.body)

    # -- analyses ------------------------------------------------------------
    def all_exprs(self):
        yield from walk_stmt_exprs(self.body)

    def is_deterministic(self) -> bool:
        return all(S.is_deterministic(e) for e in self.all_exprs())

    def called_udfs(self) -> set[str]:
        out = set()
        for e in self.all_exprs():
            for node in S.walk(e):
                if isinstance(node, S.UdfCall):
                    out.add(node.name)
        return out

    def statement_count(self) -> int:
        def count(stmts):
            n = 0
            for st in stmts:
                n += 1
                if isinstance(st, IfElse):
                    n += count(st.then_body) + count(st.else_body)
                elif isinstance(st, (While, CursorLoop)):
                    n += count(st.body)
            return n

        return count(self.body)


def walk_stmt_exprs(stmts: Sequence[Statement]):
    """Every scalar expression reachable from ``stmts``, including those
    embedded in cursor-defining plans (so determinism / called-UDF analyses
    see through loops)."""
    from repro.core import relalg as R

    for st in stmts:
        if isinstance(st, Declare) and st.init is not None:
            yield st.init
        elif isinstance(st, Assign):
            yield st.expr
        elif isinstance(st, Return):
            yield st.expr
        elif isinstance(st, IfElse):
            yield st.pred
            yield from walk_stmt_exprs(st.then_body)
            yield from walk_stmt_exprs(st.else_body)
        elif isinstance(st, While):
            yield st.pred
            yield from walk_stmt_exprs(st.body)
        elif isinstance(st, CursorLoop):
            if st.guard is not None:
                yield st.guard
            for n in R.walk_plan_deep(st.plan):
                yield from n.exprs()
            yield from walk_stmt_exprs(st.body)
