from repro.data.pipeline import DataPipeline, synthetic_corpus
from repro.data.tpch import generate_tpch

__all__ = ["DataPipeline", "synthetic_corpus", "generate_tpch"]
