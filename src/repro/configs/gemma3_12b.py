"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144; 5:1 local(1024-window):global attention, 128k context.
[hf:google/gemma-3-12b-pt]"""
import dataclasses

from repro.models.config import ArchConfig, LayerSpec

WINDOW = 1024


def config() -> ArchConfig:
    local = LayerSpec(mixer="attn", mlp="dense", window=WINDOW)
    glob = LayerSpec(mixer="attn", mlp="dense", window=None)
    return ArchConfig(
        name="gemma3-12b",
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        d_ff=15360,
        vocab=262144,
        head_dim=256,
        super_block=(local, local, local, local, local, glob),
        n_repeats=8,  # 48 layers, 40 local + 8 global
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        # local layers dominate (5:1); global layers use the KV cache
        # linearly per decoded token -> long_500k eligible (DESIGN.md §5)
        subquadratic=True,
        max_seq_len=1_048_576,
    )


def smoke_config() -> ArchConfig:
    local = LayerSpec(mixer="attn", mlp="dense", window=16)
    glob = LayerSpec(mixer="attn", mlp="dense", window=None)
    return dataclasses.replace(
        config(), d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
        head_dim=16, super_block=(local, local, glob), n_repeats=2,
        max_seq_len=128,
    )
