"""Pure-jnp oracle for ssd_scan: the naive per-timestep SSD recurrence."""
import jax
import jax.numpy as jnp


def ssd_scan_ref(xdt, dtA, B, C, n_rep):
    """S_t = exp(dtA_t)·S_{t-1} + B_t ⊗ xdt_t ;  y_t = C_t·S_t.

    xdt: (BH, L, P); dtA: (BH, L); B, C: (BG, L, N); BH == BG·n_rep."""
    BH, L, P = xdt.shape
    BG, _, N = B.shape
    Bx = jnp.repeat(B, n_rep, axis=0)  # (BH, L, N)
    Cx = jnp.repeat(C, n_rep, axis=0)

    def step(S, inputs):
        x_t, a_t, b_t, c_t = inputs
        S = jnp.exp(a_t)[:, None, None] * S + b_t[:, :, None] * x_t[:, None, :]
        y = jnp.einsum("bn,bnp->bp", c_t, S)
        return S, y

    S0 = jnp.zeros((BH, N, P), jnp.float32)
    xs = (
        jnp.swapaxes(xdt, 0, 1).astype(jnp.float32),
        jnp.swapaxes(dtA, 0, 1).astype(jnp.float32),
        jnp.swapaxes(Bx, 0, 1).astype(jnp.float32),
        jnp.swapaxes(Cx, 0, 1).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.swapaxes(ys, 0, 1).astype(xdt.dtype)  # (BH, L, P)


def ssd_scan_chunked(xdt, dtA, B, C, n_rep, chunk: int = 128):
    """Chunked SSD in pure jnp — the same math as the Pallas kernel
    (within-chunk quadratic form + cross-chunk state carry), used for
    off-TPU lowering so dry-runs see kernel-like compute/memory instead of
    a 4096-step scan.  Validated against ssd_scan_ref in tests."""
    BH, L, P = xdt.shape
    BG, _, N = B.shape
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0)))
        dtA = jnp.pad(dtA, ((0, 0), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    n_chunks = (L + pad) // chunk
    Bx = jnp.repeat(B, n_rep, axis=0)
    Cx = jnp.repeat(C, n_rep, axis=0)

    xc = xdt.reshape(BH, n_chunks, chunk, P).swapaxes(0, 1).astype(jnp.float32)
    ac = dtA.reshape(BH, n_chunks, chunk).swapaxes(0, 1).astype(jnp.float32)
    bc = Bx.reshape(BH, n_chunks, chunk, N).swapaxes(0, 1).astype(jnp.float32)
    cc = Cx.reshape(BH, n_chunks, chunk, N).swapaxes(0, 1).astype(jnp.float32)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint
    def step(S, inp):
        x, a, b, c = inp  # (BH, Q, ·)
        cum = jnp.cumsum(a, axis=1)  # (BH, Q)
        decay = jnp.where(
            tri[None], jnp.exp(cum[:, :, None] - cum[:, None, :]), 0.0
        )
        scores = jnp.einsum("bqn,bkn->bqk", c, b) * decay
        y = jnp.einsum("bqk,bkp->bqp", scores, x)
        y += jnp.exp(cum)[..., None] * jnp.einsum("bqn,bnp->bqp", c, S)
        d_end = jnp.exp(cum[:, -1:] - cum)  # (BH, Q)
        S = jnp.exp(cum[:, -1])[:, None, None] * S + jnp.einsum(
            "bqn,bqp->bnp", b, x * d_end[..., None]
        )
        return S, y

    S0 = jnp.zeros((BH, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, S0, (xc, ac, bc, cc))
    out = ys.swapaxes(0, 1).reshape(BH, L + pad, P)[:, :L]
    return out.astype(xdt.dtype)
