"""Persistent compiled-plan tier.

Froid algebrizes and optimizes a UDF-bearing statement *once* so every later
invocation reuses the compiled plan; this package extends that reuse across
process boundaries.  A :class:`PlanStore` is an on-disk (or shared-volume)
cache of serialized XLA executables keyed by the same five-tier identity the
in-memory session caches use — plan fingerprint x policy fingerprint x param
signature x batch bucket x shard token x fused template tuple — plus a
content-derived catalog/registry token so DDL invalidates entries by value,
not by process-local stamp.

Guarantees:

* writes are atomic (temp file + ``os.replace``), so concurrent writers and
  readers never observe a partial entry;
* every entry is version-stamped (repro schema, jax/jaxlib versions, backend,
  device count) and a stale stamp is rejected — the session recompiles;
* a truncated or corrupt entry raises a typed :class:`PlanCacheCorruptError`
  inside the store, which the session converts into a
  :class:`PlanCacheWarning` plus a silent recompile — never wrong results,
  never a crash.
"""
from repro.persist.keys import assert_stable_key, key_digest, parse_key
from repro.persist.store import (
    PERSIST_SCHEMA_VERSION,
    PlanCacheCorruptError,
    PlanCacheError,
    PlanCacheVersionError,
    PlanCacheWarning,
    PlanStore,
    runtime_stamp,
)

__all__ = [
    "PERSIST_SCHEMA_VERSION",
    "PlanCacheCorruptError",
    "PlanCacheError",
    "PlanCacheVersionError",
    "PlanCacheWarning",
    "PlanStore",
    "assert_stable_key",
    "key_digest",
    "parse_key",
    "runtime_stamp",
]
