"""Elastic scaling: re-mesh and re-shard after node count changes.

On failure/scale events the job restarts from the newest checkpoint with a
different device count.  Policy: the ``model`` axis is fixed by the
architecture's TP layout, so elasticity happens on the ``data``(+``pod``)
axes — the new data-parallel degree is ``devices // model_axis``.  State
re-sharding is value-level: checkpoints store unsharded global leaves, so
restoring onto the new mesh is just ``device_put`` with the new
NamedShardings (same PartitionSpec rules, new mesh).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh

from repro.dist.sharding import param_specs, shardings_for


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    def build(self, devices=None) -> Mesh:
        import numpy as np

        devices = devices if devices is not None else jax.devices()
        n = 1
        for s in self.shape:
            n *= s
        arr = np.array(devices[:n]).reshape(self.shape)
        return Mesh(arr, self.axes)


def plan_remesh(n_devices: int, model_axis: int, pods: int = 1) -> MeshPlan:
    """Largest usable mesh for ``n_devices`` keeping the TP degree.

    Drops stragglers that don't fill a full data row; raises if fewer than
    one model group survives."""
    per_pod = n_devices // pods
    data = per_pod // model_axis
    if data < 1:
        raise ValueError(
            f"{n_devices} devices cannot host model_axis={model_axis}"
        )
    if pods > 1:
        return MeshPlan((pods, data, model_axis), ("pod", "data", "model"))
    return MeshPlan((data, model_axis), ("data", "model"))


def usable_devices(n_devices: int, model_axis: int, pods: int = 1) -> int:
    plan = plan_remesh(n_devices, model_axis, pods)
    n = 1
    for s in plan.shape:
        n *= s
    return n


def reshard_state(state_tree, mesh: Mesh, cfg):
    """device_put a (restored, host-global) state pytree onto ``mesh``
    with the standard sharding rules — the elastic-restart hot path."""
    specs = param_specs(state_tree, mesh, cfg)
    shardings = shardings_for(specs, mesh)
    return jax.tree.map(jax.device_put, state_tree, shardings)
