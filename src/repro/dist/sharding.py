"""Sharding rules for the production meshes (16×16 single-pod,
2×16×16 multi-pod; axes ``data``/``model`` plus optional leading ``pod``).

Placement policy (divisibility-gated — a dim that doesn't divide its mesh
axes is replicated, never padded):

* **Params** — tensor-parallel on the trailing feature dim over ``model``,
  FSDP on the largest remaining dim over ``(pod, data)`` (falling back to
  ``data`` alone when the pod product doesn't divide).  1-D leaves (norm
  scales, gates) are replicated.
* **Batches** — leading (batch) dim over ``(pod, data)``.
* **Decode caches** — dim 1 (batch; dim 0 is the stacked-repeat axis) over
  ``(pod, data)``; the head axis (dim 2) over ``model`` when it divides.

All rules only read ``mesh.shape`` (a name→size mapping), so they work on
abstract stand-in meshes for layout validation without any devices.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec


def _axis_product(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _data_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def pick_data_axes(mesh, dim: int):
    """The PartitionSpec entry for sharding ``dim`` over the data axes:
    pod+data jointly when their product divides, data alone as fallback,
    None when neither divides.  The single divisibility-gating rule every
    data-axis placement in this package (and activation sharding, and the
    engine's sharded ``execute_many`` batches) uses."""
    present = _data_axes(mesh)
    for axes in (present, present[-1:]):
        if not axes:
            continue
        n = _axis_product(mesh, axes)
        if n > 1 and dim % n == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def data_axis_size(mesh) -> int:
    """Number of data-parallel shards the mesh offers a batch axis (the
    product of the present data axes; 1 on a data-free or absent mesh)."""
    if mesh is None:
        return 1
    return _axis_product(mesh, _data_axes(mesh))


def batch_sharding(mesh, dim: int):
    """NamedSharding placing a leading ``dim``-sized batch axis over the
    data axes, or None when divisibility gating rejects it.  Used as a jit
    in-sharding prefix: trailing dims are implicitly replicated, so one
    spec serves every leaf of a stacked-parameter pytree."""
    entry = pick_data_axes(mesh, dim)
    if entry is None:
        return None
    return NamedSharding(mesh, PartitionSpec(entry))


def replicated_sharding(mesh):
    """NamedSharding replicating a value on every device of ``mesh`` —
    how catalog tables broadcast under sharded batch execution."""
    return NamedSharding(mesh, PartitionSpec())


def _fsdp_entry(mesh, shape, taken: int | None):
    """(dim, spec entry) for the largest dim divisible by the data axes
    (preferring pod+data jointly), or (None, None)."""
    present = _data_axes(mesh)
    for axes in (present, present[-1:]):
        if not axes:
            continue
        n = _axis_product(mesh, axes)
        if n <= 1:
            continue
        cands = [d for d in range(len(shape))
                 if d != taken and shape[d] % n == 0 and shape[d] >= n]
        if cands:
            d = max(cands, key=lambda i: shape[i])
            return d, (axes if len(axes) > 1 else axes[0])
    return None, None


def _is_spec(x) -> bool:
    return isinstance(x, PartitionSpec)


def param_specs(tree, mesh, cfg):
    """PartitionSpec per leaf: TP over ``model`` on a trailing dim, FSDP
    over ``(pod, data)`` on the largest remaining dim."""
    model = mesh.shape.get("model", 1)

    def spec_for(leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd <= 1:
            return PartitionSpec()
        entries = [None] * nd
        model_dim = None
        if model > 1:
            for d in (nd - 1, nd - 2):
                if shape[d] % model == 0 and shape[d] >= model:
                    model_dim = d
                    entries[d] = "model"
                    break
        fsdp_dim, entry = _fsdp_entry(mesh, shape, model_dim)
        if fsdp_dim is not None:
            entries[fsdp_dim] = entry
        return PartitionSpec(*entries)

    return jax.tree.map(spec_for, tree)


def batch_specs(tree, mesh, cfg):
    """Shard the leading (batch) dim over the data(+pod) axes."""

    def spec_for(leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd == 0:
            return PartitionSpec()
        entry = pick_data_axes(mesh, shape[0])
        return PartitionSpec(entry, *(None,) * (nd - 1))

    return jax.tree.map(spec_for, tree)


def cache_specs(tree, mesh, cfg):
    """Decode-cache leaves are (repeats, batch, heads?, …): batch over the
    data(+pod) axes, the head-like dim 2 over ``model`` when it divides."""
    model = mesh.shape.get("model", 1)

    def spec_for(leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd < 2:
            return PartitionSpec(*(None,) * nd)
        entries = [None] * nd
        entries[1] = pick_data_axes(mesh, shape[1])
        if model > 1 and nd >= 4 and shape[2] % model == 0 and shape[2] >= model:
            entries[2] = "model"
        return PartitionSpec(*entries)

    return jax.tree.map(spec_for, tree)


def shardings_for(specs, mesh):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=_is_spec
    )
