"""UDF algebrization (paper §4): imperative body -> single relational expr.

Each region becomes a single-row *derived table* (``Compute`` over
``ConstantScan``) whose schema is the region's write-set (§4.2.2); region
DTs are chained with the ``Apply`` operator (§4.3); variable def-use is
preserved by SSA column naming (``price__3``), with ``ColRef`` for
region-local uses and ``Outer`` for uses of prior regions' columns.

Early RETURNs (§4.2.1): the *probe bit* is an explicit ``__retset`` column;
*pass-through* is expressed in predicated form — every later write to
``__ret`` and every branch merge is guarded by
``CASE WHEN __retset THEN <old> ELSE <new>``.  On a tensor machine all
lanes execute and are masked (there is no divergent control flow to skip),
so the probe/pass-through pair lowers to exactly these guards; the end
result (returnVal) is identical to the paper's construction.  See
DESIGN.md §2.

Conditional regions (Table 1 row 4): the predicate is evaluated **once**
into an implicit column (``__pred__k``) and branch write-sets merge through
``CASE WHEN __pred__k THEN <then-col> ELSE <else-col>``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import ir as IR
from repro.core import relalg as R
from repro.core import scalar as S

_NULL_DTYPES = {
    "float32": jnp.float32,
    "int32": jnp.int32,
    "date": jnp.int32,
    "bool": jnp.bool_,
    "str": jnp.int32,
}

RET = "__ret"
RETSET = "__retset"


def typed_null(dtype: str) -> S.Scalar:
    return S.Const(None, _NULL_DTYPES.get(dtype, jnp.float32))


class AlgebrizeError(Exception):
    pass


class Algebrizer:
    """One instance per UDF algebrization (fresh-name counter is local)."""

    def __init__(self, udf: IR.UdfDef):
        self.udf = udf
        self._n = 0
        self._param_names = {p for p, _ in udf.params}

    # ------------------------------------------------------------------ util
    def fresh(self, base: str) -> str:
        self._n += 1
        return f"{base}__{self._n}"

    def resolve(self, expr: S.Scalar, env: dict[str, str], local: dict[str, str]) -> S.Scalar:
        """Rewrite Var refs into column refs.  Region-local -> ColRef,
        prior-region -> Outer.  Inside subquery plans every variable becomes
        an Outer (the subquery's outer scope is the current row)."""

        def fix(e: S.Scalar) -> S.Scalar | None:
            if isinstance(e, S.Var):
                if e.name in local:
                    return S.ColRef(local[e.name])
                if e.name in env:
                    return S.Outer(env[e.name])
                if e.name in self._param_names:  # @params share the namespace
                    return S.Param(e.name)
                raise AlgebrizeError(
                    f"{self.udf.name}: undeclared variable @{e.name}"
                )
            if isinstance(e, (S.ScalarSubquery, S.Exists)):
                plan = self._resolve_plan(e.plan, env, local)
                if isinstance(e, S.ScalarSubquery):
                    return S.ScalarSubquery(plan, e.column, e.agg_default)
                return S.Exists(plan, e.negated)
            return None

        return S.transform(expr, fix)

    def _resolve_plan(self, plan: R.RelNode, env, local) -> R.RelNode:
        """Vars inside a subquery plan resolve to Outer(column) —
        region-local and prior-region columns are both visible as the
        subquery's outer row (executor scoping rule)."""

        def fix_expr(e: S.Scalar) -> S.Scalar | None:
            if isinstance(e, S.Var):
                if e.name in local:
                    return S.Outer(local[e.name])
                if e.name in env:
                    return S.Outer(env[e.name])
                if e.name in self._param_names:
                    return S.Param(e.name)
                raise AlgebrizeError(
                    f"{self.udf.name}: undeclared variable @{e.name} in subquery"
                )
            if isinstance(e, (S.ScalarSubquery, S.Exists)):
                sub = self._resolve_plan(e.plan, env, local)
                if isinstance(e, S.ScalarSubquery):
                    return S.ScalarSubquery(sub, e.column, e.agg_default)
                return S.Exists(sub, e.negated)
            return None

        def fix_node(node: R.RelNode) -> R.RelNode | None:
            if isinstance(node, R.Filter):
                return R.Filter(node.child, S.transform(node.pred, fix_expr))
            if isinstance(node, R.Compute):
                return R.Compute(
                    node.child,
                    {k: S.transform(v, fix_expr) for k, v in node.computed.items()},
                )
            if isinstance(node, R.GroupAgg):
                aggs = {
                    k: R.AggSpec(
                        a.fn,
                        None if a.expr is None else S.transform(a.expr, fix_expr),
                    )
                    for k, a in node.aggs.items()
                }
                return R.GroupAgg(node.child, node.keys, aggs, node.capacity,
                                  node.dense_range)
            return None

        return R.transform_plan(plan, fix_node)

    # ------------------------------------------------------------- combining
    @staticmethod
    def combine(plan: R.RelNode, dt: R.RelNode) -> R.RelNode:
        """E(R0) = (E(R1) Aᵒ E(R2)) Aᵒ E(R3) — §4.3."""
        if isinstance(plan, R.ConstantScan):
            return dt
        return R.Apply(plan, dt, kind="outer")

    # ------------------------------------------------------------ region emit
    def emit_regions(self, plan, env, regions):
        for reg in regions:
            if isinstance(reg, IR.SeqRegion):
                plan, env = self.emit_seq(plan, env, reg)
            else:
                plan, env = self.emit_cond(plan, env, reg)
        return plan, env

    def emit_seq(self, plan, env, reg: IR.SeqRegion):
        computed: dict[str, S.Scalar] = {}
        local: dict[str, str] = {}
        for st in reg.statements:
            if isinstance(st, IR.Declare):
                c = self.fresh(st.name)
                computed[c] = (
                    typed_null(st.dtype)
                    if st.init is None
                    else self.resolve(st.init, env, local)
                )
                local[st.name] = c
            elif isinstance(st, IR.Assign):
                c = self.fresh(st.name)
                computed[c] = self.resolve(st.expr, env, local)
                local[st.name] = c
            elif isinstance(st, IR.Return):
                e = self.resolve(st.expr, env, local)
                prev_ret = RET in local or RET in env
                if prev_ret:
                    # probe/pass-through guard: keep the first assigned value
                    pset = self.resolve(S.Var(RETSET), env, local)
                    pval = self.resolve(S.Var(RET), env, local)
                    e = S.Case([(pset, pval)], e)
                rc = self.fresh(RET)
                rs = self.fresh(RETSET)
                computed[rc] = e
                computed[rs] = S.Const(True)
                local[RET] = rc
                local[RETSET] = rs
            elif isinstance(st, (IR.While, IR.CursorLoop)):
                self.emit_loop(st, computed, local, env)
            else:
                raise AlgebrizeError(f"unsupported statement {type(st).__name__}")
        if not computed:
            return plan, env
        dt = R.Compute(R.ConstantScan(), computed)
        env = {**env, **local}
        return self.combine(plan, dt), env

    def emit_loop(self, st, computed: dict, local: dict, env: dict):
        """Cursor-loop rewrite (Aggify / repro.loops): classify the loop,
        compile it to a LoopScan over the cursor's defining query, and bind
        each live-out variable to a ScalarSubquery over the shared node.
        Non-rewritable loops raise AlgebrizeError — the binder then leaves
        the UdfCall in place and execution falls back to the per-row
        interpreter (explicit verdict, not a parse error)."""
        from repro.loops import classify, compile_loop

        verdict = classify(st)
        if not verdict.rewritable:
            raise AlgebrizeError(
                f"{self.udf.name}: non-rewritable loop — {verdict.reason}")

        plan = self._resolve_plan(st.plan, env, local)
        loop = IR.CursorLoop(st.cursor, plan, st.targets, st.body, st.guard)

        def fix_free(e: S.Scalar, carried: set) -> S.Scalar:
            def fx(x):
                if isinstance(x, S.Var) and x.name not in carried:
                    if x.name in local:
                        return S.Outer(local[x.name])
                    if x.name in env:
                        return S.Outer(env[x.name])
                    if x.name in self._param_names:
                        return S.Param(x.name)
                    raise AlgebrizeError(
                        f"{self.udf.name}: undeclared variable @{x.name} "
                        "in loop")
                return None

            return S.transform(e, fx)

        node = compile_loop(loop, verdict, fix_free, typed_null)
        for w in node.outputs:
            c = self.fresh(w)
            computed[c] = S.ScalarSubquery(node, w)
            local[w] = c

    def emit_cond(self, plan, env, reg: IR.CondRegion):
        # 1. evaluate the predicate ONCE into an implicit column (§4.2.1:
        #    "assigning the value of the predicate evaluation to an implicit
        #    boolean variable")
        pc = self.fresh("__pred")
        dtp = R.Compute(
            R.ConstantScan(), {pc: self.resolve(reg.pred, env, {})}
        )
        plan = self.combine(plan, dtp)
        env = {**env, pc: pc}  # make the pred column addressable

        # 2. emit both branches (columns accumulate on the same row; branch
        #    visibility is enforced by separate env maps)
        env_t = dict(env)
        plan, env_t = self.emit_regions(plan, env_t, reg.then_regions)
        env_e = dict(env)
        plan, env_e = self.emit_regions(plan, env_e, reg.else_regions)

        # 3. merge write-sets: CASE WHEN pred THEN then-col ELSE else-col
        written = {
            v
            for v in (set(env_t) | set(env_e))
            if env_t.get(v) != env.get(v) or env_e.get(v) != env.get(v)
        }
        written.discard(pc)
        merged: dict[str, S.Scalar] = {}
        local: dict[str, str] = {}
        prev_set = (
            S.Outer(env[RETSET]) if RETSET in env else None
        )
        for v in sorted(written):
            t_ref = S.Outer(env_t[v]) if v in env_t else typed_null("float32")
            e_ref = S.Outer(env_e[v]) if v in env_e else typed_null("float32")
            body = S.Case([(S.Outer(env[pc]), t_ref)], e_ref)
            if v in (RET, RETSET) and prev_set is not None:
                # pass-through: a row that already returned keeps its value
                prev = S.Outer(env[RET]) if v == RET else S.Const(True)
                body = S.Case([(prev_set, prev)], body)
            c = self.fresh(v)
            merged[c] = body
            local[v] = c
        if not merged:
            return plan, env
        dt = R.Compute(R.ConstantScan(), merged)
        env = {**env, **local}
        return self.combine(plan, dt), env

    # ---------------------------------------------------------------- driver
    def run(self) -> R.RelNode:
        regions = self.udf.regions()
        plan, env = self.emit_regions(R.ConstantScan(), {}, regions)
        ret = (
            S.Outer(env[RET]) if RET in env else typed_null(self.udf.return_dtype)
        )
        # final region: SELECT <ret> AS returnVal (Table 1 row 5)
        dt = R.Compute(R.ConstantScan(), {"returnVal": ret})
        out = self.combine(plan, dt)
        return R.Project(out, ["returnVal"])


def algebrize(udf: IR.UdfDef) -> R.RelNode:
    """Algebrize ``udf`` into a relational expression producing a single
    one-row, one-column (``returnVal``) table, parameterized by Param refs."""
    if not udf.is_deterministic():
        raise AlgebrizeError(
            f"{udf.name}: non-deterministic intrinsics — not inlined (paper §7.4)"
        )
    return Algebrizer(udf).run()
