"""Cost-routing walkthrough: static estimates → measured wave costs →
the router picking the cheapest configuration, all observable through
``Session.cost_stats``.

    PYTHONPATH=src python examples/cost_routing.py

The PR-8 Cobra-style routing layer in four acts:

  1. Opt in with the ``ROUTED`` preset (or ``policy.routed()``): before
     anything is measured the router falls back to the static cost
     model — estimates per candidate policy, exploration only on a
     clear estimated win.
  2. First waves train the model: every ``execute_many`` chunk, serial
     execute and fused drain feeds an EMA of measured wave seconds into
     the router; ``cost_stats`` shows the measured configurations and
     the decision log.
  3. The fuse axis: a mixed-statement drain explores the fused arm,
     then the unfused arm, then locks the measured winner (with
     hysteresis — near-tie arms don't flip-flop on noise).
  4. The bucket axis: a ragged batch rides an already-warm larger
     bucket instead of cold-compiling its natural one whenever the
     measured warm cost undercuts the estimated compile+run cost.

Samples observed while the resilience ladder is degrading a wave or a
breaker is open are excluded automatically — fault-time costs never
train the model.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import FROID, ROUTED, Session, col, param, scan
from repro.serve.scheduler import CoalescingScheduler


def fresh(n=512):
    db = Session()
    rng = np.random.default_rng(7)
    db.create_table("T", a=rng.integers(0, 200, n))
    q1 = (scan("T").filter(col("a") >= param("lo"))
          .compute(w=col("a") * param("scale")).project("a", "w"))
    q2 = scan("T").compute(y=col("a") + param("off")).project("a", "y")
    return db, db.prepare(q1, ROUTED), db.prepare(q2, ROUTED)


# ---------------------------------------------------------------- act 1
print("== act 1: ROUTED preset, estimates before measurements ==")
db, s1, s2 = fresh()
print(f"  ROUTED is FROID + route flag: {ROUTED.name}, "
      f"same plan fingerprint: "
      f"{ROUTED.fingerprint() == FROID.fingerprint()}")
r = db._ensure_router()
cands = r._policy_candidates(s1)
for cand, _ in cands:
    print(f"  estimate[{cand.name}] = "
          f"{r.estimate_policy_s(s1, cand):.2e} s")
res = s1.execute(params={"lo": 50, "scale": 2.0})
print(f"  first execute routed fine: {res.table.num_rows} rows, "
      f"cost_stats['samples']={db.cost_stats['samples']}")

# ---------------------------------------------------------------- act 2
print("== act 2: waves train the measured model ==")
for wave in range(3):
    s1.execute_many([{"lo": i % 40, "scale": 1.5} for i in range(16)])
cs = db.cost_stats
print(f"  samples={cs['samples']}, measured configs:")
for label, rec in cs["measured"].items():
    print(f"    {label}: wave_s={rec['wave_s']:.2e} (n={rec['n']})")

# ---------------------------------------------------------------- act 3
print("== act 3: fuse axis — explore both arms, lock the winner ==")
db, s1, s2 = fresh()
sched = CoalescingScheduler(max_batch=64, window_s=1e9, fuse=True)
for wave in range(4):
    tickets = [sched.submit(s1, {"lo": 10 + i, "scale": 1.5})
               for i in range(4)]
    tickets += [sched.submit(s2, {"off": 3 + i}) for i in range(4)]
    sched.flush()
    assert all(t.done() and t.result() is not None for t in tickets)
cs = db.cost_stats
fuse_log = [d for d in cs["decision_log"] if d["axis"] == "fuse"]
for d in fuse_log:
    print(f"  wave verdict: fuse={d['choice']} ({d['why']})")
print(f"  waves_fused={cs['waves_fused']}, "
      f"waves_unfused={cs['waves_unfused']}")

# ---------------------------------------------------------------- act 4
print("== act 4: bucket axis — ride a warm bucket ==")
db, s1, _ = fresh()
# warm the 8-bucket organically (several waves — the first wave's EMA
# carries the compile cost and decays 0.6x per wave), then offer a
# ragged 3-ticket batch: its natural bucket is 4, but riding the warm 8
# beats cold-compiling 4 once the measurement says so.
for w in range(8):
    s1.execute_many([{"lo": i + w, "scale": 1.0} for i in range(8)])
got = s1.execute_many([{"lo": i, "scale": 1.0} for i in range(3)])
cs = db.cost_stats
rides = [d for d in cs["decision_log"] if d["axis"] == "bucket"]
print(f"  3-ticket batch ran in bucket "
      f"{got[0].stats['batch_bucket']} "
      f"(bucket_rides={cs['bucket_rides']})")
if rides:
    d = rides[-1]
    print(f"  decision: natural={d['natural']} -> rode {d['choice']} "
          f"(warm {d['warm_wave_s']:.2e}s vs cold est "
          f"{d['cold_est_s']:.2e}s)")
else:
    print("  (cold estimate beat the warm wave here — the ride only "
          "happens when measurement says it pays)")
