"""The paper's §11 scalar UDFs (faithful ports of the T-SQL definitions)
and the TPC-H queries rewritten to use them."""
from __future__ import annotations

from repro.core import (
    UdfBuilder,
    avg_,
    between,
    case,
    col,
    count_,
    dateadd,
    datepart,
    in_list,
    like,
    lit,
    param,
    scalar_subquery,
    scan,
    sum_,
    udf,
    var,
)
from repro.data.tpch import tpch_dates

D = tpch_dates()


def register_udfs(db):
    # discount_price(extprice, disc) = extprice*(1-disc)
    u = UdfBuilder("discount_price", [("extprice", "float32"), ("disc", "float32")],
                   "float32")
    u.return_(param("extprice") * (1.0 - param("disc")))
    db.create_function(u.build())

    # discount_taxprice = discount_price(...) * (1+tax)   (nested call)
    u = UdfBuilder(
        "discount_taxprice",
        [("extprice", "float32"), ("disc", "float32"), ("tax", "float32")],
        "float32",
    )
    u.return_(udf("discount_price", param("extprice"), param("disc"))
              * (1.0 + param("tax")))
    db.create_function(u.build())

    # profit_amount
    u = UdfBuilder(
        "profit_amount",
        [("extprice", "float32"), ("discount", "float32"),
         ("suppcost", "float32"), ("qty", "int32")],
        "float32",
    )
    u.return_(param("extprice") * (1.0 - param("discount"))
              - param("suppcost") * param("qty"))
    db.create_function(u.build())

    # isShippedBefore(shipdate, duration, stdate)
    u = UdfBuilder(
        "isShippedBefore",
        [("shipdate", "date"), ("duration", "int32"), ("stdate", "date")],
        "int32",
    )
    u.declare("newdate", "date")
    u.set("newdate", dateadd("dd", param("duration"), param("stdate")))
    with u.if_(param("shipdate") > var("newdate")):
        u.return_(lit(0))
    u.return_(lit(1))
    db.create_function(u.build())

    # checkDate(d, odate, shipdate)
    u = UdfBuilder(
        "checkDate",
        [("d", "date"), ("odate", "date"), ("shipdate", "date")],
        "int32",
    )
    with u.if_((param("odate") < param("d")) & (param("shipdate") > param("d"))):
        u.return_(lit(1))
    u.return_(lit(0))
    db.create_function(u.build())

    # q3conditions(cmkt_is_building, odate, shipdate)
    u = UdfBuilder(
        "q3conditions",
        [("cmkt", "str"), ("odate", "date"), ("shipdate", "date")],
        "int32",
    )
    u.declare("thedate", "date", lit(D["1995-03-15"]))
    with u.if_(param("cmkt") != lit("BUILDING")):
        u.return_(lit(0))
    with u.if_(udf("checkDate", var("thedate"), param("odate"),
                   param("shipdate")) == 0):
        u.return_(lit(0))
    with u.if_(udf("isShippedBefore", param("shipdate"), lit(122),
                   var("thedate")) == 0):
        u.return_(lit(0))
    u.return_(lit(1))
    db.create_function(u.build())

    # q5Conditions(rname, odate)
    u = UdfBuilder("q5conditions", [("rname", "str"), ("odate", "date")], "int32")
    u.declare("beginDate", "date", lit(D["1994-01-01"]))
    u.declare("newdate", "date")
    with u.if_(param("rname") != lit("ASIA")):
        u.return_(lit(0))
    with u.if_(param("odate") < var("beginDate")):
        u.return_(lit(0))
    u.set("newdate", dateadd("yy", 1, var("beginDate")))
    with u.if_(param("odate") >= var("newdate")):
        u.return_(lit(0))
    u.return_(lit(1))
    db.create_function(u.build())

    # q6conditions(shipdate, discount, qty)
    u = UdfBuilder(
        "q6conditions",
        [("shipdate", "date"), ("discount", "float32"), ("qty", "int32")],
        "int32",
    )
    u.declare("stdate", "date", lit(D["1994-01-01"]))
    u.declare("newdate", "date")
    u.set("newdate", dateadd("yy", 1, var("stdate")))
    with u.if_(param("shipdate") < var("stdate")):
        u.return_(lit(0))
    with u.if_(param("shipdate") >= var("newdate")):
        u.return_(lit(0))
    with u.if_(param("qty") >= 24):
        u.return_(lit(0))
    u.declare("val", "float32", lit(0.06))
    u.declare("epsilon", "float32", lit(0.01))
    u.declare("lowerbound", "float32")
    u.declare("upperbound", "float32")
    u.set("lowerbound", var("val") - var("epsilon"))
    u.set("upperbound", var("val") + var("epsilon"))
    with u.if_((param("discount") >= var("lowerbound"))
               & (param("discount") <= var("upperbound"))):
        u.return_(lit(1))
    u.return_(lit(0))
    db.create_function(u.build())

    # q12conditions(shipmode, commitdate, receiptdate, shipdate)
    u = UdfBuilder(
        "q12conditions",
        [("shipmode", "str"), ("commitdate", "date"),
         ("receiptdate", "date"), ("shipdate", "date")],
        "int32",
    )
    with u.if_(in_list(param("shipmode"), ["MAIL", "SHIP"])):
        u.declare("stdate", "date", lit(D["1995-09-01"]))
        u.declare("newdate", "date")
        u.set("newdate", dateadd("mm", 1, var("stdate")))
        with u.if_(param("receiptdate") < lit(D["1994-01-01"])):
            u.return_(lit(0))
        with u.if_((param("commitdate") < param("receiptdate"))
                   & (param("shipdate") < param("commitdate"))
                   & (param("receiptdate") < var("newdate"))):
            u.return_(lit(1))
    u.return_(lit(0))
    db.create_function(u.build())

    # line_count(oprio, mode)   (paper's Q12 helper)
    u = UdfBuilder("line_count", [("oprio", "str"), ("mode", "str")], "int32")
    u.declare("val", "int32", lit(0))
    with u.if_(param("mode") == lit("high")):
        with u.if_(in_list(param("oprio"), ["1-URGENT", "2-HIGH"])):
            u.set("val", lit(1))
    with u.else_():
        with u.if_(~in_list(param("oprio"), ["1-URGENT", "2-HIGH"])):
            u.set("val", lit(1))
    u.return_(var("val"))
    db.create_function(u.build())

    # promo_disc(ptype, extprice, disc)
    u = UdfBuilder(
        "promo_disc",
        [("ptype", "str"), ("extprice", "float32"), ("disc", "float32")],
        "float32",
    )
    u.declare("val", "float32")
    with u.if_(like(param("ptype"), "PROMO%")):
        u.set("val", udf("discount_price", param("extprice"), param("disc")))
    with u.else_():
        u.set("val", lit(0.0))
    u.return_(var("val"))
    db.create_function(u.build())

    # q19conditions
    u = UdfBuilder(
        "q19conditions",
        [("pcontainer", "str"), ("lqty", "int32"), ("psize", "int32"),
         ("shipmode", "str"), ("shipinst", "str"), ("pbrand", "str")],
        "int32",
    )
    u.declare("val", "int32", lit(0))
    with u.if_(in_list(param("shipmode"), ["AIR", "AIR REG"])
               & (param("shipinst") == lit("DELIVER IN PERSON"))):
        with u.if_((param("pbrand") == lit("Brand#12"))
                   & in_list(param("pcontainer"),
                             ["SM CASE", "SM BOX", "SM PACK", "SM PKG"])
                   & between(param("lqty"), 1, 11)
                   & between(param("psize"), 1, 5)):
            u.set("val", lit(1))
        with u.if_((param("pbrand") == lit("Brand#23"))
                   & in_list(param("pcontainer"),
                             ["MED BAG", "MED BOX", "MED PKG", "MED PACK"])
                   & between(param("lqty"), 10, 20)
                   & between(param("psize"), 1, 10)):
            u.set("val", lit(1))
        with u.if_((param("pbrand") == lit("Brand#34"))
                   & in_list(param("pcontainer"),
                             ["LG CASE", "LG BOX", "LG PACK", "LG PKG"])
                   & between(param("lqty"), 20, 30)
                   & between(param("psize"), 1, 15)):
            u.set("val", lit(1))
    u.return_(var("val"))
    db.create_function(u.build())

    # total_value()  (uncorrelated subquery UDF, Q11)
    u = UdfBuilder("total_value", [], "float32")
    u.return_(
        scalar_subquery(
            scan("partsupp")
            .join(scan("supplier"), on=("ps_suppkey", "s_suppkey"))
            .join(scan("nation"), on=("s_nationkey", "n_nationkey"))
            .filter(col("n_name") == lit("GERMANY"))
            .agg(v=sum_(col("ps_supplycost") * col("ps_availqty"))),
            "v",
        )
        * 0.0001
    )
    db.create_function(u.build())

    # avg_actbal() (Q22)
    u = UdfBuilder("avg_actbal", [], "float32")
    u.return_(
        scalar_subquery(
            scan("customer")
            .filter(
                (col("c_acctbal") > 0.0)
                & in_list(col("c_phone_cc"),
                          ["13", "31", "23", "29", "30", "18", "17"])
            )
            .agg(v=avg_(col("c_acctbal"))),
            "v",
        )
    )
    db.create_function(u.build())


# ---------------------------------------------------------------------------
# queries: (name, with_udfs, original) pairs — plan builders
# ---------------------------------------------------------------------------


def q1_udf():
    return (
        scan("lineitem")
        .filter(udf("isShippedBefore", col("l_shipdate"), lit(-90),
                    lit(D["1998-12-01"])) == 1)
        .group_by(
            "l_returnflag", "l_linestatus",
            sum_qty=sum_(col("l_quantity")),
            sum_base=sum_(col("l_extendedprice")),
            sum_disc_price=sum_(udf("discount_price", col("l_extendedprice"),
                                    col("l_discount"))),
            sum_charge=sum_(udf("discount_taxprice", col("l_extendedprice"),
                                col("l_discount"), col("l_tax"))),
            avg_qty=avg_(col("l_quantity")),
            avg_price=avg_(col("l_extendedprice")),
            count_order=count_(),
        )
    )


def q1_orig():
    cutoff = dateadd("dd", -90, lit(D["1998-12-01"]))
    return (
        scan("lineitem")
        .filter(col("l_shipdate") <= cutoff)
        .group_by(
            "l_returnflag", "l_linestatus",
            sum_qty=sum_(col("l_quantity")),
            sum_base=sum_(col("l_extendedprice")),
            sum_disc_price=sum_(col("l_extendedprice") * (1.0 - col("l_discount"))),
            sum_charge=sum_(col("l_extendedprice") * (1.0 - col("l_discount"))
                            * (1.0 + col("l_tax"))),
            avg_qty=avg_(col("l_quantity")),
            avg_price=avg_(col("l_extendedprice")),
            count_order=count_(),
        )
    )


def q3_udf():
    return (
        scan("lineitem")
        .join(scan("orders"), on=("l_orderkey", "o_orderkey"))
        .join(scan("customer"), on=("o_custkey", "c_custkey"))
        .filter(udf("q3conditions", col("c_mktsegment"), col("o_orderdate"),
                    col("l_shipdate")) == 1)
        .group_by(
            "l_orderkey", "o_orderdate", "o_shippriority",
            revenue=sum_(udf("discount_price", col("l_extendedprice"),
                             col("l_discount"))),
        )
        .sort(("revenue", False), limit=10)
    )


def q3_orig():
    d = lit(D["1995-03-15"])
    return (
        scan("lineitem")
        .join(scan("orders"), on=("l_orderkey", "o_orderkey"))
        .join(scan("customer"), on=("o_custkey", "c_custkey"))
        .filter((col("c_mktsegment") == lit("BUILDING"))
                & (col("o_orderdate") < d) & (col("l_shipdate") > d)
                & (col("l_shipdate") <= dateadd("dd", 122, d)))
        .group_by(
            "l_orderkey", "o_orderdate", "o_shippriority",
            revenue=sum_(col("l_extendedprice") * (1.0 - col("l_discount"))),
        )
        .sort(("revenue", False), limit=10)
    )


def q5_udf():
    return (
        scan("lineitem")
        .join(scan("orders"), on=("l_orderkey", "o_orderkey"))
        .join(scan("customer"), on=("o_custkey", "c_custkey"))
        .join(scan("supplier"), on=("l_suppkey", "s_suppkey"))
        .join(scan("nation"), on=("s_nationkey", "n_nationkey"))
        .join(scan("region"), on=("n_regionkey", "r_regionkey"))
        .filter(col("c_nationkey") == col("s_nationkey"))
        .filter(udf("q5conditions", col("r_name"), col("o_orderdate")) == 1)
        .group_by("n_name",
                  revenue=sum_(udf("discount_price", col("l_extendedprice"),
                                   col("l_discount"))))
        .sort(("revenue", False))
    )


def q5_orig():
    lo = lit(D["1994-01-01"])
    return (
        scan("lineitem")
        .join(scan("orders"), on=("l_orderkey", "o_orderkey"))
        .join(scan("customer"), on=("o_custkey", "c_custkey"))
        .join(scan("supplier"), on=("l_suppkey", "s_suppkey"))
        .join(scan("nation"), on=("s_nationkey", "n_nationkey"))
        .join(scan("region"), on=("n_regionkey", "r_regionkey"))
        .filter(col("c_nationkey") == col("s_nationkey"))
        .filter((col("r_name") == lit("ASIA"))
                & (col("o_orderdate") >= lo)
                & (col("o_orderdate") < dateadd("yy", 1, lo)))
        .group_by("n_name",
                  revenue=sum_(col("l_extendedprice") * (1.0 - col("l_discount"))))
        .sort(("revenue", False))
    )


def q6_udf():
    return (
        scan("lineitem")
        .filter(udf("q6conditions", col("l_shipdate"), col("l_discount"),
                    col("l_quantity")) == 1)
        .agg(revenue=sum_(col("l_extendedprice") * col("l_discount")))
    )


def q6_orig():
    lo = lit(D["1994-01-01"])
    return (
        scan("lineitem")
        .filter((col("l_shipdate") >= lo)
                & (col("l_shipdate") < dateadd("yy", 1, lo))
                & (col("l_quantity") < 24)
                & between(col("l_discount"), 0.05, 0.07))
        .agg(revenue=sum_(col("l_extendedprice") * col("l_discount")))
    )


def q12_udf():
    return (
        scan("lineitem")
        .join(scan("orders"), on=("l_orderkey", "o_orderkey"))
        .filter(udf("q12conditions", col("l_shipmode"), col("l_commitdate"),
                    col("l_receiptdate"), col("l_shipdate")) == 1)
        .group_by(
            "l_shipmode",
            high=sum_(udf("line_count", col("o_orderpriority"), lit("high"))),
            low=sum_(udf("line_count", col("o_orderpriority"), lit("low"))),
        )
        .sort("l_shipmode")
    )


def q12_orig():
    lo = lit(D["1995-09-01"])
    hi = dateadd("mm", 1, lo)
    is_high = in_list(col("o_orderpriority"), ["1-URGENT", "2-HIGH"])
    return (
        scan("lineitem")
        .join(scan("orders"), on=("l_orderkey", "o_orderkey"))
        .filter(in_list(col("l_shipmode"), ["MAIL", "SHIP"])
                & (col("l_receiptdate") >= lit(D["1994-01-01"]))
                & (col("l_commitdate") < col("l_receiptdate"))
                & (col("l_shipdate") < col("l_commitdate"))
                & (col("l_receiptdate") < hi))
        .compute(h=case([(is_high, lit(1))], lit(0)),
                 lw=case([(is_high, lit(0))], lit(1)))
        .group_by("l_shipmode", high=sum_(col("h")), low=sum_(col("lw")))
        .sort("l_shipmode")
    )


def q14_udf():
    lo = lit(D["1995-09-01"])
    return (
        scan("lineitem")
        .join(scan("part"), on=("l_partkey", "p_partkey"))
        .filter((col("l_shipdate") >= lo)
                & (col("l_shipdate") < dateadd("mm", 1, lo)))
        .agg(
            promo=sum_(udf("promo_disc", col("p_type"), col("l_extendedprice"),
                           col("l_discount"))),
            total=sum_(udf("discount_price", col("l_extendedprice"),
                           col("l_discount"))),
        )
        .compute(promo_revenue=col("promo") * 100.0 / col("total"))
        .project("promo_revenue")
    )


def q14_orig():
    lo = lit(D["1995-09-01"])
    return (
        scan("lineitem")
        .join(scan("part"), on=("l_partkey", "p_partkey"))
        .filter((col("l_shipdate") >= lo)
                & (col("l_shipdate") < dateadd("mm", 1, lo)))
        .compute(pd=case([(like(col("p_type"), "PROMO%"),
                           col("l_extendedprice") * (1.0 - col("l_discount")))],
                         lit(0.0)),
                 dp=col("l_extendedprice") * (1.0 - col("l_discount")))
        .agg(promo=sum_(col("pd")), total=sum_(col("dp")))
        .compute(promo_revenue=col("promo") * 100.0 / col("total"))
        .project("promo_revenue")
    )


def q19_udf():
    return (
        scan("lineitem")
        .join(scan("part"), on=("l_partkey", "p_partkey"))
        .filter(udf("q19conditions", col("p_container"), col("l_quantity"),
                    col("p_size"), col("l_shipmode"), col("l_shipinstruct"),
                    col("p_brand")) == 1)
        .agg(revenue=sum_(udf("discount_price", col("l_extendedprice"),
                              col("l_discount"))))
    )


QUERIES = {
    "Q1": (q1_udf, q1_orig),
    "Q3": (q3_udf, q3_orig),
    "Q5": (q5_udf, q5_orig),
    "Q6": (q6_udf, q6_orig),
    "Q12": (q12_udf, q12_orig),
    "Q14": (q14_udf, q14_orig),
}
