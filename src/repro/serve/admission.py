"""Request admission/routing rules as Froid-compiled UDFs.

This is the paper's technique running inside the serving scheduler: each
scheduler tick evaluates imperative per-request business rules (token
budgeting, tier routing, temperature selection) over the *whole queued
request table* as one set-oriented plan, instead of a Python loop over
requests.  The rules are authored imperatively (UdfBuilder) and compiled
by the same binder/optimizer as any other UDF.

The scheduler holds a :class:`Session` with an eager policy: the queue
table is re-loaded every tick (fresh data, fresh stats), so plans rebuild
per tick, but the registry-keyed statement caches inside the session stay
warm across ticks.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    FROID,
    INTERPRETED,
    ExecutionPolicy,
    Session,
    UdfBuilder,
    case,
    col,
    lit,
    param,
    resolve_policy,
    scan,
    udf,
    var,
)


def default_rules(db) -> None:
    """The built-in admission rules (users register their own the same way).

    token_budget(tier, prompt_len, requested) -> granted max_new_tokens
    temp_for(tier, requested_temp)            -> effective temperature
    admit(prompt_len, queue_depth)            -> bool

    ``db`` is anything with ``create_function`` (a Session or the legacy
    Database shim).
    """
    u = UdfBuilder("token_budget",
                   [("tier", "int32"), ("plen", "int32"), ("req", "int32")],
                   "int32")
    u.declare("cap", "int32")
    with u.if_(param("tier") >= 2):
        u.set("cap", lit(4096))
    with u.else_():
        with u.if_(param("tier") == 1):
            u.set("cap", lit(1024))
        with u.else_():
            u.set("cap", lit(256))
    # long prompts eat into the budget
    with u.if_(param("plen") > 2048):
        u.set("cap", var("cap") // 2)
    with u.if_(param("req") < var("cap")):
        u.return_(param("req"))
    u.return_(var("cap"))
    db.create_function(u.build())

    u = UdfBuilder("temp_for", [("tier", "int32"), ("t", "float32")], "float32")
    with u.if_((param("t") < 0.0) | (param("t") > 2.0)):
        u.return_(lit(0.7))  # out-of-range -> default
    with u.if_(param("tier") == 0):
        # free tier is clamped
        u.return_(case([(param("t") > 1.0, lit(1.0))], param("t")))
    u.return_(param("t"))
    db.create_function(u.build())

    u = UdfBuilder("admit", [("plen", "int32"), ("depth", "int32")], "bool")
    with u.if_(param("plen") > 32768):
        u.return_(lit(False))
    with u.if_((param("depth") > 512) & (param("plen") > 8192)):
        u.return_(lit(False))  # shed long prompts under pressure
    u.return_(lit(True))
    db.create_function(u.build())


def _tick_query():
    return (
        scan("queue")
        .compute(
            admit=udf("admit", col("plen"), col("depth")),
            granted=udf("token_budget", col("tier"), col("plen"), col("req")),
            temp_eff=udf("temp_for", col("tier"), col("temp")),
        )
        .project("admit", "granted", "temp_eff")
    )


class AdmissionPolicy:
    """Evaluates the rules over the queued-request table, set-oriented.

    ``policy`` is an :class:`ExecutionPolicy` or preset name; the legacy
    ``froid`` flag maps True -> FROID, False -> INTERPRETED.
    """

    def __init__(self, froid: bool = True,
                 policy: ExecutionPolicy | str | None = None):
        self.session = Session()
        default_rules(self.session)
        if policy is None:
            policy = FROID if froid else INTERPRETED
        # the queue table is re-loaded every tick, so whole-plan jit would
        # recompile per tick — run the chosen policy eagerly
        self.policy = resolve_policy(policy).eager()
        self._query = _tick_query()

    def evaluate(self, requests: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        """requests: columns tier, prompt_len, max_new_tokens, temperature.
        Returns columns: admit (bool), granted (int32), temp (float32)."""
        n = len(requests["tier"])
        self.session.create_table(
            "queue",
            tier=requests["tier"].astype(np.int32),
            plen=requests["prompt_len"].astype(np.int32),
            req=requests["max_new_tokens"].astype(np.int32),
            temp=requests["temperature"].astype(np.float32),
            depth=np.full(n, n, np.int32),
        )
        res = self.session.execute(self._query, self.policy)
        return {
            "admit": np.asarray(res.table.columns["admit"].data).astype(bool),
            "granted": np.asarray(res.table.columns["granted"].data).astype(np.int32),
            "temp": np.asarray(res.table.columns["temp_eff"].data).astype(np.float32),
        }
