"""Plan-merge pass: cross-statement CSE over the members of a fused program.

The fusion engine's front half.  Given the bound+optimized plans of the
statements a fused program will carry, this pass finds the work they have
in common so the back half (:mod:`repro.fuse.program`) computes it once.
Three sharing tiers, all keyed by canonical structural fingerprints:

* **Constant subtrees** — no ``Param``/``Outer``/``Var`` references and no
  non-deterministic intrinsics anywhere below (including inside nested
  subquery plans).  Their result depends only on catalog state, which every
  member sees identically, so each distinct fingerprint executes **once**
  into the shared pool.  *Every* shared occurrence is marked, not only
  maximal ones: the pool is built innermost-first, so a shared sub-subtree
  beneath two distinct shared roots evaluates once and both roots' pool
  builds answer it from the pool (nested sharing).
* **Parameter-unified templates** — subtrees equal *modulo parameter
  slots* (:func:`repro.core.session.parametric_fingerprint`) unify into one
  templated subtree with canonical holes.  The fused program evaluates a
  template once per **distinct binding** of its holes across all tickets of
  all members (a binding → pool-slot map, built host-side in
  ``Session._run_fused``), and each member's trace answers its occurrence
  by gathering its ticket's slot.  Const-vs-param unification rides the
  same tier: when a *lifted* fingerprint group (liftable literal constants
  also canonicalized to holes) mixes a param-shaped and a const-shaped
  occurrence, the whole group promotes to one lifted template and ``a < 5``
  joins the ``a < Param(x)`` pool as one more distinct binding — when a
  ticket binds ``x = 5`` they dedup to a single evaluation.
* **Correlated templates** — subtrees whose only extra references are
  ``Outer`` slots (correlated-subquery bodies differing in their outer
  binding) unify through the same template path: one canonical identity in
  the merge stats, cache keys and explain output.  Their *evaluation* stays
  per member (outer bindings are whole columns, not host-enumerable
  values), but constant/param-unified subtrees *inside* them dedup via the
  tiers above — the sub-executor propagation in ``repro.fuse.program``
  carries the pool into nested subquery evaluation.

The output is a :class:`FusedPlan`; ``explain()`` renders which subtrees
were shared and under which template.  Still out of scope (ROADMAP):
binding-pooled evaluation of templates nested inside other templates.
"""
from __future__ import annotations

import dataclasses

from repro.core import optimizer as O
from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.optimizer import _rewrite_exprs
from repro.core.session import (
    const_hole_key,
    liftable_const,
    parametric_fingerprint,
    plan_fingerprint,
)

#: every relalg node the executor can run is side-effect free; anything
#: else (a future effectful node, a foreign plan object) blocks fusion.
#: LoopScan qualifies: its child scan, carry inits, and step/reduction
#: expressions are all pure (the loop rewrite pass rejects everything else)
PURE_NODES = (
    R.Scan, R.ConstantScan, R.Compute, R.Project, R.Filter,
    R.Join, R.Apply, R.GroupAgg, R.Sort, R.LoopScan,
)

#: template_binds marker for a hole bound by a lifted literal constant
#: rather than an actual parameter name: ``(CONST_BIND, value)``
CONST_BIND = "__const__"

#: canonical spelling of template hole ``i`` — the parameter name the
#: canonical template subtree is evaluated under in the binding pool
CSE_HOLE = "__cse_s{}"

#: reserved per-ticket parameter carrying a template occurrence's pool-slot
#: index through the stacked parameter axis (one per occurrence).  Spelled
#: by the occurrence's *ordinal* within its member's canonical occurrence
#: walk — a content-derived name, identical in every process, so fused
#: programs carrying slot parameters round-trip through the persistent
#: plan tier.  (The pre-PR-10 spelling embedded the occurrence's
#: process-local ``node_id``; ``repro.persist.keys.assert_stable_key``
#: now rejects that shape outright.)
SLOT_PARAM = "__cse_slot_o{}"


def hole_name(i: int) -> str:
    return CSE_HOLE.format(i)


def slot_param(ordinal: int) -> str:
    """Reserved slot-parameter name of occurrence ``ordinal`` (its index
    in the member's deterministic maximal-occurrence walk)."""
    return SLOT_PARAM.format(ordinal)


def plan_is_pure(plan: R.RelNode) -> bool:
    """True when every node of ``plan`` — including nodes of nested
    subquery plans — is a known side-effect-free operator; the fusability
    analysis's safety gate."""
    return all(isinstance(n, PURE_NODES) for n in R.walk_plan_deep(plan))


def subtree_shape(node: R.RelNode) -> str | None:
    """Shareability class of the subtree: ``"const"`` (no external
    references at all), ``"param"`` (query parameters only — pool-eligible
    after unification), ``"corr"`` (outer-row references, possibly plus
    parameters — template identity only), or ``None`` (unbound UDF locals
    or non-deterministic intrinsics like ``rand()``, which must evaluate
    per statement, never once per pool)."""
    has_param = has_outer = False
    for n in R.walk_plan_deep(node):
        for e in n.exprs():
            for s in S.walk(e):
                if isinstance(s, S.Var):
                    return None
                if isinstance(s, S.Func) and s.name in S.Func.NON_DETERMINISTIC:
                    return None
                if isinstance(s, S.Param):
                    has_param = True
                elif isinstance(s, S.Outer):
                    has_outer = True
    if has_outer:
        return "corr"
    return "param" if has_param else "const"


def subtree_is_constant(node: R.RelNode) -> bool:
    """True when the subtree's result depends only on catalog state (see
    :func:`subtree_shape`)."""
    return subtree_shape(node) == "const"


def rewrite_params(plan: R.RelNode, mapping: dict[str, str]) -> R.RelNode:
    """Deep-rename ``Param`` references per ``mapping`` (actual name →
    canonical hole name), descending into nested subquery plans.  Identity
    is preserved for untouched subtrees, so constant shared descendants of
    a rewritten template keep their ``node_id`` marks."""

    def fix_scalar(x):
        if isinstance(x, S.Param) and x.name in mapping:
            return S.Param(mapping[x.name])
        if isinstance(x, S.ScalarSubquery):
            p2 = rewrite_params(x.plan, mapping)
            if p2 is not x.plan:
                return S.ScalarSubquery(p2, x.column, x.agg_default)
        if isinstance(x, S.Exists):
            p2 = rewrite_params(x.plan, mapping)
            if p2 is not x.plan:
                return S.Exists(p2, x.negated)
        return None

    def fix_node(n):
        changed = False

        def fe(e):
            nonlocal changed
            e2 = S.transform(e, fix_scalar)
            changed = changed or (e2 is not e)
            return e2

        n2 = _rewrite_exprs(n, fe)
        return n2 if changed else None

    return R.transform_plan(plan, fix_node)


def rewrite_lifted(plan: R.RelNode, holes: tuple) -> R.RelNode:
    """Rewrite one occurrence into the canonical *lifted*-template subtree:
    ``Param`` references **and** liftable literal constants both become
    canonical hole ``Param``s, per the occurrence's lifted hole signature
    (``(kind, name_or_key)`` tuples from ``parametric_fingerprint(...,
    lift_consts=True)``)."""
    pmap: dict[str, str] = {}
    cmap: dict[tuple, str] = {}
    for i, (kind, key) in enumerate(holes):
        if kind == "param":
            pmap[key] = hole_name(i)
        else:
            cmap[key] = hole_name(i)

    def fix_scalar(x):
        if isinstance(x, S.Param) and x.name in pmap:
            return S.Param(pmap[x.name])
        if liftable_const(x):
            h = cmap.get(const_hole_key(x.value))
            if h is not None:
                return S.Param(h)
        if isinstance(x, S.ScalarSubquery):
            p2 = rewrite_lifted(x.plan, holes)
            if p2 is not x.plan:
                return S.ScalarSubquery(p2, x.column, x.agg_default)
        if isinstance(x, S.Exists):
            p2 = rewrite_lifted(x.plan, holes)
            if p2 is not x.plan:
                return S.Exists(p2, x.negated)
        return None

    def fix_node(n):
        changed = False

        def fe(e):
            nonlocal changed
            e2 = S.transform(e, fix_scalar)
            changed = changed or (e2 is not e)
            return e2

        n2 = _rewrite_exprs(n, fe)
        return n2 if changed else None

    return R.transform_plan(plan, fix_node)


@dataclasses.dataclass
class SharedTemplate:
    """One parameter-unified shared subtree (pool-eligible: param holes
    only).  ``node`` is the canonical subtree with its parameters renamed
    to the canonical hole spelling; evaluating it under
    ``params={holes[i]: binding[i]}`` reproduces any occurrence."""

    fp: tuple  # canonical parametric fingerprint (unification key)
    node: R.RelNode  # canonical subtree, params renamed to hole names
    holes: tuple  # canonical hole parameter names, slot order
    refs: int  # occurrences across all members


@dataclasses.dataclass
class FusedPlan:
    """The merge pass's product (see module docstring)."""

    members: list  # member plans, fusion order
    shared: list  # [(fp, canonical subtree)] const pool, innermost-first
    shared_ids: dict  # node_id -> fp, every shared-const occurrence
    templates: list  # [SharedTemplate], first-appearance order
    template_ids: dict  # node_id -> template fp, every occurrence
    template_binds: dict  # node_id -> {hole name -> actual param name}
    corr_ids: dict  # node_id -> template fp, correlated occurrences
    stats: dict  # merge-level counters (shared_subtrees, cse_*, ...)

    def explain(self) -> str:
        """Human-readable sharing report: every shared subtree / template,
        its reference count, and the subtree itself.  Memoized — the
        serving drain path attaches it to every warm wave's stats, and a
        FusedPlan is immutable once built."""
        cached = getattr(self, "_explain_cache", None)
        if cached is not None:
            return cached
        text = self._explain_cache = self._explain()
        return text

    def _explain(self) -> str:
        out = [f"fused members: {len(self.members)}"]
        refs: dict[tuple, int] = {}
        for fp in self.shared_ids.values():
            refs[fp] = refs.get(fp, 0) + 1
        out.append(f"shared constant subtrees ({len(self.shared)}, "
                   "evaluate once into the pool):")
        for i, (fp, node) in enumerate(self.shared):
            out.append(f"  [S{i}] x{refs.get(fp, 0)} refs")
            out.append(_indent(O.explain(node), 2))
        out.append(f"parameter-unified templates ({len(self.templates)}, "
                   "evaluate once per distinct binding):")
        for i, t in enumerate(self.templates):
            # key=repr: const-bind markers are tuples, param binds are
            # strings — not mutually comparable
            binds = sorted(
                (tuple(sorted(b.items()))
                 for nid, b in self.template_binds.items()
                 if self.template_ids[nid] == t.fp),
                key=repr,
            )
            out.append(f"  [T{i}] holes={list(t.holes)} x{t.refs} refs; "
                       f"bindings {binds}")
            out.append(_indent(O.explain(t.node), 2))
        corr: dict[tuple, int] = {}
        for fp in self.corr_ids.values():
            corr[fp] = corr.get(fp, 0) + 1
        if corr:
            out.append(f"correlated templates ({len(corr)}, unified "
                       "identity; evaluated per member):")
            for i, (fp, n) in enumerate(sorted(corr.items(), key=repr)):
                out.append(f"  [C{i}] x{n} refs")
        return "\n".join(out)


def _indent(text: str, by: int) -> str:
    pad = "  " * by
    return "\n".join(pad + line for line in text.splitlines())


def _deep_size(node: R.RelNode, memo: dict) -> int:
    s = memo.get(node.node_id)
    if s is None:
        s = sum(1 for _ in R.walk_plan_deep(node))
        memo[node.node_id] = s
    return s


def merge_plans(plans: list) -> FusedPlan:
    """Merge ``plans`` into one fused-program description.

    Two passes: classify and count every shareable subtree fingerprint
    across all members (a fingerprint occurring twice — in two members, or
    twice within one — is worth computing once), then mark occurrences and
    compute coverage stats top-down (a marked node's descendants execute
    inside its one shared evaluation, so only maximal marks count toward
    ``cse_shared_nodes``)."""
    info: dict[int, tuple | None] = {}  # node_id -> (shape, fp, holes)|None
    linfo: dict[int, tuple] = {}  # node_id -> (lifted fp, lifted holes)
    occurrences: dict[tuple, int] = {}
    loccur: dict[tuple, int] = {}  # lifted fp -> occurrence count
    lshapes: dict[tuple, set] = {}  # lifted fp -> shapes seen in the group
    canonical: dict[tuple, R.RelNode] = {}  # plain AND lifted fps (disjoint)
    appearance: dict[tuple, int] = {}  # fp -> first-appearance index

    for plan in plans:
        for n in R.walk_plan_deep(plan):
            ent = info.get(n.node_id, "unseen")
            if ent == "unseen":
                shape = subtree_shape(n)
                if shape is None:
                    ent = None
                else:
                    fp, holes = parametric_fingerprint(n)
                    ent = (shape, fp, holes)
                    if shape in ("param", "const"):
                        lfp, lholes = parametric_fingerprint(
                            n, lift_consts=True)
                        if lholes:
                            linfo[n.node_id] = (lfp, lholes)
                info[n.node_id] = ent
            if ent is not None:
                fp = ent[1]
                occurrences[fp] = occurrences.get(fp, 0) + 1
                canonical.setdefault(fp, n)
                appearance.setdefault(fp, len(appearance))
                lent = linfo.get(n.node_id)
                if lent is not None:
                    lfp = lent[0]
                    loccur[lfp] = loccur.get(lfp, 0) + 1
                    lshapes.setdefault(lfp, set()).add(ent[0])
                    canonical.setdefault(lfp, n)
                    appearance.setdefault(lfp, len(appearance))

    shared_fps = {fp for fp, c in occurrences.items() if c >= 2}
    # const-vs-param promotion: a lifted group earns a template only when
    # it actually unifies across the const/param divide — all-param groups
    # are already plain-unified, and all-const groups are better served by
    # the constant pool (per-value, no binding machinery)
    promoted = {
        lfp for lfp, c in loccur.items()
        if c >= 2 and "param" in lshapes[lfp] and "const" in lshapes[lfp]
    }

    # occurrence maps (every shared occurrence — the pool builder answers
    # nested ones; member traces are intercepted at the topmost mark)
    shared_ids: dict[int, tuple] = {}
    template_ids: dict[int, tuple] = {}
    template_binds: dict[int, dict] = {}
    corr_ids: dict[int, tuple] = {}
    for nid, ent in info.items():
        if ent is None:
            continue
        shape, fp, holes = ent
        lent = linfo.get(nid)
        if lent is not None and lent[0] in promoted:
            lfp, lholes = lent
            template_ids[nid] = lfp
            template_binds[nid] = {
                hole_name(i): (name if kind == "param"
                               else (CONST_BIND, name[1]))
                for i, (kind, name) in enumerate(lholes)
            }
            continue
        if fp not in shared_fps:
            continue
        if shape == "const":
            shared_ids[nid] = fp
        elif shape == "param":
            template_ids[nid] = fp
            template_binds[nid] = {
                hole_name(i): name for i, (_, name) in enumerate(holes)
            }
        else:  # corr — unified identity only
            corr_ids[nid] = fp

    size_memo: dict[int, int] = {}
    # const pool, innermost-first: a proper subtree is strictly smaller
    # than its parent, so ascending size puts shared children before the
    # shared roots whose pool build answers them
    const_fps = sorted(
        {fp for fp in shared_ids.values()},
        key=lambda fp: (_deep_size(canonical[fp], size_memo), appearance[fp]),
    )
    shared = [(fp, canonical[fp]) for fp in const_fps]

    templates: list[SharedTemplate] = []
    for fp in sorted({fp for fp in template_ids.values()},
                     key=lambda fp: appearance[fp]):
        occ = canonical[fp]
        if fp in promoted:  # lifted template: consts become holes too
            _, lholes = linfo[occ.node_id]
            node = rewrite_lifted(occ, lholes)
            nholes = len(lholes)
        else:
            _, _, holes = info[occ.node_id]
            mapping = {name: hole_name(i) for i, (_, name) in enumerate(holes)}
            node = rewrite_params(occ, mapping)
            nholes = len(holes)
        templates.append(SharedTemplate(
            fp,
            node,
            tuple(hole_name(i) for i in range(nholes)),
            sum(1 for f in template_ids.values() if f == fp),
        ))

    # coverage stats: maximal marks only — descendants of a marked node
    # execute inside its one shared evaluation
    counters = {"const_refs": 0, "template_refs": 0, "covered": 0}

    maximal_const_fps: set = set()

    def mark(n: R.RelNode) -> None:
        nid = n.node_id
        if nid in shared_ids:
            counters["const_refs"] += 1
            maximal_const_fps.add(shared_ids[nid])
            counters["covered"] += _deep_size(n, size_memo)
            return
        if nid in template_ids:
            counters["template_refs"] += 1
            counters["covered"] += _deep_size(n, size_memo)
            return
        for p in R.embedded_plans(n):
            mark(p)
        for c in n.children():
            mark(c)

    for plan in plans:
        mark(plan)

    pool_nodes = [n for _, n in shared] + [t.node for t in templates]
    total_scans = sum(
        1 for p in plans for n in R.walk_plan_deep(p) if isinstance(n, R.Scan)
    )
    shared_scan_nodes = sum(
        1 for sub in pool_nodes for n in R.walk_plan_deep(sub)
        if isinstance(n, R.Scan)
    )
    stats = {
        "fused_members": len(plans),
        "shared_subtrees": len(shared),
        # maximal marked references across members; refs minus the count
        # of *distinct maximal* fingerprints = evaluations the fused
        # program skips vs the per-statement path (shared_subtrees counts
        # every pooled fingerprint, nested ones included, so it is the
        # wrong subtrahend for that arithmetic)
        "shared_refs": counters["const_refs"],
        "shared_maximal_subtrees": len(maximal_const_fps),
        "cse_templates": len(templates),
        "cse_template_refs": counters["template_refs"],
        # lifted (const-vs-param unified) templates among cse_templates
        "cse_lifted_templates": sum(1 for t in templates
                                    if t.fp in promoted),
        "cse_corr_templates": len({fp for fp in corr_ids.values()}),
        "cse_corr_refs": len(corr_ids),
        # plan nodes (deep) covered by a shared evaluation — the engine's
        # sharing coverage; adding an overlapping member never decreases it
        "cse_shared_nodes": counters["covered"],
        "total_scans": total_scans,
        "shared_scan_nodes": shared_scan_nodes,
    }
    return FusedPlan(list(plans), shared, shared_ids, templates,
                     template_ids, template_binds, corr_ids, stats)


__all__ = [
    "CONST_BIND",
    "CSE_HOLE",
    "FusedPlan",
    "PURE_NODES",
    "SLOT_PARAM",
    "SharedTemplate",
    "hole_name",
    "merge_plans",
    "plan_fingerprint",
    "plan_is_pure",
    "rewrite_lifted",
    "rewrite_params",
    "slot_param",
    "subtree_is_constant",
    "subtree_shape",
]
