"""Train a reduced granite-3 model for a few hundred steps on CPU with the
full production substrate: Froid-compiled data-pipeline transforms, AdamW,
remat, checkpoint/resume, straggler tracking.

    PYTHONPATH=src python examples/train_small_lm.py [--steps 300]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax

from repro.configs import smoke_config_for
from repro.data.pipeline import DataPipeline
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.optim import AdamWConfig
from repro.train.straggler import StragglerTracker
from repro.train.train_loop import TrainState, init_state, train_loop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt", default="/tmp/repro_train_demo")
args = ap.parse_args()

cfg = smoke_config_for("granite3_2b")
model = build_model(cfg)
opt = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)

mgr = CheckpointManager(args.ckpt, keep_n=2)
step, restored = mgr.restore_latest()
if restored is not None:
    print(f"resuming from step {step}")
    state = TrainState(restored["params"], restored["opt"], None)
else:
    state = init_state(model, jax.random.PRNGKey(0), opt)

pipe = DataPipeline(batch=8, seq_len=64, vocab=cfg.vocab, seed=0)
state = train_loop(model, state, iter(pipe), opt, steps=args.steps,
                   checkpoint_mgr=mgr, checkpoint_every=100,
                   straggler=StragglerTracker(), log_every=20)
mgr.wait()
print(f"final step {int(state.opt['step'])}; checkpoints: {mgr.all_steps()}")
