"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.relagg.ref import grouped_aggregate_ref
from repro.kernels.relagg.relagg import relagg_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas


# ---------------------------------------------------------------- relagg
@pytest.mark.parametrize("n", [64, 257, 1000, 4096])
@pytest.mark.parametrize("groups", [1, 8, 130])
@pytest.mark.parametrize("n_aggs", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_relagg_sweep(rng, n, groups, n_aggs, dtype):
    gid = jnp.asarray(rng.integers(0, groups, n), jnp.int32)
    mask = jnp.asarray(rng.random(n) > 0.4)
    vals = jnp.asarray(rng.normal(size=(n, n_aggs)), dtype)
    s1, c1 = relagg_pallas(gid, mask, vals, groups, block_rows=256, interpret=True)
    s2, c2 = grouped_aggregate_ref(gid, mask, vals, groups)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_relagg_empty_mask(rng):
    gid = jnp.zeros(128, jnp.int32)
    mask = jnp.zeros(128, bool)
    vals = jnp.ones((128, 2), jnp.float32)
    s, c = relagg_pallas(gid, mask, vals, 4, block_rows=128, interpret=True)
    assert float(jnp.abs(s).sum()) == 0.0 and float(c.sum()) == 0.0


# ---------------------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "B,Hq,Hk,Sq,Sk,D",
    [
        (1, 4, 2, 256, 256, 64),
        (2, 4, 4, 128, 128, 32),
        (1, 8, 2, 96, 160, 64),   # non-multiple-of-block sizes
        (1, 2, 1, 64, 320, 128),
    ],
)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, B, Hq, Hk, Sq, Sk, D, causal, dtype):
    q = jnp.asarray(rng.normal(size=(B, Hq, Sq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hk, Sk, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hk, Sk, D)), dtype)
    a = flash_attention_pallas(q, k, v, causal=causal, interpret=True, bq=64, bk=64)
    b = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(rng, window):
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    a = flash_attention_pallas(q, k, v, causal=True, window=window,
                               interpret=True, bq=64, bk=64)
    b = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_flash_attention_decode_offset(rng):
    """Sq=1 with q_offset == cache position (serving decode path)."""
    q = jnp.asarray(rng.normal(size=(2, 4, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 512, 64)), jnp.float32)
    a = flash_attention_pallas(q, k, v, causal=True, q_offset=511, interpret=True)
    b = flash_attention_ref(q, k, v, causal=True, q_offset=511)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- ssd scan
@pytest.mark.parametrize(
    "BH,BG,L,P,N,chunk",
    [
        (4, 2, 256, 32, 64, 64),
        (2, 2, 100, 16, 32, 32),  # unpadded length
        (6, 3, 64, 64, 128, 64),
        (2, 1, 512, 64, 128, 128),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(rng, BH, BG, L, P, N, chunk, dtype):
    n_rep = BH // BG
    xdt = jnp.asarray(rng.normal(size=(BH, L, P)) * 0.5, dtype)
    dtA = -jnp.asarray(rng.uniform(0.01, 0.5, size=(BH, L)), dtype)
    B = jnp.asarray(rng.normal(size=(BG, L, N)) * 0.3, dtype)
    C = jnp.asarray(rng.normal(size=(BG, L, N)) * 0.3, dtype)
    a = ssd_scan_pallas(xdt, dtA, B, C, n_rep, chunk=chunk, interpret=True)
    b = ssd_scan_ref(xdt, dtA, B, C, n_rep)
    scale = float(jnp.max(jnp.abs(b.astype(jnp.float32)))) + 1e-9
    err = float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) / scale
    assert err < (3e-4 if dtype == jnp.float32 else 3e-2), err


def test_ssd_matches_decode_steps(rng):
    from repro.kernels.ssd_scan.ops import ssd_decode_step, ssd_scan

    Bb, L, H, P, G, N = 2, 16, 4, 8, 2, 16
    x = jnp.asarray(rng.normal(size=(Bb, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.3, size=(Bb, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.1, 1.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(Bb, L, G, N)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(Bb, L, G, N)) * 0.3, jnp.float32)
    y_full = ssd_scan(x, dt, A, Bm, Cm, use_kernel=False)
    state = jnp.zeros((Bb, H, N, P), jnp.float32)
    ys = []
    for t in range(L):
        state, y_t = ssd_decode_step(state, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(y_t)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(ys, 1)), np.asarray(y_full), atol=1e-4
    )
