"""Multi-worker fleet serving over the shared persistent plan tier.

The fleet conformance contract (``check_fleet_oracle``): a fleet drain of
a mixed-statement queue equals the single-worker serial drain element-wise
— whatever the store served (hits, cold misses, stale stamps, corrupt
entries), wherever round-robin landed each request, and under injected
faults and DDL broadcasts.  Persistence may only change costs.
"""
from __future__ import annotations

import glob
import os
import warnings

import pytest

from conformance_util import (
    FIXED_PROGRAMS,
    build_udf,
    check_fleet_oracle,
    fleet_setup,
    fusion_calls_spec,
    populate_session,
)
from repro.core import FROID, ROUTED, Session
from repro.persist import PlanCacheWarning, PlanStore, runtime_stamp
from repro.serve import AdmissionPolicy, FleetEngine
from repro.serve.scheduler import CoalescingScheduler

N_ROWS = 23


# ---------------------------------------------------------------------------
# the fleet oracle across its axes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workers", [1, 2, 3])
def test_fleet_oracle_matrix(tmp_path, workers):
    check_fleet_oracle(3, N_ROWS, workers=workers, store=str(tmp_path),
                       waves=2)


def test_fleet_oracle_no_store():
    """A store-less fleet still answers correctly (each worker compiles
    for itself — persistence is an optimization, never a requirement)."""
    stats = check_fleet_oracle(3, N_ROWS, workers=2, store=None)
    assert stats["fleet"]["persist_hits"] == 0
    assert "store" not in stats


def test_fleet_oracle_empty_table(tmp_path):
    check_fleet_oracle(4, 0, workers=2, store=str(tmp_path))


def test_fleet_warm_start_from_store(tmp_path):
    """A fresh fleet over a populated store answers its whole first drain
    from the persistent tier — no worker re-traces anything."""
    check_fleet_oracle(3, N_ROWS, workers=2, store=str(tmp_path))
    stats = check_fleet_oracle(3, N_ROWS, workers=2, store=str(tmp_path))
    assert stats["fleet"]["persist_hits"] >= 1
    assert stats["fleet"]["persist_misses"] == 0


def test_fleet_intra_cold_sharing(tmp_path):
    """Within one cold fleet, later workers warm-start from entries the
    first worker saved — compilation is a fleet-wide cost."""
    stats = check_fleet_oracle(5, N_ROWS, workers=2, store=str(tmp_path))
    per_worker = {pw["wid"]: pw["cache"] for pw in stats["workers"]}
    assert per_worker[0]["persist_misses"] >= 1  # paid the compile
    assert per_worker[1]["persist_hits"] >= 1    # rode it


def test_fleet_ddl_broadcast(tmp_path):
    """DDL landing between submit and drain (broadcast to every worker):
    the drain sees the new catalog state on every worker."""
    check_fleet_oracle(3, N_ROWS, workers=2, store=str(tmp_path), ddl=True)


def test_fleet_parallel_drain(tmp_path):
    check_fleet_oracle(3, N_ROWS, workers=3, store=str(tmp_path),
                       parallel=True, waves=2)


def test_fleet_corrupt_store_silent_recompile(tmp_path):
    """Every store entry corrupted: the fleet recompiles behind a typed
    warning and still equals the single-worker oracle — never stale plans,
    never an error surfaced to a ticket."""
    check_fleet_oracle(6, N_ROWS, workers=2, store=str(tmp_path))
    for p in glob.glob(os.path.join(str(tmp_path), "*.plan")):
        with open(p, "r+b") as f:
            f.truncate(32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PlanCacheWarning)
        stats = check_fleet_oracle(6, N_ROWS, workers=2, store=str(tmp_path))
    assert stats["fleet"]["persist_rejects"] >= 1


def test_fleet_version_stamp_mismatch_silent_recompile(tmp_path):
    """Entries written by a different jax/jaxlib (simulated via a stale
    runtime stamp): silently rejected, recompiled, oracle-equal."""
    check_fleet_oracle(6, N_ROWS, workers=2, store=str(tmp_path))
    stale = PlanStore(str(tmp_path),
                      stamp={**runtime_stamp(), "jax": "0.0.0"})
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # version skew must NOT warn
        stats = check_fleet_oracle(6, N_ROWS, workers=2, store=stale)
    assert stats["fleet"]["persist_rejects"] >= 1
    # the first worker never loads a mismatched entry (it recompiles and
    # re-saves under the store's own stamp; later workers may hit those)
    first = min(stats["workers"], key=lambda pw: pw["wid"])["cache"]
    assert first["persist_hits"] == 0 and first["persist_rejects"] >= 1


def test_fleet_injected_faults(tmp_path):
    """Faults on non-interp seams in every worker: the resilient drains
    still deliver the oracle answer on every ticket."""
    from repro.resilience import FaultSpec

    specs = [FaultSpec(site="dispatch", times=2),
             FaultSpec(site="compile", times=1)]
    check_fleet_oracle(7, N_ROWS, workers=2, store=str(tmp_path),
                       fault_specs=specs, waves=2)


# ---------------------------------------------------------------------------
# engine mechanics: intake, latency, stats, cost persistence
# ---------------------------------------------------------------------------


def test_fleet_round_robin_and_pinning(tmp_path):
    fleet = FleetEngine(fleet_setup(3, N_ROWS, FROID), workers=2,
                        store=str(tmp_path))
    for _ in range(4):
        fleet.submit("q2")
    fleet.submit("q2", worker=1)
    fleet.drain()
    sub = [w.scheduler.stats["submitted"] for w in fleet.workers]
    assert sub == [2, 3]  # round-robin 2/2, then the pinned one


def test_fleet_rejects_bad_setup(tmp_path):
    with pytest.raises(TypeError):
        FleetEngine(lambda s: None, workers=1, store=str(tmp_path))
    with pytest.raises(ValueError):
        FleetEngine(fleet_setup(3, N_ROWS, FROID), workers=0)
    fleet = FleetEngine(fleet_setup(3, N_ROWS, FROID), workers=1,
                        store=str(tmp_path))
    with pytest.raises(KeyError):
        fleet.submit("nope")


def test_ticket_latency_stamped():
    """Tickets carry submit-to-fill latency on the scheduler's own clock
    (deterministic under an injected clock)."""
    now = [0.0]
    sched = CoalescingScheduler(max_batch=256, window_s=10.0,
                                clock=lambda: now[0])
    s = Session()
    populate_session(s, 3, N_ROWS)
    s.create_function(
        build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    from conformance_util import param_query

    stmt = s.prepare(param_query(), FROID)
    t = sched.submit(stmt, {"cut": 5, "shift": 0.5})
    assert t.submitted_at == 0.0 and t.latency_s is None
    now[0] = 1.5
    sched.flush()
    t.result()
    assert t.latency_s == pytest.approx(1.5)


def test_fleet_latency_collection(tmp_path):
    fleet = FleetEngine(fleet_setup(3, N_ROWS, FROID), workers=2,
                        store=str(tmp_path))
    spec = fusion_calls_spec()
    for i, p in spec:
        fleet.submit(f"q{i}", p)
    fleet.drain()
    assert len(fleet.latencies_s) == len(spec)
    assert all(l >= 0.0 for l in fleet.latencies_s)


def test_fleet_stats_shape(tmp_path):
    fleet = FleetEngine(fleet_setup(3, N_ROWS, FROID), workers=2,
                        store=str(tmp_path))
    fleet.submit("q2")
    fleet.drain()
    stats = fleet.stats
    assert len(stats["workers"]) == 2
    for pw in stats["workers"]:
        assert {"cache", "persist", "scheduler"} <= pw.keys()
        assert pw["persist"]["enabled"]
    assert stats["store"]["entries"] >= 1
    assert stats["fleet"]["drained"] == 1


def test_fleet_cost_persistence_warm_routing(tmp_path):
    """A routed fleet saves its measured costs; a fresh fleet's workers
    route warm from the shared store (costs_loaded > 0) and still match
    the oracle."""
    fleet = FleetEngine(fleet_setup(3, N_ROWS, ROUTED), workers=2,
                        store=str(tmp_path))
    for _ in range(3):
        for i, p in fusion_calls_spec():
            fleet.submit(f"q{i}", p)
        fleet.drain()
    assert fleet.save_costs() >= 1

    check_fleet_oracle(3, N_ROWS, workers=2, store=str(tmp_path),
                       policy=ROUTED)
    fresh = FleetEngine(fleet_setup(3, N_ROWS, ROUTED), workers=2,
                        store=str(tmp_path))
    fresh.broadcast(lambda s: s._ensure_router())
    assert all(w.session.persist_stats["costs_loaded"] > 0
               for w in fresh.workers)


def test_fleet_broadcast_returns_worker_order(tmp_path):
    fleet = FleetEngine(fleet_setup(3, N_ROWS, FROID), workers=3,
                        store=str(tmp_path))
    wids = fleet.broadcast(lambda s: s)  # sessions in worker order
    assert [id(s) for s in wids] == [id(w.session) for w in fleet.workers]


# ---------------------------------------------------------------------------
# admission-path persistence (ServeEngine pass-through)
# ---------------------------------------------------------------------------


def test_admission_store_warm_start(tmp_path):
    reqs = dict(
        tier=__import__("numpy").array([0, 1, 2]),
        prompt_len=__import__("numpy").array([10, 100, 3000]),
        max_new_tokens=__import__("numpy").array([50, 2000, 500]),
        temperature=__import__("numpy").array([0.5, 3.0, 0.9],
                                              dtype="float32"),
    )
    cold = AdmissionPolicy(store=str(tmp_path))
    v_cold = cold.evaluate_coalesced(reqs)
    assert cold._request_session.persist_stats["saves"] >= 1

    warm = AdmissionPolicy(store=str(tmp_path))
    v_warm = warm.evaluate_coalesced(reqs)
    assert warm._request_session.cache_stats["persist_hits"] >= 1
    for k in v_cold:
        assert (v_cold[k] == v_warm[k]).all(), k
