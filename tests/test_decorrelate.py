"""Decorrelation pass: fixed conformance runs of the shared oracle
(``conformance_util.check_decorrelation_oracle``), rewrite-shape and
explain assertions, shared-build dedup, content-derived naming stability,
cost-model pricing, and the hypothesis layer over the same spec space
(skipped where hypothesis is absent — the fixed grid below is the
deterministic floor).

The oracle's contract: the decorrelated plan (keyed GroupAgg build +
left/semi/anti join) equals the per-row apply element-wise — masks,
validity (NULL for a binding with no matching group; COUNT coalesces to
0), and values — across FROID/INTERPRETED/HEKATON, serial and
``execute_many`` (sharded and unsharded), empty inner relations, and DDL
invalidation.  Non-rewritable bodies (non-equi correlation) keep the
per-row apply and still answer identically.
"""
from __future__ import annotations

import numpy as np
import pytest

from conformance_util import (
    DECORR_AGGS,
    DECORR_KEYSHAPES,
    DECORR_KINDS,
    _plan_has_correlated_subquery,
    check_decorrelation_oracle,
    decorr_query,
    make_session,
    populate_session,
)
from repro.core import FROID, Session
from repro.core import relalg as R

# ---------------------------------------------------------------------------
# fixed oracle grid: every kind and keyshape, the full agg set on the
# canonical shape, plus the empty-inner / missing-group / DDL axes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", DECORR_KINDS)
@pytest.mark.parametrize("keyshape", DECORR_KEYSHAPES)
def test_decorr_oracle_kinds_by_keyshapes(kind, keyshape):
    check_decorrelation_oracle(kind, keyshape, "sum", seed=3, n_rows=23)


@pytest.mark.parametrize("agg", DECORR_AGGS)
def test_decorr_oracle_all_aggs(agg):
    check_decorrelation_oracle("agg", "direct", agg, seed=5, n_rows=23)


@pytest.mark.parametrize("agg", ("sum", "count", "min"))
def test_decorr_oracle_empty_inner(agg):
    """Zero fact rows: every binding is an empty group — scalar aggs go
    NULL (COUNT goes 0), EXISTS goes false, semi joins empty out."""
    check_decorrelation_oracle("agg", "direct", agg, seed=2, n_rows=0)
    check_decorrelation_oracle("exists", "direct", agg, seed=2, n_rows=0)


def test_decorr_oracle_missing_groups_null_semantics():
    """The "expr" keyshape shifts bindings past the fact domain: those
    outer rows must see NULL (scalar) / FALSE (exists) exactly like the
    per-row apply over an empty filtered relation."""
    for agg in ("sum", "avg", "count"):
        check_decorrelation_oracle("agg", "expr", agg, seed=11, n_rows=23)
    check_decorrelation_oracle("not_exists", "expr", "sum", seed=11, n_rows=23)


def test_decorr_oracle_ddl_invalidation():
    check_decorrelation_oracle("agg", "direct", "sum", seed=7, n_rows=23,
                               ddl=True)
    check_decorrelation_oracle("semi", "multi", "sum", seed=7, n_rows=23,
                               ddl=True)


# ---------------------------------------------------------------------------
# rewrite shape: explain surfacing, shared-build dedup, stable naming
# ---------------------------------------------------------------------------


def test_explain_shows_decorrelated_shape():
    db = make_session(3, 23)
    stmt = db.prepare(decorr_query("agg", "direct", "sum"), FROID)
    txt = stmt.explain()
    assert "GroupAgg keys=" in txt and "Join[left]" in txt, txt
    assert not _plan_has_correlated_subquery(stmt.plan)
    # the non-rewritable shape keeps (and shows) the per-row apply
    stmt2 = db.prepare(decorr_query("agg", "nonequi", "sum"), FROID)
    assert "Join[left]" not in stmt2.explain()
    assert _plan_has_correlated_subquery(stmt2.plan)


def test_semi_anti_join_shapes():
    db = make_session(3, 23)
    kinds = {
        "semi": "Join[semi]",
        "anti": "Join[anti]",
    }
    for kind, marker in kinds.items():
        txt = db.prepare(decorr_query(kind, "direct", "sum"), FROID).explain()
        assert marker in txt, f"{kind}:\n{txt}"


def test_shared_build_dedup():
    """Three subqueries over the same correlated body collapse into ONE
    keyed GroupAgg build and ONE join — the shared-scan materialization
    half of the pass."""
    from repro.core.frontend import col, lit, scan, scalar_subquery, sum_
    from repro.core import scalar as S

    db = make_session(3, 23)

    def body():
        return (scan("facts").filter(col("fk") == S.Outer("k"))
                .agg(s=sum_(col("val"))))

    q = (scan("keys")
         .compute(a=scalar_subquery(body(), "s"),
                  b=scalar_subquery(body(), "s") * lit(2.0),
                  c=scalar_subquery(body(), "s") + lit(1.0))
         .project("k", "a", "b", "c"))
    stmt = db.prepare(q, FROID)
    assert not _plan_has_correlated_subquery(stmt.plan)
    joins = [n for n in R.walk_plan(stmt.plan) if isinstance(n, R.Join)]
    builds = [n for n in R.walk_plan(stmt.plan)
              if isinstance(n, R.GroupAgg) and n.keys]
    assert len(joins) == 1, stmt.explain()
    assert len(builds) == 1, stmt.explain()


def test_decorrelated_naming_is_content_derived():
    """Two independently-built sessions produce fingerprint-identical
    decorrelated plans: the rewrite's generated column names come from
    content digests, never from process-local counters — the property
    every cache tier (and the persistent store) keys on."""
    from repro.core.fingerprint import plan_fingerprint

    fps = []
    for _ in range(2):
        db = make_session(3, 23)
        stmt = db.prepare(decorr_query("agg", "multi", "sum"), FROID)
        fps.append(plan_fingerprint(stmt.plan))
    assert fps[0] == fps[1]


# ---------------------------------------------------------------------------
# cost model: decorrelated priced by distinct-binding cardinality, per-row
# priced by outer cardinality — the ratio the router consumes
# ---------------------------------------------------------------------------


def test_cost_model_prefers_decorrelated_at_scale():
    from repro.core import optimizer as O
    from repro.cost.model import estimate_plan

    db = Session()
    rng = np.random.default_rng(0)
    n = 1024
    db.create_table("facts",
                    fk=rng.integers(0, 7, n),
                    val=rng.normal(size=n).astype(np.float32),
                    qty=rng.integers(0, 9, n))
    db.create_table("keys", k=np.arange(1024) % 7)
    node = decorr_query("agg", "direct", "sum").node
    wanted = set(R.output_columns(node, db.catalog))
    dec = O.optimize(node, db.catalog, required=wanted)
    rules = tuple(r for r in O.DEFAULT_RULES
                  if r not in (O.decorrelate_in_computes,
                               O.decorrelate_filters))
    perrow = O.optimize(node, db.catalog, required=wanted, rules=rules)
    assert _plan_has_correlated_subquery(perrow)
    assert not _plan_has_correlated_subquery(dec)
    e_dec = estimate_plan(dec, db.catalog)
    e_row = estimate_plan(perrow, db.catalog)
    # per-row re-runs the body once per outer row; the decorrelated build
    # runs it once — at N=1024 outer rows the work profiles must separate
    # by a wide, algorithmic margin.  (seconds() adds the same fixed
    # dispatch overhead to both, so the roofline terms carry the signal
    # the router's comparison consumes.)
    assert e_row.flops > 50 * e_dec.flops, (
        f"per-row {e_row.flops:.3e} flops vs decorrelated "
        f"{e_dec.flops:.3e}")
    assert e_row.bytes > 50 * e_dec.bytes, (
        f"per-row {e_row.bytes:.3e} bytes vs decorrelated "
        f"{e_dec.bytes:.3e}")


# ---------------------------------------------------------------------------
# hypothesis layer: the same oracle over the generated spec space
# ---------------------------------------------------------------------------

try:  # no pip install in this environment: skip where absent
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    decorr_specs = st.tuples(
        st.sampled_from(DECORR_KINDS),
        st.sampled_from(DECORR_KEYSHAPES),
        st.sampled_from(DECORR_AGGS),
        st.integers(min_value=0, max_value=2**16),
        st.sampled_from((0, 1, 23, 64)),
    )

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(decorr_specs,
           st.lists(st.integers(min_value=0, max_value=10), min_size=1,
                    max_size=4))
    def test_decorr_oracle_generative(spec, minqs):
        kind, keyshape, agg, seed, n_rows = spec
        check_decorrelation_oracle(
            kind, keyshape, agg, seed=seed, n_rows=n_rows,
            params_list=[{"minq": m} for m in minqs])

else:  # deterministic stand-in so the axis is never silently dark

    def test_decorr_oracle_generative_fallback():
        for spec in [("agg", "expr", "avg", 17, 1),
                     ("anti", "multi", "count", 23, 64),
                     ("exists", "nonequi", "max", 29, 23)]:
            kind, keyshape, agg, seed, n_rows = spec
            check_decorrelation_oracle(kind, keyshape, agg,
                                       seed=seed, n_rows=n_rows)
