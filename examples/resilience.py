"""Resilience walkthrough: fault injection → degradation ladder →
circuit breaker → recovery, all observable through stats.

    PYTHONPATH=src python examples/resilience.py

The PR-7 resilience layer in four acts:

  1. Fault-free baseline: a mixed-statement wave drains fused (tier
     "fused") through the CoalescingScheduler with the ladder on — zero
     overhead paths, tier counters show where the work ran.
  2. Inject a deterministic dispatch fault: the fused wave demotes to
     per-statement ``execute_many``; a persistent fault walks the full
     ladder fused → many → serial → INTERPRETED per-row, and the ticket
     still gets the right answer (the interpreter is the floor).
  3. Keep failing one statement until its circuit breaker opens:
     subsequent waves skip the broken tier for that statement without
     paying the failure; after the cooldown a half-open probe runs and,
     once the fault clears, restores the breaker to closed.
  4. Deadlines: tickets carry a deadline from ``timeout_s``; expired
     tickets shed with a typed ``DeadlineExceeded`` *before* any device
     work happens, never a hang.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import FROID, Session, col, param, scan
from repro.resilience import (
    BreakerConfig,
    DeadlineExceeded,
    FaultInjector,
    FaultSpec,
    ResilienceConfig,
    RetryPolicy,
)
from repro.serve.scheduler import CoalescingScheduler


def fresh(n=64):
    db = Session()
    db.create_table("T", x=np.arange(n, dtype=np.int64))
    s1 = db.prepare(
        scan("T").filter(col("x") < param("cutoff")).project("x"), FROID)
    s2 = db.prepare(
        scan("T").compute(y=col("x") * param("m")).project("x", "y"), FROID)
    return db, s1, s2


def drain(sched, s1, s2, k=4):
    tickets = [sched.submit(s1, {"cutoff": 10 + i}) for i in range(k)]
    tickets += [sched.submit(s2, {"m": 2 + i}) for i in range(k)]
    sched.flush()
    return tickets


def tiers(sched):
    snap = sched.resilience_stats["counters"]
    return {k: v for k, v in sorted(snap.items()) if v}


# ---------------------------------------------------------------- act 1
print("== act 1: fault-free fused drain ==")
db, s1, s2 = fresh()
sched = CoalescingScheduler(max_batch=64, window_s=1e9, fuse=True)
for t in drain(sched, s1, s2):
    assert t.done() and t.result() is not None
print(f"  active counters: {tiers(sched)}")
# tier_fused_ok only — the ladder's fast path IS the legacy fast path.

# ---------------------------------------------------------------- act 2
print("== act 2: injected faults walk the ladder ==")
db, s1, s2 = fresh()
fi = FaultInjector([FaultSpec(site="dispatch", times=1)])
fi.install(db)
sched = CoalescingScheduler(max_batch=64, window_s=1e9, fuse=True)
for t in drain(sched, s1, s2):
    assert np.asarray(t.result().table.columns["x"].data) is not None
print(f"  one dispatch fault: {tiers(sched)}")

db, s1, s2 = fresh()
fi = FaultInjector([FaultSpec(site="*", stmt=s1._query_fp, times=3)])
fi.install(db)
sched = CoalescingScheduler(max_batch=64, window_s=1e9, fuse=True)
tickets = drain(sched, s1, s2)
rows = np.asarray(tickets[0].result().table.columns["x"].data)
print(f"  persistent fault on stmt1 -> interpreter floor, "
      f"rows still correct: {rows[:5]}...")
print(f"  counters: {tiers(sched)}")

# ---------------------------------------------------------------- act 3
print("== act 3: circuit breaker opens, probes, restores ==")
db, s1, s2 = fresh()
fi = FaultInjector(
    [FaultSpec(site="dispatch", stmt=s2._query_fp, times=None)])
fi.install(db)
clock = [0.0]
cfg = ResilienceConfig(
    retry=RetryPolicy(max_attempts=1),
    breaker=BreakerConfig(failure_threshold=2, window_s=30.0, cooldown_s=5.0),
)
sched = CoalescingScheduler(max_batch=64, window_s=1e9, fuse=False,
                            resilience=cfg, clock=lambda: clock[0])
for wave in range(3):  # 2 failures open it; wave 3 skips the tier
    for t in drain(sched, s1, s2, k=2):
        t.result()
board = sched.resilience_stats["breakers"]
key = next(k for k, b in board.items() if b["state"] == "open")
print(f"  breaker (fp#{hash(key[0]) & 0xffff:04x}, {key[1]}) -> "
      f"{board[key]['state']} (opened={board[key]['opened']})")

fi.specs.clear()          # the outage ends
clock[0] += 10.0          # cooldown elapses -> next wave is the probe
for t in drain(sched, s1, s2, k=2):
    t.result()
b = sched.resilience_stats["breakers"][key]
print(f"  after cooldown probe: state={b['state']} "
      f"(probes={b['probes']}, restored={b['restored']})")

# ---------------------------------------------------------------- act 4
print("== act 4: ticket deadlines shed, typed ==")
db, s1, s2 = fresh()
clock = [0.0]
sched = CoalescingScheduler(max_batch=64, window_s=1e9, fuse=True,
                            default_timeout_s=0.5, clock=lambda: clock[0])
tk = [sched.submit(s1, {"cutoff": 5}), sched.submit(s2, {"m": 3})]
clock[0] += 1.0           # both tickets expire before the drain
sched.flush()
for t in tk:
    assert t.done()
    try:
        t.result()
    except DeadlineExceeded as e:
        print(f"  ticket shed: {e}")
print(f"  deadline_shed={sched.stats['deadline_shed']}, injector idle "
      f"(no device work was attempted for expired tickets)")
