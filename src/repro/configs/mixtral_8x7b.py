"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000; 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]"""
import dataclasses

from repro.models.config import ArchConfig, LayerSpec, MoEConfig

WINDOW = 4096


def config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b",
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=32000,
        head_dim=128,
        super_block=(LayerSpec(mixer="attn", mlp="moe", window=WINDOW),),
        n_repeats=32,
        moe=MoEConfig(n_experts=8, top_k=2),
        subquadratic=True,  # SWA: decode cost is O(window) per token
        max_seq_len=1_048_576,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        config(), d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
        head_dim=16, n_repeats=2,
        super_block=(LayerSpec(mixer="attn", mlp="moe", window=16),),
        moe=MoEConfig(n_experts=4, top_k=2),
        max_seq_len=128,
    )
