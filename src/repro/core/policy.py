"""Execution policies: the paper's experiment axes as one value object.

The engine historically exposed its modes as a soup of boolean kwargs
(``froid=…, mode=…, optimize=…, jit_statements=…, pallas_agg=…``) spread
over ``Database.run`` / ``Database.run_compiled``.  ``ExecutionPolicy``
packages one point of that space; the named presets are the paper's
Table 5 quadrants:

* ``FROID``       — bind-time UDF inlining + rewrite rules + set-oriented
  plan, whole-plan compilation (the paper's contribution).
* ``INTERPRETED`` — iterative per-tuple UDF interpretation, statement at a
  time with per-statement plan caching (classic T-SQL, §2.2).  The host
  drives control flow, so plans execute eagerly (no whole-plan jit).
* ``HEKATON``     — natively-compiled-but-still-iterative UDFs (§8.2.7):
  the UDF body traces to one compiled function driven per row inside the
  compiled plan.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """One point in the engine's execution-mode space.

    ``name`` is a display label only — two policies with the same knobs
    compare (and cache) equal regardless of name.
    """

    name: str = dataclasses.field(default="custom", compare=False)
    #: bind-time UDF inlining (the paper's Froid pass)
    inline_udfs: bool = True
    #: iterative evaluation mode for non-inlined UDFs: "python" (statement
    #: at a time, host control flow) | "scan" (whole-body native trace)
    udf_mode: str = "python"
    #: run the rewrite-rule optimizer over the bound plan
    optimize: bool = True
    #: cache + jit per-statement plans inside the "python" interpreter
    jit_statements: bool = True
    #: fused Pallas relagg kernel for eligible group-bys (batch mode)
    pallas_agg: bool = False
    #: compile the whole plan to one jitted callable (prepared-statement
    #: hot path); False = eager op-by-op execution
    compile_plan: bool = True

    # -- batch-execution knobs (tuning, not identity: two policies that
    # differ only here compare equal and share plan/executable caches; the
    # knobs shape how `execute_many` buckets work and how the serving
    # scheduler coalesces, not what the compiled plan computes) -------------
    #: largest single device batch `execute_many` will dispatch; larger
    #: request lists split into chunks of at most this size
    max_batch: int = dataclasses.field(default=1024, compare=False)
    #: how long the coalescing scheduler holds a partial microbatch open
    #: waiting for more same-statement arrivals (seconds)
    coalesce_window_s: float = dataclasses.field(default=0.002, compare=False)
    #: whether `execute_async` may defer device sync to result access;
    #: False degrades it to eager synchronous execution (still correct)
    allow_async: bool = dataclasses.field(default=True, compare=False)
    #: bound on dispatched-but-unsynced `execute_async` calls per session;
    #: at the bound a new dispatch first blocks on the oldest in-flight one
    #: (backpressure — a runaway producer cannot queue unbounded device work)
    max_inflight: int = dataclasses.field(default=64, compare=False)

    # -- mesh-sharding knobs (tuning like the batch knobs: never part of
    # plan/executable identity — the sharded-executable cache tier keys on
    # shard_token() separately, so policies that differ only here still
    # share plans and the single-device executables) -----------------------
    #: device mesh sharded `execute_many` places batches on (None = the
    #: single default device; axes named per repro.dist.sharding)
    mesh: object = dataclasses.field(default=None, compare=False, repr=False)
    #: shard the stacked parameter axis of `execute_many` buckets over the
    #: mesh's data axes; divisibility-gated per bucket — buckets the data
    #: axes don't divide run on the replicated single-device path
    shard_batches: bool = dataclasses.field(default=False, compare=False)

    # -- multi-statement fusion knobs (tuning like the batch/shard knobs:
    # never part of plan/executable identity — the fused-executable cache
    # tier keys on the member set separately, so policies that differ only
    # here still share plans and per-statement executables) ----------------
    #: allow this statement to be coalesced with *other* statements into one
    #: fused device program (shared scans, tagged outputs); False always
    #: takes the per-statement path
    fuse: bool = dataclasses.field(default=True, compare=False)
    #: most distinct statements one fused program may carry; larger mixed
    #: queues split into multiple fused programs (singleton remainders fall
    #: back to the per-statement path)
    max_fused_statements: int = dataclasses.field(default=8, compare=False)

    # -- cost-routing knob (tuning like the rest: never part of plan or
    # executable identity — the router may *re-prepare* a statement under a
    # differently-fingerprinted policy, but a routed and an unrouted FROID
    # statement share every cache tier) ------------------------------------
    #: let the session's CostRouter steer this statement: FROID/HEKATON
    #: choice per statement, batch-bucket riding, fuse-or-not per drain
    #: wave.  Decisions are visible in ``Session.cost_stats``; results are
    #: guaranteed unchanged (``check_routing_oracle``)
    route: bool = dataclasses.field(default=False, compare=False)

    # -- persistence knob (tuning like the rest: never part of plan or
    # executable identity — the persistent tier keys on the same identity
    # tuples the in-memory tiers use, so opting out only skips the store
    # round-trip, never changes what executes) -----------------------------
    #: let this statement use the session's persistent plan store (when one
    #: is attached): executables load from / save to disk across processes.
    #: False pins the statement to in-process caches only
    persist: bool = dataclasses.field(default=True, compare=False)

    def __post_init__(self):
        if self.udf_mode not in ("python", "scan"):
            raise ValueError(f"udf_mode must be python|scan, got {self.udf_mode!r}")
        if self.compile_plan and not self.inline_udfs and self.udf_mode == "python":
            raise ValueError(
                "python-mode UDF interpretation drives control flow on the "
                "host and cannot live inside a compiled plan; use "
                "udf_mode='scan' or compile_plan=False"
            )

    def fingerprint(self) -> tuple:
        """Hashable identity for plan/executable cache keys (name excluded).

        Cached on the (frozen) instance: the router compares fingerprints
        on every routed call, and rebuilding the tuple each time showed up
        in the cache-resident overhead budget."""
        fp = self.__dict__.get("_fp")
        if fp is None:
            fp = (
                self.inline_udfs, self.udf_mode, self.optimize,
                self.jit_statements, self.pallas_agg, self.compile_plan,
            )
            object.__setattr__(self, "_fp", fp)
        return fp

    def eager(self) -> "ExecutionPolicy":
        """The same policy with whole-plan compilation off."""
        if not self.compile_plan:
            return self
        return dataclasses.replace(self, name=self.name, compile_plan=False)

    def batched(self, max_batch: int | None = None,
                coalesce_window_s: float | None = None,
                allow_async: bool | None = None,
                max_inflight: int | None = None) -> "ExecutionPolicy":
        """The same policy with different batch-execution knobs."""
        return dataclasses.replace(
            self,
            name=self.name,
            max_batch=self.max_batch if max_batch is None else max_batch,
            coalesce_window_s=(self.coalesce_window_s
                               if coalesce_window_s is None
                               else coalesce_window_s),
            allow_async=self.allow_async if allow_async is None else allow_async,
            max_inflight=(self.max_inflight if max_inflight is None
                          else max_inflight),
        )

    def sharded(self, mesh, shard_batches: bool = True) -> "ExecutionPolicy":
        """The same policy placing `execute_many` batches on ``mesh``."""
        return dataclasses.replace(
            self, name=self.name, mesh=mesh, shard_batches=shard_batches,
        )

    def fused(self, fuse: bool | None = None,
              max_fused_statements: int | None = None) -> "ExecutionPolicy":
        """The same policy with different multi-statement fusion knobs."""
        return dataclasses.replace(
            self,
            name=self.name,
            fuse=self.fuse if fuse is None else fuse,
            max_fused_statements=(self.max_fused_statements
                                  if max_fused_statements is None
                                  else max_fused_statements),
        )

    def routed(self, route: bool = True) -> "ExecutionPolicy":
        """The same policy with cost-based routing toggled."""
        if route == self.route:
            return self
        return dataclasses.replace(self, name=self.name, route=route)

    def persisted(self, persist: bool = True) -> "ExecutionPolicy":
        """The same policy with the persistent plan tier toggled."""
        if persist == self.persist:
            return self
        return dataclasses.replace(self, name=self.name, persist=persist)

    def shard_devices(self) -> int:
        """Data-parallel shard count batched execution may spread over:
        the mesh's data-axis product when sharding is on, else 1."""
        if not (self.shard_batches and self.mesh is not None
                and self.compile_plan):
            return 1
        from repro.dist.sharding import data_axis_size

        return data_axis_size(self.mesh)

    def shard_token(self) -> tuple:
        """Hashable identity of the sharding placement for the sharded-
        executable cache tier: the mesh's axis layout plus the concrete
        device assignment (a rebuilt mesh over the same devices hits; a
        different device set or shape re-specializes)."""
        if self.shard_devices() <= 1:
            return ()
        tok = self.__dict__.get("_shard_tok")
        if tok is None:
            mesh = self.mesh
            axes = tuple((str(a), int(s)) for a, s in mesh.shape.items())
            devices = tuple(int(d.id) for d in mesh.devices.flat)
            tok = (axes, devices)
            object.__setattr__(self, "_shard_tok", tok)
        return tok

    @classmethod
    def from_kwargs(
        cls,
        froid: bool = True,
        mode: str = "python",
        optimize: bool = True,
        jit_statements: bool = True,
        pallas_agg: bool = False,
        compiled: bool = False,
    ) -> "ExecutionPolicy":
        """Map the legacy ``Database.run``/``run_compiled`` kwargs onto a
        policy (the deprecation path for the boolean-kwarg API)."""
        return cls(
            name="legacy",
            inline_udfs=froid,
            udf_mode=mode,
            optimize=optimize,
            jit_statements=jit_statements,
            pallas_agg=pallas_agg,
            compile_plan=compiled,
        )


#: paper Table 5 presets
FROID = ExecutionPolicy(name="froid")
INTERPRETED = ExecutionPolicy(
    name="interpreted", inline_udfs=False, udf_mode="python", compile_plan=False,
    # eager host-driven control flow: no device program to batch or overlap,
    # so execute_many degrades to a serial loop and async to sync
    max_batch=64, allow_async=False,
)
HEKATON = ExecutionPolicy(name="hekaton", inline_udfs=False, udf_mode="scan")
#: FROID knobs + cost-based routing: the session's CostRouter may move the
#: statement to a cheaper configuration (measured + estimated costs) without
#: changing results
ROUTED = dataclasses.replace(FROID, name="routed", route=True)

PRESETS = {p.name: p for p in (FROID, INTERPRETED, HEKATON, ROUTED)}


def resolve_policy(policy) -> ExecutionPolicy:
    """Accept an ExecutionPolicy or a preset name."""
    if isinstance(policy, ExecutionPolicy):
        return policy
    if isinstance(policy, str):
        try:
            return PRESETS[policy.lower()]
        except KeyError:
            raise KeyError(
                f"unknown policy preset {policy!r}; have {sorted(PRESETS)}"
            ) from None
    raise TypeError(f"policy must be ExecutionPolicy or str, got {type(policy)}")
