"""Activation batch-sharding constraints.

``shard_batch`` pins the batch axis of an activation to the ``data`` (and
``pod``) mesh axes via ``with_sharding_constraint`` — called at the
super-block boundaries so XLA keeps activations data-parallel through the
whole stack instead of re-deciding per op.

The mesh is process-global context (set by launchers around lower/compile,
cleared after): model code stays mesh-agnostic, and on single-device test
runs — no mesh set — ``shard_batch`` is the identity.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec

_ACTIVATION_MESH = None


def set_activation_mesh(mesh) -> None:
    """Install ``mesh`` as the activation-sharding context."""
    global _ACTIVATION_MESH
    _ACTIVATION_MESH = mesh


def clear_activation_mesh() -> None:
    global _ACTIVATION_MESH
    _ACTIVATION_MESH = None


def current_activation_mesh():
    return _ACTIVATION_MESH


def shard_batch(x):
    """Constrain dim 0 of ``x`` to the data(+pod) mesh axes; the trailing
    feature dim stays on ``model`` when it divides (matching the TP weight
    layout, so embedding gathers/projections don't force a reshard).
    Identity when no mesh is installed or the batch doesn't divide."""
    from repro.dist.sharding import pick_data_axes

    mesh = _ACTIVATION_MESH
    if mesh is None or getattr(x, "ndim", 0) < 1:
        return x
    entry = pick_data_axes(mesh, x.shape[0])
    if entry is None:
        return x
    entries = [entry] + [None] * (x.ndim - 1)
    model = mesh.shape.get("model", 1)
    # rank >= 3 only: (B, S, D) activations carry a feature dim; rank-2
    # arrays here are token/label ids whose trailing dim is sequence
    if x.ndim >= 3 and model > 1 and x.shape[-1] % model == 0:
        entries[-1] = "model"
    spec = PartitionSpec(*entries)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
