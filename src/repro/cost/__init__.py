"""Cost-based routing (Cobra-style): a static cardinality/roofline cost
model plus an online router that records measured wave costs and steers
each prepared statement and each drain wave through the cheapest
configuration — FROID/HEKATON choice, fuse-or-not, fusion-group chunking,
batch bucket.  The conformance harness (``check_routing_oracle``)
guarantees routing never changes results, only which path computes them.
"""
from repro.cost.model import (
    COMPILE_S_PER_NODE,
    DISPATCH_OVERHEAD_S,
    PlanProfile,
    estimate_compile_s,
    estimate_node_s,
    estimate_plan,
    estimate_statement_s,
)
from repro.cost.router import CostRouter

__all__ = [
    "COMPILE_S_PER_NODE",
    "DISPATCH_OVERHEAD_S",
    "CostRouter",
    "PlanProfile",
    "estimate_compile_s",
    "estimate_node_s",
    "estimate_plan",
    "estimate_statement_s",
]
