"""End-to-end behaviour tests for the Froid core (the paper's system).

Each test checks that the froid (algebrized + optimized + set-oriented)
result equals the iterative interpreter result, and where the paper makes a
structural claim (inferred joins, dead-code elimination, constant folding /
dynamic slicing) asserts on the plan shape too.
"""
import numpy as np
import pytest

from repro.core import (
    Database,
    InlineConstraints,
    UdfBuilder,
    case,
    col,
    count_,
    exists,
    lit,
    param,
    scalar_subquery,
    scan,
    sum_,
    udf,
    var,
)
from repro.core import optimizer as O
from repro.core import relalg as R
from repro.core import scalar as S


def _mkdb(rng, n_cust=50, n_ord=300):
    db = Database()
    db.create_table("customer", c_custkey=np.arange(n_cust))
    db.create_table(
        "orders",
        o_custkey=rng.integers(0, n_cust, n_ord),
        o_totalprice=rng.uniform(10, 1000, n_ord).astype(np.float32),
        o_qty=rng.integers(1, 50, n_ord),
    )
    db.create_table(
        "customer_prefs",
        custkey=np.arange(n_cust),
        currency=np.array(["USD" if i % 3 else "EUR" for i in range(n_cust)]),
    )
    db.create_table(
        "xchg",
        from_cur=np.array(["USD"]),
        to_cur=np.array(["EUR"]),
        rate=np.array([0.9], dtype=np.float32),
    )
    return db


def _totals(db):
    u = UdfBuilder("total_price", [("key", "int32")], "float32")
    u.declare("price", "float32")
    u.declare("pref_currency", "str")
    u.declare("default_currency", "str", lit("USD"))
    u.select({"price": sum_(col("o_totalprice"))}, frm=scan("orders"),
             where=col("o_custkey") == param("key"))
    u.select({"pref_currency": col("currency")}, frm=scan("customer_prefs"),
             where=col("custkey") == param("key"))
    with u.if_(var("pref_currency") != var("default_currency")):
        u.set("price", var("price") * 0.9)
    u.return_(var("price"))
    return db.create_function(u.build())


def _compare(db, q, rtol=1e-4, modes=("python", "scan")):
    r_on = db.run(q, froid=True)
    outs = {}
    for m in modes:
        r_off = db.run(q, froid=False, mode=m)
        for name in r_on.table.names():
            a, av = (
                np.asarray(r_on.table.columns[name].data),
                np.asarray(r_on.table.columns[name].validity()),
            )
            b, bv = (
                np.asarray(r_off.table.columns[name].data),
                np.asarray(r_off.table.columns[name].validity()),
            )
            assert (av == bv).all(), f"{m}:{name}: validity mismatch"
            both = av & bv
            np.testing.assert_allclose(
                a[both].astype(np.float64),
                b[both].astype(np.float64),
                rtol=rtol,
                err_msg=f"{m}:{name}",
            )
        outs[m] = r_off
    return r_on, outs


# ---------------------------------------------------------------------------


def test_paper_figure1_total_price(rng):
    db = _mkdb(rng)
    _totals(db)
    q = scan("customer").compute(total=udf("total_price", col("c_custkey")))
    r_on, _ = _compare(db, q)
    # structural claim (Figure 5): plan contains inferred Join + GroupAgg,
    # and no Apply / correlated subquery remains
    kinds = {type(n).__name__ for n in R.walk_plan(r_on.plan)}
    assert "Join" in kinds and "GroupAgg" in kinds
    assert "Apply" not in kinds


def test_nested_udf_inlined(rng):
    db = _mkdb(rng)
    u = UdfBuilder("xchg_rate", [("frm", "str"), ("to", "str")], "float32")
    u.return_(
        scalar_subquery(
            scan("xchg")
            .filter((col("from_cur") == param("frm")) & (col("to_cur") == param("to")))
            .compute(r=col("rate"))
            .project("r"),
            "r",
        )
    )
    db.create_function(u.build())
    u = UdfBuilder("conv", [("amount", "float32"), ("cur", "str")], "float32")
    with u.if_(var("cur") != lit("USD")):
        u.return_(var("amount") * udf("xchg_rate", lit("USD"), var("cur")))
    u.return_(var("amount"))
    db.create_function(u.build())

    q = scan("customer_prefs").compute(
        v=udf("conv", col("custkey") * 1.5, col("currency"))
    )
    _compare(db, q)


def test_multiple_returns_first_wins(rng):
    db = _mkdb(rng)
    u = UdfBuilder("bracket", [("x", "float32")], "float32")
    with u.if_(param("x") > 100):
        u.return_(lit(100.0))
    with u.if_(param("x") > 10):
        u.return_(param("x") * 2.0)
    u.return_(param("x"))
    db.create_function(u.build())
    q = scan("orders").compute(b=udf("bracket", col("o_totalprice")))
    _compare(db, q)


def test_unconditional_return_drops_dead_tail(rng):
    db = _mkdb(rng)
    u = UdfBuilder("f", [("x", "float32")], "float32")
    u.return_(param("x") + 1.0)
    u.set("never", lit(123.0))  # unreachable
    udf_def = db.create_function(u.build())
    # region construction must drop the unreachable statement
    regions = udf_def.regions()
    assert len(regions) == 1
    assert len(regions[0].statements) == 1


def test_dead_code_eliminated_from_plan(rng):
    """The paper's §6.3 example: an assignment from a subquery that is never
    used must not appear in the final plan (projection pushdown)."""
    db = _mkdb(rng)
    u = UdfBuilder("total2", [("key", "int32")], "float32")
    u.declare("t", "float32")
    u.select({"t": count_()}, frm=scan("orders"),
             where=col("o_custkey") == param("key"))  # dead
    u.return_(param("key") * 2.0)
    db.create_function(u.build())
    q = scan("customer").compute(v=udf("total2", col("c_custkey")))
    r_on, _ = _compare(db, q)
    # the orders subquery must be gone
    scans = [n.table for n in R.walk_plan(r_on.plan) if isinstance(n, R.Scan)]
    assert "orders" not in scans, O.explain(r_on.plan)


def test_constant_folding_dynamic_slicing(rng):
    """Figure 6: getVal(5000) folds to a constant at plan time."""
    db = _mkdb(rng)
    u = UdfBuilder("getVal", [("x", "int32")], "float32")
    u.declare("val", "float32")
    with u.if_(param("x") > 1000):
        u.set("val", lit(10.0))
    with u.else_():
        u.set("val", lit(1.0))
    u.return_(var("val") + 5.0)
    db.create_function(u.build())
    q = scan("customer").compute(v=udf("getVal", lit(5000)))
    plan = db.plan_for(q)
    # after folding, the computed column must be the constant 15.0
    comp = [n for n in R.walk_plan(plan) if isinstance(n, R.Compute)]
    assert comp, O.explain(plan)
    exprs = [e for c in comp for e in c.computed.values()]
    consts = [e.value for e in exprs if isinstance(e, S.Const)]
    assert any(abs(v - 15.0) < 1e-6 for v in consts if v is not None), O.explain(plan)
    _compare(db, q)


def test_exists_predicate(rng):
    db = _mkdb(rng)
    u = UdfBuilder("has_orders", [("key", "int32")], "bool")
    with u.if_(exists(scan("orders").filter(col("o_custkey") == param("key")))):
        u.return_(lit(True))
    u.return_(lit(False))
    db.create_function(u.build())
    q = scan("customer").compute(h=udf("has_orders", col("c_custkey")))
    _compare(db, q)


def test_nondeterministic_udf_not_inlined(rng):
    db = _mkdb(rng)
    u = UdfBuilder("noisy", [("x", "float32")], "float32")
    u.return_(param("x") + S.Func("rand", [lit(1)]))
    db.create_function(u.build())
    from repro.core.binder import Binder

    binder = Binder(db.registry)
    assert binder.algebrized("noisy") is None


def test_size_constraint_leaves_udf_iterative(rng):
    db = _mkdb(np.random.default_rng(7))
    db.constraints = InlineConstraints(max_plan_size=5)  # absurdly small
    _totals(db)
    q = scan("customer").compute(total=udf("total_price", col("c_custkey")))
    plan = db.plan_for(q)
    calls = [
        e
        for n in R.walk_plan(plan)
        if isinstance(n, R.Compute)
        for ex in n.computed.values()
        for e in S.walk(ex)
        if isinstance(e, S.UdfCall)
    ]
    assert calls, "UDF call should remain when the size budget is exhausted"
    # hybrid execution still gives correct results via the interpreter hook
    r = db.run(q, froid=True)
    db2 = _mkdb(np.random.default_rng(7))
    _totals(db2)
    r2 = db2.run(q, froid=True)
    a = np.asarray(r.table.columns["total"].data)
    b = np.asarray(r2.table.columns["total"].data)
    va = np.asarray(r.table.columns["total"].validity())
    vb = np.asarray(r2.table.columns["total"].validity())
    assert (va == vb).all()
    np.testing.assert_allclose(a[va], b[vb], rtol=1e-4)


def test_recursive_udf_handled(rng):
    db = _mkdb(rng)
    u = UdfBuilder("countdown", [("x", "float32")], "float32")
    with u.if_(param("x") <= 0):
        u.return_(lit(0.0))
    u.return_(udf("countdown", param("x") - 1.0) + 1.0)
    db.create_function(u.build())
    q = scan("customer").filter(col("c_custkey") < 5).compute(
        d=udf("countdown", col("c_custkey") * 1.0)
    )
    r = db.run(q, froid=True)  # inlines up to depth, interpreter finishes
    d = np.asarray(r.table.columns["d"].data)
    np.testing.assert_allclose(d, np.arange(5, dtype=np.float32))


def test_udf_in_where_clause(rng):
    db = _mkdb(rng)
    u = UdfBuilder("is_big", [("p", "float32")], "bool")
    with u.if_(param("p") > 500.0):
        u.return_(lit(True))
    u.return_(lit(False))
    db.create_function(u.build())
    q = scan("orders").filter(udf("is_big", col("o_totalprice")) == lit(True))
    r_on = db.run(q, froid=True)
    r_off = db.run(q, froid=False, mode="python")
    assert r_on.table.num_rows == r_off.table.num_rows
    tp = np.asarray(db.catalog["orders"].columns["o_totalprice"].data)
    assert r_on.table.num_rows == int((tp > 500.0).sum())


def test_udf_inside_aggregate(rng):
    db = _mkdb(rng)
    u = UdfBuilder("disc", [("p", "float32"), ("d", "float32")], "float32")
    u.return_(param("p") * (1.0 - param("d")))
    db.create_function(u.build())
    q = scan("orders").group_by(
        "o_custkey", rev=sum_(udf("disc", col("o_totalprice"), lit(0.1)))
    )
    r_on = db.run(q, froid=True)
    tp = np.asarray(db.catalog["orders"].columns["o_totalprice"].data)
    ck = np.asarray(db.catalog["orders"].columns["o_custkey"].data)
    exp = {k: tp[ck == k].sum() * 0.9 for k in np.unique(ck)}
    got_k = np.asarray(r_on.table.columns["o_custkey"].data)
    got_v = np.asarray(r_on.table.columns["rev"].data)
    for k, v in zip(got_k, got_v):
        np.testing.assert_allclose(v, exp[k], rtol=1e-4)
