"""Assigned-architecture registry: one module per arch, each exposing
``config()`` (the exact published configuration) and ``smoke_config()``
(a reduced same-family config for CPU smoke tests).

Select with ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "mamba2_370m",
    "llama32_vision_90b",
    "jamba15_large_398b",
    "granite3_2b",
    "minicpm3_4b",
    "phi3_mini_38b",
    "gemma3_12b",
    "mixtral_8x7b",
    "granite_moe_3b_a800m",
    "seamless_m4t_large_v2",
]

# public ids (hyphenated) -> module names
ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_arch(name: str):
    """Return the config module for an arch id (accepts - or _ forms)."""
    mod_name = ALIASES.get(name, name).replace("-", "_")
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{mod_name}")


def config_for(name: str):
    return get_arch(name).config()


def smoke_config_for(name: str):
    return get_arch(name).smoke_config()


def all_configs():
    return {a: config_for(a) for a in ARCH_IDS}
