"""Training data pipeline with Froid-compiled per-example transforms.

The per-example logic (quality filtering, label masking, curriculum
weighting) is authored imperatively as UDFs and compiled by the Froid core
into one set-oriented plan per batch — the paper's technique applied to the
framework's own input path (DESIGN.md §4.1).

Determinism & sharding: example i of step s is a pure function of
(seed, s, i); each data-parallel host reads only its slice
[host*per_host, (host+1)*per_host), so restarts and elastic re-shards
reproduce the exact stream.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (
    FROID,
    INTERPRETED,
    Session,
    UdfBuilder,
    col,
    lit,
    param,
    scan,
    udf,
    var,
)


def synthetic_corpus(seed: int, step: int, batch: int, seq_len: int, vocab: int,
                     host: int = 0, num_hosts: int = 1):
    """Deterministic synthetic token batch (counter-based RNG)."""
    per_host = batch // num_hosts
    ss = np.random.SeedSequence([seed, step, host])
    rng = np.random.default_rng(ss)
    toks = rng.integers(0, vocab, (per_host, seq_len + 1), dtype=np.int32)
    return toks


def default_transforms(db):
    """Imperative per-example rules compiled by Froid.

    keep_example(doc_score, length)  -> quality filter
    loss_weight(doc_score, repeats)  -> curriculum weight
    """
    u = UdfBuilder("keep_example", [("score", "float32"), ("length", "int32")],
                   "bool")
    with u.if_(param("length") < 16):
        u.return_(lit(False))
    with u.if_(param("score") < 0.2):
        u.return_(lit(False))
    u.return_(lit(True))
    db.create_function(u.build())

    u = UdfBuilder("loss_weight", [("score", "float32"), ("repeats", "int32")],
                   "float32")
    u.declare("w", "float32", lit(1.0))
    with u.if_(param("score") > 0.8):
        u.set("w", lit(2.0))
    with u.if_(param("repeats") > 2):
        u.set("w", var("w") * 0.5)
    u.return_(var("w"))
    db.create_function(u.build())


@dataclasses.dataclass
class DataPipeline:
    batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    host: int = 0
    num_hosts: int = 1
    froid: bool = True

    def __post_init__(self):
        self.session = Session()
        default_transforms(self.session)
        # fresh examples table per batch -> eager froid (whole-plan jit
        # would recompile every step)
        self.policy = FROID.eager() if self.froid else INTERPRETED
        self._query = scan("examples").compute(
            keep=udf("keep_example", col("score"), col("length")),
            w=udf("loss_weight", col("score"), col("repeats")),
        ).project("keep", "w")

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def batch_at(self, step: int):
        import jax.numpy as jnp

        toks = synthetic_corpus(
            self.seed, step, self.batch, self.seq_len, self.vocab,
            self.host, self.num_hosts,
        )
        n = toks.shape[0]
        ss = np.random.SeedSequence([self.seed, step, self.host, 7])
        rng = np.random.default_rng(ss)
        meta = {
            "score": rng.random(n).astype(np.float32),
            "length": np.full(n, self.seq_len, np.int32),
            "repeats": rng.integers(0, 4, n).astype(np.int32),
        }
        self.session.create_table("examples", **meta)
        res = self.session.execute(self._query, self.policy)
        keep = np.asarray(res.table.columns["keep"].data).astype(bool)
        w = np.asarray(res.table.columns["w"].data).astype(np.float32)
        mask = keep[:, None] & np.ones((n, self.seq_len), bool)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
            "mask": jnp.asarray(mask),
            "weight": jnp.asarray(w),
        }
