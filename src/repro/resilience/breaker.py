"""Per-statement circuit breakers for the degradation ladder.

A persistently-failing configuration (one statement whose fused or sharded
executable keeps dying) must stop burning a full retry ladder on every
wave.  Each ``(statement fingerprint, tier)`` pair gets a breaker:

* **closed** — requests flow; failures are counted in a sliding time
  window.  At ``failure_threshold`` failures within ``window_s`` the
  breaker **opens**.
* **open** — ``allow()`` is False, so the ladder routes the statement
  straight to the next tier down without attempting this one.  After
  ``cooldown_s`` the next ``allow()`` transitions to **half-open** and
  admits one probe.
* **half-open** — the probe's outcome decides: success restores
  **closed** (counters reset), failure re-opens with a fresh cooldown.

Clocks are injectable (the scheduler's deterministic test clock drives
breaker timing too), and every transition is counted so tests and serving
dashboards can watch ``opened / reopened / restored / probes`` per
breaker and per board.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    #: failures within ``window_s`` that trip a closed breaker open
    failure_threshold: int = 3
    #: sliding failure-count window (seconds)
    window_s: float = 30.0
    #: how long an open breaker rejects before admitting a half-open probe
    cooldown_s: float = 5.0


class CircuitBreaker:
    """One breaker; see module docstring for the state machine."""

    __slots__ = ("config", "clock", "state", "failures", "opened_at", "stats")

    def __init__(self, config: BreakerConfig | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.config = config or BreakerConfig()
        self.clock = clock
        self.state = CLOSED
        self.failures: deque[float] = deque()  # failure timestamps, windowed
        self.opened_at: float | None = None
        self.stats = {"opened": 0, "reopened": 0, "restored": 0, "probes": 0,
                      "rejected": 0}

    def _prune(self, now: float) -> None:
        w = self.config.window_s
        while self.failures and now - self.failures[0] > w:
            self.failures.popleft()

    def allow(self) -> bool:
        """May a request attempt this tier right now?  An open breaker
        past its cooldown admits exactly one half-open probe (drains are
        serialized, so the probe's outcome lands before the next ask)."""
        if self.state == CLOSED:
            return True
        now = self.clock()
        if self.state == OPEN:
            if now - self.opened_at >= self.config.cooldown_s:
                self.state = HALF_OPEN
                self.stats["probes"] += 1
                return True
            self.stats["rejected"] += 1
            return False
        # HALF_OPEN: a probe is already accounted; admit (the serialized
        # drain records its outcome before anyone else asks)
        return True

    def record_success(self, now: float | None = None) -> None:
        now = self.clock() if now is None else now
        if self.state == HALF_OPEN:
            self.state = CLOSED
            self.failures.clear()
            self.opened_at = None
            self.stats["restored"] += 1
            return
        self._prune(now)

    def record_failure(self, now: float | None = None) -> None:
        now = self.clock() if now is None else now
        if self.state == HALF_OPEN:
            self.state = OPEN
            self.opened_at = now
            self.stats["reopened"] += 1
            return
        if self.state == OPEN:
            return  # already open; nothing to count
        self.failures.append(now)
        self._prune(now)
        if len(self.failures) >= self.config.failure_threshold:
            self.state = OPEN
            self.opened_at = now
            self.failures.clear()
            self.stats["opened"] += 1


class BreakerBoard:
    """Lazy dict of breakers keyed by ``(statement fingerprint, tier)``.

    The board is what the ladder consults: ``allow(key)`` before an
    attempt, ``success(key)`` / ``failure(key)`` after.  ``snapshot()``
    is the introspection surface (state + counters per live breaker),
    mirroring ``Session.cache_stats``'s role for the cache tiers.
    """

    def __init__(self, config: BreakerConfig | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.config = config or BreakerConfig()
        self.clock = clock
        self.breakers: dict = {}

    def _get(self, key) -> CircuitBreaker:
        b = self.breakers.get(key)
        if b is None:
            b = self.breakers[key] = CircuitBreaker(self.config, self.clock)
        return b

    def allow(self, key) -> bool:
        b = self.breakers.get(key)
        return True if b is None else b.allow()

    def success(self, key) -> None:
        b = self.breakers.get(key)
        if b is not None:
            b.record_success()

    def failure(self, key) -> None:
        self._get(key).record_failure()

    def state(self, key) -> str:
        b = self.breakers.get(key)
        return CLOSED if b is None else b.state

    def snapshot(self) -> dict:
        """``{key: {"state": ..., **counters}}`` for every live breaker."""
        return {
            key: {"state": b.state, **b.stats}
            for key, b in self.breakers.items()
        }


__all__ = ["BreakerConfig", "CircuitBreaker", "BreakerBoard",
           "CLOSED", "OPEN", "HALF_OPEN"]
