"""Fused filter + project + grouped-aggregate Pallas TPU kernel.

This is the hot loop of every set-oriented plan Froid produces (the paper's
TPC-H experiments bottom out in exactly this op), adapted to the TPU:

* hash tables are a poor fit for the MXU/VPU, so grouping is done as
  **one-hot × matmul partial aggregation**: for a VMEM tile of rows, build
  the (rows × groups) one-hot matrix of group ids (masked by the fused
  filter), then ``onehot.T @ values`` on the MXU accumulates per-group sums
  for the whole tile in one systolic pass;
* the row stream is tiled ``(BLOCK_ROWS,)`` through VMEM; the accumulator
  ``(groups, n_aggs)`` lives in the output block which stays resident in
  VMEM across the sequential grid (TPU grids iterate the last axis
  innermost and revisit the same output block).

Count aggregation falls out of the same matmul by appending a column of
ones to the value matrix.

VMEM budget: BLOCK_ROWS×(n_aggs+2)×4 B for the tile + groups×n_aggs×4 B for
the accumulator + BLOCK_ROWS×groups×4 B for the one-hot. With
BLOCK_ROWS=1024, groups≤2048, n_aggs≤8: ≈ 1024·2048·4 ≈ 8 MiB one-hot —
fits the 16 MiB v5e VMEM with room; MXU dims (1024×2048×8) are
128-aligned when groups and BLOCK_ROWS are multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 1024


def _relagg_kernel(gid_ref, mask_ref, vals_ref, out_ref, *, num_groups: int):
    """Grid: (num_row_tiles,).  out_ref block: (num_groups, n_aggs+1)."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    gid = gid_ref[...]  # (BLOCK_ROWS,) int32
    mask = mask_ref[...]  # (BLOCK_ROWS,) bool — the fused filter
    vals = vals_ref[...]  # (BLOCK_ROWS, n_aggs) f32

    # one-hot group matrix, filter fused in (masked rows hit no group)
    groups = jax.lax.broadcasted_iota(jnp.int32, (gid.shape[0], num_groups), 1)
    onehot = (gid[:, None] == groups) & mask[:, None]
    onehot = onehot.astype(jnp.float32)

    # append a ones column -> counts fall out of the same MXU pass
    ones = jnp.ones((vals.shape[0], 1), jnp.float32)
    vals_and_ones = jnp.concatenate([vals, ones], axis=1)

    # (G, rows) @ (rows, n_aggs+1) on the MXU
    partial = jax.lax.dot_general(
        onehot,
        vals_and_ones,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += partial


def relagg_pallas(
    gid: jnp.ndarray,  # (n,) int32 group ids in [0, num_groups)
    mask: jnp.ndarray,  # (n,) bool
    vals: jnp.ndarray,  # (n, n_aggs) f32
    num_groups: int,
    block_rows: int = BLOCK_ROWS,
    interpret: bool = False,
):
    n, n_aggs = vals.shape
    n_pad = (-n) % block_rows
    if n_pad:
        gid = jnp.pad(gid, (0, n_pad))
        mask = jnp.pad(mask, (0, n_pad))  # pads False: no contribution
        vals = jnp.pad(vals, ((0, n_pad), (0, 0)))
    tiles = (n + n_pad) // block_rows

    out = pl.pallas_call(
        functools.partial(_relagg_kernel, num_groups=num_groups),
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((block_rows,), lambda t: (t,)),
            pl.BlockSpec((block_rows,), lambda t: (t,)),
            pl.BlockSpec((block_rows, n_aggs), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec(
            (num_groups, n_aggs + 1), lambda t: (0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((num_groups, n_aggs + 1), jnp.float32),
        interpret=interpret,
    )(gid, mask, vals)
    return out[:, :n_aggs], out[:, n_aggs]  # (sums, counts)
