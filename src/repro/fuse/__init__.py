"""Multi-statement fusion engine.

The paper's set-oriented argument, applied one level beyond PR 2/3's
batching: a serving queue holding N *different* prepared statements over
the same tables still pays N device dispatches and N redundant evaluations
of whatever catalog-only work the statements share.  This package merges
the members of such a queue into **one fused device program** — shared
scans/subtrees execute once, per-statement outputs come back tagged — with
a fusability analysis that routes anything unsafe back to the
per-statement path.

Layers (front to back):

* :mod:`repro.fuse.analysis` — which calls may fuse, grouped by
  compatible policy; everything else falls back.
* :mod:`repro.fuse.merge` — the plan-merge pass: dedup common param-free
  subtrees across member plans by structural fingerprint.
* :mod:`repro.fuse.program` — the fused raw closure: shared-subtree pool
  plus one ``vmap`` per member inside a single jit.

Entry points: :meth:`repro.core.Session.execute_fused` runs a mixed call
list; ``CoalescingScheduler(fuse=True)`` drains mixed-statement queues
through it; fused executables live in the session's ``fuse_hits`` /
``fuse_misses`` cache tier.
"""
from repro.fuse.analysis import (
    fusion_group_key,
    is_fusable,
    partition_calls,
    shareable_fingerprint_costs,
    shareable_fingerprints,
)
from repro.fuse.merge import (
    CONST_BIND,
    FusedPlan,
    SharedTemplate,
    hole_name,
    merge_plans,
    plan_is_pure,
    rewrite_lifted,
    rewrite_params,
    slot_param,
    subtree_is_constant,
    subtree_shape,
)
from repro.fuse.program import FUSE_PAD, SharedScanExecutor, build_fused_raw

__all__ = [
    "CONST_BIND",
    "FusedPlan",
    "FUSE_PAD",
    "rewrite_lifted",
    "SharedScanExecutor",
    "SharedTemplate",
    "build_fused_raw",
    "fusion_group_key",
    "hole_name",
    "is_fusable",
    "merge_plans",
    "partition_calls",
    "plan_is_pure",
    "rewrite_params",
    "shareable_fingerprint_costs",
    "shareable_fingerprints",
    "slot_param",
    "subtree_is_constant",
    "subtree_shape",
]
