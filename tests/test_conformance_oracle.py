"""Deterministic floor under the differential conformance harness: the
fixed programs in ``conformance_util.FIXED_PROGRAMS`` run through the same
mode/invocation oracles the hypothesis suite fuzzes, so conformance is
enforced even where hypothesis is unavailable — and on the forced-8-device
CI job, where the sharded arm of the invocation oracle actually spans the
mesh.
"""
import pytest

from conformance_util import (
    FIXED_PROGRAMS,
    N_ROWS,
    check_invocation_oracle,
    check_mode_oracle,
)

PROGRAMS = sorted(FIXED_PROGRAMS)

#: mixed-signature parameter list (int and float shifts split sub-batches),
#: with repeats so bucketing/padding paths engage
PARAMS_MIXED = (
    [{"cut": c, "shift": 0.5} for c in (2, 7, 4, 0, 5)]
    + [{"cut": c, "shift": 1} for c in (3, 6, 1)]
)


@pytest.mark.parametrize("name", PROGRAMS)
@pytest.mark.parametrize("n_rows", [0, N_ROWS], ids=["empty", "populated"])
def test_mode_oracle_fixed_programs(name, n_rows):
    check_mode_oracle(FIXED_PROGRAMS[name], seed=1, n_rows=n_rows)


@pytest.mark.parametrize("name", PROGRAMS)
@pytest.mark.parametrize("n_rows", [0, N_ROWS], ids=["empty", "populated"])
def test_invocation_oracle_fixed_programs(name, n_rows):
    check_invocation_oracle(
        FIXED_PROGRAMS[name], seed=2, n_rows=n_rows, params_list=PARAMS_MIXED
    )


def test_invocation_oracle_empty_params_list():
    check_invocation_oracle(
        FIXED_PROGRAMS["correlated_min_null_guard"], seed=0,
        n_rows=N_ROWS, params_list=[],
    )
