"""Coalescing microbatch scheduler: per-request submits, set-oriented drains.

The serving path naturally produces one statement execution per request — a
serial loop of dispatch + sync, exactly the iterative shape the paper's
set-oriented argument is about.  This scheduler turns it back into batches:
concurrent ``submit`` calls for the same :class:`PreparedStatement`
accumulate in a pending microbatch, and the batch drains through
``execute_many`` (one vmapped device program) when any of

* the batch reaches ``max_batch`` (flush-on-full),
* the oldest entry has waited longer than ``window_s`` (flush-on-window;
  checked on each submit and by ``poll()``), or
* a caller forces it (``flush()``, or ``Ticket.result()`` on a pending
  ticket — a consumer that needs its answer never deadlocks waiting for
  traffic that might not arrive).

Drains run through the **degradation ladder**
(:class:`repro.resilience.ladder.DegradationLadder`) by default: a failed
fused wave retries per-statement, a failed batch retries per ticket, a
failed compiled execute retries interpreted, so a ticket only surfaces an
error when the interpreter itself fails.  Per-``(statement, tier)``
circuit breakers stop persistently-failing configurations from burning
retries, and per-ticket **deadlines** (``submit(..., timeout_s=…)`` or the
scheduler-wide ``default_timeout_s``) shed expired tickets with a typed
:class:`~repro.resilience.faults.DeadlineExceeded` before each tier
attempt.  ``resilience=False`` restores the bare single-tier drains.

The scheduler is synchronous and thread-safe: it never starts threads of
its own, so drains happen on the caller that trips a flush condition.
Drains are serialized on a dedicated lock (the underlying Session caches
are not thread-safe), while submits to other statements stay concurrent;
a Session driven through a scheduler must not also be driven concurrently
outside it.  ``clock`` is injectable for deterministic window tests (and
drives deadlines and breaker cooldowns too); ``sleep`` is injectable for
instant retry-backoff tests.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Any, Callable

from repro.core.session import PreparedStatement, QueryResult
from repro.resilience.faults import WaveResultMismatch
from repro.resilience.ladder import (
    UNSET as _UNSET,
    DegradationLadder,
    ResilienceConfig,
    WaveGroup,
    WorkItem,
)


class Ticket:
    """Handle for one submitted request; filled when its batch drains.
    ``_result`` uses a dedicated unset sentinel: a legitimate result may
    be any object, so ``None`` must not mean "pending"."""

    __slots__ = ("_sched", "_group", "_result", "_error", "_deadline",
                 "submitted_at", "latency_s")

    def __init__(self, sched: "CoalescingScheduler", group: "_Group",
                 deadline: float | None = None,
                 submitted_at: float | None = None):
        self._sched = sched
        self._group = group
        self._result: Any = _UNSET
        self._error: BaseException | None = None
        self._deadline = deadline
        #: scheduler-clock submit time / submit-to-fill seconds, stamped
        #: when the ticket's drain completes — the fleet bench's p50/p99
        #: source (deterministic under an injected clock)
        self.submitted_at = submitted_at
        self.latency_s: float | None = None

    def done(self) -> bool:
        return self._result is not _UNSET or self._error is not None

    def result(self) -> QueryResult:
        """The request's :class:`QueryResult`; forces a drain of the
        ticket's batch if it is still pending.  If another thread is
        mid-drain (the batch was popped but not yet filled), waits for
        that drain to finish instead of racing it.  Raises the ticket's
        error (a typed resilience error, or the raw failure once the
        ladder is exhausted) instead of returning wrong data."""
        if not self.done():
            self._sched._flush_group(self._group)
            self._group.done_evt.wait()
        if self._error is not None:
            raise self._error
        assert self._result is not _UNSET
        return self._result


class _Group:
    """Pending same-statement microbatch."""

    __slots__ = ("stmt", "params", "deadlines", "tickets", "opened_at",
                 "done_evt")

    def __init__(self, stmt: PreparedStatement, opened_at: float):
        self.stmt = stmt
        self.params: list[dict] = []
        self.deadlines: list[float | None] = []
        self.tickets: list[Ticket] = []
        self.opened_at = opened_at
        # set once every ticket is filled: drains happen outside the
        # scheduler lock, so a concurrent Ticket.result() waits on this
        # instead of racing the in-flight drain
        self.done_evt = threading.Event()


class CoalescingScheduler:
    """Accumulates concurrent same-statement requests into microbatches.

    ``max_batch`` / ``window_s`` default per statement from its policy's
    batch knobs (``ExecutionPolicy.max_batch`` / ``coalesce_window_s``), so
    presets tune coalescing without scheduler-side configuration.  For a
    mesh-sharded statement the flush-on-full threshold scales to the mesh:
    ``max_batch`` bounds the *per-device* batch, so a policy sharding over
    D devices coalesces up to ``max_batch × D`` requests before a full
    flush — online traffic fills every device instead of one.

    **Fusion drain mode** (``fuse=True``): when several *different*
    statements' batches drain together (a ``flush()``, an expired-window
    ``poll()``, or a submit that trips multiple groups), they go down as
    one mixed-statement wave through ``Session.execute_fused`` — one fused
    device program with shared scans — instead of one ``execute_many`` per
    statement.  Statements the fusability analysis rejects fall back to the
    per-statement path inside ``execute_fused``; a lone draining batch
    skips fusion entirely.

    **Adaptive coalescing** (``adaptive=True``): each statement's effective
    flush window tracks an EMA of *that statement's* inter-arrival gaps —
    ``min(window_s, adaptive_hold × ema_gap)``, i.e. hold a partial batch
    only about as long as the next few same-statement arrivals should
    take, clamped to ``[0, window_s]``.  Fast traffic drains almost
    immediately (latency tracks the arrival rate, not the worst-case
    window); sparse traffic degrades to the configured window.  The EMA is
    per statement, not global — round-robin traffic over many statements
    must not shrink every group's window below its own refill rate.  The
    injectable ``clock`` keeps the EMA deterministic in tests.

    **Resilience** (``resilience=True``, the default): drains run through
    the degradation ladder (fused → many → serial → interp) with circuit
    breakers and deadlines; pass a
    :class:`~repro.resilience.ladder.ResilienceConfig` to tune retries /
    breaker thresholds, or ``False`` for the bare single-tier drains.
    ``default_timeout_s`` gives every ticket a deadline unless its
    ``submit`` overrides one.

    Stats (``self.stats``): submitted, batches, drained, flush reasons,
    fused_batches / fused_statements, plus — under resilience — the ladder
    counters (``demote_*``, ``tier_*_ok``, ``deadline_shed``,
    ``breaker_open_skips``, ``retry_backoffs``, ``ladder_exhausted``).
    ``resilience_stats`` bundles those with per-breaker state snapshots.
    """

    def __init__(self, max_batch: int | None = None,
                 window_s: float | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 fuse: bool = False,
                 adaptive: bool = False,
                 adaptive_alpha: float = 0.2,
                 adaptive_hold: float = 4.0,
                 resilience: "ResilienceConfig | bool" = True,
                 default_timeout_s: float | None = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.max_batch = max_batch
        self.window_s = window_s
        self.clock = clock
        self.fuse = fuse
        self.adaptive = adaptive
        self.adaptive_alpha = adaptive_alpha
        self.adaptive_hold = adaptive_hold
        self.default_timeout_s = default_timeout_s
        # id(stmt) -> (last arrival, EMA gap | None); bounded by the
        # statement population (sessions cap prepared handles)
        self._arrivals: dict[int, tuple[float, float | None]] = {}
        self._lock = threading.Lock()
        # serializes drains: execute_many mutates Session caches that have
        # no synchronization of their own
        self._drain_lock = threading.Lock()
        self._groups: dict[int, _Group] = {}  # id(stmt) -> pending batch
        self.stats = {
            "submitted": 0, "batches": 0, "drained": 0,
            "flush_full": 0, "flush_window": 0, "flush_forced": 0,
            "fused_batches": 0, "fused_statements": 0,
            "fused_isolated_retries": 0, "fused_isolated_errors": 0,
            # waves whose fuse-or-not choice came from the cost router
            # (mixed-statement waves of routed statements only)
            "routed_waves": 0,
        }
        self.ladder: DegradationLadder | None = None
        if resilience:
            cfg = resilience if isinstance(resilience, ResilienceConfig) \
                else None
            # ladder counters land in self.stats so demotions/sheds read
            # next to the drain counters clients already watch
            self.ladder = DegradationLadder(cfg, clock=clock, sleep=sleep,
                                            counters=self.stats)
            self.stats.update({
                "deadline_shed": 0, "breaker_open_skips": 0,
                "retry_backoffs": 0, "ladder_exhausted": 0,
                "demote_fused_to_many": 0, "demote_many_to_serial": 0,
                "demote_serial_to_interp": 0,
                "tier_fused_ok": 0, "tier_many_ok": 0,
                "tier_serial_ok": 0, "tier_interp_ok": 0,
            })

    # -- knob resolution ----------------------------------------------------
    def _max_batch(self, stmt: PreparedStatement) -> int:
        base = (self.max_batch if self.max_batch is not None
                else stmt.policy.max_batch)
        # mesh-sized buckets: per-device bound × data-parallel shard count
        return base * stmt.policy.shard_devices()

    def _window(self, stmt: PreparedStatement) -> float:
        return (self.window_s if self.window_s is not None
                else stmt.policy.coalesce_window_s)

    def ema_gap_s(self, stmt: PreparedStatement) -> float | None:
        """``stmt``'s inter-arrival EMA (None until two submits arrive)."""
        _, ema = self._arrivals.get(id(stmt), (None, None))
        return ema

    def effective_window(self, stmt: PreparedStatement) -> float:
        """The flush window actually in force for ``stmt``: the configured
        window, shrunk by ``stmt``'s own arrival-rate EMA under
        ``adaptive``."""
        base = self._window(stmt)
        ema = self.ema_gap_s(stmt)
        if not self.adaptive or ema is None:
            return base
        return min(base, max(0.0, ema * self.adaptive_hold))

    def _observe_arrival_locked(self, stmt: PreparedStatement,
                                now: float) -> None:
        if not self.adaptive:
            return
        last, ema = self._arrivals.get(id(stmt), (None, None))
        if last is not None:
            gap = now - last
            a = self.adaptive_alpha
            ema = gap if ema is None else a * gap + (1.0 - a) * ema
        self._arrivals[id(stmt)] = (now, ema)

    @property
    def resilience_stats(self) -> dict | None:
        """Ladder counters + per-``(statement, tier)`` breaker snapshot
        (state and opened/reopened/restored/probes/rejected counts); None
        when resilience is off."""
        return None if self.ladder is None else self.ladder.snapshot()

    # -- public API ----------------------------------------------------------
    def submit(self, stmt: PreparedStatement, params: dict | None = None,
               timeout_s: float | None = None) -> Ticket:
        """Queue one execution of ``stmt``; returns its :class:`Ticket`.
        May drain (this or another) batch if a flush condition trips.
        ``timeout_s`` (default: the scheduler's ``default_timeout_s``)
        gives the ticket an absolute deadline; a ticket still undrained
        when it expires is shed with
        :class:`~repro.resilience.faults.DeadlineExceeded` instead of
        executed (shed-before-drain)."""
        to_drain: list[_Group] = []
        with self._lock:
            self.stats["submitted"] += 1
            now = self.clock()
            self._observe_arrival_locked(stmt, now)
            t_s = timeout_s if timeout_s is not None else self.default_timeout_s
            deadline = (now + t_s) if t_s is not None else None
            g = self._groups.get(id(stmt))
            if g is None:
                g = _Group(stmt, now)
                self._groups[id(stmt)] = g
            t = Ticket(self, g, deadline, submitted_at=now)
            g.params.append(dict(params) if params else {})
            g.deadlines.append(deadline)
            g.tickets.append(t)
            if len(g.params) >= self._max_batch(stmt):
                self.stats["flush_full"] += 1
                self._groups.pop(id(stmt), None)
                to_drain.append(g)
            to_drain.extend(self._take_expired_locked())
        self._drain_all(to_drain)
        return t

    def poll(self) -> int:
        """Drain every batch whose coalesce window has expired; returns the
        number of requests drained.  Serving loops call this once per tick."""
        with self._lock:
            expired = self._take_expired_locked()
        n = sum(len(g.params) for g in expired)
        self._drain_all(expired)
        return n

    def flush(self) -> int:
        """Drain all pending batches regardless of window; returns the
        number of requests drained.  Under fusion drain mode a
        mixed-statement flush goes down as one fused wave."""
        with self._lock:
            groups = list(self._groups.values())
            self._groups.clear()
            if groups:
                self.stats["flush_forced"] += len(groups)
        n = sum(len(g.params) for g in groups)
        self._drain_all(groups)
        return n

    @property
    def pending(self) -> int:
        with self._lock:
            return sum(len(g.params) for g in self._groups.values())

    # -- internals -----------------------------------------------------------
    def _take_expired_locked(self) -> list[_Group]:
        now = self.clock()
        expired = [
            g for g in self._groups.values()
            if now - g.opened_at >= self.effective_window(g.stmt)
        ]
        for g in expired:
            self._groups.pop(id(g.stmt), None)
            self.stats["flush_window"] += 1
        return expired

    def _flush_group(self, group: _Group) -> None:
        """Forced drain of one batch (Ticket.result on a pending ticket)."""
        with self._lock:
            live = self._groups.get(id(group.stmt))
            if live is not group:
                return  # already drained by another path
            self._groups.pop(id(group.stmt), None)
            self.stats["flush_forced"] += 1
        self._drain_all([group])

    def _route_fuse(self, groups: list[_Group]) -> bool:
        """Wave-level fuse-or-not routing.  When fusion drain mode is on,
        the wave is mixed-statement, and every member statement is routed
        (``policy.route``) on one shared session, the session's cost
        router picks between the fused wave and per-statement drains from
        measured wave costs (each arm explored once, then the cheaper
        wins).  Any unrouted member — or a single-statement wave — keeps
        the scheduler's static ``fuse`` knob."""
        if not (self.fuse and len(groups) >= 2):
            return self.fuse
        stmts = [g.stmt for g in groups]
        if not all(s.policy.route for s in stmts):
            return self.fuse
        sess = stmts[0].session
        if any(s.session is not sess for s in stmts[1:]):
            return self.fuse
        router = sess._ensure_router()
        self.stats["routed_waves"] += 1
        return router.choose_fuse([(g.stmt, len(g.params)) for g in groups])

    def _drain_all(self, groups: list[_Group]) -> None:
        """Drain a set of batches that tripped together: through the
        degradation ladder under resilience (one fused wave when fusion
        drain mode is on and the wave is mixed-statement, demoting on
        failure), else the bare single-tier drains.  Routed waves may
        override the fuse choice per wave (``_route_fuse``)."""
        if not groups:
            return
        fuse = self._route_fuse(groups)
        if self.ladder is not None:
            self._drain_ladder(groups, fuse)
            return
        if fuse and len(groups) >= 2:
            self._drain_fused(groups)
            return
        for g in groups:
            self._drain(g)

    def _drain_ladder(self, groups: list[_Group],
                      fuse: bool | None = None) -> None:
        """Ladder-backed drain: hand the wave to the resilience layer,
        then map every WorkItem outcome onto its ticket.  The ladder
        resolves every item with a result or a typed/raw error; an
        interrupt (BaseException) mid-ladder parks a diagnostic on the
        still-unresolved tickets and re-raises."""
        wave = [
            WaveGroup(g.stmt, [WorkItem(p, deadline=d)
                               for p, d in zip(g.params, g.deadlines)])
            for g in groups
        ]
        try:
            self.ladder.drain(wave, fuse=self.fuse if fuse is None else fuse,
                              lock=self._drain_lock)
        except BaseException as e:
            for g, wg in zip(groups, wave):
                for t, it in zip(g.tickets, wg.items):
                    if it.error is not None:
                        t._error = it.error
                    elif it.result is not _UNSET:
                        t._result = it.result
                    else:
                        t._error = e
            raise
        else:
            for g, wg in zip(groups, wave):
                for t, it in zip(g.tickets, wg.items):
                    if it.error is not None:
                        t._error = it.error
                    else:
                        t._result = it.result
        finally:
            for g in groups:
                self._finish(g)

    # -- bare drains (resilience=False) --------------------------------------
    def _drain_fused(self, groups: list[_Group]) -> None:
        """Mixed-statement drain through ``Session.execute_fused``, with
        **per-group error isolation**: when the fused wave fails (one
        member referencing a dropped table must not poison every ticket of
        the wave), each statement's batch retries independently on its own
        per-statement path — only the genuinely failing group's tickets
        carry the error, and ``stats['fused_isolated_retries']`` /
        ``['fused_isolated_errors']`` record the fallout."""
        self.stats["batches"] += 1
        self.stats["drained"] += sum(len(g.params) for g in groups)
        self.stats["fused_batches"] += 1
        self.stats["fused_statements"] += len(groups)
        calls = [(g.stmt, p) for g in groups for p in g.params]
        try:
            with self._drain_lock:
                # execute_fused routes foreign-session / non-fusable
                # statements back to their own per-statement path
                results = groups[0].stmt.session.execute_fused(calls)
            if len(results) != len(calls):
                # a protocol violation must fail the wave with a typed
                # error, not leak StopIteration from the zip below
                raise WaveResultMismatch(len(calls), len(results),
                                         "execute_fused")
            it = iter(results)
            for g in groups:
                for t in g.tickets:
                    t._result = next(it)
        except Exception:
            # the wave failed as a unit; re-run each group alone so the
            # failure lands only on the tickets that earn it.  These are
            # fault-window runs: the cost router must not learn from them
            router = getattr(groups[0].stmt.session, "cost_router", None)
            suppress = (router.suppress if router is not None
                        else contextlib.nullcontext)
            try:
                for g in groups:
                    self.stats["fused_isolated_retries"] += 1
                    try:
                        with self._drain_lock, suppress():
                            rs = g.stmt.execute_many(g.params)
                        if len(rs) != len(g.tickets):
                            raise WaveResultMismatch(len(g.tickets), len(rs),
                                                     "execute_many")
                        for t, r in zip(g.tickets, rs):
                            t._result = r
                    except Exception as e:
                        self.stats["fused_isolated_errors"] += 1
                        for t in g.tickets:
                            t._error = e
            except BaseException as e:  # interrupt mid-retry: park a
                for g in groups:        # diagnostic on every unfilled
                    for t in g.tickets:  # ticket, let the interrupt rise
                        if t._result is _UNSET and t._error is None:
                            t._error = e
                raise
        except BaseException as e:  # KeyboardInterrupt/SystemExit: park a
            for g in groups:         # diagnostic on the tickets, but let
                for t in g.tickets:  # the interrupt reach the caller
                    t._error = e
            raise
        finally:
            for g in groups:
                self._finish(g)

    def _finish(self, group: _Group) -> None:
        """Stamp submit-to-fill latency on the group's tickets and release
        their waiters (every drain path funnels through here)."""
        now = self.clock()
        for t in group.tickets:
            if t.submitted_at is not None:
                t.latency_s = now - t.submitted_at
        group.done_evt.set()

    def _drain(self, group: _Group) -> None:
        self.stats["batches"] += 1
        self.stats["drained"] += len(group.params)
        try:
            with self._drain_lock:
                results = group.stmt.execute_many(group.params)
            if len(results) != len(group.tickets):
                raise WaveResultMismatch(len(group.tickets), len(results),
                                         "execute_many")
            for t, r in zip(group.tickets, results):
                t._result = r
        except Exception as e:  # fan the failure out to every waiter
            for t in group.tickets:
                t._error = e
        except BaseException as e:  # KeyboardInterrupt/SystemExit: park a
            for t in group.tickets:  # diagnostic on the tickets, but let
                t._error = e         # the interrupt reach the caller
            raise
        finally:
            self._finish(group)


__all__ = ["CoalescingScheduler", "Ticket"]
