"""Cost-router persistence: measured wave-cost EMAs as store entries.

The PR-8 cost router learns per-wave costs online; persisting its measured
tables lets a fresh worker route warm — no re-exploration of policy/bucket/
fuse arms it has already paid for elsewhere.  The entry is JSON (no pickled
code): rows of ``[repr(key), wave_s, n, last_s, meta]`` produced by
``CostRouter.export_state`` and re-parsed with the same strict stable-key
parser the plan tier uses.

Fault-window exclusion is inherited, not re-implemented: samples observed
under ``CostRouter.suppress`` never reach the measured tables in the first
place, so a save cannot leak degraded-wave costs no matter when it runs.

Costs are keyed by the session's content-derived environment token only —
they are advisory (routing hints), so one table serves every policy and
statement population under a given catalog/registry state.
"""
from __future__ import annotations

import json

from repro.persist.store import PlanCacheCorruptError, PlanStore

#: bump on incompatible changes to the cost-row layout
COSTS_SCHEMA_VERSION = 1


def costs_key(env_token: tuple) -> tuple:
    return ("repro-costs", COSTS_SCHEMA_VERSION, env_token)


def save_costs(store: PlanStore, env_token: tuple, router) -> bool:
    """Write the router's measured tables; returns False for an empty model
    (nothing worth persisting — avoids clobbering a populated entry)."""
    state = router.export_state()
    if not state["measured"] and not state["per_ticket"]:
        return False
    blob = json.dumps(state, sort_keys=True).encode("utf-8")
    store.put(costs_key(env_token), {"kind": "costs"}, blob)
    return True


def load_costs(store: PlanStore, env_token: tuple, router, *,
               replace: bool = False) -> int:
    """Warm-start ``router`` from the store; returns records adopted (0 on
    a clean miss).  Raises the store's typed errors on stale/corrupt
    entries — callers degrade to an empty model."""
    got = store.get(costs_key(env_token))
    if got is None:
        return 0
    _meta, blob = got
    try:
        state = json.loads(blob.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise PlanCacheCorruptError(f"undecodable cost table: {e}") from e
    return router.import_state(state, replace=replace)
