#!/usr/bin/env bash
# Tier-1 verify: the repo's test suite, then the perf smoke CI runs.
# pyproject.toml sets pythonpath=src, so no PYTHONPATH export is needed for
# pytest — this script exists so `scripts/verify.sh` is the one canonical
# spelling (extra pytest args pass through, e.g.
# `scripts/verify.sh -m "not slow"`).
#
# VERIFY_BENCH=0 skips the perf smoke (tests only).
set -euo pipefail
cd "$(dirname "$0")/.."
python -m pytest -x -q "$@"

if [ "${VERIFY_BENCH:-1}" != "0" ]; then
  echo "--- perf smoke: benchmarks.run --quick --only prepared,table4,execmany"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run --quick --only prepared,table4,execmany \
      --run-id verify --json-dir /tmp
fi
