"""Pure-jnp oracle for flash_attention (+ a chunked online-softmax variant
with flash-style O(S·bk) memory, used when lowering off-TPU so dry-runs
reflect kernel-like memory behaviour)."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, q_offset=0,
                        sm_scale=None):
    B, Hq, Sq, D = q.shape
    _, Hk, Sk, _ = k.shape
    n_rep = Hq // Hk
    if sm_scale is None:
        sm_scale = D ** -0.5
    k = jnp.repeat(k, n_rep, axis=1)
    v = jnp.repeat(v, n_rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None, None], p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(denom > 0, denom, 1.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def flash_attention_chunked(q, k, v, *, causal=True, window=None, q_offset=0,
                            sm_scale=None, bk=512):
    """Online-softmax attention via lax.scan over key blocks — the pure-jnp
    twin of the Pallas kernel's memory behaviour (never materializes the
    (Sq, Sk) score matrix).  Used for off-TPU lowering of big shapes."""
    B, Hq, Sq, D = q.shape
    _, Hk, Sk, _ = k.shape
    n_rep = Hq // Hk
    if sm_scale is None:
        sm_scale = D ** -0.5
    bk = min(bk, Sk)
    pad = (-Sk) % bk
    kv_len = Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = (Sk + pad) // bk
    kb = k.reshape(B, Hk, nk, bk, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hk, nk, bk, D).transpose(2, 0, 1, 3, 4)

    qf = q.astype(jnp.float32) * sm_scale
    if n_rep > 1:
        qf = qf.reshape(B, Hk, n_rep, Sq, D)
    qpos = q_offset + jnp.arange(Sq)

    @jax.checkpoint  # flash backward: recompute p per block
    def step(carry, blk):
        m, l, acc, kk = carry
        kc, vc = blk  # (B, Hk, bk, D)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        if n_rep > 1:
            s = jnp.einsum("bhrqd,bhkd->bhrqk", qf, kc)
        else:
            s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc)
        kpos = kk * bk + jnp.arange(bk)
        mask = jnp.broadcast_to(kpos[None, :] < kv_len, (Sq, bk))
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        # mask is (Sq, bk): broadcasts against the trailing dims of s
        s = jnp.where(mask, s, -1e30)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        if n_rep > 1:
            upd = jnp.einsum("bhrqk,bhkd->bhrqd", p, vc)
        else:
            upd = jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        acc_new = acc * alpha[..., None] + upd
        return (m_new, l_new, acc_new, kk + 1), 0

    shape_ml = (B, Hk, n_rep, Sq) if n_rep > 1 else (B, Hq, Sq)
    m0 = jnp.full(shape_ml, -1e30, jnp.float32)
    l0 = jnp.zeros(shape_ml, jnp.float32)
    acc0 = jnp.zeros(shape_ml + (D,), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, acc0, 0), (kb, vb))
    out = acc / jnp.where(l > 0, l, 1.0)[..., None]
    if n_rep > 1:
        out = out.reshape(B, Hq, Sq, D)
    return out.astype(q.dtype)
