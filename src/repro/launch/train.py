"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite3_2b --smoke \
        --steps 100 --batch 8 --seq 64 --ckpt /tmp/ckpt

Production launch (multi-host) uses the same entry point under
``jax.distributed.initialize`` with the 16x16 (or 2x16x16) mesh; this
container is 1-CPU so --smoke reduced configs are the runnable path.
Fault tolerance: every run resumes from the newest verifiable checkpoint;
straggler stats print at the end (feed the eviction set to an elastic
restart, see repro.train.elastic).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import config_for, smoke_config_for
from repro.data.pipeline import DataPipeline
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.optim import AdamWConfig
from repro.train.straggler import StragglerTracker
from repro.train.train_loop import TrainState, init_state, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite3_2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config_for(args.arch) if args.smoke else config_for(args.arch)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
                          total_steps=args.steps)

    mgr = None
    state = None
    if args.ckpt:
        mgr = CheckpointManager(args.ckpt, keep_n=3)
        step, restored = mgr.restore_latest()
        if restored is not None:
            print(f"resuming from checkpoint step {step}")
            state = TrainState(restored["params"], restored["opt"], None)
    if state is None:
        state = init_state(model, jax.random.PRNGKey(args.seed), opt_cfg,
                           compress=args.compress)

    pipe = DataPipeline(batch=args.batch, seq_len=args.seq, vocab=cfg.vocab,
                        seed=args.seed)
    straggler = StragglerTracker()
    state = train_loop(
        model, state, iter(pipe), opt_cfg, steps=args.steps,
        checkpoint_mgr=mgr, checkpoint_every=args.checkpoint_every,
        straggler=straggler, microbatches=args.microbatches,
        compress=args.compress,
    )
    if mgr is not None:
        mgr.save(args.steps, {"params": state.params, "opt": state.opt})
        mgr.wait()
    if straggler.should_evict():
        print(f"straggler eviction candidates: {straggler.should_evict()}")
    print(f"done at step {int(state.opt['step'])}")


if __name__ == "__main__":
    main()
