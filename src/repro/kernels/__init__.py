# Pallas TPU kernels for the perf-critical compute layers.
# Each kernel package has:
#   <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
#   ops.py     — the jit'd public wrapper (auto-interpret on CPU)
#   ref.py     — pure-jnp oracle used by the allclose test sweeps
#
# relagg          — fused filter+project+group-aggregate (the paper's
#                   set-oriented plan hot loop, batch-mode §8.2.6, as
#                   one-hot × MXU matmul partial aggregation)
# flash_attention — blockwise online-softmax attention (causal / sliding
#                   window / GQA) for the assigned LM architectures
# ssd_scan        — Mamba-2 state-space-duality chunked scan
