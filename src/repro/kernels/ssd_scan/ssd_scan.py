"""Mamba-2 SSD (state-space duality) chunked scan — Pallas TPU kernel.

The SSD recurrence (per batch b, head h, state dim N, head dim P):

    S_t = exp(A_h·dt_t) · S_{t-1} + B_t ⊗ (dt_t · x_t)
    y_t = C_t · S_t

is evaluated chunk-by-chunk (chunk length Q): the *within-chunk* part is the
quadratic "attention-like" form `(C Bᵀ ∘ decay) @ (dt·x)` — two MXU matmuls
— and the *cross-chunk* part threads the (N, P) state through VMEM scratch
across the sequential chunk axis of the grid.  This is the TPU-native
realization of the paper's duality: the MXU does the quadratic form, the
scratch carry does the linear recurrence (no per-timestep loop ever runs).

Grid: (B·H, L/Q) with the chunk axis sequential.  VMEM per step:
Q·P (x) + Q·N (B,C) + N·P (state) + Q² (decay) floats — with Q=128,
P=64..128, N=128 well under 2 MiB.

Inputs are pre-fused by ops.py: ``xdt = x·dt`` and ``dtA = A_h·dt`` so the
kernel sees only tensors (no per-head scalar lookup inside the kernel).
Numerical note: A<0, dt>0 ⟹ all exponents are ≤ 0, every exp() ≤ 1 — the
chunked form is self-stabilizing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(xdt_ref, dtA_ref, b_ref, c_ref, y_ref, state_ref, *, chunk):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0].astype(jnp.float32)  # (Q, P)
    dtA = dtA_ref[0].astype(jnp.float32)  # (Q,)
    Bc = b_ref[0].astype(jnp.float32)  # (Q, N)
    Cc = c_ref[0].astype(jnp.float32)  # (Q, N)

    cum = jnp.cumsum(dtA)  # (Q,)
    # decay(i<-j) = exp(cum_i - cum_j), lower-triangular (j <= i)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = ii >= jj
    decay = jnp.where(tri, jnp.exp(cum[:, None] - cum[None, :]), 0.0)

    # within-chunk (quadratic / "attention" form) on the MXU
    scores = jax.lax.dot_general(
        Cc, Bc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * decay  # (Q, Q)
    y = jax.lax.dot_general(
        scores, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    # cross-chunk: contribution of the carried state
    state = state_ref[...]  # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cc, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update: each source decays to the chunk end
    decay_to_end = jnp.exp(cum[-1] - cum)  # (Q,)
    state_ref[...] = jnp.exp(cum[-1]) * state + jax.lax.dot_general(
        Bc, xdt * decay_to_end[:, None],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_pallas(
    xdt: jnp.ndarray,  # (BH, L, P)  — x * dt, pre-fused
    dtA: jnp.ndarray,  # (BH, L)     — A_h * dt, pre-fused
    B: jnp.ndarray,  # (BG, L, N)
    C: jnp.ndarray,  # (BG, L, N)
    n_rep: int,  # heads per B/C group (BH == BG * n_rep per batch)
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
):
    BH, L, P = xdt.shape
    BG, _, N = B.shape
    assert BH % n_rep == 0
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0)))
        dtA = jnp.pad(dtA, ((0, 0), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    n_chunks = (L + pad) // chunk

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)),
            pl.BlockSpec(
                (1, chunk, N), lambda bh, c, n_rep=n_rep: (bh // n_rep, c, 0)
            ),
            pl.BlockSpec(
                (1, chunk, N), lambda bh, c, n_rep=n_rep: (bh // n_rep, c, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, L + pad, P), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ) if hasattr(pltpu, "CompilerParams") else None,
    )(xdt, dtA, B, C)
    return out[:, :L]
