"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7,fig9,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced cardinalities / query subsets")
    ap.add_argument("--only", default=None,
                    help="comma list: fig7,fig8,fig9,fig11,fig13,table4,"
                         "table5,prepared")
    args, _ = ap.parse_known_args()

    from benchmarks import (
        bench_batchmode,
        bench_compile,
        bench_factor,
        bench_invocations,
        bench_native,
        bench_prepared,
        bench_resources,
        bench_tpch,
    )

    suites = {
        "fig7": bench_invocations.run,     # invocation-count sweep
        "fig8": bench_compile.run,         # cold-cache compile overhead
        "fig9": bench_tpch.run,            # TPC-H queries with UDFs
        "fig11": bench_factor.run,         # factor of improvement (W1/W2)
        "fig13": bench_resources.run,      # CPU time + logical reads (fig14)
        "table4": bench_batchmode.run,     # batch mode / relagg kernel
        "table5": bench_native.run,        # native compilation quadrant
        "prepared": bench_prepared.run,    # Session prepare/execute lifecycle
    }
    only = args.only.split(",") if args.only else list(suites)

    print("name,us_per_call,derived")
    failed = []
    for key in only:
        try:
            suites[key](quick=args.quick)
        except Exception as e:
            failed.append(key)
            print(f"{key}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
