"""Resilience overhead + observability: the degradation ladder on the
fault-free hot path vs the bare (pre-PR-7) drain, and a faulted drain
demonstrating the demotion machinery under load.

    PYTHONPATH=src python -m benchmarks.bench_resilience [--quick]

Rows:
    resilience/bare_fused/<n>    — scheduler with resilience=False
    resilience/ladder_fused/<n>  — scheduler with the ladder on (default)
    resilience/faulted_fused/<n> — ladder under a periodic dispatch fault

The ladder row's `derived` carries ``overhead=<ratio>`` — ladder time over
bare time on an identical fault-free queue.  CI gates on overhead <= 1.05
(the fault-free hot path pays only breaker-gate lookups and per-item
bookkeeping; all device work is byte-identical).  The faulted row's
`derived` carries the demotion/tier counters, proving every ticket was
answered (parity asserted) while a recurring injected dispatch fault
forced fused→many demotions mid-drain.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import FROID, Session, UdfBuilder, col, lit, param, scan, sum_, udf, var
from repro.resilience import FaultInjector, FaultSpec
from repro.serve.scheduler import CoalescingScheduler

M_ROWS, N_T, PER_STMT = 20_000, 2_000, 64
M_ROWS_QUICK, N_T_QUICK, PER_STMT_QUICK = 5_000, 500, 24


def _setup(quick: bool) -> Session:
    m = M_ROWS_QUICK if quick else M_ROWS
    n = N_T_QUICK if quick else N_T
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 400, m),
        d_val=rng.uniform(0, 100, m).astype(np.float32),
    )
    db.create_table("T", a=rng.integers(0, 400, n))
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    with u.if_(var("s").is_null()):
        u.return_(lit(0.0))
    u.return_(var("s"))
    db.create_function(u.build())
    return db


def _queue(db, per_stmt: int):
    stmts = [
        db.prepare(scan("T").filter(col("a") < param("cutoff"))
                            .compute(v=udf("key_total", col("a")))
                            .project("v"), FROID),
        db.prepare(scan("T").filter(col("a") >= param("lo"))
                            .compute(w=col("a") * param("scale"))
                            .project("a", "w"), FROID),
        db.prepare(scan("T").filter((col("a") > param("lo"))
                                    & (col("a") < param("hi")))
                            .compute(z=col("a") + param("off"))
                            .project("z"), FROID),
    ]
    rng = np.random.default_rng(7)
    waves = []
    for _ in range(per_stmt):
        waves.append((stmts[0], {"cutoff": int(rng.integers(1, 400))}))
        waves.append((stmts[1], {"lo": int(rng.integers(0, 200)),
                                 "scale": float(round(rng.uniform(0.5, 2), 2))}))
        waves.append((stmts[2], {"lo": int(rng.integers(0, 100)),
                                 "hi": int(rng.integers(200, 400)),
                                 "off": int(rng.integers(0, 10))}))
    return waves


def _drain_time(queue, *, resilience, iters: int = 5):
    """Median wall seconds to drain the queue; returns (t, stats, results)."""
    ts, stats, results = [], {}, None
    for _ in range(iters):
        sched = CoalescingScheduler(max_batch=1024, window_s=10.0, fuse=True,
                                    resilience=resilience)
        t0 = time.perf_counter()
        tickets = [sched.submit(s, p) for s, p in queue]
        sched.flush()
        results = [t.result().masked for t in tickets]
        ts.append(time.perf_counter() - t0)
        stats = sched.stats
    return float(np.median(ts)), stats, results


def _check_identical(expected, got):
    for s, b in zip(expected, got):
        m = np.asarray(s.mask)
        np.testing.assert_array_equal(m, np.asarray(b.mask))
        for n, c in s.table.columns.items():
            np.testing.assert_allclose(
                np.asarray(b.table.columns[n].data)[m],
                np.asarray(c.data)[m], rtol=1e-5,
            )


def run(quick: bool = False):
    db = _setup(quick)
    per_stmt = PER_STMT_QUICK if quick else PER_STMT
    queue = _queue(db, per_stmt)
    n = len(queue)

    # warm both arms' jit caches (device programs are shared either way)
    _drain_time(queue, resilience=False, iters=1)

    t_bare, _, ref = _drain_time(queue, resilience=False)
    emit(f"resilience/bare_fused/{n}", t_bare / n * 1e6,
         "pre-ladder drain (resilience=False)")

    t_lad, st, got = _drain_time(queue, resilience=True)
    _check_identical(ref, got)
    emit(
        f"resilience/ladder_fused/{n}", t_lad / n * 1e6,
        f"overhead={t_lad / t_bare:.4f} tier_fused_ok={st.get('tier_fused_ok')} "
        f"demotions={st.get('demote_fused_to_many', 0)}",
    )

    # faulted arm: one dispatch fault per drain kills the fused wave; the
    # ladder demotes every group to execute_many and every ticket still
    # gets its rows (parity asserted against the bare-arm reference)
    ts, faults = [], 0
    fst, fgot = {}, None
    try:
        for _ in range(3):
            fi = FaultInjector([FaultSpec(site="dispatch", times=1)])
            fi.install(db)
            sched = CoalescingScheduler(max_batch=1024, window_s=10.0,
                                        fuse=True, resilience=True)
            t0 = time.perf_counter()
            tickets = [sched.submit(s, p) for s, p in queue]
            sched.flush()
            fgot = [t.result().masked for t in tickets]
            ts.append(time.perf_counter() - t0)
            faults += len(fi.injected)
            fst = sched.stats
    finally:
        db.fault_injector = None
    _check_identical(ref, fgot)
    t_fault = float(np.median(ts))
    emit(
        f"resilience/faulted_fused/{n}", t_fault / n * 1e6,
        f"faults={faults} "
        f"demote_fused_to_many={fst.get('demote_fused_to_many')} "
        f"tier_many_ok={fst.get('tier_many_ok')} "
        f"fused_isolated_retries={fst.get('fused_isolated_retries')} "
        f"parity=ok",
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
