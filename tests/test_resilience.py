"""Resilience layer tests: degradation ladder, circuit breakers, deadlines,
fault injection, and the chaos conformance oracle (fixed schedules).

The generative layer (random seeded fault schedules through hypothesis)
rides in ``tests/test_property_froid.py``; this module is the
deterministic floor that runs everywhere — including the forced-8-device
CI chaos smoke job — plus unit coverage for the breaker state machine,
the injector's schedule semantics, the ``Ticket`` result sentinel, and
the fused-drain result-count guard.
"""
import numpy as np
import pytest

from conformance_util import check_chaos_oracle
from repro.core import FROID, Session, col, param, scan
from repro.resilience import (
    CLOSED,
    HALF_OPEN,
    OPEN,
    BreakerConfig,
    CircuitBreaker,
    DeadlineExceeded,
    FaultInjector,
    FaultSpec,
    InjectedFault,
    ResilienceConfig,
    ResilienceError,
    RetryPolicy,
    WaveResultMismatch,
)
from repro.serve.scheduler import CoalescingScheduler


class Clock:
    """Manually-advanced monotonic clock for deterministic deadline and
    breaker-cooldown tests."""

    def __init__(self, now: float = 0.0):
        self.now = now

    def __call__(self) -> float:
        return self.now


def _mk(n: int = 8):
    """Session + two distinct prepared statements over one small table."""
    s = Session()
    s.create_table("T", x=np.arange(n, dtype=np.int32))
    q1 = scan("T").filter(col("x") < param("cutoff")).project("x")
    q2 = scan("T").compute(y=col("x") * param("m")).project("x", "y")
    return s, s.prepare(q1, FROID), s.prepare(q2, FROID)


def _sched(clock=None, **kw):
    kw.setdefault("max_batch", 64)
    kw.setdefault("window_s", 1e9)
    kw.setdefault("sleep", lambda s: None)
    if clock is not None:
        kw["clock"] = clock
    return CoalescingScheduler(**kw)


def _xs(result):
    return np.asarray(result.table.columns["x"].data).tolist()


# ---------------------------------------------------------------------------
# circuit breaker state machine
# ---------------------------------------------------------------------------


def test_breaker_opens_at_threshold_within_window():
    c = Clock()
    b = CircuitBreaker(BreakerConfig(failure_threshold=3, window_s=10.0,
                                     cooldown_s=5.0), clock=c)
    assert b.state == CLOSED and b.allow()
    b.record_failure(); b.record_failure()
    assert b.state == CLOSED  # below threshold
    b.record_failure()
    assert b.state == OPEN and b.stats["opened"] == 1
    assert not b.allow() and b.stats["rejected"] == 1


def test_breaker_window_prunes_old_failures():
    c = Clock()
    b = CircuitBreaker(BreakerConfig(failure_threshold=3, window_s=10.0),
                       clock=c)
    b.record_failure()
    c.now = 11.0  # first failure ages out of the window
    b.record_failure(); b.record_failure()
    assert b.state == CLOSED  # only 2 failures inside the window
    b.record_failure()
    assert b.state == OPEN


def test_breaker_half_open_probe_restores():
    c = Clock()
    b = CircuitBreaker(BreakerConfig(failure_threshold=1, cooldown_s=5.0),
                       clock=c)
    b.record_failure()
    assert b.state == OPEN and not b.allow()
    c.now = 6.0  # past cooldown: one probe admitted
    assert b.allow() and b.state == HALF_OPEN and b.stats["probes"] == 1
    b.record_success()
    assert b.state == CLOSED and b.stats["restored"] == 1
    assert b.allow()


def test_breaker_half_open_probe_failure_reopens():
    c = Clock()
    b = CircuitBreaker(BreakerConfig(failure_threshold=1, cooldown_s=5.0),
                       clock=c)
    b.record_failure()
    c.now = 6.0
    assert b.allow() and b.state == HALF_OPEN
    b.record_failure()
    assert b.state == OPEN and b.stats["reopened"] == 1
    assert not b.allow()  # fresh cooldown from the reopen
    c.now = 12.0
    assert b.allow() and b.state == HALF_OPEN  # probes again


# ---------------------------------------------------------------------------
# fault injector schedule semantics
# ---------------------------------------------------------------------------


def test_fault_spec_site_stmt_after_times():
    fp = ("some", "fingerprint")
    fi = FaultInjector([FaultSpec(site="dispatch", stmt=fp, after=1, times=2)])
    fi.check("dispatch", ())          # wrong statement: no match
    fi.check("compile", (fp,))        # wrong site: no match
    fi.check("dispatch", (fp,))       # match 1: skipped by after=1
    with pytest.raises(InjectedFault):
        fi.check("dispatch", (fp,))   # match 2: fires
    with pytest.raises(InjectedFault):
        fi.check("dispatch", (fp, ("other",)))  # fused wave membership
    fi.check("dispatch", (fp,))       # times=2 exhausted: quiet
    assert fi.fired == 2
    assert fi.events == {"dispatch": 5, "compile": 1}


def test_fault_spec_times_none_fires_forever():
    fi = FaultInjector([FaultSpec(site="sync", times=None)])
    for _ in range(5):
        with pytest.raises(InjectedFault):
            fi.check("sync", ())
    assert fi.fired == 5


def _fire_pattern(fi: FaultInjector, site: str, n: int) -> list:
    pat = []
    for _ in range(n):
        try:
            fi.check(site, ())
            pat.append(0)
        except InjectedFault:
            pat.append(1)
    return pat


def test_seeded_schedule_is_deterministic_and_seed_sensitive():
    a = _fire_pattern(FaultInjector.seeded(5, 0.5), "dispatch", 64)
    b = _fire_pattern(FaultInjector.seeded(5, 0.5), "dispatch", 64)
    other = _fire_pattern(FaultInjector.seeded(6, 0.5), "dispatch", 64)
    assert a == b            # same seed -> identical schedule
    assert a != other        # different seed -> different schedule
    assert 0 < sum(a) < 64   # rate 0.5 fires some, not all


def test_seeded_schedule_max_faults_bounds_firing():
    fi = FaultInjector.seeded(5, 1.0, max_faults=3)
    pat = _fire_pattern(fi, "dispatch", 10)
    assert sum(pat) == 3 and fi.fired == 3
    assert pat[:3] == [1, 1, 1]  # rate 1.0 fires until the bound


# ---------------------------------------------------------------------------
# Ticket sentinel (satellite a) and result-count guard (satellite b)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("resilience", [True, False])
def test_ticket_sentinel_distinguishes_none_result(monkeypatch, resilience):
    """A drain legitimately returning ``None`` must still mark the ticket
    done — the old ``_result is None`` check conflated that with
    "unfilled" and would deadlock/assert in ``result()``."""
    s, stmt, _ = _mk()
    sched = _sched(resilience=resilience)
    t = sched.submit(stmt, {"cutoff": 3})
    monkeypatch.setattr(stmt, "execute_many",
                        lambda plist: [None] * len(plist))
    sched.flush()
    assert t.done()
    assert t.result() is None


def test_bare_fused_drain_result_mismatch_is_typed(monkeypatch):
    """Bare scheduler (resilience off): a short ``execute_fused`` result
    list must fail the wave with WaveResultMismatch (isolation retry then
    recovers per statement), never leak StopIteration from the zip."""
    s, stmt1, stmt2 = _mk()
    sched = _sched(fuse=True, resilience=False)
    real = s.execute_fused
    monkeypatch.setattr(s, "execute_fused", lambda calls: real(calls)[:-1])
    t1 = sched.submit(stmt1, {"cutoff": 3})
    t2 = sched.submit(stmt2, {"m": 2})
    sched.flush()
    assert _xs(t1.result()) == [0, 1, 2]  # isolation retry recovered
    assert len(_xs(t2.result())) == 8
    assert sched.stats["fused_isolated_retries"] == 2
    assert sched.stats["fused_isolated_errors"] == 0


def test_bare_many_drain_result_mismatch_is_typed(monkeypatch):
    s, stmt, _ = _mk()
    sched = _sched(resilience=False)
    real = stmt.execute_many
    monkeypatch.setattr(stmt, "execute_many", lambda plist: real(plist)[:-1])
    t = sched.submit(stmt, {"cutoff": 3})
    sched.flush()
    assert t.done()
    with pytest.raises(WaveResultMismatch):
        t.result()


def test_ladder_recovers_from_result_mismatch(monkeypatch):
    """Under resilience a short result list is just another tier failure:
    the ladder demotes and the ticket still gets its answer."""
    s, stmt, _ = _mk()
    sched = _sched()
    real = stmt.execute_many
    monkeypatch.setattr(stmt, "execute_many", lambda plist: real(plist)[:-1])
    t = sched.submit(stmt, {"cutoff": 4})
    sched.flush()
    assert _xs(t.result()) == [0, 1, 2, 3]
    assert sched.stats["demote_many_to_serial"] == 1
    assert sched.stats["tier_serial_ok"] == 1


# ---------------------------------------------------------------------------
# degradation ladder: demotions per site and tier
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("site", ["compile", "dispatch", "sync"])
def test_single_statement_fault_demotes_to_serial(site):
    s, stmt, _ = _mk()
    FaultInjector([FaultSpec(site=site, times=1)]).install(s)
    sched = _sched()
    t = sched.submit(stmt, {"cutoff": 4})
    sched.flush()
    assert _xs(t.result()) == [0, 1, 2, 3]
    assert sched.stats["demote_many_to_serial"] == 1
    assert sched.stats["tier_serial_ok"] == 1
    assert sched.stats["ladder_exhausted"] == 0


def test_fault_chain_demotes_to_interp():
    """Two dispatch faults eat the many and serial tiers; the INTERPRETED
    floor answers (dispatch never fires on the eager path)."""
    s, stmt, _ = _mk()
    fi = FaultInjector([FaultSpec(site="dispatch", times=None)]).install(s)
    sched = _sched()
    t = sched.submit(stmt, {"cutoff": 4})
    sched.flush()
    assert _xs(t.result()) == [0, 1, 2, 3]
    assert sched.stats["demote_many_to_serial"] == 1
    assert sched.stats["demote_serial_to_interp"] == 1
    assert sched.stats["tier_interp_ok"] == 1
    assert fi.fired >= 2


def test_interp_fault_surfaces_typed_error():
    """Only when the interpreter floor itself fails does the ticket error —
    and the error is typed (the injected fault), never silent data."""
    s, stmt, _ = _mk()
    FaultInjector([FaultSpec(site="*", times=None)]).install(s)
    sched = _sched()
    t = sched.submit(stmt, {"cutoff": 4})
    sched.flush()
    assert t.done()
    with pytest.raises(InjectedFault):
        t.result()
    assert sched.stats["ladder_exhausted"] == 1
    assert sched.stats["tier_interp_ok"] == 0


def test_fused_wave_fault_demotes_members_independently():
    """A fused-wave dispatch fault targeted at one member demotes the wave;
    per-statement retries then isolate the fault to the targeted member's
    tier walk while the other member succeeds at ``many``."""
    s, stmt1, stmt2 = _mk()
    fi = FaultInjector(
        [FaultSpec(site="dispatch", stmt=stmt1._query_fp, times=None)]
    ).install(s)
    sched = _sched(fuse=True)
    t1 = sched.submit(stmt1, {"cutoff": 3})
    t2 = sched.submit(stmt2, {"m": 2})
    sched.flush()
    assert _xs(t1.result()) == [0, 1, 2]  # via serial-or-deeper tier
    assert len(_xs(t2.result())) == 8     # via its own many tier
    assert sched.stats["fused_batches"] == 1   # the wave was attempted
    assert sched.stats["demote_fused_to_many"] == 2
    assert sched.stats["fused_isolated_retries"] == 2
    assert sched.stats["fused_isolated_errors"] == 0
    assert sched.stats["tier_many_ok"] == 1    # stmt2
    assert sched.stats["tier_interp_ok"] == 1  # stmt1 (dispatch faults
    assert fi.fired >= 3                       # hit many+serial tiers too)


def test_retry_backoff_within_tier():
    """Bounded in-tier retries absorb transient faults without demotion;
    backoff delays follow the exponential policy via the injected sleep."""
    s, stmt, _ = _mk()
    FaultInjector([FaultSpec(site="dispatch", times=2)]).install(s)
    sleeps: list = []
    cfg = ResilienceConfig(
        retry=RetryPolicy(max_attempts=3, backoff_s=0.1, backoff_mult=2.0))
    sched = _sched(resilience=cfg, sleep=sleeps.append)
    t = sched.submit(stmt, {"cutoff": 4})
    sched.flush()
    assert _xs(t.result()) == [0, 1, 2, 3]
    assert sched.stats["tier_many_ok"] == 1
    assert sched.stats["demote_many_to_serial"] == 0
    assert sched.stats["retry_backoffs"] == 2
    np.testing.assert_allclose(sleeps, [0.1, 0.2])


# ---------------------------------------------------------------------------
# circuit breakers on the serving path
# ---------------------------------------------------------------------------


def _drain_one(sched, stmt, cutoff=4):
    t = sched.submit(stmt, {"cutoff": cutoff})
    sched.flush()
    return t


def test_breaker_opens_then_half_open_probe_restores():
    """Persistent tier failures open the (statement, tier) breakers; open
    breakers route straight to the interp floor without burning retries;
    once the fault clears and the cooldown passes, the half-open probe
    restores the fast tiers."""
    s, stmt, _ = _mk()
    fi = FaultInjector([FaultSpec(site="dispatch", times=None)]).install(s)
    c = Clock()
    cfg = ResilienceConfig(breaker=BreakerConfig(
        failure_threshold=2, window_s=100.0, cooldown_s=5.0))
    sched = _sched(clock=c, resilience=cfg)
    key_many = (stmt._query_fp, "many")
    board = sched.ladder.board

    for i in range(2):  # two failing drains trip threshold=2 per tier
        assert _xs(_drain_one(sched, stmt).result()) == [0, 1, 2, 3]
    assert board.state(key_many) == OPEN
    assert board.state((stmt._query_fp, "serial")) == OPEN

    fired_before = fi.fired
    skips_before = sched.stats["breaker_open_skips"]
    t = _drain_one(sched, stmt)  # breakers open: straight to interp
    assert _xs(t.result()) == [0, 1, 2, 3]
    assert sched.stats["breaker_open_skips"] >= skips_before + 2
    assert fi.fired == fired_before  # no dispatch even attempted

    fi.specs.clear()  # the fault heals
    c.now += 10.0     # past cooldown: next ask admits a half-open probe
    t = _drain_one(sched, stmt)
    assert _xs(t.result()) == [0, 1, 2, 3]
    assert board.state(key_many) == CLOSED
    snap = sched.resilience_stats["breakers"][key_many]
    assert snap["opened"] == 1 and snap["probes"] == 1
    assert snap["restored"] == 1
    assert sched.stats["tier_many_ok"] >= 1


def test_breaker_half_open_probe_failure_reopens_on_ladder():
    s, stmt, _ = _mk()
    fi = FaultInjector([FaultSpec(site="dispatch", times=None)]).install(s)
    c = Clock()
    cfg = ResilienceConfig(breaker=BreakerConfig(
        failure_threshold=1, window_s=100.0, cooldown_s=5.0))
    sched = _sched(clock=c, resilience=cfg)
    key = (stmt._query_fp, "many")
    _drain_one(sched, stmt)  # one failure: threshold=1 opens immediately
    assert sched.ladder.board.state(key) == OPEN
    c.now += 10.0            # probe admitted, but the fault persists
    t = _drain_one(sched, stmt)
    assert _xs(t.result()) == [0, 1, 2, 3]  # interp floor still answers
    snap = sched.resilience_stats["breakers"][key]
    assert snap["reopened"] == 1
    assert sched.ladder.board.state(key) == OPEN


def test_fused_tier_breaker_skips_wave_membership():
    """An open fused-tier breaker drops the statement out of the wave
    before it forms; with only one eligible member left, fusion is
    skipped entirely and the groups drain per statement."""
    s, stmt1, stmt2 = _mk()
    fi = FaultInjector([FaultSpec(site="dispatch", times=None)]).install(s)
    cfg = ResilienceConfig(breaker=BreakerConfig(
        failure_threshold=1, window_s=100.0, cooldown_s=1e9))
    sched = _sched(fuse=True, resilience=cfg)
    t1 = sched.submit(stmt1, {"cutoff": 3})
    t2 = sched.submit(stmt2, {"m": 2})
    sched.flush()  # wave fails; both fused breakers open (threshold=1)
    t1.result(); t2.result()
    fb = sched.stats["fused_batches"]
    fi.specs.clear()
    t1 = sched.submit(stmt1, {"cutoff": 3})
    t2 = sched.submit(stmt2, {"m": 2})
    sched.flush()
    assert _xs(t1.result()) == [0, 1, 2]
    assert sched.stats["fused_batches"] == fb  # no new wave attempted
    assert sched.stats["breaker_open_skips"] >= 2


# ---------------------------------------------------------------------------
# deadlines: shed-before-drain
# ---------------------------------------------------------------------------


def test_expired_ticket_sheds_with_typed_error():
    s, stmt, _ = _mk()
    c = Clock()
    sched = _sched(clock=c, default_timeout_s=5.0)
    t_live = sched.submit(stmt, {"cutoff": 3})
    t_dead = sched.submit(stmt, {"cutoff": 4}, timeout_s=1.0)
    c.now = 3.0  # past t_dead's deadline, inside t_live's
    sched.flush()
    assert _xs(t_live.result()) == [0, 1, 2]
    assert t_dead.done()
    with pytest.raises(DeadlineExceeded):
        t_dead.result()
    assert sched.stats["deadline_shed"] == 1


def test_deadline_shed_is_pre_drain_not_mid_ladder():
    """All tickets expired: the drain sheds everything and never touches
    the session (no executor work for dead tickets)."""
    s, stmt, _ = _mk()
    fi = FaultInjector([]).install(s)  # pure event counter
    c = Clock()
    sched = _sched(clock=c, default_timeout_s=1.0)
    ts = [sched.submit(stmt, {"cutoff": k}) for k in (2, 3)]
    c.now = 10.0
    sched.flush()
    for t in ts:
        with pytest.raises(DeadlineExceeded):
            t.result()
    assert sched.stats["deadline_shed"] == 2
    assert fi.events == {}  # no seam was ever reached


def test_no_timeout_means_no_deadline():
    s, stmt, _ = _mk()
    c = Clock()
    sched = _sched(clock=c)
    t = sched.submit(stmt, {"cutoff": 3})
    c.now = 1e12
    sched.flush()
    assert _xs(t.result()) == [0, 1, 2]
    assert sched.stats["deadline_shed"] == 0


def test_admission_timeout_passthrough():
    from repro.serve.admission import AdmissionPolicy

    c = Clock()
    sched = _sched(clock=c)
    ap = AdmissionPolicy(scheduler=sched)
    t = ap.submit(tier=1, prompt_len=100, max_new_tokens=50,
                  temperature=0.5, timeout_s=2.0)
    c.now = 5.0
    ap.scheduler.flush()
    with pytest.raises(DeadlineExceeded):
        t.result()
    assert sched.stats["deadline_shed"] == 1


# ---------------------------------------------------------------------------
# chaos conformance oracle: fixed fault schedules
# (site × schedule shape × ladder tier reached × breaker state)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("site", ["compile", "dispatch", "sync"])
@pytest.mark.parametrize("times", [1, 3, None])
def test_chaos_fixed_schedule_recovers(site, times):
    """Any bounded or persistent fault at a recoverable site: every ticket
    still gets the fault-free oracle's answer."""
    out = check_chaos_oracle(5, 23, [FaultSpec(site=site, times=times)])
    assert all(kind == "ok" for kind, _ in out["outcomes"])
    if times is None:
        # the persistent schedule must have pushed at least one group all
        # the way to the interp floor
        assert out["stats"]["tier_interp_ok"] >= 1


def test_chaos_interp_floor_faults_are_typed():
    out = check_chaos_oracle(
        5, 23, [FaultSpec(site="*", times=None)],
        sites=("compile", "dispatch", "sync", "interp"))
    assert all(kind == "error" for kind, _ in out["outcomes"])
    assert all(isinstance(e, ResilienceError) for _, e in out["outcomes"])
    assert out["stats"]["ladder_exhausted"] == len(out["outcomes"])


def test_chaos_targeted_statement_fault():
    """A persistent fault scoped to one statement fingerprint: the wave
    demotes, the targeted statement walks its ladder, the others recover
    at their own tier — all tickets correct."""
    from conformance_util import fusion_queries, make_session

    probe = make_session(5, 23)
    fp = probe.prepare(fusion_queries()[1], FROID)._query_fp
    out = check_chaos_oracle(
        5, 23, [FaultSpec(site="dispatch", stmt=fp, times=None)])
    assert all(kind == "ok" for kind, _ in out["outcomes"])
    assert out["stats"]["demote_fused_to_many"] >= 2
    assert all(site == "dispatch" for site, _, _ in out["injector"].injected)


def test_chaos_open_breaker_still_conformant():
    """Threshold-1 breakers + persistent dispatch faults: breakers open
    mid-drain and route around the failing tiers; results stay correct
    and the transitions are observable."""
    cfg = ResilienceConfig(breaker=BreakerConfig(
        failure_threshold=1, window_s=100.0, cooldown_s=1e9))
    out = check_chaos_oracle(
        5, 23, [FaultSpec(site="dispatch", times=None)], resilience=cfg,
        clock=Clock())
    assert all(kind == "ok" for kind, _ in out["outcomes"])
    opened = sum(b["opened"] for b in out["resilience"]["breakers"].values())
    assert opened >= 1


def test_chaos_half_open_probe_still_conformant():
    """A fault that dies after one firing + an instant cooldown: the
    breaker opens, the very next ask probes half-open, the probe succeeds
    and restores — under a live queue, with conformant results."""
    c = Clock()
    cfg = ResilienceConfig(breaker=BreakerConfig(
        failure_threshold=1, window_s=100.0, cooldown_s=0.0))
    out = check_chaos_oracle(
        5, 23, [FaultSpec(site="dispatch", times=1)], resilience=cfg,
        clock=c)
    assert all(kind == "ok" for kind, _ in out["outcomes"])


@pytest.mark.parametrize("chaos_seed", [0, 1, 2, 3, 4])
def test_chaos_seeded_sweep(chaos_seed):
    """Deterministic mirror of the hypothesis chaos strategy (per the
    PR-5 precedent: the generative surface keeps a fixed-seed floor that
    runs where hypothesis is absent)."""
    out = check_chaos_oracle(7, 23, chaos_seed=chaos_seed, rate=0.4)
    assert all(kind == "ok" for kind, _ in out["outcomes"])


def test_chaos_seeded_sweep_with_interp_faults():
    out = check_chaos_oracle(
        7, 23, chaos_seed=2, rate=0.5,
        sites=("compile", "dispatch", "sync", "interp"))
    for kind, v in out["outcomes"]:
        assert kind == "ok" or isinstance(v, ResilienceError)


def test_chaos_deadline_under_faults():
    """Deadlines compose with fault schedules: with an advancing clock and
    a tight timeout, tickets either answer correctly, shed typed, or (if
    the schedule exhausts the ladder) carry the typed fault."""

    class Step:
        def __init__(self):
            self.now = 0.0

        def __call__(self):
            self.now += 0.5
            return self.now

    out = check_chaos_oracle(
        5, 23, [FaultSpec(site="dispatch", times=2)], clock=Step(),
        timeout_s=4.0)
    kinds = [k for k, _ in out["outcomes"]]
    assert all(k in ("ok", "error") for k in kinds)
    for kind, v in out["outcomes"]:
        if kind == "error":
            assert isinstance(v, ResilienceError)


# ---------------------------------------------------------------------------
# serving engine: shed completions instead of crashed drains
# ---------------------------------------------------------------------------


def test_serve_engine_drain_sheds_expired_admission():
    import jax

    from repro.configs import smoke_config_for
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine

    class Step:  # every clock() call advances 1s: tickets expire between
        def __init__(self):  # submit and drain
            self.now = 0.0

        def __call__(self):
            self.now += 1.0
            return self.now

    cfg = smoke_config_for("granite3_2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = _sched(clock=Step(), default_timeout_s=0.5)
    eng = ServeEngine(model, params, slots=2, max_len=64,
                      admission_scheduler=sched)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
            max_new_tokens=4))
    done = eng.drain()
    assert len(done) == 3
    assert all(c.reason == "shed" and c.tokens == [] for c in done)
    assert len(eng.shed) == 3
    assert sched.stats["deadline_shed"] == 3
