#!/usr/bin/env bash
# Tier-1 verify: the repo's test suite.  pyproject.toml sets
# pythonpath=src, so no PYTHONPATH export is needed — this script exists so
# `scripts/verify.sh` is the one canonical spelling (extra pytest args pass
# through, e.g. `scripts/verify.sh -m "not slow"`).
set -euo pipefail
cd "$(dirname "$0")/.."
exec python -m pytest -x -q "$@"
