"""Figure 7: time vs number of UDF invocations (cardinality of T).

Three series, as in the paper:
  * froid OFF, interpreted          (solid line)   — python mode
  * froid OFF, natively compiled    (Table 5 mode) — scan mode
  * froid ON                        (dashed line)  — set-oriented plan

The UDF is F1-style: calls a second UDF and runs a lookup query per
invocation, so froid OFF does O(N·M) work.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_run
from repro.core import (
    FROID,
    HEKATON,
    INTERPRETED,
    Session,
    UdfBuilder,
    col,
    lit,
    param,
    scan,
    sum_,
    udf,
    var,
)

CARDINALITIES = (10, 100, 1_000, 10_000, 100_000)
PYTHON_MODE_CAP = 1_000  # interpreted per-row execution gets slow fast
M_ROWS = 20_000  # inner table size


def _setup(n_keys=500):
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table(
        "detail",
        d_key=rng.integers(0, n_keys, M_ROWS),
        d_val=rng.uniform(0, 100, M_ROWS).astype(np.float32),
    )

    u = UdfBuilder("F2", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    with u.if_(var("s").is_null()):
        u.return_(lit(0.0))
    u.return_(var("s"))
    db.create_function(u.build())

    u = UdfBuilder("F1", [("a", "int32"), ("b", "float32")], "float32")
    u.declare("total", "float32")
    u.set("total", udf("F2", param("a")))
    with u.if_(var("total") > 1000.0):
        u.return_(var("total") * param("b"))
    u.return_(var("total"))
    db.create_function(u.build())
    return db, n_keys


def run(quick: bool = False):
    db, n_keys = _setup()
    rng = np.random.default_rng(1)
    cards = CARDINALITIES[:3] if quick else CARDINALITIES
    for n in cards:
        db.create_table(
            "T",
            a=rng.integers(0, n_keys, n),
            b=rng.uniform(0.5, 1.5, n).astype(np.float32),
        )
        q = scan("T").compute(v=udf("F1", col("a"), col("b"))).project("v")

        # warm plan cache (paper: cached plans, compile excluded)
        fn_on = db.prepare(q, FROID)
        t_on = time_run(fn_on)
        emit(f"fig7/froid_on/N={n}", t_on * 1e6, f"{t_on*1e9/max(n,1):.0f} ns/row")

        fn_scan = db.prepare(q, HEKATON)
        t_scan = time_run(fn_scan, warmup=1, iters=1 if n >= 10_000 else 3)
        emit(f"fig7/native_iterative/N={n}", t_scan * 1e6,
             f"speedup_vs_froid={t_scan/t_on:.0f}x")

        if n <= PYTHON_MODE_CAP:
            t_py = time_run(
                lambda: db.execute(q, INTERPRETED).masked.mask,
                warmup=0, iters=1,
            )
            emit(f"fig7/interpreted/N={n}", t_py * 1e6,
                 f"speedup_vs_froid={t_py/t_on:.0f}x")


if __name__ == "__main__":
    run()
