"""Cost-based routing layer (ISSUE-8 contract): the static cost model,
the online router (measured wave EMAs, fault-window sample exclusion,
bounded decision log), the three routing axes (policy, batch bucket,
fuse-or-not), the ``ROUTED`` preset / ``policy.routed()`` surface, the
routing conformance oracle, and the stats audit (monotone counters,
``wave_tickets`` normalization, no double counting).

Runs everywhere; the generative layer in ``test_property_froid.py``
drives the same routing oracle over random overlap queues in CI.
"""
import numpy as np
import pytest

import jax

from repro.core import FROID, HEKATON, ROUTED, Session, col, param, scan
from repro.cost import (
    CostRouter,
    estimate_compile_s,
    estimate_plan,
    estimate_statement_s,
)
from repro.cost.router import _Ema
from repro.resilience import FaultInjector, FaultSpec
from repro.serve.scheduler import CoalescingScheduler
from conformance_util import (
    FIXED_PROGRAMS,
    N_ROWS,
    assert_rows_equal,
    build_udf,
    check_routing_oracle,
    fusion_calls_spec,
    fusion_queries,
    make_session,
    param_query,
)


def _routed_session(seed: int = 3):
    db = make_session(seed)
    db.create_function(
        build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    return db


# ---------------------------------------------------------------------------
# policy surface: the ROUTED preset and the routed() tuning knob
# ---------------------------------------------------------------------------


def test_routed_preset_and_helper():
    assert ROUTED.route and ROUTED.name == "routed"
    # route is a tuning knob: routed plans/caches are shared with FROID
    assert ROUTED.fingerprint() == FROID.fingerprint()
    assert FROID.routed().route
    assert not ROUTED.routed(False).route
    # no-op toggles return the same object (replace() churns cache keys)
    assert ROUTED.routed() is ROUTED
    assert FROID.routed(False) is FROID


def test_router_attaches_lazily():
    db = Session()
    db.create_table("t", x=np.arange(8))
    assert db.cost_stats == {"enabled": False}
    q = scan("t").compute(y=col("x") * 2.0).project("y")
    db.prepare(q, FROID)
    assert db.cost_router is None  # unrouted statements never pay for one
    db.prepare(q, ROUTED)
    assert isinstance(db.cost_router, CostRouter)
    assert db.cost_stats["enabled"]


# ---------------------------------------------------------------------------
# static cost model sanity
# ---------------------------------------------------------------------------


def test_estimates_scale_with_work():
    db = _routed_session()
    stmt = db.prepare(param_query(), FROID)
    plan = stmt.plan
    prof = estimate_plan(plan, db.catalog)
    assert prof.rows > 0 and prof.flops > 0 and prof.nodes > 0
    assert prof.seconds() > 0
    # more tickets per wave = more estimated work; more devices = less
    e1 = estimate_statement_s(plan, db.catalog, bucket=1)
    e64 = estimate_statement_s(plan, db.catalog, bucket=64)
    assert e64 > e1
    assert estimate_statement_s(plan, db.catalog, bucket=64, devices=8) < e64
    # compile estimates grow with plan size
    small = db.prepare(scan("keys").compute(z=col("k") * 2.0), FROID).plan
    assert estimate_compile_s(plan) > estimate_compile_s(small) > 0


# ---------------------------------------------------------------------------
# sample intake: EMA updates and fault-window exclusion
# ---------------------------------------------------------------------------


def test_observe_updates_ema_and_counters():
    db = _routed_session()
    stmt = db.prepare(param_query(), ROUTED)
    r = db.cost_router
    r.observe_serial(stmt._query_fp, stmt.policy, 1.0)
    r.observe_serial(stmt._query_fp, stmt.policy, 0.0)
    key = ("serial", stmt._query_fp, stmt.policy.fingerprint())
    ema = r.measured[key]
    assert ema.n == 2 and 0.0 < ema.wave_s < 1.0  # EMA, not last-write-wins
    assert r.stats["samples"] == 2 and r.stats["samples_excluded"] == 0


def test_suppress_drops_samples_and_is_reentrant():
    db = _routed_session()
    stmt = db.prepare(param_query(), ROUTED)
    r = db.cost_router
    with r.suppress():
        with r.suppress():  # ladder tiers nest retries inside demotions
            r.observe_serial(stmt._query_fp, stmt.policy, 9.9)
        assert r.suppressed
        r.observe_many(stmt._query_fp, stmt.policy, (), 4, 9.9, 4,
                       shard=False)
    assert not r.suppressed
    assert r.stats["samples_excluded"] == 2 and r.stats["samples"] == 0
    assert not r.measured and not r.per_ticket  # nothing trained


def test_fault_window_samples_excluded_end_to_end():
    """Dispatch faults push the ladder into retries/demotions; the routed
    session must drop those samples instead of training on them."""
    db = _routed_session()
    qs = fusion_queries()
    stmts = [db.prepare(q, ROUTED) for q in qs]
    FaultInjector([FaultSpec(site="dispatch", times=3)]).install(db)
    sched = CoalescingScheduler(max_batch=256, window_s=10.0,
                                clock=lambda: 0.0, fuse=True,
                                sleep=lambda s: None)
    tickets = [sched.submit(stmts[i], p) for i, p in fusion_calls_spec()]
    sched.flush()
    for t in tickets:
        t.result()  # the ladder recovers every ticket fault-free
    cs = db.cost_stats
    assert cs["samples_excluded"] >= 1, cs
    # the fault-free oracle answer still comes back (ladder floor)
    oracle = _routed_session()
    o_stmts = [oracle.prepare(q, FROID) for q in qs]
    for (i, p), t in zip(fusion_calls_spec(), tickets):
        assert_rows_equal(o_stmts[i].execute(params=p), t.result(),
                          "faulted routed ticket vs oracle")


# ---------------------------------------------------------------------------
# axis: FROID vs HEKATON policy
# ---------------------------------------------------------------------------


def test_choose_policy_prefers_measured_winner():
    db = _routed_session()
    stmt = db.prepare(param_query(), ROUTED)
    r = db.cost_router
    cands = r._policy_candidates(stmt)
    assert len(cands) >= 2  # the UDF makes froid/hekaton genuinely differ
    alt = next(c for c, cfp in cands
               if cfp != stmt.policy.fingerprint())
    fp = stmt._query_fp
    # same-kind measured evidence: the alternative is 10x cheaper
    r.per_ticket[("many", fp, stmt.policy.fingerprint())] = _Ema(1e-2)
    r.per_ticket[("many", fp, alt.fingerprint())] = _Ema(1e-3)
    chosen = r.choose_policy(stmt)
    assert chosen.fingerprint() == alt.fingerprint()
    assert r.stats["policy_reroutes"] == 1
    assert any(d["axis"] == "policy" and d["why"] == "measured"
               for d in r.decisions)
    # flipped evidence flips the route back
    r.per_ticket[("many", fp, alt.fingerprint())] = _Ema(1e-1)
    assert r.choose_policy(stmt).fingerprint() == stmt.policy.fingerprint()


def test_choose_policy_estimate_gated_exploration():
    """Without measurements, an alternative is explored only on a clear
    estimated win — equal estimates never justify a fresh compile."""
    db = _routed_session()
    stmt = db.prepare(param_query(), ROUTED)
    r = db.cost_router
    # force equal estimates: every candidate looks the same on paper
    for c, cfp in r._policy_candidates(stmt):
        key = ("policy", stmt._query_fp, cfp,
               db._catalog_token())
        r.estimates[key] = 1.0
    assert r.choose_policy(stmt).fingerprint() == stmt.policy.fingerprint()
    assert r.stats["policy_reroutes"] == 0


def test_routed_execute_delegates_and_matches():
    """A policy reroute actually executes under the delegate — and the
    answer is still the oracle's (the mode oracle's guarantee, now load-
    bearing for routing)."""
    db = _routed_session()
    stmt = db.prepare(param_query(), ROUTED)
    params = {"cut": 5, "shift": 0.5}
    expected = _routed_session().execute(param_query(), FROID, params=params)
    r = db.cost_router
    alt = next(c for c, cfp in r._policy_candidates(stmt)
               if cfp != stmt.policy.fingerprint())
    fp = stmt._query_fp
    r.per_ticket[("many", fp, stmt.policy.fingerprint())] = _Ema(1.0)
    r.per_ticket[("many", fp, alt.fingerprint())] = _Ema(1e-6)
    got = stmt.execute(params=params)
    assert_rows_equal(expected, got, "rerouted execute vs oracle")
    assert db.cost_stats["policy_reroutes"] >= 1
    # the delegate runs unrouted: one routing decision per call, no loops
    batched = stmt.execute_many([params, {"cut": 3, "shift": 1.5}])
    assert_rows_equal(expected, batched[0], "rerouted execute_many vs oracle")


# ---------------------------------------------------------------------------
# axis: batch bucket (ride a warm larger bucket over a cold compile)
# ---------------------------------------------------------------------------


def test_choose_bucket_rides_warm_bucket():
    db = _routed_session()
    stmt = db.prepare(param_query(), ROUTED)
    r = db.cost_router
    # warm the bucket-8 configuration organically
    params8 = [{"cut": int(k % 6), "shift": 0.5} for k in range(8)]
    stmt.execute_many(params8)
    key8 = next(k for k in r.measured if k[0] == "many" and k[-1] == 8)
    sig = key8[3]
    # measured says bucket 8 is nearly free; the cold bucket-4 compile
    # estimate cannot beat that
    r.measured[key8].wave_s = 1e-9
    assert r.choose_bucket(stmt, sig, 3, 4, 256, shard=False) == 8
    assert r.stats["bucket_rides"] == 1
    # a warm *natural* bucket is never overridden
    assert r.choose_bucket(stmt, sig, 7, 8, 256, shard=False) == 8
    # measured says the warm bucket is terrible: pay the cold compile
    r.measured[key8].wave_s = 1e9
    assert r.choose_bucket(stmt, sig, 3, 4, 256, shard=False) == 4


def test_bucket_ride_preserves_results_end_to_end():
    db = _routed_session()
    stmt = db.prepare(param_query(), ROUTED)
    stmt.execute_many([{"cut": int(k % 6), "shift": 0.5} for k in range(8)])
    r = db.cost_router
    for k in list(r.measured):
        if k[0] == "many":
            r.measured[k].wave_s = 1e-9  # make every warm bucket a ride
    small = [{"cut": 2, "shift": 0.5}, {"cut": 5, "shift": 0.5},
             {"cut": 1, "shift": 0.5}]
    got = stmt.execute_many(small)
    oracle = _routed_session()
    o = oracle.prepare(param_query(), FROID)
    for i, (p, g) in enumerate(zip(small, got)):
        assert_rows_equal(o.execute(params=p), g, f"bucket-ride[{i}]")
    assert db.cost_stats["bucket_rides"] >= 1
    # the ridden wave reports the bucket it actually ran in
    assert got[0].stats["batch_bucket"] == 8


# ---------------------------------------------------------------------------
# axis: fuse-or-not (wave-level routing through the scheduler)
# ---------------------------------------------------------------------------


def test_fuse_axis_explores_both_arms_then_measures():
    """Drain the same mixed wave three times: explore-fused, then
    explore-unfused, then a measured decision — every wave conformant."""
    cs = check_routing_oracle(7, N_ROWS, fuse=True, waves=3)
    assert cs["waves_fused"] >= 1 and cs["waves_unfused"] >= 1, cs
    fuse_whys = [d["why"] for d in cs["decision_log"]
                 if d["axis"] == "fuse"]
    assert fuse_whys[0] == "explore-fused"
    assert "explore-unfused" in fuse_whys
    assert fuse_whys[-1] == "measured"


def test_route_fuse_requires_all_routed():
    """A wave with any unrouted member keeps the scheduler's static fuse
    knob — routing is per-statement opt-in, not a session-wide ambush."""
    db = _routed_session()
    qs = fusion_queries()
    stmts = [db.prepare(qs[0], ROUTED), db.prepare(qs[1], FROID)]
    sched = CoalescingScheduler(max_batch=256, window_s=10.0,
                                clock=lambda: 0.0, fuse=True)
    t1 = sched.submit(stmts[0], {"cut": 5, "shift": 0.5})
    t2 = sched.submit(stmts[1], {"minq": 4, "scale": 2.0})
    sched.flush()
    t1.result(), t2.result()
    assert sched.stats["routed_waves"] == 0
    assert sched.stats["fused_batches"] >= 1  # static knob still fused it


# ---------------------------------------------------------------------------
# routing conformance oracle: sharded/unsharded × fused/unfused
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fuse", [True, False], ids=["fused", "unfused"])
@pytest.mark.parametrize("shard", [False, True], ids=["unsharded", "sharded"])
def test_routing_oracle_matrix(fuse, shard):
    check_routing_oracle(11, N_ROWS, fuse=fuse, shard=shard, waves=2)


def test_routing_oracle_empty_table():
    check_routing_oracle(12, 0, fuse=True, waves=2)


# ---------------------------------------------------------------------------
# stats audit: monotone counters, wave normalization, snapshot shape
# ---------------------------------------------------------------------------


def test_stats_audit_monotone_and_consistent():
    """Scripted drain: cumulative counters never decrease across waves,
    per-ticket wave stats carry the ``wave_tickets`` divisor, and the
    router's sample accounting adds up."""
    db = _routed_session()
    qs = fusion_queries()
    stmts = [db.prepare(q, ROUTED) for q in qs]
    spec = fusion_calls_spec()
    sched = CoalescingScheduler(max_batch=256, window_s=10.0,
                                clock=lambda: 0.0, fuse=True)
    mono_keys = ("samples", "samples_excluded", "decisions",
                 "policy_reroutes", "bucket_rides", "waves_fused",
                 "waves_unfused")
    cache_keys = ("fuse_hits", "fuse_misses", "cse_hits",
                  "cse_shared_nodes", "persist_hits", "persist_misses",
                  "persist_rejects")
    prev_cost = {k: 0 for k in mono_keys}
    prev_cache = {k: 0 for k in cache_keys}
    prev_sched = {"demote_fused_to_many": 0, "demote_many_to_serial": 0,
                  "demote_serial_to_interp": 0, "deadline_shed": 0}
    for wave in range(3):
        tickets = [sched.submit(stmts[i], p) for i, p in spec]
        sched.flush()
        results = [t.result() for t in tickets]
        cs = db.cost_stats
        for k in mono_keys:
            assert cs[k] >= prev_cost[k], (wave, k, cs)
            prev_cost[k] = cs[k]
        for k in cache_keys:
            assert db.cache_stats[k] >= prev_cache[k], (wave, k)
            prev_cache[k] = db.cache_stats[k]
        for k in prev_sched:
            assert sched.stats[k] >= prev_sched[k], (wave, k)
            prev_sched[k] = sched.stats[k]
        for r in results:
            st = r.stats
            assert st.get("dispatch_s", 0.0) >= 0.0
            assert st.get("sync_s", 0.0) >= 0.0
            if st.get("fused"):
                # wave-level numbers are broadcast to every ticket of the
                # wave; wave_tickets is the divisor that undoes it
                assert st["wave_tickets"] == len(results)
                assert st["cse_pool_slots"] >= st["cse_bindings"] >= 0
            elif "wave_tickets" in st:
                assert 1 <= st["wave_tickets"] <= len(spec)
    # router sample accounting: each intake either trains or is excluded
    n_emas = sum(e.n for e in db.cost_router.measured.values())
    assert n_emas == cs["samples"]


def test_cost_stats_snapshot_printable():
    cs = check_routing_oracle(13, N_ROWS, fuse=True, waves=2)
    for label, rec in cs["measured"].items():
        assert isinstance(label, str) and ":" in label
        assert rec["n"] >= 1 and rec["wave_s"] >= 0.0
    for d in cs["decision_log"]:
        assert {"axis", "choice", "why"} <= d.keys()
    # decision log is bounded: it must never grow past the deque cap
    from repro.cost.router import DECISION_LOG
    assert len(cs["decision_log"]) <= DECISION_LOG


def test_routed_sharded_many_matches_serial():
    """The routed execute_many path on a sharded mesh still equals the
    serial oracle (bucket riding and sharding compose)."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    db = _routed_session()
    stmt = db.prepare(param_query(), ROUTED.sharded(mesh))
    params = [{"cut": int(k % 6), "shift": 0.5} for k in range(8)]
    got = stmt.execute_many(params)
    oracle = _routed_session()
    o = oracle.prepare(param_query(), FROID)
    for i, (p, g) in enumerate(zip(params, got)):
        assert_rows_equal(o.execute(params=p), g, f"routed sharded[{i}]")
    assert db.cost_stats["samples"] >= 1
