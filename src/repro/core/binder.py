"""Binding-time UDF substitution (paper §5, §7.1, §7.2).

Froid performs inlining during *binding*, not cost-based optimization: when a
``UdfCall`` is encountered, the UDF body is algebrized (cached per UDF) and
substituted as a correlated scalar subquery, with formal parameters replaced
by actual-argument expressions (rewritten into the subquery's outer scope)
plus explicit type casts (§7.4).  The process repeats for nested calls until
a fixpoint — bounded by ``max_depth`` and ``max_plan_size`` (§7.2): when the
budget is exhausted, remaining ``UdfCall``s are left for the iterative
interpreter (hybrid execution, exactly the paper's fallback).
"""
from __future__ import annotations

import dataclasses
import re

import jax.numpy as jnp

from repro.core import algebrizer as A
from repro.core import ir as IR
from repro.core import relalg as R
from repro.core import scalar as S

_SSA = re.compile(r".*__\d+$")

_CAST_DTYPES = {
    "float32": jnp.float32,
    "int32": jnp.int32,
    "date": jnp.int32,
    "bool": jnp.bool_,
}


@dataclasses.dataclass
class InlineConstraints:
    """§7.2 knobs: bound the algebrized tree."""

    max_depth: int = 8
    max_plan_size: int = 50_000
    enabled: bool = True


class Binder:
    def __init__(self, registry: dict[str, IR.UdfDef],
                 constraints: InlineConstraints | None = None):
        self.registry = registry
        self.constraints = constraints or InlineConstraints()
        self._algebrized: dict[str, R.RelNode | None] = {}
        self._inline_id = 0
        self.stats = {"inlined": 0, "skipped": 0}

    # ------------------------------------------------------------------
    def algebrized(self, name: str) -> R.RelNode | None:
        """Algebrize (and cache) a UDF; None if not inlineable."""
        if name not in self._algebrized:
            udf = self.registry.get(name)
            if udf is None:
                self._algebrized[name] = None
            else:
                try:
                    self._algebrized[name] = A.algebrize(udf)
                except A.AlgebrizeError:
                    self._algebrized[name] = None
        return self._algebrized[name]

    # ------------------------------------------------------------------
    def bind(self, plan: R.RelNode) -> R.RelNode:
        """Normalize UdfCalls into Compute columns, then inline to fixpoint."""
        if not self.constraints.enabled:
            return plan
        plan = _normalize_udf_calls(plan)
        for _ in range(self.constraints.max_depth):
            plan, changed = self._inline_pass(plan)
            if not changed:
                break
            plan = _normalize_udf_calls(plan)
        return plan

    # ------------------------------------------------------------------
    def _inline_pass(self, plan: R.RelNode):
        changed = [False]
        budget = self.constraints.max_plan_size - R.plan_size(plan)

        def fix_expr(e: S.Scalar) -> S.Scalar:
            def f(x):
                nonlocal budget
                if isinstance(x, S.ScalarSubquery):
                    p2, ch = self._inline_in_plan(x.plan, fix_expr)
                    if ch:
                        changed[0] = True
                        return S.ScalarSubquery(p2, x.column, x.agg_default)
                    return None
                if isinstance(x, S.Exists):
                    p2, ch = self._inline_in_plan(x.plan, fix_expr)
                    if ch:
                        changed[0] = True
                        return S.Exists(p2, x.negated)
                    return None
                if not isinstance(x, S.UdfCall):
                    return None
                body = self.algebrized(x.name)
                if body is None:
                    self.stats["skipped"] += 1
                    return None
                size = R.plan_size(body)
                if size > budget:
                    self.stats["skipped"] += 1
                    return None  # §7.2: tree-size constraint hit
                budget -= size
                changed[0] = True
                self.stats["inlined"] += 1
                return self._substitute(x)
            return S.transform(e, f)

        plan, _ = self._inline_in_plan(plan, fix_expr)
        return plan, changed[0]

    def _inline_in_plan(self, plan: R.RelNode, fix_expr):
        before = [False]

        def node_fn(node: R.RelNode):
            if isinstance(node, R.Compute):
                new = {k: fix_expr(v) for k, v in node.computed.items()}
                if any(new[k] is not node.computed[k] for k in new):
                    before[0] = True
                    return R.Compute(node.child, new)
            if isinstance(node, R.Filter):
                p2 = fix_expr(node.pred)
                if p2 is not node.pred:
                    before[0] = True
                    return R.Filter(node.child, p2)
            if isinstance(node, R.GroupAgg):
                aggs = {
                    k: R.AggSpec(a.fn, None if a.expr is None else fix_expr(a.expr))
                    for k, a in node.aggs.items()
                }
                if any(
                    aggs[k].expr is not node.aggs[k].expr for k in aggs
                ):
                    before[0] = True
                    return R.GroupAgg(node.child, node.keys, aggs, node.capacity,
                                  node.dense_range)
            return None

        return R.transform_plan(plan, node_fn), before[0]

    # ------------------------------------------------------------------
    def _substitute(self, call: S.UdfCall) -> S.ScalarSubquery:
        """Replace a UdfCall with its algebrized body: rename SSA columns
        (one inline site == one fresh namespace), bind actual parameters
        (rewritten into Outer scope, with explicit casts — §7.4)."""
        udf = self.registry[call.name]
        body = self.algebrized(call.name)
        self._inline_id += 1
        suffix = f"_i{self._inline_id}"

        def rn(name: str) -> str:
            if _SSA.match(name) or name == "returnVal":
                return name + suffix
            return name

        # actual parameters, rewritten into the subquery's outer scope
        args: dict[str, S.Scalar] = {}
        for (pname, pdtype), arg in zip(udf.params, call.args):
            a = S.transform(
                arg,
                lambda x: S.Outer(x.name) if isinstance(x, S.ColRef) else None,
            )
            if pdtype in _CAST_DTYPES and not isinstance(a, S.Const):
                a = S.Cast(a, _CAST_DTYPES[pdtype])
            args[pname] = a

        def fix_scalar(e: S.Scalar) -> S.Scalar:
            def f(x):
                if isinstance(x, S.ColRef):
                    return S.ColRef(rn(x.name))
                if isinstance(x, S.Outer):
                    return S.Outer(rn(x.name))
                if isinstance(x, S.Param):
                    if x.name not in args:
                        return None  # outer query's own params
                    return args[x.name]
                if isinstance(x, S.ScalarSubquery):
                    return S.ScalarSubquery(fix_plan(x.plan), x.column, x.agg_default)
                if isinstance(x, S.Exists):
                    return S.Exists(fix_plan(x.plan), x.negated)
                return None

            return S.transform(e, f)

        def fix_plan(p: R.RelNode) -> R.RelNode:
            def nf(node: R.RelNode):
                if isinstance(node, R.Compute):
                    return R.Compute(
                        node.child,
                        {rn(k): fix_scalar(v) for k, v in node.computed.items()},
                    )
                if isinstance(node, R.Filter):
                    return R.Filter(node.child, fix_scalar(node.pred))
                if isinstance(node, R.Project):
                    return R.Project(
                        node.child, {rn(k): rn(v) for k, v in node.cols.items()}
                    )
                if isinstance(node, R.GroupAgg):
                    aggs = {
                        rn(k): R.AggSpec(
                            a.fn, None if a.expr is None else fix_scalar(a.expr)
                        )
                        for k, a in node.aggs.items()
                    }
                    return R.GroupAgg(node.child, node.keys, aggs, node.capacity,
                                  node.dense_range)
                if isinstance(node, R.Apply) and node.passthrough is not None:
                    return R.Apply(
                        node.left, node.right, node.kind,
                        fix_scalar(node.passthrough),
                    )
                if hasattr(node, "map_exprs"):  # LoopScan & friends
                    return node.map_exprs(fix_scalar)
                return None

            return R.transform_plan(p, nf)

        new_plan = fix_plan(body)
        sq = S.ScalarSubquery(new_plan, "returnVal" + suffix)
        if udf.return_dtype in _CAST_DTYPES:
            return S.Cast(sq, _CAST_DTYPES[udf.return_dtype])
        return sq


# ---------------------------------------------------------------------------
# normalization: pull UdfCalls out of Filter preds / agg exprs into Computes
# so substitution always happens inside a Compute (clean splice target).
# ---------------------------------------------------------------------------


def _has_udf_call(e: S.Scalar) -> bool:
    return any(isinstance(x, S.UdfCall) for x in S.walk(e))


def _normalize_udf_calls(plan: R.RelNode) -> R.RelNode:
    ctr = [0]

    def extract(e: S.Scalar, pre: dict[str, S.Scalar]) -> S.Scalar:
        """Replace top-level-reachable UdfCalls in e with ColRefs to new
        computed columns collected in ``pre``."""

        def f(x):
            if isinstance(x, S.UdfCall):
                ctr[0] += 1
                name = f"__udf{ctr[0]}"
                pre[name] = x
                return S.ColRef(name)
            return None

        return S.transform(e, f)

    def rule(node: R.RelNode):
        if isinstance(node, R.Filter) and _has_udf_call(node.pred):
            pre: dict[str, S.Scalar] = {}
            pred = extract(node.pred, pre)
            return R.Filter(R.Compute(node.child, pre), pred)
        if isinstance(node, R.GroupAgg) and any(
            a.expr is not None and _has_udf_call(a.expr)
            for a in node.aggs.values()
        ):
            pre = {}
            aggs = {}
            for k, a in node.aggs.items():
                if a.expr is not None and _has_udf_call(a.expr):
                    aggs[k] = R.AggSpec(a.fn, extract(a.expr, pre))
                else:
                    aggs[k] = a
            return R.GroupAgg(
                R.Compute(node.child, pre), node.keys, aggs, node.capacity,
                node.dense_range,
            )
        return None

    return R.transform_plan(plan, rule)
