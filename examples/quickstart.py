"""Quickstart: the paper's Figure 1 example end-to-end.

    PYTHONPATH=src python examples/quickstart.py

Defines the `total_price` UDF (imperative: declarations, SELECT-assigns,
IF/ELSE, nested UDF call), runs a query over customers with Froid OFF
(iterative, per-tuple interpretation) and Froid ON (algebrized + inlined +
set-oriented plan), prints the plans and the speedup.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (
    Database, UdfBuilder, col, lit, param, scalar_subquery, scan, sum_, udf, var,
)

db = Database()
rng = np.random.default_rng(0)
n_cust, n_ord = 2_000, 20_000
db.create_table("customer", c_custkey=np.arange(n_cust))
db.create_table("orders",
                o_custkey=rng.integers(0, n_cust, n_ord),
                o_totalprice=rng.uniform(10, 1000, n_ord).astype(np.float32))
db.create_table("customer_prefs", custkey=np.arange(n_cust),
                currency=np.array(["USD" if i % 3 else "EUR" for i in range(n_cust)]))
db.create_table("xchg", from_cur=np.array(["USD"]), to_cur=np.array(["EUR"]),
                rate=np.array([0.9], dtype=np.float32))

# dbo.xchg_rate
u = UdfBuilder("xchg_rate", [("frm", "str"), ("to", "str")], "float32")
u.return_(scalar_subquery(
    scan("xchg")
    .filter((col("from_cur") == param("frm")) & (col("to_cur") == param("to")))
    .compute(r=col("rate")).project("r"), "r"))
db.create_function(u.build())

# dbo.total_price (Figure 1)
u = UdfBuilder("total_price", [("key", "int32")], "float32")
u.declare("price", "float32")
u.declare("rate", "float32")
u.declare("pref_currency", "str")
u.declare("default_currency", "str", lit("USD"))
u.select({"price": sum_(col("o_totalprice"))},
         frm=scan("orders"), where=col("o_custkey") == param("key"))
u.select({"pref_currency": col("currency")},
         frm=scan("customer_prefs"), where=col("custkey") == param("key"))
with u.if_(var("pref_currency") != var("default_currency")):
    u.set("rate", udf("xchg_rate", var("default_currency"), var("pref_currency")))
    u.set("price", var("price") * var("rate"))
u.return_(var("price"))
db.create_function(u.build())

q = scan("customer").compute(total=udf("total_price", col("c_custkey"))) \
                    .project("c_custkey", "total")

print("=== Froid ON: algebrized + inlined + optimized plan ===")
print(db.explain(q, froid=True))

import time
import jax
fn_on, _ = db.run_compiled(q, froid=True)
jax.block_until_ready(fn_on())  # warm (plan cache)
t0 = time.perf_counter()
jax.block_until_ready(fn_on())
t_on = time.perf_counter() - t0

# iterative baseline on a subset (it is slow — that is the point)
sub = scan("customer").filter(col("c_custkey") < 100) \
    .compute(total=udf("total_price", col("c_custkey")))
r_off = db.run(sub, froid=False, mode="python")
t_off = r_off.elapsed_s * n_cust / 100

r_on = db.run(q, froid=True)
a = np.asarray(r_on.table.columns["total"].data)
print(f"\nfirst totals: {a[:5]}")
print(f"froid ON  (warm, {n_cust} rows):  {t_on*1e3:9.1f} ms")
print(f"froid OFF (interpreted, extrap.): {t_off*1e3:9.1f} ms")
print(f"speedup: {t_off/t_on:.0f}x")
