"""seamless-m4t-large-v2 [audio] — enc-dec, d_model=1024 16H d_ff=8192
vocab=256206; 24 encoder + 24 decoder layers.  The modality frontend is a
STUB: input_specs() provides precomputed frame embeddings.
[arXiv:2308.11596]"""
import dataclasses

from repro.models.config import ArchConfig, LayerSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-large-v2",
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab=256206,
        head_dim=64,
        super_block=(LayerSpec(mixer="attn", mlp="dense", cross_memory=True),),
        n_repeats=24,  # decoder
        n_encoder_layers=24,
        encoder_frontend_dim=1024,
        max_seq_len=32_768,
        subquadratic=False,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        config(), d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
        head_dim=16, n_repeats=2, n_encoder_layers=2, encoder_frontend_dim=64,
        max_seq_len=128,
    )
