"""Backward-compatible facade over :class:`repro.core.session.Session`.

``Database`` was the original entry point, exposing the paper's experiment
axes as boolean kwargs (``froid=…, mode=…, optimize=…``) and re-planning on
every ``run()``.  It is now a thin shim: every call maps its kwargs onto an
:class:`ExecutionPolicy` and routes through the session's plan/executable
caches.  New code should use ``Session.prepare(…).execute(…)`` with the
policy presets (``FROID`` / ``INTERPRETED`` / ``HEKATON``) directly — see
ROADMAP.md §Public API for the deprecation path.
"""
from __future__ import annotations

from repro.core import relalg as R
from repro.core.binder import InlineConstraints
from repro.core.policy import ExecutionPolicy
from repro.core.session import QueryResult, RunResult, Session
from repro.tables.table import Table


class Database:
    def __init__(self, constraints: InlineConstraints | None = None):
        self.session = Session(constraints=constraints)

    # the session owns catalog/registry/constraints; the shim forwards both
    # reads and (legacy benchmark-style) whole-attribute assignment
    @property
    def catalog(self) -> dict[str, Table]:
        return self.session.catalog

    @catalog.setter
    def catalog(self, value):
        self.session.catalog = value

    @property
    def registry(self):
        return self.session.registry

    @registry.setter
    def registry(self, value):
        self.session.registry = value

    @property
    def constraints(self) -> InlineConstraints:
        return self.session.constraints

    @constraints.setter
    def constraints(self, value):
        self.session.constraints = value

    # -- DDL ---------------------------------------------------------------
    # name/table positional-only: columns may be called "name"/"table"
    def create_table(self, name: str, table: Table | None = None, /, **arrays):
        return self.session.create_table(name, table, **arrays)

    def create_function(self, udf):
        return self.session.create_function(udf)

    # -- planning ----------------------------------------------------------
    def plan_for(self, query, froid: bool = True, optimize: bool = True) -> R.RelNode:
        policy = ExecutionPolicy.from_kwargs(froid=froid, optimize=optimize)
        return self.session.prepare(query, policy).plan

    def explain(self, query, froid: bool = True, optimize: bool = True) -> str:
        policy = ExecutionPolicy.from_kwargs(froid=froid, optimize=optimize)
        return self.session.explain(query, policy)

    # -- execution ---------------------------------------------------------
    def run(
        self,
        query,
        froid: bool = True,
        mode: str = "python",
        optimize: bool = True,
        params: dict | None = None,
        jit_statements: bool = True,
        pallas_agg: bool = False,
    ) -> QueryResult:
        """Eager execution with the legacy kwarg axes (deprecated spelling
        of ``session.execute(query, policy, params)``)."""
        policy = ExecutionPolicy.from_kwargs(
            froid=froid, mode=mode, optimize=optimize,
            jit_statements=jit_statements, pallas_agg=pallas_agg,
            compiled=False,
        )
        return self.session.execute(query, policy, params=params)

    def run_compiled(self, query, froid: bool = True, mode: str = "scan",
                     optimize: bool = True):
        """Deprecated spelling of ``session.prepare(…)``: returns the raw
        compiled callable plus the plan (the old warm-cache benchmark
        interface).  ``PreparedStatement`` itself is the replacement."""
        policy = ExecutionPolicy.from_kwargs(
            froid=froid, mode=mode, optimize=optimize, compiled=True,
        )
        ps = self.session.prepare(query, policy)
        return ps, ps.plan


__all__ = ["Database", "QueryResult", "RunResult"]
