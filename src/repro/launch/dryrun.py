import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST precede any jax import (device count locks on
# first init).
#
# Multi-pod dry-run: lower + compile every (architecture × input shape)
# for the production meshes and record memory / cost / collective stats.
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch granite3_2b \
#         --shape train_4k --mesh single
#     PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
#
# Each cell lowers the real step function (train_step / prefill /
# serve_step) with ShapeDtypeStruct inputs — no arrays are ever allocated —
# and must ``.lower().compile()`` cleanly on the 16×16 (single-pod) and
# 2×16×16 (multi-pod) meshes.  Failures here (sharding mismatch, OOM at
# compile, unsupported collective) are bugs in the system.
import argparse
import gc
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, config_for
from repro.dist.activations import clear_activation_mesh, set_activation_mesh
from repro.dist.sharding import (
    batch_specs,
    cache_specs,
    param_specs,
    shardings_for,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    model_flops_decode,
    model_flops_train,
    roofline_from_compiled,
)
from repro.models.config import SHAPES
from repro.models.model_zoo import (
    build_model,
    input_specs,
    memory_len_for,
    shape_supported,
)
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.train_loop import TrainState, make_train_step


def _shardings(tree, specs, mesh):
    return shardings_for(specs, mesh)


def _serving_dtype(param_shapes):
    """Serving deployments store bf16 weights (inference checkpoints)."""
    import jax.numpy as jnp

    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if (s.dtype == jnp.float32 and len(s.shape) >= 2)
        else s,
        param_shapes,
    )


RESIDUAL_BUDGET = 4 * 2**30  # per-device budget for the remat carry stack


def auto_microbatches(cfg, shape, dp: int) -> int:
    """Smallest microbatch count whose remat residual stack
    (n_repeats × B_local × S × D × 6 B, the bf16+f32 stacking) fits the
    budget.  Must divide the global batch and keep B/mb ≥ dp."""
    reps = cfg.n_repeats + cfg.n_encoder_layers
    for mb in (1, 2, 4, 8, 16):
        if shape.global_batch % mb or (shape.global_batch // mb) % dp:
            continue
        b_local = shape.global_batch // mb // dp
        stack = reps * b_local * shape.seq_len * cfg.d_model * 6
        if stack <= RESIDUAL_BUDGET:
            return mb
    return 16


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                microbatches: int = 1, remat: bool = True,
                int8_kv: bool = False) -> dict:
    import dataclasses as _dc

    cfg = config_for(arch)
    if int8_kv:
        cfg = _dc.replace(cfg, kv_cache_int8=True)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "skip",
    }
    if not ok:
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    set_activation_mesh(mesh)
    chips = 512 if multi_pod else 256
    dp = chips // 16  # data(*pod) degree
    if microbatches == 0 and shape.kind == "train":
        microbatches = auto_microbatches(cfg, shape, dp)
    rec["microbatches"] = microbatches if shape.kind == "train" else None
    model = build_model(cfg)
    specs = input_specs(cfg, shape)

    t0 = time.perf_counter()
    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            param_shapes = model.init_shapes()
            opt_shapes = jax.eval_shape(
                lambda p: adamw_init(p, opt_cfg), param_shapes
            )
            state_shapes = TrainState(param_shapes, opt_shapes, None)
            p_specs = param_specs(param_shapes, mesh, cfg)
            o_specs = {
                "m": p_specs,
                "v": p_specs,
                "step": jax.sharding.PartitionSpec(),
            }
            state_sh = TrainState(
                shardings_for(p_specs, mesh),
                shardings_for(o_specs, mesh),
                None,
            )
            b_specs = batch_specs(specs, mesh, cfg)
            batch_sh = shardings_for(b_specs, mesh)
            step = make_train_step(model, opt_cfg, microbatches=microbatches,
                                   remat=remat)
            lowered = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=0,
            ).lower(state_shapes, specs)
            flops_model = model_flops_train(
                cfg, shape.global_batch * shape.seq_len
            )
        elif shape.kind == "prefill":
            param_shapes = _serving_dtype(model.init_shapes())
            # NB: mode="serve" (TP-only weights) was tried and REFUTED for
            # B=1 decode: replicated weights cost more HBM reads than the
            # FSDP all-gather they remove (EXPERIMENTS.md §Perf).
            p_specs = param_specs(param_shapes, mesh, cfg)
            param_sh = shardings_for(p_specs, mesh)
            b_specs = batch_specs(specs, mesh, cfg)
            batch_sh = shardings_for(b_specs, mesh)

            def prefill_fn(params, batch):
                return model.prefill(
                    params, batch["tokens"], batch.get("memory"),
                    max_len=shape.seq_len,
                )

            lowered = jax.jit(
                prefill_fn, in_shardings=(param_sh, batch_sh)
            ).lower(param_shapes, specs)
            flops_model = 2.0 * cfg.active_param_count() * (
                shape.global_batch * shape.seq_len
            )
        else:  # decode
            param_shapes = _serving_dtype(model.init_shapes())
            # NB: mode="serve" (TP-only weights) was tried and REFUTED for
            # B=1 decode: replicated weights cost more HBM reads than the
            # FSDP all-gather they remove (EXPERIMENTS.md §Perf).
            p_specs = param_specs(param_shapes, mesh, cfg)
            param_sh = shardings_for(p_specs, mesh)
            cache_shapes = specs["cache"]
            c_specs = cache_specs(cache_shapes, mesh, cfg)
            cache_sh = shardings_for(c_specs, mesh)
            tok_sh = shardings_for(
                batch_specs({"tokens": specs["tokens"]}, mesh, cfg), mesh
            )["tokens"]

            def serve_step(params, cache, tokens):
                return model.decode_step(params, cache, tokens)

            lowered = jax.jit(
                serve_step,
                in_shardings=(param_sh, cache_sh, tok_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=1,
            ).lower(param_shapes, cache_shapes, specs["tokens"])
            flops_model = model_flops_decode(cfg, shape.global_batch)

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    clear_activation_mesh()
    mem = compiled.memory_analysis()
    roof, colls = roofline_from_compiled(compiled, chips)
    rec.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "per_device_total": int(
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
        roofline=roof.as_dict(),
        collectives={
            "bytes": colls.bytes_by_kind,
            "count": colls.count_by_kind,
        },
        model_flops_global=flops_model,
        model_flops_per_chip=flops_model / chips,
        useful_flop_ratio=(
            flops_model / chips / roof.flops if roof.flops else None
        ),
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=0)  # 0 = auto
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--int8-kv", action="store_true",
                    help="int8 KV cache variant (§Perf)")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}.{shape}.{'multi' if multi else 'single'}"
                if args.int8_kv:
                    tag += ".int8kv"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = dryrun_cell(arch, shape, multi,
                                      microbatches=args.microbatches,
                                      remat=not args.no_remat,
                                      int8_kv=args.int8_kv)
                except Exception as e:
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if multi else "16x16",
                        "status": "fail", "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    m = rec["memory"]["per_device_total"] / 2**30
                    d = rec["roofline"]["dominant"]
                    extra = (f" mem/dev={m:.2f}GiB dom={d} "
                             f"compile={rec['compile_s']:.0f}s")
                elif status == "fail":
                    extra = " " + rec["error"][:120]
                print(f"[{status:4s}] {tag}{extra}", flush=True)
                gc.collect()
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
