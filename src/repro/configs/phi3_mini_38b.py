"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064; RoPE + SwiGLU.  [arXiv:2404.14219]"""
import dataclasses

from repro.models.config import ArchConfig, LayerSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="phi3-mini-3.8b",
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32064,
        head_dim=96,
        super_block=(LayerSpec(mixer="attn", mlp="dense"),),
        n_repeats=32,
        max_seq_len=131_072,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        config(), d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
        head_dim=16, n_repeats=2, max_seq_len=128,
    )
