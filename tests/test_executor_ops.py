"""Unit tests: relational operators vs numpy oracles, NULL semantics,
date intrinsics, Apply probe/pass-through."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Database, avg_, col, count_, lit, max_, min_, scan, sum_
from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.executor import Executor
from repro.tables.table import Table, civil_from_days, date_add, date_part, days_from_civil


def _db(rng, n=200, k=13):
    db = Database()
    db.create_table(
        "t",
        k=rng.integers(0, k, n),
        v=rng.uniform(-5, 5, n).astype(np.float32),
        q=rng.integers(0, 100, n),
    )
    db.create_table("d", dk=np.arange(k), w=rng.uniform(0, 1, k).astype(np.float32))
    return db


def test_filter_and_groupby_vs_numpy(rng):
    db = _db(rng)
    q = (
        scan("t")
        .filter(col("q") > 50)
        .group_by("k", s=sum_(col("v")), c=count_(), m=min_(col("v")),
                  x=max_(col("v")), a=avg_(col("v")))
    )
    r = db.run(q).table
    kk = np.asarray(db.catalog["t"].columns["k"].data)
    vv = np.asarray(db.catalog["t"].columns["v"].data)
    qq = np.asarray(db.catalog["t"].columns["q"].data)
    sel = qq > 50
    got = {int(k): i for i, k in enumerate(np.asarray(r.columns["k"].data))}
    for key in np.unique(kk[sel]):
        rows = vv[sel & (kk == key)]
        i = got[int(key)]
        np.testing.assert_allclose(r.columns["s"].data[i], rows.sum(), rtol=1e-5)
        assert int(r.columns["c"].data[i]) == len(rows)
        np.testing.assert_allclose(r.columns["m"].data[i], rows.min(), rtol=1e-5)
        np.testing.assert_allclose(r.columns["x"].data[i], rows.max(), rtol=1e-5)
        np.testing.assert_allclose(r.columns["a"].data[i], rows.mean(), rtol=1e-4)


def test_join_left_and_inner(rng):
    db = _db(rng)
    q = scan("t").join(scan("d"), on=("k", "dk"), kind="inner").compute(
        wv=col("v") * col("w")
    )
    r = db.run(q).table
    assert r.num_rows == db.catalog["t"].num_rows  # all keys exist in d
    vv = np.asarray(db.catalog["t"].columns["v"].data)
    kk = np.asarray(db.catalog["t"].columns["k"].data)
    ww = np.asarray(db.catalog["d"].columns["w"].data)
    # result preserves probe order
    np.testing.assert_allclose(
        np.asarray(r.columns["wv"].data), vv * ww[kk], rtol=1e-5
    )


def test_left_join_null_padding(rng):
    db = Database()
    db.create_table("a", x=np.array([0, 1, 2, 3]))
    db.create_table("b", y=np.array([1, 3]), z=np.array([10.0, 30.0], dtype=np.float32))
    q = scan("a").join(scan("b"), on=("x", "y"), kind="left")
    r = db.run(q)
    z = r.table.columns["z"]
    valid = np.asarray(z.validity())
    assert valid.tolist() == [False, True, False, True]
    assert np.asarray(z.data)[1] == 10.0 and np.asarray(z.data)[3] == 30.0


def test_semi_anti_join(rng):
    db = Database()
    db.create_table("a", x=np.array([0, 1, 2, 3, 4]))
    db.create_table("b", y=np.array([1, 3]))
    semi = db.run(scan("a").join(scan("b"), on=("x", "y"), kind="semi")).table
    anti = db.run(scan("a").join(scan("b"), on=("x", "y"), kind="anti")).table
    assert sorted(np.asarray(semi.columns["x"].data).tolist()) == [1, 3]
    assert sorted(np.asarray(anti.columns["x"].data).tolist()) == [0, 2, 4]


def test_sort_limit(rng):
    db = _db(rng)
    q = scan("t").sort(("v", False), limit=5)
    r = db.run(q).table
    vv = np.sort(np.asarray(db.catalog["t"].columns["v"].data))[::-1][:5]
    np.testing.assert_allclose(np.asarray(r.columns["v"].data), vv, rtol=1e-6)


def test_null_three_valued_logic():
    n = S.Const(None)
    t = S.Const(True)
    f = S.Const(False)
    ctx = S.EvalContext()

    def ev(e):
        v = S.eval_scalar(e, {}, ctx)
        return (bool(np.asarray(v.data)), bool(np.asarray(v.validity())))

    # Kleene: NULL or TRUE == TRUE; NULL and FALSE == FALSE; NULL and TRUE == NULL
    assert ev(S.BoolOp("or", [n, t])) == (True, True)
    assert ev(S.BoolOp("and", [n, f]))[1] is True and ev(S.BoolOp("and", [n, f]))[0] is False
    assert ev(S.BoolOp("and", [n, t]))[1] is False
    assert ev(S.BoolOp("not", [n]))[1] is False
    # arithmetic propagates NULL
    assert ev(S.Const(1) + n)[1] is False
    # IS NULL / COALESCE
    assert ev(S.IsNull(n)) == (True, True)
    v = S.eval_scalar(S.Coalesce([n, S.Const(3)]), {}, ctx)
    assert int(np.asarray(v.data)) == 3 and bool(np.asarray(v.validity()))


def test_division_by_zero_is_null():
    ctx = S.EvalContext()
    v = S.eval_scalar(S.Const(1.0) / S.Const(0.0), {}, ctx)
    assert not bool(np.asarray(v.validity()))


def test_date_roundtrip_and_arith():
    days = jnp.asarray([0, 1, 365, 10957, 19000, -1], jnp.int32)
    y, m, d = civil_from_days(days)
    back = days_from_civil(y, m, d)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(days))
    assert np.asarray(y).tolist() == [1970, 1970, 1971, 2000, 2022, 1969]
    # 1970-01-01 + 1 month = 1970-02-01
    feb = date_add("mm", 1, jnp.asarray(0))
    assert int(np.asarray(feb)) == 31
    assert int(np.asarray(date_part("yy", date_add("yy", 5, jnp.asarray(0))))) == 1975
    # dw: 1970-01-01 was a Thursday (dw=5 with Sunday=1)
    assert int(np.asarray(date_part("dw", jnp.asarray(0)))) == 5


def test_apply_probe_passthrough(rng):
    """Apply.passthrough: rows where the predicate is true bypass the right
    side (their right-side columns are NULL) — paper §4.2.1."""
    db = Database()
    db.create_table("a", x=np.array([1.0, 2.0, 3.0, 4.0], dtype=np.float32))
    right = R.Compute(R.ConstantScan(), {"y": S.Outer("x") * S.Const(10.0)})
    plan = R.Apply(R.Scan("a"), right, kind="outer", passthrough=S.ColRef("x") > S.Const(2.5))
    ex = Executor(db.catalog)
    out = ex.execute(plan)
    valid = np.asarray(out.table.columns["y"].validity())
    data = np.asarray(out.table.columns["y"].data)
    assert valid.tolist() == [True, True, False, False]
    np.testing.assert_allclose(data[:2], [10.0, 20.0])


def test_uncorrelated_subquery_hoisted(rng):
    db = _db(rng)
    q = scan("t").compute(
        rel=col("v")
        - S.ScalarSubquery(
            R.GroupAgg(R.Scan("t"), [], {"m": R.AggSpec("avg", S.ColRef("v"))}), "m"
        )
    )
    r = db.run(q).table
    vv = np.asarray(db.catalog["t"].columns["v"].data)
    np.testing.assert_allclose(
        np.asarray(r.columns["rel"].data), vv - vv.mean(), rtol=1e-4, atol=1e-5
    )


def test_string_like_and_in(rng):
    db = Database()
    db.create_table(
        "p",
        pname=np.array(["PROMO A", "STANDARD B", "PROMO C", "ECO D"]),
        v=np.array([1.0, 2.0, 3.0, 4.0], dtype=np.float32),
    )
    from repro.core import like, in_list

    r = db.run(scan("p").filter(like(col("pname"), "PROMO%"))).table
    assert r.num_rows == 2
    r2 = db.run(scan("p").filter(in_list(col("pname"), ["ECO D", "PROMO A"]))).table
    assert r2.num_rows == 2


def test_groupagg_capacity_overflow_guard(rng):
    db = _db(rng, n=50, k=10)
    q = scan("t").group_by("k", capacity=10, s=sum_(col("v")))
    r = db.run(q).table
    assert r.num_rows == len(np.unique(np.asarray(db.catalog["t"].columns["k"].data)))


def test_relagg_batchmode_matches_sort_path(rng):
    """GroupAgg via the fused Pallas relagg kernel (batch mode, §8.2.6)
    equals the sort-based path on a dictionary key."""
    db = Database()
    n = 500
    flags = np.array(["A", "B", "C"])[rng.integers(0, 3, n)]
    db.create_table(
        "li",
        flag=flags,
        price=rng.uniform(1, 100, n).astype(np.float32),
        qty=rng.integers(1, 10, n),
    )
    q = scan("li").filter(col("qty") > 3).group_by(
        "flag", s=sum_(col("price")), c=count_(), a=avg_(col("price"))
    )
    r_sort = db.run(q, pallas_agg=False).table
    r_pal = db.run(q, pallas_agg=True).table
    key_sort = {db.catalog["li"].columns["flag"].dictionary.decode(k): i
                for i, k in enumerate(np.asarray(r_sort.columns["flag"].data))}
    key_pal = {db.catalog["li"].columns["flag"].dictionary.decode(k): i
               for i, k in enumerate(np.asarray(r_pal.columns["flag"].data))}
    assert set(key_sort) == set(key_pal)
    for key in key_sort:
        i, j = key_sort[key], key_pal[key]
        for colname in ("s", "c", "a"):
            np.testing.assert_allclose(
                np.asarray(r_sort.columns[colname].data)[i],
                np.asarray(r_pal.columns[colname].data)[j],
                rtol=1e-4,
                err_msg=f"{key}:{colname}",
            )
