"""Request admission/routing rules as Froid-compiled UDFs.

This is the paper's technique running inside the serving scheduler: each
scheduler tick evaluates imperative per-request business rules (token
budgeting, tier routing, temperature selection) over the *whole queued
request table* as one set-oriented plan, instead of a Python loop over
requests.  The rules are authored imperatively (UdfBuilder) and compiled
by the same binder/optimizer as any other UDF.

The scheduler holds a :class:`Session` with an eager policy: the queue
table is re-loaded every tick (fresh data, fresh stats), so plans rebuild
per tick, but the registry-keyed statement caches inside the session stay
warm across ticks.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (
    FROID,
    INTERPRETED,
    ExecutionPolicy,
    Q,
    Session,
    UdfBuilder,
    case,
    col,
    lit,
    param,
    resolve_policy,
    scan,
    udf,
    var,
)
from repro.core import relalg as R
from repro.serve.scheduler import CoalescingScheduler, Ticket


def default_rules(db) -> None:
    """The built-in admission rules (users register their own the same way).

    token_budget(tier, prompt_len, requested) -> granted max_new_tokens
    temp_for(tier, requested_temp)            -> effective temperature
    admit(prompt_len, queue_depth)            -> bool

    ``db`` is anything with ``create_function`` (a Session or the legacy
    Database shim).
    """
    u = UdfBuilder("token_budget",
                   [("tier", "int32"), ("plen", "int32"), ("req", "int32")],
                   "int32")
    u.declare("cap", "int32")
    with u.if_(param("tier") >= 2):
        u.set("cap", lit(4096))
    with u.else_():
        with u.if_(param("tier") == 1):
            u.set("cap", lit(1024))
        with u.else_():
            u.set("cap", lit(256))
    # long prompts eat into the budget
    with u.if_(param("plen") > 2048):
        u.set("cap", var("cap") // 2)
    with u.if_(param("req") < var("cap")):
        u.return_(param("req"))
    u.return_(var("cap"))
    db.create_function(u.build())

    u = UdfBuilder("temp_for", [("tier", "int32"), ("t", "float32")], "float32")
    with u.if_((param("t") < 0.0) | (param("t") > 2.0)):
        u.return_(lit(0.7))  # out-of-range -> default
    with u.if_(param("tier") == 0):
        # free tier is clamped
        u.return_(case([(param("t") > 1.0, lit(1.0))], param("t")))
    u.return_(param("t"))
    db.create_function(u.build())

    u = UdfBuilder("admit", [("plen", "int32"), ("depth", "int32")], "bool")
    with u.if_(param("plen") > 32768):
        u.return_(lit(False))
    with u.if_((param("depth") > 512) & (param("plen") > 8192)):
        u.return_(lit(False))  # shed long prompts under pressure
    u.return_(lit(True))
    db.create_function(u.build())


def _tick_query():
    return (
        scan("queue")
        .compute(
            admit=udf("admit", col("plen"), col("depth")),
            granted=udf("token_budget", col("tier"), col("plen"), col("req")),
            temp_eff=udf("temp_for", col("tier"), col("temp")),
        )
        .project("admit", "granted", "temp_eff")
    )


def _request_query():
    """The same rules as a *parameterized* one-row statement: each request's
    fields arrive as params over a ConstantScan, so thousands of individual
    requests ride one prepared plan and coalesce into `execute_many`
    microbatches — no per-tick table reload, no plan-cache churn."""
    return (
        Q(R.ConstantScan())
        .compute(
            admit=udf("admit", param("plen"), param("depth")),
            granted=udf("token_budget", param("tier"), param("plen"),
                        param("req")),
            temp_eff=udf("temp_for", param("tier"), param("temp")),
        )
        .project("admit", "granted", "temp_eff")
    )


def _compiled_variant(policy: ExecutionPolicy) -> ExecutionPolicy:
    """The closest whole-plan-compiling policy: batched per-request
    admission needs a device program to vmap.  Python-mode interpretation
    cannot live inside a compiled plan, so non-inlined python policies hop
    to the 'scan' interpreter (same results, traceable)."""
    if policy.compile_plan:
        return policy
    udf_mode = policy.udf_mode
    if not policy.inline_udfs and udf_mode == "python":
        udf_mode = "scan"
    return dataclasses.replace(
        policy, name=policy.name + "+compiled", compile_plan=True,
        udf_mode=udf_mode,
    )


class AdmissionPolicy:
    """Evaluates the rules over the queued-request table, set-oriented.

    ``policy`` is an :class:`ExecutionPolicy` or preset name; the legacy
    ``froid`` flag maps True -> FROID, False -> INTERPRETED.
    """

    def __init__(self, froid: bool = True,
                 policy: ExecutionPolicy | str | None = None,
                 scheduler: CoalescingScheduler | None = None,
                 mesh=None, fuse: bool = False, adaptive: bool = False,
                 timeout_s: float | None = None, store=None):
        # store: persistent plan store (PlanStore or path) — warm-starts the
        # per-request admission statement across engine restarts
        self.session = Session(store=store)
        default_rules(self.session)
        if policy is None:
            policy = FROID if froid else INTERPRETED
        # the queue table is re-loaded every tick, so whole-plan jit would
        # recompile per tick — run the chosen policy eagerly
        self.policy = resolve_policy(policy).eager()
        # mesh for the per-request coalescing path: admission microbatches
        # shard their stacked request axis over the mesh's data axes (the
        # tick path is eager and unaffected)
        self.mesh = mesh
        self._query = _tick_query()
        # per-request path: a second session sharing the rule registry but
        # with an empty catalog, so the compiled request statement's cache
        # key is immune to the tick path's queue-table reloads
        self._request_session = Session(store=self.session.store)
        self._request_session.registry = self.session.registry
        self._request_stmt = None
        # fuse: mixed-statement waves (e.g. custom rule statements sharing
        # the request session) drain as one fused device program; adaptive:
        # the flush window tracks the observed arrival rate; timeout_s:
        # default per-ticket deadline (expired tickets shed with a typed
        # DeadlineExceeded instead of executing — the engine maps that to
        # a "shed" completion)
        self.timeout_s = timeout_s
        self.scheduler = scheduler or CoalescingScheduler(
            fuse=fuse, adaptive=adaptive, default_timeout_s=timeout_s,
        )

    def evaluate(self, requests: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        """requests: columns tier, prompt_len, max_new_tokens, temperature.
        Returns columns: admit (bool), granted (int32), temp (float32)."""
        n = len(requests["tier"])
        self.session.create_table(
            "queue",
            tier=requests["tier"].astype(np.int32),
            plen=requests["prompt_len"].astype(np.int32),
            req=requests["max_new_tokens"].astype(np.int32),
            temp=requests["temperature"].astype(np.float32),
            depth=np.full(n, n, np.int32),
        )
        res = self.session.execute(self._query, self.policy)
        return {
            "admit": np.asarray(res.table.columns["admit"].data).astype(bool),
            "granted": np.asarray(res.table.columns["granted"].data).astype(np.int32),
            "temp": np.asarray(res.table.columns["temp_eff"].data).astype(np.float32),
        }

    # -- per-request coalescing path ----------------------------------------
    def request_statement(self):
        """The rules as one prepared parameterized statement (lazy)."""
        if self._request_stmt is None:
            policy = _compiled_variant(self.policy)
            if self.mesh is not None:
                policy = policy.sharded(self.mesh)
            self._request_stmt = self._request_session.prepare(
                _request_query(), policy
            )
        return self._request_stmt

    def submit(self, *, tier: int, prompt_len: int, max_new_tokens: int,
               temperature: float, depth: int = 0,
               timeout_s: float | None = None) -> Ticket:
        """Queue one request's admission evaluation; concurrent submits for
        the same statement coalesce into `execute_many` microbatches.
        ``timeout_s`` overrides the policy-wide ticket deadline."""
        return self.scheduler.submit(
            self.request_statement(),
            {"tier": int(tier), "plen": int(prompt_len),
             "req": int(max_new_tokens), "temp": float(temperature),
             "depth": int(depth)},
            timeout_s=timeout_s,
        )

    @staticmethod
    def verdict(result) -> dict:
        """Decode one per-request QueryResult into the evaluate() schema."""
        cols = result.table.columns
        return {
            "admit": bool(np.asarray(cols["admit"].data)[0]),
            "granted": int(np.asarray(cols["granted"].data)[0]),
            "temp": float(np.asarray(cols["temp_eff"].data)[0]),
        }

    def evaluate_coalesced(self, requests: dict[str, np.ndarray]) -> dict:
        """`evaluate`, but through per-request submits + one scheduler
        drain — the serving path's shape, returning the tick-path schema."""
        n = len(requests["tier"])
        tickets = [
            self.submit(
                tier=int(requests["tier"][i]),
                prompt_len=int(requests["prompt_len"][i]),
                max_new_tokens=int(requests["max_new_tokens"][i]),
                temperature=float(requests["temperature"][i]),
                depth=n,
            )
            for i in range(n)
        ]
        self.scheduler.flush()
        out = [self.verdict(t.result()) for t in tickets]
        return {
            "admit": np.array([v["admit"] for v in out], bool),
            "granted": np.array([v["granted"] for v in out], np.int32),
            "temp": np.array([v["temp"] for v in out], np.float32),
        }
