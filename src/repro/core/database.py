"""The engine facade: catalog + UDF registry + query execution modes.

Execution modes (paper experiment axes):

* ``froid=True``  (default): bind-time UDF inlining + rewrite rules +
  set-oriented vectorized execution — the paper's contribution.
* ``froid=False, mode="python"``: iterative interpreted UDFs (the classic
  evaluation the paper §2 describes).
* ``froid=False, mode="scan"``: natively-compiled-but-still-iterative UDFs
  (Hekaton analogue, Table 5).

``run_compiled`` returns a jitted callable over the catalog arrays — the
"cached plan" used for warm-cache benchmark runs.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import optimizer as O
from repro.core import relalg as R
from repro.core.binder import Binder, InlineConstraints
from repro.core.executor import Executor, MaskedTable
from repro.core.frontend import Q
from repro.core.interpreter import Interpreter
from repro.core.ir import UdfDef
from repro.tables.table import Table


@dataclasses.dataclass
class RunResult:
    table: Table  # compacted result rows
    masked: MaskedTable  # raw masked result (jit-friendly form)
    plan: R.RelNode  # the executed plan (post-binding/optimization)
    elapsed_s: float
    stats: dict


class Database:
    def __init__(self, constraints: InlineConstraints | None = None):
        self.catalog: dict[str, Table] = {}
        self.registry: dict[str, UdfDef] = {}
        self.constraints = constraints or InlineConstraints()

    # -- DDL ---------------------------------------------------------------
    def create_table(self, name: str, table: Table | None = None, **arrays):
        t = table if table is not None else Table.from_arrays(**arrays)
        t.compute_stats()  # histograms for the optimizer (§Perf)
        self.catalog[name] = t
        return t

    def create_function(self, udf: UdfDef):
        self.registry[udf.name] = udf
        return udf

    # -- planning ------------------------------------------------------------
    def plan_for(self, query, froid: bool = True, optimize: bool = True) -> R.RelNode:
        plan = query.node if isinstance(query, Q) else query
        # the query's intended output schema (before inlining widens rows)
        try:
            wanted = R.output_columns(plan, self.catalog)
        except Exception:
            wanted = None
        if froid:
            binder = Binder(self.registry, self.constraints)
            plan = binder.bind(plan)
        if optimize:
            plan = O.optimize(plan, self.catalog, required=set(wanted) if wanted else None)
        if wanted is not None:
            try:
                have = R.output_columns(plan, self.catalog)
            except Exception:
                have = None
            if have is not None and have != wanted:
                plan = R.Project(plan, wanted)
        return plan

    def explain(self, query, froid: bool = True, optimize: bool = True) -> str:
        return O.explain(self.plan_for(query, froid, optimize))

    # -- execution -------------------------------------------------------------
    def run(
        self,
        query,
        froid: bool = True,
        mode: str = "python",
        optimize: bool = True,
        params: dict | None = None,
        jit_statements: bool = True,
        pallas_agg: bool = False,
    ) -> RunResult:
        plan = self.plan_for(query, froid, optimize)
        interp = Interpreter(
            self.catalog, self.registry, mode=mode, jit_statements=jit_statements
        )
        executor = Executor(
            self.catalog,
            udf_column_evaluator=interp.eval_udf_call,
            use_pallas_agg=pallas_agg,
        )
        t0 = time.perf_counter()
        masked = executor.execute(plan, params=params)
        jax.block_until_ready(masked.mask)
        elapsed = time.perf_counter() - t0
        stats = {**executor._stats, **interp.stats}
        return RunResult(masked.compact(), masked, plan, elapsed, stats)

    def run_compiled(self, query, froid: bool = True, mode: str = "scan",
                     optimize: bool = True):
        """Compile the whole plan once (the cached plan); returns
        ``fn() -> (mask, {col: (data, valid)})`` plus the plan.

        Table columns are passed as *arguments* to the jitted function (not
        closed-over constants) so XLA cannot constant-fold the query away —
        warm calls measure real execution.

        With froid=False the UDF columns go through the iterative 'scan'
        interpreter *inside* the compiled plan, matching "interpreted query
        + native UDF" as closely as a tensor runtime can."""
        from repro.tables.table import Column as _Column, Table as _Table

        plan = self.plan_for(query, froid, optimize)
        interp = Interpreter(self.catalog, self.registry, mode=mode)
        hook = None if froid else interp.eval_udf_call

        # host-side metadata (dictionaries) stays captured; data goes by arg
        meta = {
            tname: {c: col.dictionary for c, col in t.columns.items()}
            for tname, t in self.catalog.items()
        }

        def raw(args):
            catalog = {
                tname: _Table(
                    {
                        c: _Column(data, valid, meta[tname][c])
                        for c, (data, valid) in cols.items()
                    }
                )
                for tname, cols in args.items()
            }
            ex = Executor(catalog, udf_column_evaluator=hook)
            out = ex.execute(plan)
            cols = {
                n: (c.data, c.validity()) for n, c in out.table.columns.items()
            }
            return out.mask, cols

        jitted = jax.jit(raw)
        args = {
            tname: {c: (col.data, col.validity()) for c, col in t.columns.items()}
            for tname, t in self.catalog.items()
        }
        return (lambda: jitted(args)), plan
