"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required: smoke tests see 1 device; only
dryrun.py forces 512).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # pre-AxisType jax: meshes are Auto by default
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_small_mesh(data: int = 1, model: int = 1):
    """Mesh for tests/examples on whatever devices exist."""
    return _make_mesh((data, model), ("data", "model"))
