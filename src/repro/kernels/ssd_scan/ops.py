"""Public jit'd wrapper for the SSD scan: fuses dt into x and A, reshapes
(B, L, H, P) model-layout tensors into kernel layout, auto-interpret off-TPU.
"""
import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ref import ssd_scan_chunked, ssd_scan_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def ssd_scan(x, dt, A, B, C, chunk=128, interpret=None, use_kernel=None):
    """Mamba-2 SSD selective scan.

    x:  (B, L, H, P)   sequence input per head
    dt: (B, L, H)      positive step sizes (post-softplus)
    A:  (H,)           negative per-head decay rates
    B:  (B, L, G, N)   input projection (G groups, shared across H//G heads)
    C:  (B, L, G, N)   output projection
    returns y: (B, L, H, P)
    """
    Bb, L, H, P = x.shape
    _, _, G, N = B.shape
    n_rep = H // G

    xdt = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(Bb * H, L, P)
    dtA = (dt * A[None, None, :]).transpose(0, 2, 1).reshape(Bb * H, L)
    Bk = B.transpose(0, 2, 1, 3).reshape(Bb * G, L, N)
    Ck = C.transpose(0, 2, 1, 3).reshape(Bb * G, L, N)

    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        if interpret is None:
            interpret = not _on_tpu()
        y = ssd_scan_pallas(xdt, dtA, Bk, Ck, n_rep, chunk=chunk,
                            interpret=interpret)
    elif L > 64:
        # off-TPU big shapes: chunked jnp form (kernel-like cost/memory)
        y = ssd_scan_chunked(xdt, dtA, Bk, Ck, n_rep, chunk=chunk)
    else:
        y = ssd_scan_ref(xdt, dtA, Bk, Ck, n_rep)
    return y.reshape(Bb, H, L, P).transpose(0, 2, 1, 3)


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """Single-token SSD update for serving.

    state: (B, H, N, P); x_t: (B, H, P); dt_t: (B, H); A: (H,);
    B_t, C_t: (B, G, N).  Returns (new_state, y_t (B, H, P))."""
    Bb, H, N, P = state.shape
    G = B_t.shape[1]
    n_rep = H // G
    Bx = jnp.repeat(B_t, n_rep, axis=1)  # (B, H, N)
    Cx = jnp.repeat(C_t, n_rep, axis=1)
    decay = jnp.exp(A[None, :] * dt_t)  # (B, H)
    xdt = x_t * dt_t[..., None]  # (B, H, P)
    new_state = (
        decay[..., None, None] * state + Bx[..., :, None] * xdt[..., None, :]
    )
    y = jnp.einsum("bhn,bhnp->bhp", Cx, new_state)
    return new_state, y
