"""Fleet serving over the persistent plan tier: cold vs warm startup and
multi-tenant drain latency/throughput.

    PYTHONPATH=src python -m benchmarks.bench_fleet [--quick]

Rows:
    fleet/cold_first_call/<s>  — fresh store: first execute of every
                                 statement (trace + AOT compile + save)
    fleet/warm_first_call/<s>  — fresh session, populated store: the same
                                 first calls load compiled executables
    fleet/single_engine/<k>    — one warm session + scheduler draining a
                                 replayed multi-tenant trace
    fleet/drain_1w/<k>         — FleetEngine, 1 worker, same trace
    fleet/drain_2w/<k>         — FleetEngine, 2 workers, threaded drains

The warm row's ``derived`` carries ``warm_speedup`` (cold first-call time
over warm — the persistent tier's whole value proposition; the CI gate
requires >= 10x on this >= 12-statement population) and ``persist_hits``
(must cover every statement: nothing re-traced).  The drain rows carry
``p50_ms``/``p99_ms`` submit-to-fill latency percentiles from
``Ticket.latency_s`` and ``throughput_rps``; the 2-worker row's
``vs_single`` ratio gates host-aware — warm-hit fleet throughput must not
fall below the single-engine drain (full bar on >= 8-CPU hosts, relaxed
where two workers contend for two cores).  Parity against the serial
oracle is asserted in-bench on every arm.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.core import FROID, Session, col, param, scan
from repro.serve import CoalescingScheduler, FleetEngine

N_STMTS = 12
N_T, TRACE_K = 2_000, 96
N_T_QUICK, TRACE_K_QUICK = 500, 48


def _populate(s: Session, n_rows: int) -> None:
    rng = np.random.default_rng(0)
    s.create_table("T", a=rng.integers(0, 400, n_rows))


def _query(i: int):
    """Statement ``i`` of the population: distinct filter/compute shapes
    (and output names) so every statement has its own plan fingerprint."""
    q = scan("T")
    q = (q.filter(col("a") < param("lo")) if i % 2 == 0
         else q.filter(col("a") >= param("lo")))
    if i % 3 == 0:
        q = q.compute(**{f"w{i}": col("a") * param("scale")})
    elif i % 3 == 1:
        q = q.compute(**{f"w{i}": col("a") + param("scale") * float(i + 1)})
    else:
        q = q.compute(**{f"w{i}": col("a") * 1.0 - param("scale") / float(i)})
    return q.project("a", f"w{i}")


def _setup_factory(n_rows: int):
    def setup(session: Session) -> dict:
        _populate(session, n_rows)
        return {f"s{i}": session.prepare(_query(i), FROID)
                for i in range(N_STMTS)}

    return setup


def _trace(k: int) -> list[tuple[str, dict]]:
    """Replayed multi-tenant trace: k requests round-robin-ish over the
    statement population with varied parameters (deterministic)."""
    rng = np.random.default_rng(5)
    return [
        (f"s{int(rng.integers(0, N_STMTS))}",
         {"lo": int(rng.integers(0, 400)),
          "scale": float(round(rng.uniform(0.5, 2.0), 2))})
        for _ in range(k)
    ]


def _first_calls(store, n_rows: int):
    """Fresh session over ``store``: seconds for the first execute of every
    statement in the population, plus the session (for stats/parity)."""
    s = Session(store=store)
    stmts = _setup_factory(n_rows)(s)
    params = {"lo": 200, "scale": 1.5}
    t0 = time.perf_counter()
    rs = [stmts[f"s{i}"].execute(params=params) for i in range(N_STMTS)]
    return time.perf_counter() - t0, rs, s


def _check_identical(expected, got):
    for e, g in zip(expected, got):
        em, gm = e.masked, g.masked
        m = np.asarray(em.mask)
        np.testing.assert_array_equal(m, np.asarray(gm.mask))
        for n, c in em.table.columns.items():
            np.testing.assert_allclose(
                np.asarray(gm.table.columns[n].data)[m],
                np.asarray(c.data)[m], rtol=1e-5)


def run(quick: bool = False):
    n_rows = N_T_QUICK if quick else N_T
    k = TRACE_K_QUICK if quick else TRACE_K
    root = tempfile.mkdtemp(prefix="bench_fleet_")
    cpus = os.cpu_count()

    # -- cold vs warm first-call startup ------------------------------------
    t_cold, rs_cold, s_cold = _first_calls(root, n_rows)
    assert s_cold.persist_stats["saves"] >= N_STMTS, s_cold.persist_stats
    emit(f"fleet/cold_first_call/{N_STMTS}", t_cold / N_STMTS * 1e6,
         f"statements={N_STMTS} host_cpus={cpus}")

    t_warm, rs_warm, s_warm = _first_calls(root, n_rows)
    hits = s_warm.cache_stats["persist_hits"]
    assert hits >= N_STMTS, s_warm.cache_stats  # nothing re-traced
    _check_identical(rs_cold, rs_warm)
    emit(f"fleet/warm_first_call/{N_STMTS}", t_warm / N_STMTS * 1e6,
         f"warm_speedup={t_cold / t_warm:.1f}x persist_hits={hits} "
         f"statements={N_STMTS} host_cpus={cpus} parity=ok")

    # -- multi-tenant trace drains ------------------------------------------
    trace = _trace(k)
    oracle = Session()
    o_stmts = _setup_factory(n_rows)(oracle)
    expected = [o_stmts[name].execute(params=p) for name, p in trace]

    # single engine: one warm session + one scheduler (the pre-fleet shape)
    single = Session(store=root)
    stmts = _setup_factory(n_rows)(single)
    sched = CoalescingScheduler(max_batch=1024, window_s=10.0)
    ts_single, got = [], None
    for _ in range(3):
        t0 = time.perf_counter()
        tickets = [sched.submit(stmts[name], p) for name, p in trace]
        sched.flush()
        got = [t.result() for t in tickets]
        ts_single.append(time.perf_counter() - t0)
    t_single = float(np.min(ts_single))
    _check_identical(expected, got)
    emit(f"fleet/single_engine/{k}", t_single / k * 1e6,
         f"throughput_rps={k / t_single:.0f} parity=ok")

    # fleet arms: workers warm-start from the shared store; one un-timed
    # drain absorbs the store loads, then best-of timed warm-hit drains
    for workers in (1, 2):
        fleet = FleetEngine(_setup_factory(n_rows), workers=workers,
                            store=root, parallel=workers > 1)
        for name, p in trace:
            fleet.submit(name, p)
        fleet.drain()  # warm-up: persistent-tier loads happen here
        ts, got = [], None
        for _ in range(3):
            n0 = len(fleet.latencies_s)
            t0 = time.perf_counter()
            for name, p in trace:
                fleet.submit(name, p)
            got = fleet.drain()
            ts.append(time.perf_counter() - t0)
            lat = np.asarray(fleet.latencies_s[n0:])
        t_fleet = float(np.min(ts))
        _check_identical(expected, got)
        p50, p99 = (float(np.percentile(lat, q)) * 1e3 for q in (50, 99))
        emit(
            f"fleet/drain_{workers}w/{k}", t_fleet / k * 1e6,
            f"p50_ms={p50:.2f} p99_ms={p99:.2f} "
            f"throughput_rps={k / t_fleet:.0f} "
            f"vs_single={t_single / t_fleet:.2f} "
            f"workers={workers} host_cpus={cpus} parity=ok",
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
