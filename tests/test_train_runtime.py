"""Training runtime: loop convergence, checkpoint fault tolerance,
microbatch equivalence, gradient compression, straggler policy, elastic
re-mesh planning, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config_for
from repro.data.pipeline import DataPipeline
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.optim import AdamWConfig
from repro.train.train_loop import TrainState, init_state, make_train_step, train_loop


def _model_and_state(arch="granite3_2b", seed=0, compress=False):
    cfg = smoke_config_for(arch)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    state = init_state(model, jax.random.PRNGKey(seed), opt_cfg, compress)
    return model, opt_cfg, state, cfg


def _pipeline(cfg, batch=4, seq=32):
    return DataPipeline(batch=batch, seq_len=seq, vocab=cfg.vocab, seed=1)


def test_train_loop_loss_decreases(tmp_path):
    model, opt_cfg, state, cfg = _model_and_state()
    pipe = _pipeline(cfg)
    step = jax.jit(make_train_step(model, opt_cfg))
    losses = []
    it = iter(pipe)
    for _ in range(8):
        state, m = step(state, next(it))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))


def test_checkpoint_roundtrip_and_resume(tmp_path):
    model, opt_cfg, state, cfg = _model_and_state()
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_write=False)
    pipe = _pipeline(cfg)

    state = train_loop(model, state, iter(pipe), opt_cfg, steps=4,
                       checkpoint_mgr=mgr, checkpoint_every=2, log_every=0)
    assert mgr.latest_step() == 4
    step_, restored = mgr.restore_latest()
    assert step_ == 4

    # resume: fresh state from checkpoint continues identically
    _, _, state2, _ = _model_and_state()
    state2 = TrainState(restored["params"], restored["opt"], None)
    assert int(state2.opt["step"]) == 4
    p_old = jax.tree.leaves(state.params)[0]
    p_new = jax.tree.leaves(state2.params)[0]
    np.testing.assert_allclose(np.asarray(p_old), np.asarray(p_new))


def test_checkpoint_atomicity_and_corruption_skip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=5, async_write=False)
    tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((2, 3))}}
    mgr.save(1, tree)
    mgr.save(2, tree)
    # corrupt checkpoint 2 (truncate a shard)
    d = os.path.join(str(tmp_path), "step_00000002")
    shard = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, shard), "wb") as f:
        f.write(b"corrupt")
    step, restored = mgr.restore_latest()
    assert step == 1  # falls back to the newest verifiable checkpoint
    np.testing.assert_allclose(np.asarray(restored["a"]), np.arange(8.0))


def test_checkpoint_keep_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_write=False)
    tree = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_microbatch_equivalence():
    """Grad accumulation over 4 microbatches == single big batch (loss)."""
    model, opt_cfg, state, cfg = _model_and_state()
    pipe = _pipeline(cfg, batch=8)
    batch = next(iter(pipe))
    s1, m1 = make_train_step(model, opt_cfg, microbatches=1)(state, batch)
    _, _, state2, _ = _model_and_state()
    s2, m2 = make_train_step(model, opt_cfg, microbatches=4)(state2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-2)
    a = jax.tree.leaves(s1.params)[0]
    b = jax.tree.leaves(s2.params)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2)


def test_compressed_training_converges():
    model, opt_cfg, state, cfg = _model_and_state(compress=True)
    pipe = _pipeline(cfg)
    step = jax.jit(make_train_step(model, opt_cfg, compress=True))
    it = iter(pipe)
    losses = []
    # same 8-step horizon as test_train_loop_loss_decreases: the synthetic
    # stream is noisy enough that even lossless training is not monotone
    # over fewer steps
    for _ in range(8):
        state, m = step(state, next(it))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    # error-feedback buffers are being used (non-zero residuals)
    ef = jax.tree.leaves(state.ef_error)
    assert any(float(jnp.abs(e).max()) > 0 for e in ef)


def test_quantization_error_bound(rng):
    from repro.dist.compress import dequantize_int8, ef_quantize, quantize_int8

    x = jnp.asarray(rng.normal(size=(1000,)) * 5, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6  # half-ulp bound
    # EF invariant: q(x+e) + e' == x + e  (exactly, by construction)
    e0 = jnp.asarray(rng.normal(size=(1000,)) * 0.01, jnp.float32)
    q2, s2, e1 = ef_quantize(x, e0)
    np.testing.assert_allclose(
        np.asarray(dequantize_int8(q2, s2) + e1), np.asarray(x + e0), rtol=1e-5
    )


def test_straggler_policy():
    from repro.train.straggler import StragglerConfig, StragglerTracker

    tr = StragglerTracker(StragglerConfig(alpha=1.0, threshold=1.5, patience=3))
    for step in range(6):
        for host in range(8):
            t = 1.0 if host != 3 else 2.5  # host 3 persistently slow
            tr.record(host, step, t)
    assert tr.should_evict() == {3}
    # transient slowness is not evicted
    tr2 = StragglerTracker(StragglerConfig(alpha=1.0, threshold=1.5, patience=3))
    for step in range(6):
        for host in range(8):
            t = 2.5 if (host == 3 and step == 2) else 1.0
            tr2.record(host, step, t)
    assert tr2.should_evict() == set()


def test_elastic_remesh_plans():
    from repro.train.elastic import plan_remesh, usable_devices

    p = plan_remesh(256, model_axis=16)
    assert p.shape == (16, 16)
    # lose 5 hosts (say 40 chips): usable shrinks to full data rows
    p2 = plan_remesh(216, model_axis=16)
    assert p2.shape == (13, 16)
    assert usable_devices(216, 16) == 208
    p3 = plan_remesh(512, model_axis=16, pods=2)
    assert p3.shape == (2, 16, 16)
    with pytest.raises(ValueError):
        plan_remesh(8, model_axis=16)


def test_elastic_checkpoint_restart(tmp_path):
    """Failure scenario: train, checkpoint, 'lose' devices, restore onto a
    new topology (value-level resharding path) and keep training."""
    model, opt_cfg, state, cfg = _model_and_state()
    pipe = _pipeline(cfg)
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    state = train_loop(model, state, iter(pipe), opt_cfg, steps=2,
                       checkpoint_mgr=mgr, checkpoint_every=2, log_every=0)
    step, restored = mgr.restore_latest()
    from repro.launch.mesh import make_small_mesh
    from repro.train.elastic import reshard_state

    mesh = make_small_mesh(1, 1)  # the "new" topology (1 device here)
    params2 = reshard_state(restored["params"], mesh, cfg)
    state2 = TrainState(params2, restored["opt"], None)
    state2 = train_loop(model, state2, iter(pipe), opt_cfg, steps=4,
                        log_every=0)
    assert int(state2.opt["step"]) == 4


def test_sharding_rules_divisibility():
    """Every param/batch/cache spec must be layout-valid on the production
    meshes (mesh.shape is all the rules need — no devices required)."""
    from jax.sharding import PartitionSpec

    from repro.configs import ARCH_IDS, config_for
    from repro.dist.sharding import batch_specs, cache_specs, param_specs
    from repro.models import input_specs
    from repro.models.config import SHAPES
    from repro.models.model_zoo import shape_supported

    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape

    meshes = [
        FakeMesh({"data": 16, "model": 16}),
        FakeMesh({"pod": 2, "data": 16, "model": 16}),
    ]
    for arch in ARCH_IDS:
        cfg = config_for(arch)
        from repro.models import build_model as bm

        shapes_tree = bm(cfg).init_shapes()
        for mesh in meshes:
            specs = param_specs(shapes_tree, mesh, cfg)

            def check(path, leaf, spec):
                assert isinstance(spec, PartitionSpec)
                for dim, ax in zip(leaf.shape, spec):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    n = 1
                    for a in axes:
                        n *= mesh.shape[a]
                    assert dim % n == 0, (arch, path, leaf.shape, spec)

            jax.tree_util.tree_map_with_path(
                check, shapes_tree, specs
            )
            for sname, sh in SHAPES.items():
                if not shape_supported(cfg, sh)[0]:
                    continue
                sp = input_specs(cfg, sh)
                if sh.kind == "decode":
                    cs = cache_specs(sp["cache"], mesh, cfg)
                    jax.tree_util.tree_map_with_path(check, sp["cache"], cs)
                else:
                    bs = batch_specs(sp, mesh, cfg)
                    jax.tree_util.tree_map_with_path(check, sp, bs)
