"""Cost-based routing: routed drains vs the static configurations they
choose between, routing bookkeeping overhead on a cache-resident path,
and the CSE d-bucketing bugfix's compile-churn / padded-pool trade.

    PYTHONPATH=src python -m benchmarks.bench_cost_routing [--quick]

Rows:
    routing/static_fused/<n>    — FROID statements, scheduler fuse=True
    routing/static_unfused/<n>  — FROID statements, scheduler fuse=False
    routing/routed/<n>          — ROUTED statements, router picks per wave
    routing/overhead/<k>        — ROUTED vs FROID execute_many, cache-resident
    routing/cse_exact_d/<n>     — drifting-d fused waves, exact pools
    routing/cse_bucketed_d/<n>  — same waves, power-of-two d-bucketing
    routing/cse_padded_wave/<n> — steady-state padded-pool wave overhead

The routed row's `derived` carries ``routed_vs_best`` / ``routed_vs_worst``
(routed time over the best / worst static arm) and ``host_cpus`` — the CI
gate is host-aware: routed must stay within 5% of the best static arm
everywhere, and must beat the worst static arm only on >= 8-CPU hosts
(on 1-2 cores the fused/unfused gap drowns in noise).  The overhead row's
``overhead`` ratio gates <= 1.05: per-wave routing is dictionary
bookkeeping, not device work.  The cse rows carry ``recompiles`` (the
d-churn the bucketing removes) and ``padded_overhead`` (what the padded
pool slots cost at a fixed d).  Parity is asserted in-bench on every arm.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core import (
    FROID,
    ROUTED,
    Session,
    UdfBuilder,
    col,
    lit,
    param,
    scan,
    sum_,
    udf,
    var,
)
from repro.core.frontend import scalar_subquery
from repro.serve.scheduler import CoalescingScheduler

M_ROWS, N_T, PER_STMT, MANY_K = 20_000, 2_000, 48, 128
M_ROWS_QUICK, N_T_QUICK, PER_STMT_QUICK, MANY_K_QUICK = 5_000, 500, 16, 64


def _setup(quick: bool) -> Session:
    m = M_ROWS_QUICK if quick else M_ROWS
    n = N_T_QUICK if quick else N_T
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 400, m),
        d_val=rng.uniform(0, 100, m).astype(np.float32),
    )
    db.create_table("T", a=rng.integers(0, 400, n))
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    with u.if_(var("s").is_null()):
        u.return_(lit(0.0))
    u.return_(var("s"))
    db.create_function(u.build())
    return db


def _queries():
    return [
        scan("T").filter(col("a") < param("cutoff"))
                 .compute(v=udf("key_total", col("a")))
                 .project("v"),
        scan("T").filter(col("a") >= param("lo"))
                 .compute(w=col("a") * param("scale"))
                 .project("a", "w"),
        scan("T").filter((col("a") > param("lo")) & (col("a") < param("hi")))
                 .compute(z=col("a") + param("off"))
                 .project("z"),
    ]


def _queue(stmts, per_stmt: int):
    rng = np.random.default_rng(7)
    waves = []
    for _ in range(per_stmt):
        waves.append((stmts[0], {"cutoff": int(rng.integers(1, 400))}))
        waves.append((stmts[1], {"lo": int(rng.integers(0, 200)),
                                 "scale": float(round(rng.uniform(0.5, 2), 2))}))
        waves.append((stmts[2], {"lo": int(rng.integers(0, 100)),
                                 "hi": int(rng.integers(200, 400)),
                                 "off": int(rng.integers(0, 10))}))
    return waves


def _drain(sched, queue):
    tickets = [sched.submit(s, p) for s, p in queue]
    sched.flush()
    return [t.result().masked for t in tickets]


def _check_identical(expected, got):
    for s, b in zip(expected, got):
        m = np.asarray(s.mask)
        np.testing.assert_array_equal(m, np.asarray(b.mask))
        for n, c in s.table.columns.items():
            np.testing.assert_allclose(
                np.asarray(b.table.columns[n].data)[m],
                np.asarray(c.data)[m], rtol=1e-5,
            )


def _static_time(db, queue, *, fuse: bool, iters: int = 5):
    # best-of-N: the ratio gates compare identical repeated work, and min
    # is the noise-robust estimator for that (median still moves ~10% on
    # a busy 1-CPU host)
    ts, got = [], None
    for _ in range(iters):
        sched = CoalescingScheduler(max_batch=1024, window_s=10.0, fuse=fuse)
        t0 = time.perf_counter()
        got = _drain(sched, queue)
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts)), got


def _tmpl_q(pname: str, out: str):
    inner = (scan("detail").filter(col("d_val") > param(pname))
             .agg(s=sum_(col("d_val"))))
    return (scan("T")
            .compute(**{out: scalar_subquery(inner.node, "s")
                        + col("a") * 0.0})
            .project("a", out))


def _cse_wave(s1, s2, d: int, tickets_per: int = 6):
    """One fused wave with exactly ``d`` distinct template bindings and a
    fixed per-member ticket count (constant batch buckets, so the fused
    cache key varies only through the pool size)."""
    vals = [float(v) for v in range(d)]
    calls = []
    for t in range(tickets_per):
        calls.append((s1, {"x": vals[t % d] if t < d else vals[0]}))
    for t in range(tickets_per):
        j = tickets_per + t
        calls.append((s2, {"y": vals[j % d] if j < d else vals[0]}))
    return calls


def _cse_arm(quick: bool, exact_threshold: int | None, d_list):
    """Drain one fused wave per ``d`` in ``d_list`` on a fresh session,
    cold — compile churn included, which is the point: exact pools pay a
    fused recompile for every new distinct-binding count, bucketed pools
    pay one per power-of-two bucket.  Returns (seconds, recompiles, db,
    s1, s2)."""
    from repro.core import session as sess_mod

    db = _setup(quick)
    saved = sess_mod.CSE_EXACT_D
    if exact_threshold is not None:
        sess_mod.CSE_EXACT_D = exact_threshold
    try:
        s1 = db.prepare(_tmpl_q("x", "v1"), FROID)
        s2 = db.prepare(_tmpl_q("y", "v2"), FROID)
        misses0 = db.cache_stats["fuse_misses"]
        t0 = time.perf_counter()
        for d in d_list:
            db.execute_fused(_cse_wave(s1, s2, d, tickets_per=8))
        t = time.perf_counter() - t0
        recompiles = db.cache_stats["fuse_misses"] - misses0
        return t, recompiles, db, s1, s2
    finally:
        sess_mod.CSE_EXACT_D = saved


def run(quick: bool = False):
    db = _setup(quick)
    per_stmt = PER_STMT_QUICK if quick else PER_STMT
    qs = _queries()
    froid_stmts = [db.prepare(q, FROID) for q in qs]
    queue = _queue(froid_stmts, per_stmt)
    n = len(queue)

    # warm both static arms' device programs
    _, ref = _static_time(db, queue, fuse=True, iters=1)
    _static_time(db, queue, fuse=False, iters=1)

    # routed arm: one scheduler + session-attached router across drains so
    # measurements accrue; the first drains explore both arms, then the
    # measured winner sticks (hysteresis) — time the steady state.  The
    # three arms are timed in interleaved rounds (static-fused,
    # static-unfused, routed per round, best-of over rounds) so host load
    # drift hits all of them equally instead of whichever ran last.
    routed_stmts = [db.prepare(q, ROUTED) for q in qs]
    routed_queue = [(routed_stmts[froid_stmts.index(s)], p)
                    for s, p in queue]
    sched = CoalescingScheduler(max_batch=1024, window_s=10.0, fuse=True)
    for _ in range(3):  # exploration: fused arm, unfused arm, first verdict
        got_r = _drain(sched, routed_queue)
        _check_identical(ref, got_r)
    ts_f, ts_u, ts_r = [], [], []
    for _ in range(5):
        t, got_f = _static_time(db, queue, fuse=True, iters=1)
        ts_f.append(t)
        t, got_u = _static_time(db, queue, fuse=False, iters=1)
        ts_u.append(t)
        t0 = time.perf_counter()
        got_r = _drain(sched, routed_queue)
        ts_r.append(time.perf_counter() - t0)
    _check_identical(ref, got_f)
    _check_identical(ref, got_u)
    _check_identical(ref, got_r)
    t_fused, t_unfused = float(np.min(ts_f)), float(np.min(ts_u))
    emit(f"routing/static_fused/{n}", t_fused / n * 1e6,
         "static FROID, scheduler fuse=True")
    emit(f"routing/static_unfused/{n}", t_unfused / n * 1e6,
         "static FROID, scheduler fuse=False")
    t_routed = float(np.min(ts_r))
    # gate ratios are the median of per-round ratios: a load spike hits
    # one round's triple, not the aggregate
    vs_best = float(np.median([r / min(f, u) for f, u, r
                               in zip(ts_f, ts_u, ts_r)]))
    vs_worst = float(np.median([r / max(f, u) for f, u, r
                                in zip(ts_f, ts_u, ts_r)]))
    cs = db.cost_stats
    emit(
        f"routing/routed/{n}", t_routed / n * 1e6,
        f"routed_vs_best={vs_best:.4f} "
        f"routed_vs_worst={vs_worst:.4f} "
        f"host_cpus={os.cpu_count()} "
        f"waves_fused={cs['waves_fused']} waves_unfused={cs['waves_unfused']} "
        f"decisions={cs['decisions']} parity=ok",
    )

    # routing overhead: cache-resident execute_many, static vs routed —
    # the delta is pure router bookkeeping (choose_policy + choose_bucket)
    k = MANY_K_QUICK if quick else MANY_K
    params = [{"lo": int(i % 200), "scale": 1.5} for i in range(k)]
    s_static = froid_stmts[1]
    s_routed = routed_stmts[1]
    s_static.execute_many(params)  # warm the bucket
    s_routed.execute_many(params)

    # interleaved A/B pairs, best-of each: the delta under test is pure
    # host-side bookkeeping, so drift between two back-to-back blocks
    # would otherwise dominate the ratio
    ts_s, ts_r = [], []
    rs_s = rs_r = None
    for _ in range(15):
        t0 = time.perf_counter()
        rs_s = s_static.execute_many(params)
        ts_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        rs_r = s_routed.execute_many(params)
        ts_r.append(time.perf_counter() - t0)
    t_s, t_r = float(np.min(ts_s)), float(np.min(ts_r))
    overhead = float(np.median([r / s for s, r in zip(ts_s, ts_r)]))
    _check_identical([r.masked for r in rs_s], [r.masked for r in rs_r])
    emit(f"routing/overhead/{k}", t_r / k * 1e6,
         f"overhead={overhead:.4f} static_us={t_s / k * 1e6:.1f} parity=ok")

    # CSE d-bucketing: a drifting distinct-binding count (9, 10, 11, …).
    # Exact pools compile a fresh fused program for every new d; bucketed
    # pools ride one padded 16-slot program for the whole drift
    d_list = tuple(range(9, 15 if quick else 17))
    n_waves = len(d_list)
    t_exact, rec_exact, *_ = _cse_arm(quick, 1 << 20, d_list)
    emit(f"routing/cse_exact_d/{n_waves}", t_exact / n_waves * 1e6,
         f"recompiles={rec_exact} d_drift={list(d_list)}")
    t_bucket, rec_bucket, bdb, b1, b2 = _cse_arm(quick, None, d_list)
    emit(f"routing/cse_bucketed_d/{n_waves}", t_bucket / n_waves * 1e6,
         f"recompiles={rec_bucket} d_drift={list(d_list)} "
         f"churn_speedup={t_exact / t_bucket:.2f}")
    assert rec_exact == n_waves, (rec_exact, n_waves)  # one compile per d
    assert rec_bucket == 1, rec_bucket  # one 16-slot program for the drift

    # padded-pool overhead at a fixed d: the bucketed program evaluates 16
    # pool slots where the exact one evaluates 9 — measure what the
    # padding costs per wave (parity asserted against serial)
    wave9 = _cse_wave(b1, b2, 9, tickets_per=8)
    _, _, edb, e1, e2 = _cse_arm(quick, 1 << 20, (9,))
    ewave9 = _cse_wave(e1, e2, 9, tickets_per=8)

    def _wave_time(sess, wave, iters=5):
        ts, rs = [], None
        for _ in range(iters):
            t0 = time.perf_counter()
            rs = sess.execute_fused(wave)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts)), rs

    t_pad, rs_pad = _wave_time(bdb, wave9)
    t_ex, _ = _wave_time(edb, ewave9)
    serial = [s.execute(params=p).masked for s, p in wave9]
    _check_identical(serial, [r.masked for r in rs_pad])
    assert rs_pad[0].stats["cse_pool_slots"] == 16
    assert rs_pad[0].stats["cse_bindings"] == 9
    emit(f"routing/cse_padded_wave/{len(wave9)}", t_pad / len(wave9) * 1e6,
         f"padded_overhead={t_pad / t_ex:.4f} pool_slots=16 bindings=9 "
         f"parity=ok")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
