"""Graceful-degradation execution ladder for scheduler drains.

The engine stacks four execution alternatives for any drained wave, from
fastest/most-shared to slowest/most-isolated:

    fused wave  →  execute_many  →  serial execute  →  INTERPRETED per-row

(the paper's own fallback argument, PAPER.md §6: unsupported or failing
constructs revert to interpreted execution rather than failing the
query).  The ladder makes that contract hold for *any* failure at any
seam — trace, compile, dispatch, sync, or a genuine data error — by
retrying the failed work one tier down with bounded attempts and
narrowing granularity:

* a **fused wave** failure demotes every member group to its own
  ``execute_many`` (the PR-5 isolation retry, now tier 1 of 4);
* a **group** failure demotes each of its tickets to a serial compiled
  ``execute``;
* a **ticket** failure demotes that ticket to eager INTERPRETED
  execution — the mode oracle guarantees identical answers, so a
  demotion is invisible in results;
* only when the interpreter itself fails does the ticket surface an
  error (raw for genuine data errors, typed for injected/derived ones).

Per-statement **circuit breakers** (``breaker.py``) guard every tier: a
statement whose fused/batched configuration keeps failing routes straight
to the next tier down instead of burning the retry budget each wave, and
a half-open probe restores it once it heals.  **Deadlines** shed expired
tickets with a typed :class:`~repro.resilience.faults.DeadlineExceeded`
*before* work starts at each tier (shed-before-drain), so a retry storm
cannot hold dead tickets through the whole ladder.

Every demotion, shed, breaker short-circuit and per-tier success is
counted in the ``counters`` dict the scheduler shares (see
``CoalescingScheduler.stats`` / ``resilience_stats``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable

from repro.core.policy import INTERPRETED
from repro.resilience.breaker import BreakerBoard, BreakerConfig
from repro.resilience.faults import (
    DeadlineExceeded,
    ResilienceError,
    WaveResultMismatch,
)

#: ladder tiers, top (most shared) to bottom (most isolated)
TIERS = ("fused", "many", "serial", "interp")

#: sentinel for "no result yet" (a legitimate result may be any object)
UNSET = object()


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded in-tier retry: each tier gets at most ``max_attempts``
    tries, with ``backoff_s × backoff_mult**(attempt-1)`` between them
    (``sleep`` is injectable on the ladder, so tests stay instant)."""

    max_attempts: int = 1
    backoff_s: float = 0.0
    backoff_mult: float = 2.0

    def delay(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (1-based)."""
        return self.backoff_s * (self.backoff_mult ** (attempt - 1))


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    retry: RetryPolicy = RetryPolicy()
    breaker: BreakerConfig = BreakerConfig()
    #: allow the final INTERPRETED per-row tier (off = serial compiled
    #: execution is the floor and its error surfaces)
    interp_fallback: bool = True


class _NullLock:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


@dataclasses.dataclass
class WorkItem:
    """One ticket's work: parameters, optional absolute deadline, and the
    outcome the ladder fills (exactly one of result/error is set)."""

    params: dict
    deadline: float | None = None
    result: Any = UNSET
    error: BaseException | None = None
    #: the most recent tier failure (surfaced if every tier is exhausted)
    last_error: BaseException | None = None

    @property
    def resolved(self) -> bool:
        return self.result is not UNSET or self.error is not None


@dataclasses.dataclass
class WaveGroup:
    """One statement's batch within a drained wave."""

    stmt: Any  # PreparedStatement
    items: list  # [WorkItem]
    #: batches/drained counters bumped (first tier this group entered)
    counted: bool = False
    #: group was part of a fused wave that failed (legacy isolation stats)
    from_fused: bool = False
    #: group is running under a fault window (open breaker skipped its
    #: fused tier, or it was demoted): the cost router must not learn
    #: from its timings
    suppress_samples: bool = False

    def key(self):
        return self.stmt._query_fp

    def unresolved(self) -> list:
        return [it for it in self.items if not it.resolved]


class DegradationLadder:
    """Drains waves down the tier ladder; see module docstring.

    ``counters`` is any mutable mapping — the scheduler passes its own
    ``stats`` dict so ladder counters surface next to the drain counters
    clients already read.  ``clock``/``sleep`` are injectable for
    deterministic breaker-timing and backoff tests.
    """

    def __init__(self, config: ResilienceConfig | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 counters: dict | None = None):
        self.config = config or ResilienceConfig()
        self.clock = clock
        self.sleep = sleep
        self.counters = counters if counters is not None else {}
        self.board = BreakerBoard(self.config.breaker, clock)

    # -- bookkeeping ---------------------------------------------------------
    def _bump(self, key: str, n: int = 1) -> None:
        self.counters[key] = self.counters.get(key, 0) + n

    def snapshot(self) -> dict:
        """Introspection bundle (``Session.cache_stats`` style): ladder
        counters + per-breaker state/transition counts."""
        return {"counters": dict(self.counters),
                "breakers": self.board.snapshot()}

    def _count_group(self, g: WaveGroup) -> None:
        if not g.counted:
            g.counted = True
            self._bump("batches")
            self._bump("drained", len(g.items))

    def _shed_expired(self, items: list) -> list:
        """Shed-before-drain: expire overdue items with a typed error;
        return the still-live ones."""
        now = self.clock()
        live = []
        for it in items:
            if it.deadline is not None and now > it.deadline:
                it.error = DeadlineExceeded(it.deadline, now)
                self._bump("deadline_shed")
            else:
                live.append(it)
        return live

    def _sample_guard(self, session, suppress: bool = True):
        """Context excluding cost-router samples while held — retries,
        demoted tiers, and breaker-skip fallout run inside it so
        fault-window timings never train the cost model.  A no-op when
        the session has no router (or ``suppress`` is False)."""
        router = getattr(session, "cost_router", None)
        if router is None or not suppress:
            return contextlib.nullcontext()
        return router.suppress()

    def _backoff(self, attempt: int) -> None:
        d = self.config.retry.delay(attempt)
        if d > 0:
            self._bump("retry_backoffs")
            self.sleep(d)

    # -- public API ----------------------------------------------------------
    def drain(self, groups: list, *, fuse: bool = False,
              lock=None) -> None:
        """Resolve every item of every group: ladder tiers top-down,
        breaker-gated, deadline-shedding at each tier boundary.  ``lock``
        serializes session access (Session caches are not thread-safe)."""
        lock = lock if lock is not None else _NullLock()
        if fuse and len(groups) >= 2:
            self._tier_fused(groups, lock)
        for g in groups:
            self._run_group(g, lock)
            if g.from_fused and any(it.error is not None for it in g.items):
                self._bump("fused_isolated_errors")

    # -- tier: fused wave ----------------------------------------------------
    def _tier_fused(self, groups: list, lock) -> None:
        eligible = []
        for g in groups:
            if self.board.allow((g.key(), "fused")):
                eligible.append(g)
            else:
                self._bump("breaker_open_skips")
                # this group runs per-statement *because a breaker is
                # open* — a fault window, not a routing decision; its
                # timings must not train the cost model
                g.suppress_samples = True
        if len(eligible) < 2:
            return  # a lone group fuses with nobody; per-group path
        # wave-level accounting (legacy drain counters: one fused wave is
        # ONE batch however many member groups it carries)
        for g in eligible:
            if not g.counted:
                g.counted = True
                self._bump("drained", len(g.items))
        self._bump("batches")
        self._bump("fused_batches")
        self._bump("fused_statements", len(eligible))
        live_by_group = [self._shed_expired(g.items) for g in eligible]
        calls = [(g.stmt, it.params)
                 for g, live in zip(eligible, live_by_group) for it in live]
        if not calls:
            return
        session = eligible[0].stmt.session
        retry = self.config.retry
        for attempt in range(1, retry.max_attempts + 1):
            try:
                # retries are fault-window runs (something already failed
                # once); only the first attempt may train the cost model
                with lock, self._sample_guard(session, attempt > 1):
                    results = session.execute_fused(calls)
                if len(results) != len(calls):
                    raise WaveResultMismatch(len(calls), len(results),
                                             "execute_fused")
            except Exception as e:
                for g in eligible:
                    self.board.failure((g.key(), "fused"))
                if attempt < retry.max_attempts:
                    self._backoff(attempt)
                    continue
                # demote: every member group retries on its own
                # per-statement path (the PR-5 isolation semantics)
                for g, live in zip(eligible, live_by_group):
                    g.from_fused = True
                    for it in live:
                        it.last_error = e
                    self._bump("fused_isolated_retries")
                    self._bump("demote_fused_to_many")
                return
            it = iter(results)
            for g, live in zip(eligible, live_by_group):
                for item in live:
                    item.result = next(it)
                self.board.success((g.key(), "fused"))
            self._bump("tier_fused_ok")
            return

    # -- tiers: per-group and per-item ---------------------------------------
    def _run_group(self, g: WaveGroup, lock) -> None:
        if not g.unresolved():
            return
        self._count_group(g)
        session = g.stmt.session
        # a group that reaches the many tier through demotion or an open
        # breaker is degradation work end-to-end; a group that starts here
        # (unfused wave) is the normal path and may train the cost model
        with self._sample_guard(session,
                                g.from_fused or g.suppress_samples):
            self._tier_many(g, lock)
        # serial/interp only ever see items a higher tier failed —
        # demotion-only tiers never train the cost model
        with self._sample_guard(session):
            self._tier_serial(g, lock)
            self._tier_interp(g, lock)
        # ladder exhausted (or fallback disabled): surface the last error
        for it in g.unresolved():
            it.error = it.last_error if it.last_error is not None else \
                ResilienceError("ladder exhausted with no recorded error")
            self._bump("ladder_exhausted")

    def _tier_many(self, g: WaveGroup, lock) -> None:
        key = (g.key(), "many")
        if not self.board.allow(key):
            self._bump("breaker_open_skips")
            self._bump("demote_many_to_serial")
            return
        live = self._shed_expired(g.unresolved())
        if not live:
            return
        retry = self.config.retry
        for attempt in range(1, retry.max_attempts + 1):
            try:
                with lock, self._sample_guard(g.stmt.session, attempt > 1):
                    results = g.stmt.execute_many([it.params for it in live])
                if len(results) != len(live):
                    raise WaveResultMismatch(len(live), len(results),
                                             "execute_many")
            except Exception as e:
                self.board.failure(key)
                if attempt < retry.max_attempts:
                    self._backoff(attempt)
                    continue
                for it in live:
                    it.last_error = e
                self._bump("demote_many_to_serial")
                return
            for it, r in zip(live, results):
                it.result = r
            self.board.success(key)
            self._bump("tier_many_ok")
            return

    def _per_item_tier(self, g: WaveGroup, lock, tier: str, run,
                       demote_key: str | None) -> None:
        """Shared per-item tier driver: breaker gate, shed, bounded
        retries of ``run(item)`` per item, demotion accounting."""
        pending = g.unresolved()
        if not pending:
            return
        key = (g.key(), tier)
        if not self.board.allow(key):
            self._bump("breaker_open_skips")
            if demote_key is not None:
                self._bump(demote_key)
            return
        retry = self.config.retry
        for it in self._shed_expired(pending):
            for attempt in range(1, retry.max_attempts + 1):
                try:
                    with lock:
                        it.result = run(it)
                except Exception as e:
                    self.board.failure(key)
                    if attempt < retry.max_attempts:
                        self._backoff(attempt)
                        continue
                    it.last_error = e
                    if demote_key is not None:
                        self._bump(demote_key)
                    break
                else:
                    self.board.success(key)
                    self._bump(f"tier_{tier}_ok")
                    break

    def _tier_serial(self, g: WaveGroup, lock) -> None:
        self._per_item_tier(
            g, lock, "serial",
            lambda it: g.stmt.execute(params=it.params),
            "demote_serial_to_interp",
        )

    def _tier_interp(self, g: WaveGroup, lock) -> None:
        if not self.config.interp_fallback:
            return
        session = g.stmt.session
        node = g.stmt.node
        self._per_item_tier(
            g, lock, "interp",
            lambda it: session.execute(node, INTERPRETED,
                                       params=it.params or None),
            None,
        )


__all__ = ["TIERS", "UNSET", "RetryPolicy", "ResilienceConfig",
           "WorkItem", "WaveGroup", "DegradationLadder"]
