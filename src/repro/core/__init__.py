# Froid in JAX — the paper's primary contribution: an optimization framework
# that algebrizes imperative UDFs into relational expressions, inlines them
# into calling queries at binding time, and executes set-oriented vectorized
# plans (paper: "Optimization of Imperative Programs in a Relational
# Database", PVLDB 11(4), 2017).
from repro.core.algebrizer import AlgebrizeError, algebrize
from repro.core.binder import Binder, InlineConstraints
from repro.core.database import Database
from repro.core.executor import Executor, MaskedTable
from repro.core.policy import (
    FROID,
    HEKATON,
    INTERPRETED,
    PRESETS,
    ROUTED,
    ExecutionPolicy,
    resolve_policy,
)
from repro.core.session import (
    AsyncResult,
    PreparedStatement,
    QueryResult,
    RunResult,
    Session,
    batch_bucket,
    param_signature,
    plan_fingerprint,
)
from repro.core.frontend import (
    Q,
    UdfBuilder,
    avg_,
    between,
    case,
    cast,
    coalesce,
    col,
    count_,
    dateadd,
    datepart,
    exists,
    func,
    in_list,
    isnull,
    like,
    lit,
    max_,
    min_,
    not_exists,
    param,
    scalar_subquery,
    scan,
    sum_,
    udf,
    var,
)
from repro.core.interpreter import Interpreter
from repro.core.ir import (
    Assign,
    Break,
    CursorLoop,
    Declare,
    Fetch,
    IfElse,
    Return,
    UdfDef,
    While,
)
from repro.core.optimizer import explain, optimize
from repro.core.tsql import UnsupportedConstructError, parse_udf

__all__ = [
    "AlgebrizeError", "algebrize", "Binder", "InlineConstraints", "Database",
    "RunResult", "Executor", "MaskedTable", "Q", "UdfBuilder", "avg_",
    "between", "case", "cast", "coalesce", "col", "count_", "dateadd",
    "datepart", "exists", "func", "in_list", "isnull", "like", "lit", "max_",
    "min_", "not_exists", "param", "scalar_subquery", "scan", "sum_", "udf",
    "var", "Interpreter", "Assign", "Declare", "IfElse", "Return", "UdfDef",
    "Break", "While", "Fetch", "CursorLoop",
    "UnsupportedConstructError", "parse_udf",
    "explain", "optimize",
    # prepare/execute API
    "Session", "PreparedStatement", "QueryResult", "AsyncResult",
    "ExecutionPolicy", "FROID", "INTERPRETED", "HEKATON", "ROUTED", "PRESETS",
    "resolve_policy", "plan_fingerprint", "param_signature", "batch_bucket",
]
