"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8)
d_ff(expert)=512 vocab=49155; MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-3b-a800m-base]"""
import dataclasses

from repro.models.config import ArchConfig, LayerSpec, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m",
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab=49155,
        head_dim=64,
        super_block=(LayerSpec(mixer="attn", mlp="moe"),),
        n_repeats=32,
        # §Perf hillclimb 1: pad 40 experts -> 48 (multiple of the 16-way
        # model axis) so expert parallelism shards cleanly; without this the
        # expert weights fall back to TP sharding with an (B,S,E,F) partial-
        # sum all-reduce per MoE layer (see EXPERIMENTS.md §Perf).
        moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512,
                      pad_experts_to=48),
        tie_embeddings=True,
        max_seq_len=131_072,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        config(), d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
        head_dim=16, n_repeats=2,
        moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=64),
        max_seq_len=128,
    )
