"""Columnar tables over JAX arrays.

TPU-native layout: one contiguous ``jnp`` array per column plus an optional
validity bitmap (SQL NULL semantics).  This mirrors a column store
(paper §8.2.6) — set-oriented plans stream whole columns through the VPU/MXU
instead of interpreting rows.

Strings are dictionary-encoded (int32 codes into a host-side vocabulary),
which is both what real column stores do and the only sane representation on
a tensor machine.  Dates are int32 days since 1970-01-01 (civil-day math is
implemented in pure integer jnp so date intrinsics vectorize).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Dictionary encoding for string columns
# ---------------------------------------------------------------------------


class DictEncoding:
    """A host-side vocabulary assigning int32 codes to strings."""

    def __init__(self, values: Sequence[str] = ()):
        self._to_code: dict[str, int] = {}
        self._from_code: list[str] = []
        for v in values:
            self.code(v)

    def code(self, value: str) -> int:
        c = self._to_code.get(value)
        if c is None:
            c = len(self._from_code)
            self._to_code[value] = c
            self._from_code.append(value)
        return c

    def lookup(self, value: str) -> int:
        """Code for ``value`` or -1 if absent (compares false against all)."""
        return self._to_code.get(value, -1)

    def decode(self, code: int) -> str:
        return self._from_code[int(code)]

    @property
    def vocab(self) -> tuple[str, ...]:
        """The code -> string table, in code order (round-trips the
        encoding: ``DictEncoding(enc.vocab)`` assigns identical codes)."""
        return tuple(self._from_code)

    def __len__(self) -> int:
        return len(self._from_code)

    def like_mask(self, pattern: str) -> np.ndarray:
        """Bool mask over the vocabulary for a SQL LIKE pattern.

        Supports ``%`` wildcards (prefix/suffix/contains).  Evaluated host-
        side once per query; on device LIKE becomes a gather into this mask
        (the TPU adaptation of string predicates).
        """
        import fnmatch

        pat = pattern.replace("%", "*")
        return np.array(
            [fnmatch.fnmatchcase(v, pat) for v in self._from_code], dtype=bool
        )


# ---------------------------------------------------------------------------
# Civil-date <-> day-number conversions (Howard Hinnant's algorithms),
# pure int32 arithmetic so they vectorize on the VPU.
# ---------------------------------------------------------------------------


def days_from_civil(y, m, d):
    """days since 1970-01-01 from (year, month, day); jnp-vectorized."""
    y = jnp.asarray(y, jnp.int32)
    m = jnp.asarray(m, jnp.int32)
    d = jnp.asarray(d, jnp.int32)
    y = y - (m <= 2).astype(jnp.int32)
    era = jnp.where(y >= 0, y, y - 399) // 400
    yoe = y - era * 400
    mp = (m + 9) % 12
    doy = (153 * mp + 2) // 5 + d - 1
    doe = yoe * 365 + yoe // 4 - yoe // 100 + doy
    return era * 146097 + doe - 719468


def civil_from_days(z):
    """(year, month, day) from days since epoch; jnp-vectorized."""
    z = jnp.asarray(z, jnp.int32) + 719468
    era = jnp.where(z >= 0, z, z - 146096) // 146097
    doe = z - era * 146097
    yoe = (doe - doe // 1460 + doe // 36524 - doe // 146096) // 365
    y = yoe + era * 400
    doy = doe - (365 * yoe + yoe // 4 - yoe // 100)
    mp = (5 * doy + 2) // 153
    d = doy - (153 * mp + 2) // 5 + 1
    m = jnp.where(mp < 10, mp + 3, mp - 9)
    y = y + (m <= 2).astype(jnp.int32)
    return y, m, d


def date_add(part: str, n, days):
    """SQL DATEADD on day-number dates.  part in {dd, mm, yy}."""
    days = jnp.asarray(days, jnp.int32)
    n = jnp.asarray(n, jnp.int32)
    if part in ("dd", "day"):
        return days + n
    y, m, d = civil_from_days(days)
    if part in ("yy", "year"):
        return days_from_civil(y + n, m, d)
    if part in ("mm", "month"):
        tot = (y * 12 + (m - 1)) + n
        return days_from_civil(tot // 12, tot % 12 + 1, d)
    raise ValueError(f"unsupported DATEADD part {part!r}")


def date_part(part: str, days):
    """SQL DATEPART on day-number dates.  part in {yy, mm, dd, dw}."""
    y, m, d = civil_from_days(days)
    if part in ("yy", "year"):
        return y
    if part in ("mm", "month"):
        return m
    if part in ("dd", "day"):
        return d
    if part == "dw":  # 1=Sunday..7=Saturday (1970-01-01 was a Thursday)
        return (jnp.asarray(days, jnp.int32) + 4) % 7 + 1
    raise ValueError(f"unsupported DATEPART part {part!r}")


# ---------------------------------------------------------------------------
# Columns and Tables
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Column:
    """One column: data array + optional validity (True == non-NULL) +
    optional dictionary for string columns."""

    data: jnp.ndarray
    valid: jnp.ndarray | None = None  # None means all-valid
    dictionary: DictEncoding | None = None

    @property
    def dtype(self):
        return self.data.dtype

    def validity(self) -> jnp.ndarray:
        if self.valid is None:
            return jnp.ones(self.data.shape, dtype=bool)
        return self.valid


class Table:
    """An ordered mapping name -> Column with uniform row count."""

    def __init__(self, columns: Mapping[str, Column] | None = None):
        self.columns: dict[str, Column] = dict(columns or {})
        # per-column statistics (n_distinct, min, max) — populated by
        # compute_stats(); drives capacity hints in the query optimizer
        self.stats: dict[str, tuple[int, int, int]] = {}
        if self.columns:
            n = {int(c.data.shape[0]) for c in self.columns.values()}
            if len(n) != 1:
                raise ValueError(f"ragged table: row counts {n}")

    def compute_stats(self) -> "Table":
        """Host-side column statistics for integer/dictionary columns
        (the costing input the paper notes UDFs used to hide, §2.3)."""
        for name, c in self.columns.items():
            if c.dictionary is not None:
                self.stats[name] = (len(c.dictionary), 0, len(c.dictionary) - 1)
            elif jnp.issubdtype(c.data.dtype, jnp.integer) and c.data.size:
                arr = np.asarray(c.data)
                self.stats[name] = (
                    int(len(np.unique(arr))), int(arr.min()), int(arr.max())
                )
        return self

    # -- constructors ------------------------------------------------------
    @staticmethod
    def from_arrays(**arrays) -> "Table":
        cols = {}
        for name, arr in arrays.items():
            if isinstance(arr, Column):
                cols[name] = arr
                continue
            a = np.asarray(arr)
            if a.dtype.kind in ("U", "S", "O"):  # strings -> dict encode
                enc = DictEncoding()
                codes = np.array([enc.code(str(v)) for v in a], dtype=np.int32)
                cols[name] = Column(jnp.asarray(codes), dictionary=enc)
            else:
                if a.dtype == np.float64:
                    a = a.astype(np.float32)
                if a.dtype == np.int64:
                    a = a.astype(np.int32)
                cols[name] = Column(jnp.asarray(a))
        return Table(cols)

    # -- basic ops ---------------------------------------------------------
    @property
    def num_rows(self) -> int:
        if not self.columns:
            return 1  # ConstantScan semantics: one row, no columns
        return int(next(iter(self.columns.values())).data.shape[0])

    def names(self) -> list[str]:
        return list(self.columns.keys())

    def column(self, name: str) -> Column:
        return self.columns[name]

    def with_column(self, name: str, col: Column) -> "Table":
        cols = dict(self.columns)
        cols[name] = col
        return Table(cols)

    def project(self, names: Sequence[str]) -> "Table":
        return Table({n: self.columns[n] for n in names})

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        return Table({mapping.get(n, n): c for n, c in self.columns.items()})

    def gather(self, idx: jnp.ndarray, valid: jnp.ndarray | None = None) -> "Table":
        """Row gather; optionally invalidates rows where ``valid`` is False
        (used for outer-join null padding)."""
        cols = {}
        for n, c in self.columns.items():
            data = jnp.take(c.data, idx, axis=0, mode="clip")
            v = jnp.take(c.validity(), idx, axis=0, mode="clip")
            if valid is not None:
                v = v & valid
            cols[n] = Column(data, v, c.dictionary)
        return Table(cols)

    def to_numpy(self) -> dict[str, np.ndarray]:
        """Materialize to host, decoding dictionaries and masking NULLs
        (NULL floats become nan; NULL ints become the raw value — use
        ``valids``)."""
        out = {}
        for n, c in self.columns.items():
            arr = np.asarray(c.data)
            if c.dictionary is not None:
                arr = np.array([c.dictionary.decode(v) for v in arr], dtype=object)
            out[n] = arr
        return out

    def valids(self) -> dict[str, np.ndarray]:
        return {n: np.asarray(c.validity()) for n, c in self.columns.items()}

    def nbytes(self) -> int:
        tot = 0
        for c in self.columns.values():
            tot += c.data.size * c.data.dtype.itemsize
            if c.valid is not None:
                tot += c.valid.size
        return tot

    def __repr__(self) -> str:
        cols = ", ".join(f"{n}:{c.dtype}" for n, c in self.columns.items())
        return f"Table[{self.num_rows} rows]({cols})"
