"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes a machine-readable
``BENCH_<run>.json`` artifact (suite → name → us_per_call) so the perf
trajectory is trackable across PRs / CI runs.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7,fig9,...]
                                            [--run-id ID] [--json-dir DIR]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path


def write_json(path: Path, run_id: str, args, rows: list[tuple],
               failed: list[str]) -> None:
    by_suite: dict[str, dict] = {}
    for name, us, derived in rows:
        suite = name.split("/", 1)[0]
        by_suite.setdefault(suite, {})[name] = {
            "us_per_call": round(float(us), 3), "derived": derived,
        }
    doc = {
        "run": run_id,
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "quick": bool(args.quick),
        "only": args.only,
        "failed_suites": failed,
        "suites": by_suite,
    }
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced cardinalities / query subsets")
    ap.add_argument("--only", default=None,
                    help="comma list: fig7,fig8,fig9,fig11,fig13,table4,"
                         "table5,prepared,execmany,shardmany,fused,"
                         "cursorloop,decorr,resilience,routing,fleet")
    ap.add_argument("--run-id", default=None,
                    help="label baked into the BENCH_<run>.json filename "
                         "(default: local timestamp)")
    ap.add_argument("--json-dir", default=".",
                    help="directory for the BENCH_<run>.json artifact "
                         "('' disables JSON emission)")
    args, _ = ap.parse_known_args()

    from benchmarks import (
        bench_batchmode,
        bench_compile,
        bench_cost_routing,
        bench_cursor_loops,
        bench_decorrelate,
        bench_execute_many,
        bench_factor,
        bench_fleet,
        bench_fused,
        bench_invocations,
        bench_native,
        bench_prepared,
        bench_resilience,
        bench_resources,
        bench_sharded_many,
        bench_tpch,
    )
    from benchmarks.common import ROWS

    suites = {
        "fig7": bench_invocations.run,     # invocation-count sweep
        "fig8": bench_compile.run,         # cold-cache compile overhead
        "fig9": bench_tpch.run,            # TPC-H queries with UDFs
        "fig11": bench_factor.run,         # factor of improvement (W1/W2)
        "fig13": bench_resources.run,      # CPU time + logical reads (fig14)
        "table4": bench_batchmode.run,     # batch mode / relagg kernel
        "table5": bench_native.run,        # native compilation quadrant
        "prepared": bench_prepared.run,    # Session prepare/execute lifecycle
        "execmany": bench_execute_many.run,  # batched invocation engine
        "shardmany": bench_sharded_many.run,  # mesh-sharded batches
        "fused": bench_fused.run,          # multi-statement fusion
        "cursorloop": bench_cursor_loops.run,  # loop-to-scan rewrite
        "decorr": bench_decorrelate.run,   # correlated-subquery rewrite
        "resilience": bench_resilience.run,  # ladder overhead + demotions
        "routing": bench_cost_routing.run,  # cost-based routing + d-bucketing
        "fleet": bench_fleet.run,          # persistent tier + worker fleet
    }
    only = args.only.split(",") if args.only else list(suites)

    print("name,us_per_call,derived")
    failed = []
    for key in only:
        try:
            suites[key](quick=args.quick)
        except Exception as e:
            failed.append(key)
            print(f"{key}/ERROR,0,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)

    if args.json_dir != "":
        run_id = args.run_id or time.strftime("%Y%m%d_%H%M%S")
        write_json(Path(args.json_dir) / f"BENCH_{run_id}.json",
                   run_id, args, ROWS, failed)
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
