"""Straggler detection/mitigation policy.

At pod scale the step time is the max over hosts; a single slow host
(thermal throttle, failing HBM, noisy neighbor) drags the fleet.  The
tracker keeps an EWMA of per-host step time; a host whose EWMA exceeds
``threshold`` × the fleet median for ``patience`` consecutive windows is
flagged for eviction — the launcher then triggers an elastic restart
without it (train/elastic.py).  Pure logic, unit-tested with synthetic
timings.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass
class StragglerConfig:
    alpha: float = 0.2  # EWMA coefficient
    threshold: float = 1.5  # x median
    patience: int = 3  # consecutive slow windows before eviction


class StragglerTracker:
    def __init__(self, cfg: StragglerConfig | None = None):
        self.cfg = cfg or StragglerConfig()
        self.ewma: dict[int, float] = {}
        self.slow_streak: dict[int, int] = defaultdict(int)
        self.evicted: set[int] = set()

    def record(self, host: int, step: int, seconds: float):
        a = self.cfg.alpha
        prev = self.ewma.get(host)
        self.ewma[host] = seconds if prev is None else (1 - a) * prev + a * seconds
        # evaluate only the reporting host: the slow-streak counts *its*
        # consecutive slow observations, not fleet-wide record events
        med = self._median()
        if med <= 0 or host in self.evicted:
            return
        if self.ewma[host] > self.cfg.threshold * med:
            self.slow_streak[host] += 1
            if self.slow_streak[host] >= self.cfg.patience:
                self.evicted.add(host)
        else:
            self.slow_streak[host] = 0

    def _median(self) -> float:
        vals = sorted(v for h, v in self.ewma.items() if h not in self.evicted)
        if not vals:
            return 0.0
        n = len(vals)
        return vals[n // 2] if n % 2 else 0.5 * (vals[n // 2 - 1] + vals[n // 2])

    def flagged(self) -> set[int]:
        """Hosts currently above threshold (pre-eviction warning)."""
        med = self._median()
        return {
            h
            for h, v in self.ewma.items()
            if h not in self.evicted and med > 0 and v > self.cfg.threshold * med
        }

    def should_evict(self) -> set[int]:
        return set(self.evicted)
