"""Structural plan fingerprints — the engine's cache-key vocabulary.

Identity-free normalization of plan trees and scalar expressions into
hashable tuples.  Every cache tier keys off these: the session's plan /
executable / batch / shard / fuse caches, the persistent
:class:`~repro.persist.store.PlanStore`, and the cross-statement CSE
engine's unification test (:mod:`repro.fuse.merge`).

Lives below both :mod:`repro.core.optimizer` and
:mod:`repro.core.session` in the import graph, so optimizer rewrites
(decorrelation's shared-build dedup) can fingerprint subtrees without a
cycle through the session.  ``session`` re-exports every public name for
backward compatibility.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import relalg as R
from repro.core import scalar as S

__all__ = [
    "plan_fingerprint",
    "parametric_fingerprint",
    "liftable_const",
    "const_hole_key",
]


def _norm(v, special=None) -> Any:
    """Normalize an attribute value into a hashable structure.

    ``special(v) -> tuple | None`` pre-empts the default rules when it
    returns non-None — :func:`parametric_fingerprint` uses it to replace
    parameter/outer references with canonical slot holes while sharing the
    rest of the structural normalization."""
    if special is not None:
        out = special(v)
        if out is not None:
            return out
    if isinstance(v, S.Scalar):
        return _expr_key(v, special)
    if isinstance(v, R.RelNode):
        return ("Rel:" + type(v).__name__,) + tuple(
            (k, _norm(x, special)) for k, x in vars(v).items() if k != "node_id"
        )
    if isinstance(v, dict):
        return ("dict",) + tuple((k, _norm(x, special)) for k, x in v.items())
    if isinstance(v, (list, tuple)):
        return ("seq",) + tuple(_norm(x, special) for x in v)
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return (type(v).__name__,) + tuple(
            (f.name, _norm(getattr(v, f.name), special))
            for f in dataclasses.fields(v)
        )
    if isinstance(v, (str, int, float, bool, type(None))):
        return v
    if hasattr(v, "shape") and hasattr(v, "dtype"):
        # array-valued constants: content digest, never repr (repr elides
        # the middle of large arrays, collapsing distinct values)
        arr = np.asarray(v)
        return ("array", str(arr.dtype), arr.shape,
                hashlib.sha1(arr.tobytes()).hexdigest())
    return repr(v)


def _expr_key(e: S.Scalar, special=None) -> tuple:
    return (type(e).__name__,) + tuple(
        (k, _norm(v, special)) for k, v in vars(e).items()
    )


def plan_fingerprint(node: R.RelNode) -> tuple:
    """Identity-free structural fingerprint of a plan/query tree: two
    independently-built trees of the same shape fingerprint equal."""
    return _norm(node)


def liftable_const(v) -> bool:
    """True when a :class:`~repro.core.scalar.Const` may be *lifted* into a
    template hole: re-injecting its value as a parameter binding reproduces
    the constant's evaluation exactly.  int consts always evaluate int32
    (matching ``_param_value``); float consts match only at the default
    float32 dtype.  bool/str/NULL consts are structural (predication flags,
    typed nulls, dictionary literals) and never lift."""
    if not isinstance(v, S.Const):
        return False
    if isinstance(v.value, bool) or v.value is None:
        return False
    if isinstance(v.value, (int, np.integer)):
        return True
    if isinstance(v.value, (float, np.floating)):
        return v.dtype is None or v.dtype == jnp.float32
    return False


def const_hole_key(value) -> tuple:
    """Dtype-aware hole-numbering key of a liftable const's value (``5``
    and ``5.0`` hash equal as plain dict keys but evaluate int32 vs
    float32, so they must stay distinct holes)."""
    if isinstance(value, (int, np.integer)):
        return ("int", int(value))
    return ("float", float(value))


def parametric_fingerprint(node: R.RelNode,
                           lift_consts: bool = False) -> tuple[tuple, tuple]:
    """``(fingerprint, holes)`` with parameter slots canonicalized.

    The fingerprint is :func:`plan_fingerprint` with every ``Param``/``Outer``
    reference replaced by a numbered hole in first-encounter order, so two
    subtrees equal *modulo parameter naming* fingerprint equal — the
    unification test of the cross-statement CSE engine (repro.fuse.merge).
    Hole numbering is per-name: ``Param(a) + Param(a)`` canonicalizes to
    ``hole0 + hole0`` and therefore never unifies with ``Param(x) +
    Param(y)`` (``hole0 + hole1``); param and outer references are distinct
    hole kinds and never unify with each other.

    With ``lift_consts=True``, :func:`liftable_const` constants additionally
    become holes, and param/const holes share one hole tag — ``a < 5``
    fingerprints equal to ``a < Param(x)``, the const-vs-param unification
    key (numbering stays per-key: ``5 + 5`` is ``hole0 + hole0`` like
    ``Param(a) + Param(a)``).  The lifted fingerprint lives in its own
    namespace (tags differ from the plain form), so callers never mix the
    two key spaces.

    ``holes`` is the tuple of ``(kind, actual_name_or_value)`` in canonical
    order — the subtree's slot signature, which callers combine with the
    canonical hole spelling (``merge.hole_name``) to build per-occurrence
    binding maps.  A hole-free subtree fingerprints identically to its
    plain :func:`plan_fingerprint`."""
    holes: list[tuple[str, Any]] = []
    index: dict[tuple[str, Any], int] = {}

    def special(v):
        if isinstance(v, S.Param):
            kind, name = "param", v.name
        elif isinstance(v, S.Outer):
            kind, name = "outer", v.name
        elif lift_consts and liftable_const(v):
            # dtype-aware key: int 5 and float 5.0 compare equal as dict
            # keys, but evaluate at different dtypes — they must number as
            # distinct holes within one subtree
            kind, name = "const", const_hole_key(v.value)
        else:
            return None
        k = (kind, name)
        if k not in index:
            index[k] = len(holes)
            holes.append(k)
        tag = "lifted" if (lift_consts and kind != "outer") else kind
        return ("hole", tag, index[k])

    return _norm(node, special), tuple(holes)
