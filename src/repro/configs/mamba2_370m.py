"""mamba2-370m [ssm] — 48L d_model=1024, attention-free SSD blocks,
vocab=50280, ssm_state=128.  [arXiv:2405.21060]"""
import dataclasses

from repro.models.config import ArchConfig, LayerSpec, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m",
        d_model=1024,
        n_heads=32,       # SSD heads (d_inner=2048 / head_dim=64)
        n_kv_heads=32,
        d_ff=0,           # attention-free, no MLP (pure Mamba-2 blocks)
        vocab=50280,
        head_dim=64,
        super_block=(LayerSpec(mixer="mamba", mlp="none"),),
        n_repeats=48,
        ssm=SSMConfig(state_dim=128, head_dim=64, n_groups=1, conv_kernel=4,
                      expand=2),
        tie_embeddings=True,
        subquadratic=True,
        max_seq_len=1_048_576,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        config(),
        d_model=64,
        n_heads=8,
        n_kv_heads=8,
        vocab=128,
        head_dim=16,
        n_repeats=2,
        ssm=SSMConfig(state_dim=16, head_dim=16, n_groups=1, conv_kernel=4,
                      expand=2),
        max_seq_len=128,
    )
