"""AdamW with optional low-precision moments (DESIGN.md §6: bf16 moments
keep jamba-398B's optimizer state inside 16 GB/chip), global-norm clipping,
and a linear-warmup + cosine-decay schedule.  Pure functions over pytrees —
optimizer state shards exactly like the parameters (same PartitionSpecs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "bfloat16"  # f32 master params; bf16 m/v
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * b1 + (1 - b1) * g
        vf = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mh = mf / bc1
        vh = vf / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        new_p = p.astype(jnp.float32) * (1 - lr * cfg.weight_decay) - lr * delta
        return new_p.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
