from repro.serve.engine import Request, ServeEngine
from repro.serve.admission import AdmissionPolicy

__all__ = ["Request", "ServeEngine", "AdmissionPolicy"]
