"""Mesh-sharded `execute_many`: the batched invocation engine one level up
the hardware hierarchy.

Covers the ISSUE-3 contract: element-wise identity between the sharded
path and the serial `execute` loop, divisibility gating (buckets the mesh's
data axes don't divide run on the replicated path), the sharded-executable
cache tier (`shard_hits`/`shard_misses`), mesh-capacity chunking
(`max_batch` bounds the per-device batch), mesh-sized scheduler flushes,
catalog invalidation of sharded executables, and the sharded admission
path of the serving engine.

Every test passes on a single device (sharding degrades to the replicated
path) and is exercised for real under the CI job that forces
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import numpy as np
import pytest

import jax

from repro.core import (
    FROID,
    ExecutionPolicy,
    Session,
    UdfBuilder,
    col,
    lit,
    param,
    scan,
    sum_,
    udf,
    var,
)
from repro.dist.sharding import data_axis_size, pick_data_axes
from repro.serve.scheduler import CoalescingScheduler

N_DEV = len(jax.devices())

multi_device = pytest.mark.skipif(
    N_DEV < 2, reason="needs >1 device (XLA_FLAGS=--xla_force_host_"
                      "platform_device_count=8)"
)


def _mesh():
    return jax.make_mesh((N_DEV,), ("data",))


def _populate(db, n_detail=2000, n_t=200, seed=0):
    rng = np.random.default_rng(seed)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 50, n_detail),
        d_val=rng.uniform(0, 100, n_detail).astype(np.float32),
    )
    db.create_table("T", a=rng.integers(0, 50, n_t))
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    with u.if_(var("s").is_null()):
        u.return_(lit(0.0))
    u.return_(var("s"))
    db.create_function(u.build())


def _q():
    return (
        scan("T")
        .filter(col("a") < param("cutoff"))
        .compute(v=udf("key_total", col("a")))
        .project("v")
    )


def _assert_same(serial, batched):
    assert len(serial) == len(batched)
    for s, b in zip(serial, batched):
        m = np.asarray(s.masked.mask)
        np.testing.assert_array_equal(m, np.asarray(b.masked.mask))
        # surviving rows only: dead lanes carry arbitrary values and may
        # differ between single-device and mesh-partitioned compilations
        np.testing.assert_allclose(
            np.asarray(s.masked.table.columns["v"].data)[m],
            np.asarray(b.masked.table.columns["v"].data)[m],
            rtol=1e-5,
        )


@pytest.fixture
def db():
    s = Session()
    _populate(s)
    return s


# ---------------------------------------------------------------------------
# policy knobs
# ---------------------------------------------------------------------------


def test_shard_knobs_are_not_identity():
    mesh = _mesh()
    pol = FROID.sharded(mesh)
    assert pol == FROID
    assert pol.fingerprint() == FROID.fingerprint()
    assert pol.mesh is mesh and pol.shard_batches
    assert pol.shard_devices() == data_axis_size(mesh)
    assert FROID.shard_devices() == 1 and FROID.shard_token() == ()
    # eager (no compiled plan) never shards, even with a mesh attached
    assert pol.eager().shard_devices() == 1


def test_shard_token_tracks_mesh_identity():
    mesh = _mesh()
    pol = FROID.sharded(mesh)
    if N_DEV == 1:
        assert pol.shard_token() == ()  # 1-device mesh: no data sharding
        return
    axes, devices = pol.shard_token()
    assert axes == (("data", N_DEV),)
    assert len(devices) == N_DEV
    # a rebuilt mesh over the same devices produces the same token (cache
    # hits survive mesh reconstruction)
    assert FROID.sharded(_mesh()).shard_token() == pol.shard_token()


def test_prepare_sharded_and_unsharded_do_not_alias(db):
    s1 = db.prepare(_q(), FROID)
    s2 = db.prepare(_q(), FROID.sharded(_mesh()))
    if N_DEV == 1:
        assert s2.policy.shard_devices() == 1
        return
    assert s1 is not s2
    assert s1.policy.mesh is None and s2.policy.mesh is not None


# ---------------------------------------------------------------------------
# element-wise identity with the serial loop
# ---------------------------------------------------------------------------


def test_sharded_execute_many_matches_serial_loop(db):
    stmt = db.prepare(_q(), FROID.sharded(_mesh()))
    rng = np.random.default_rng(1)
    params_list = [{"cutoff": int(k)} for k in rng.integers(1, 50, 2 * N_DEV)]
    serial = [stmt.execute(params=p) for p in params_list]
    batched = stmt.execute_many(params_list)
    _assert_same(serial, batched)
    st = batched[0].stats
    assert st["batched"] and st["batch_size"] == 2 * N_DEV
    if N_DEV > 1:
        assert st["sharded"] and st["shard_devices"] == N_DEV


def test_sharded_mixed_signatures_match_serial(db):
    stmt = db.prepare(_q(), FROID.sharded(_mesh()))
    params_list = (
        [{"cutoff": int(k)} for k in range(1, 1 + 2 * N_DEV)]
        + [{"cutoff": float(k) + 0.5} for k in range(1, 1 + N_DEV)]
    )
    batched = stmt.execute_many(params_list)
    serial = [stmt.execute(params=p) for p in params_list]
    _assert_same(serial, batched)


def test_sharded_empty_table_matches_serial():
    db = Session()
    _populate(db)
    db.create_table("T", a=np.array([], np.int64))
    stmt = db.prepare(_q(), FROID.sharded(_mesh()))
    params_list = [{"cutoff": int(k)} for k in range(N_DEV)]
    batched = stmt.execute_many(params_list)
    serial = [stmt.execute(params=p) for p in params_list]
    _assert_same(serial, batched)
    assert all(r.masked.num_rows == 0 for r in batched)


def test_empty_aggregate_source_table_runs():
    """Aggregating over a zero-row table must produce NULL aggregates (the
    UDF's NULL branch), not crash — on every path."""
    db = Session()
    db.create_table("detail", d_key=np.array([], np.int64),
                    d_val=np.array([], np.float32))
    db.create_table("T", a=np.arange(4))
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    with u.if_(var("s").is_null()):
        u.return_(lit(0.0))
    u.return_(var("s"))
    db.create_function(u.build())
    stmt = db.prepare(_q(), FROID.sharded(_mesh()))
    rs = stmt.execute_many([{"cutoff": 3}] * max(2, N_DEV))
    serial = [stmt.execute(params={"cutoff": 3})] * max(2, N_DEV)
    _assert_same(serial, rs)
    np.testing.assert_array_equal(
        np.asarray(rs[0].masked.table.columns["v"].data)[
            np.asarray(rs[0].masked.mask)],
        0.0,
    )


# ---------------------------------------------------------------------------
# divisibility gating + cache tier
# ---------------------------------------------------------------------------


@multi_device
def test_small_bucket_runs_replicated(db):
    """A bucket the data axes don't divide (here bucket 1 < devices) must
    run on the replicated single-device path, never padded to the mesh."""
    stmt = db.prepare(_q(), FROID.sharded(_mesh()))
    rs = stmt.execute_many([{"cutoff": 7}])
    assert "sharded" not in rs[0].stats
    assert db.cache_stats["shard_misses"] == 0
    assert pick_data_axes(_mesh(), 1) is None


@multi_device
def test_shard_cache_tier_hits(db):
    stmt = db.prepare(_q(), FROID.sharded(_mesh()))
    params_list = [{"cutoff": int(k)} for k in range(N_DEV)]
    r1 = stmt.execute_many(params_list)
    assert r1[0].stats["sharded"] and not r1[0].cache_hit
    assert db.cache_stats["shard_misses"] == 1
    r2 = stmt.execute_many([{"cutoff": int(k) + 9} for k in range(N_DEV)])
    assert r2[0].cache_hit
    assert db.cache_stats["shard_hits"] == 1
    assert db.cache_stats["shard_misses"] == 1
    # the sharded tier is separate from the single-device batch tier: an
    # unsharded statement on the same query re-specializes there
    un = db.prepare(_q(), FROID)
    un.execute_many(params_list)
    assert db.cache_stats["batch_misses"] >= 1


@pytest.mark.skipif(N_DEV < 8, reason="needs 8 forced devices")
def test_replicated_fallback_respects_max_batch(db):
    """A bucket the data axes don't divide falls back to the replicated
    path re-chunked at the *per-device* bound — the mesh-capacity cap must
    never land whole on one device."""
    from jax.sharding import Mesh

    mesh6 = Mesh(np.array(jax.devices()[:6]), ("data",))
    stmt = db.prepare(_q(), FROID.sharded(mesh6).batched(max_batch=2))
    plist = [{"cutoff": int(k)} for k in range(5)]  # bucket 8, 8 % 6 != 0
    rs = stmt.execute_many(plist)
    assert all("sharded" not in r.stats for r in rs)
    assert all(r.stats["batch_bucket"] <= 2 for r in rs)
    assert [r.stats["batch_size"] for r in rs] == [2, 2, 2, 2, 1]
    _assert_same([stmt.execute(params=p) for p in plist], rs)


@multi_device
def test_mesh_capacity_chunking(db):
    """`max_batch` bounds the per-device batch: a mesh of D devices takes
    max_batch × D parameter sets in one sharded dispatch."""
    stmt = db.prepare(_q(), FROID.sharded(_mesh()).batched(max_batch=2))
    n = 2 * N_DEV + 2  # one full mesh dispatch + a remainder chunk
    params_list = [{"cutoff": int(k % 50)} for k in range(n)]
    rs = stmt.execute_many(params_list)
    sizes = [r.stats["batch_size"] for r in rs]
    assert sizes[: 2 * N_DEV] == [2 * N_DEV] * (2 * N_DEV)
    assert sizes[2 * N_DEV:] == [2, 2]
    assert rs[0].stats["sharded"]
    assert rs[-1].stats["batch_bucket"] == 2
    _assert_same([stmt.execute(params=p) for p in params_list], rs)


# ---------------------------------------------------------------------------
# invalidation
# ---------------------------------------------------------------------------


def test_ddl_invalidates_sharded_executables(db):
    stmt = db.prepare(_q(), FROID.sharded(_mesh()))
    params_list = [{"cutoff": int(k)} for k in range(max(2, N_DEV))]
    r1 = stmt.execute_many(params_list)
    assert stmt.execute_many(params_list)[0].cache_hit
    rng = np.random.default_rng(42)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 50, 2000),
        d_val=rng.uniform(0, 100, 2000).astype(np.float32),
    )
    r2 = stmt.execute_many(params_list)
    assert not r2[0].cache_hit
    _assert_same([stmt.execute(params=p) for p in params_list], r2)
    # new data actually flowed through (same T, same mask; fresh detail)
    m = np.asarray(r2[-1].masked.mask)
    a1 = np.asarray(r1[-1].masked.table.columns["v"].data)[m]
    a2 = np.asarray(r2[-1].masked.table.columns["v"].data)[m]
    assert not np.allclose(a1, a2)


# ---------------------------------------------------------------------------
# scheduler + serving integration
# ---------------------------------------------------------------------------


def test_scheduler_flushes_mesh_sized_buckets(db):
    """Flush-on-full for a sharded statement waits for max_batch × devices
    requests — online traffic fills every device, not one."""
    clock = lambda: 0.0  # noqa: E731 — window never expires
    sched = CoalescingScheduler(window_s=10.0, clock=clock)
    stmt = db.prepare(_q(), FROID.sharded(_mesh()).batched(max_batch=2))
    target = 2 * N_DEV
    tickets = [sched.submit(stmt, {"cutoff": int(k % 50)})
               for k in range(target - 1)]
    assert sched.pending == target - 1  # still coalescing
    tickets.append(sched.submit(stmt, {"cutoff": 1}))  # fills the mesh
    assert sched.pending == 0 and sched.stats["flush_full"] == 1
    assert all(t.done() for t in tickets)
    if N_DEV > 1:
        assert tickets[0].result().stats["sharded"]
    assert tickets[0].result().stats["batch_size"] == target


def test_ddl_between_submit_and_drain_not_stale_sharded(db):
    """Catalog replacement while tickets are queued must re-specialize the
    sharded executable at drain time — never serve stale results."""
    clock = lambda: 0.0  # noqa: E731
    sched = CoalescingScheduler(window_s=10.0, clock=clock)
    stmt = db.prepare(_q(), FROID.sharded(_mesh()))
    params_list = [{"cutoff": int(k)} for k in range(max(2, N_DEV))]
    stmt.execute_many(params_list)  # warm the pre-DDL executable
    tickets = [sched.submit(stmt, p) for p in params_list]
    rng = np.random.default_rng(7)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 50, 2000),
        d_val=rng.uniform(0, 100, 2000).astype(np.float32),
    )
    sched.flush()
    results = [t.result() for t in tickets]
    assert not results[0].cache_hit  # re-specialized, not stale
    _assert_same([stmt.execute(params=p) for p in params_list], results)


def test_admission_sharded_matches_tick_path():
    from repro.serve.admission import AdmissionPolicy

    n = 4 * max(2, N_DEV)
    rng = np.random.default_rng(5)
    reqs = {
        "tier": rng.integers(0, 3, n),
        "prompt_len": rng.integers(10, 40000, n),
        "max_new_tokens": rng.integers(1, 9000, n),
        "temperature": rng.uniform(-1, 3, n).astype(np.float32),
    }
    ap = AdmissionPolicy(froid=True, mesh=_mesh())
    tick = ap.evaluate(reqs)
    co = ap.evaluate_coalesced(reqs)
    np.testing.assert_array_equal(tick["admit"], co["admit"])
    np.testing.assert_array_equal(tick["granted"], co["granted"])
    np.testing.assert_allclose(tick["temp"], co["temp"], rtol=1e-6)
    if N_DEV > 1:
        assert ap.request_statement().policy.shard_devices() == N_DEV


@multi_device
def test_serve_engine_accepts_admission_mesh():
    """ServeEngine wires admission_mesh through to the sharded per-request
    admission statement (full decode loop covered by test_serve_and_data)."""
    from repro.serve.engine import ServeEngine

    class _NoModel:
        def decode_step(self, params, cache, tok):  # pragma: no cover
            raise AssertionError("decode never reached in this test")

    eng = ServeEngine(_NoModel(), params=None, admission_mesh=_mesh())
    assert eng.admission.mesh is not None
    assert eng.admission.request_statement().policy.shard_devices() == N_DEV
