"""Serving engine (with Froid-compiled admission) + data pipeline tests."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config_for
from repro.data.pipeline import DataPipeline
from repro.models import build_model
from repro.serve.admission import AdmissionPolicy
from repro.serve.engine import Request, ServeEngine


def test_admission_policy_froid_matches_interpreter():
    reqs = {
        "tier": np.array([0, 1, 2, 0, 2]),
        "prompt_len": np.array([100, 3000, 9000, 40000, 100]),
        "max_new_tokens": np.array([50, 2000, 8000, 10, 100]),
        "temperature": np.array([0.5, 1.5, -1.0, 0.7, 3.0], np.float32),
    }
    on = AdmissionPolicy(froid=True).evaluate(reqs)
    off = AdmissionPolicy(froid=False).evaluate(reqs)
    np.testing.assert_array_equal(on["admit"], off["admit"])
    np.testing.assert_array_equal(on["granted"], off["granted"])
    np.testing.assert_allclose(on["temp"], off["temp"], rtol=1e-6)
    # semantic spot checks
    assert not on["admit"][3]  # prompt > 32768 rejected
    assert on["granted"][0] == 50  # request below cap honored
    assert on["granted"][1] == 512  # tier-1 cap, halved for >2048 prompt
    assert on["temp"][4] == pytest.approx(0.7)  # out-of-range -> default
    assert on["temp"][0] == pytest.approx(0.5)


def test_serve_engine_end_to_end():
    cfg = smoke_config_for("granite3_2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=2, max_len=64, eos_id=None)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new_tokens=6, temperature=0.0, tier=1)
        for i in range(5)
    ]
    done = eng.run(reqs)
    assert len(done) == 5
    for c in done:
        assert c.reason in ("length", "eos")
        assert len(c.tokens) == 6
        assert all(0 <= t < cfg.vocab for t in c.tokens)


def test_serve_greedy_deterministic():
    cfg = smoke_config_for("granite3_2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, params, slots=1, max_len=32)
        done = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=5)])
        outs.append(done[0].tokens)
    assert outs[0] == outs[1]


def test_serve_rejects_oversized():
    cfg = smoke_config_for("granite3_2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=1, max_len=32)
    big = Request(rid=9, prompt=np.zeros(8, np.int32), max_new_tokens=4)
    big_prompt = Request(rid=10, prompt=np.zeros(8, np.int32), max_new_tokens=4)
    # monkey the admission input by tier/prompt: oversized prompt_len comes
    # from the request itself
    r = Request(rid=11, prompt=np.zeros(8, np.int32), max_new_tokens=4)
    reqs = [big, big_prompt, r]
    done = eng.run(reqs)
    assert all(c.reason in ("length", "eos", "rejected") for c in done)


def test_serve_submit_drain_matches_run():
    """The online intake (submit per request + coalesced admission) must
    complete the same requests with the same budgets as the tick path."""
    cfg = smoke_config_for("granite3_2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_new_tokens=int(rng.integers(3, 7)), temperature=0.0,
                tier=int(rng.integers(0, 3)))
        for i in range(5)
    ]
    run_out = {c.rid: c for c in ServeEngine(model, params, slots=2,
                                             max_len=64).run(reqs)}
    eng = ServeEngine(model, params, slots=2, max_len=64)
    for r in reqs:
        eng.submit(r)
    drain_out = {c.rid: c for c in eng.drain()}
    assert set(run_out) == set(drain_out)
    for rid in run_out:
        assert run_out[rid].reason == drain_out[rid].reason
        assert run_out[rid].tokens == drain_out[rid].tokens
    assert eng.admission.scheduler.stats["batches"] >= 1
    assert eng.drain() == []  # nothing pending


def test_data_pipeline_deterministic_and_froid_consistent():
    cfg = smoke_config_for("granite3_2b")
    p1 = DataPipeline(batch=8, seq_len=16, vocab=cfg.vocab, seed=3, froid=True)
    p2 = DataPipeline(batch=8, seq_len=16, vocab=cfg.vocab, seed=3, froid=True)
    b1, b2 = p1.batch_at(5), p2.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    np.testing.assert_array_equal(np.asarray(b1["mask"]), np.asarray(b2["mask"]))
    # froid ON == interpreter OFF for the compiled transforms
    p3 = DataPipeline(batch=8, seq_len=16, vocab=cfg.vocab, seed=3, froid=False)
    b3 = p3.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["mask"]), np.asarray(b3["mask"]))
    np.testing.assert_allclose(
        np.asarray(b1["weight"]), np.asarray(b3["weight"]), rtol=1e-6
    )


def test_data_pipeline_host_sharding():
    cfg = smoke_config_for("granite3_2b")
    full = DataPipeline(batch=8, seq_len=16, vocab=cfg.vocab, seed=3,
                        host=0, num_hosts=1)
    h0 = DataPipeline(batch=8, seq_len=16, vocab=cfg.vocab, seed=3,
                      host=0, num_hosts=2)
    h1 = DataPipeline(batch=8, seq_len=16, vocab=cfg.vocab, seed=3,
                      host=1, num_hosts=2)
    b0, b1 = h0.batch_at(0), h1.batch_at(0)
    assert b0["tokens"].shape[0] == 4 and b1["tokens"].shape[0] == 4
    # different hosts get different data
    assert not np.array_equal(np.asarray(b0["tokens"]), np.asarray(b1["tokens"]))
