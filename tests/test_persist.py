"""Persistent plan-cache tier: store format, key stability, session
integration, and cost-table persistence.

The tier's contract is *costs only*: whatever the store serves — a hit, a
miss, a stale stamp, a truncated file, a concurrent writer — the session
answers identically to a store-less run.  Every degradation path here
asserts both the typed signal (counter/warning/exception) and result
parity.
"""
from __future__ import annotations

import glob
import json
import os
import threading
import warnings

import numpy as np
import pytest

from conformance_util import (
    FIXED_PROGRAMS,
    assert_rows_equal,
    build_udf,
    make_session,
    param_query,
    populate_session,
)
from repro.core import FROID, ROUTED, Session
from repro.persist import (
    PERSIST_SCHEMA_VERSION,
    PlanCacheCorruptError,
    PlanCacheVersionError,
    PlanCacheWarning,
    PlanStore,
    assert_stable_key,
    parse_key,
    runtime_stamp,
)

PARAMS = {"cut": 5, "shift": 0.5}


def _session(tmp_path, seed=7, n_rows=23, store=True):
    s = Session(store=str(tmp_path) if store else None)
    populate_session(s, seed, n_rows)
    s.create_function(build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    return s


# ---------------------------------------------------------------------------
# store unit tests: entry format, atomicity, typed degradation
# ---------------------------------------------------------------------------


def test_store_put_get_roundtrip(tmp_path):
    st = PlanStore(str(tmp_path))
    key = ("plan", "exec", ("fp",), (True, "python"), (), 0)
    st.put(key, {"kind": "exec"}, b"payload-bytes")
    got = st.get(key)
    assert got is not None
    meta, blob = got
    assert meta["kind"] == "exec" and blob == b"payload-bytes"
    assert st.get(("plan", "other")) is None  # clean miss
    assert st.stats()["entries"] == 1


def test_store_corrupt_entry_raises_typed(tmp_path):
    st = PlanStore(str(tmp_path))
    key = ("k", 1)
    st.put(key, {}, b"x" * 64)
    path = st.path_for(key)
    # truncation at several depths: magic, header length, header, blob
    for size in (3, 10, 12, 70):
        with open(path, "r+b") as f:
            f.truncate(size)
        with pytest.raises(PlanCacheCorruptError):
            st.get(key)
        st.put(key, {}, b"x" * 64)  # restore for next depth
    # flipped payload byte: digest mismatch
    raw = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(raw[:-1] + bytes([raw[-1] ^ 0xFF]))
    with pytest.raises(PlanCacheCorruptError):
        st.get(key)


def test_store_version_stamp_mismatch(tmp_path):
    st = PlanStore(str(tmp_path))
    st.put(("k",), {}, b"blob")
    stale = PlanStore(str(tmp_path),
                      stamp={**runtime_stamp(), "jax": "0.0.0"})
    with pytest.raises(PlanCacheVersionError):
        stale.get(("k",))
    # same-stamp reader still loads
    assert PlanStore(str(tmp_path)).get(("k",)) is not None


def test_store_concurrent_writers_atomic(tmp_path):
    """N threads racing puts on one key: readers always see a complete
    entry (one writer's whole blob, never a torn mix)."""
    st = PlanStore(str(tmp_path))
    key = ("contended",)
    payloads = [bytes([i]) * 4096 for i in range(8)]
    errs = []

    def write(i):
        try:
            for _ in range(20):
                st.put(key, {"w": i}, payloads[i])
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=write, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for _ in range(50):
        got = st.get(key)
        if got is not None:
            meta, blob = got
            assert blob == payloads[meta["w"]]
    for t in threads:
        t.join()
    assert not errs
    meta, blob = st.get(key)
    assert blob == payloads[meta["w"]]
    # no leaked tempfiles
    assert not [p for p in os.listdir(tmp_path) if p.startswith("tmp")]


def test_store_rejects_unstable_keys(tmp_path):
    st = PlanStore(str(tmp_path))

    class Opaque:
        pass

    for bad in ((Opaque(),), (("x", [1, 2]),), ({"a": 1},)):
        with pytest.raises(TypeError):
            st.put(bad, {}, b"")


# ---------------------------------------------------------------------------
# key stability: repr round-trip, cross-process determinism
# ---------------------------------------------------------------------------


def test_stable_key_scalars_and_nesting():
    key = ("plan", 1, 2.5, True, None, b"b", ("nested", ("deeper", 0)))
    assert_stable_key(key)
    assert parse_key(repr(key)) == key


def test_stable_key_rejects_process_local():
    with pytest.raises(TypeError):
        assert_stable_key((object(),))
    with pytest.raises(TypeError):
        assert_stable_key(("ok", ["lists", "are", "mutable"]))
    with pytest.raises(TypeError):
        assert_stable_key(({"dicts": "too"},))


def test_persist_keys_identical_across_sessions(tmp_path):
    """Two independently-built same-content sessions must produce
    bit-identical persist identity — the whole point of the shared tier.
    An ``id()``-derived or dict-order-dependent token would diverge here,
    and the second session's store lookups would all miss."""
    tokens = []
    for _ in range(2):
        s = _session(tmp_path, store=False)
        tok = s._content_env_token()
        assert_stable_key(tok)
        assert parse_key(repr(tok)) == tok
        tokens.append(tok)
    assert tokens[0] == tokens[1]
    # end-to-end: the second session's first execute hits the first's entry
    a = _session(tmp_path)
    a.execute(param_query(), FROID, params=PARAMS)
    b = _session(tmp_path)
    b.execute(param_query(), FROID, params=PARAMS)
    assert b.cache_stats["persist_hits"] >= 1
    assert b.cache_stats["persist_misses"] == 0


def test_content_env_token_tracks_data(tmp_path):
    s = _session(tmp_path, store=False)
    t0 = s._content_env_token()
    assert s._content_env_token() == t0  # memoized + stable
    s.create_table("facts", fk=np.arange(4), val=np.ones(4, np.float32),
                   qty=np.arange(4))
    t1 = s._content_env_token()
    assert t1 != t0  # data changed -> token changed
    assert_stable_key(t1)


# ---------------------------------------------------------------------------
# session integration: hit/miss/invalidate, degradation parity
# ---------------------------------------------------------------------------


def test_session_cold_then_warm(tmp_path):
    cold = _session(tmp_path)
    q = param_query()
    expected = cold.execute(q, FROID, params=PARAMS)
    assert cold.cache_stats["persist_misses"] >= 1
    assert cold.persist_stats["saves"] >= 1

    warm = _session(tmp_path)
    got = warm.execute(q, FROID, params=PARAMS)
    assert_rows_equal(expected, got, "warm vs cold")
    assert warm.cache_stats["persist_hits"] >= 1
    assert warm.cache_stats["persist_misses"] == 0


def test_session_invalidate_by_content(tmp_path):
    cold = _session(tmp_path, seed=7)
    cold.execute(param_query(), FROID, params=PARAMS)

    other = _session(tmp_path, seed=8)  # different data, same store
    other.execute(param_query(), FROID, params=PARAMS)
    assert other.cache_stats["persist_hits"] == 0
    assert other.cache_stats["persist_misses"] >= 1


def test_session_corrupt_entry_recompiles_with_warning(tmp_path):
    cold = _session(tmp_path)
    q = param_query()
    expected = cold.execute(q, FROID, params=PARAMS)
    for p in glob.glob(os.path.join(str(tmp_path), "*.plan")):
        with open(p, "r+b") as f:
            f.truncate(16)
    warm = _session(tmp_path)
    with pytest.warns(PlanCacheWarning):
        got = warm.execute(q, FROID, params=PARAMS)
    assert_rows_equal(expected, got, "corrupt-store vs oracle")
    assert warm.cache_stats["persist_rejects"] >= 1
    assert warm.cache_stats["persist_hits"] == 0
    assert warm.persist_stats["saves"] >= 1  # evicted + re-saved behind
    # so a third session warm-starts from the repaired entry
    third = _session(tmp_path)
    third.execute(q, FROID, params=PARAMS)
    assert third.cache_stats["persist_hits"] >= 1


def test_session_stale_stamp_recompiles_silently(tmp_path):
    cold = _session(tmp_path)
    q = param_query()
    expected = cold.execute(q, FROID, params=PARAMS)
    stale = Session(store=PlanStore(
        str(tmp_path), stamp={**runtime_stamp(), "schema": -1}))
    populate_session(stale, 7, 23)
    stale.create_function(
        build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # version skew must NOT warn
        got = stale.execute(q, FROID, params=PARAMS)
    assert_rows_equal(expected, got, "stale-stamp vs oracle")
    assert stale.cache_stats["persist_rejects"] >= 1


def test_policy_opt_out(tmp_path):
    s = _session(tmp_path)
    s.execute(param_query(), FROID.persisted(False), params=PARAMS)
    assert s.cache_stats["persist_misses"] == 0
    assert s.persist_stats["saves"] == 0
    # identity unchanged: opted-out and opted-in policies share caches
    assert FROID.persisted(False).fingerprint() == FROID.fingerprint()


def test_execute_many_warm_start(tmp_path):
    cold = _session(tmp_path)
    stmt = cold.prepare(param_query(), FROID)
    plist = [{"cut": c, "shift": 0.5} for c in (3, 5, 6)]
    expected = stmt.execute_many(plist)

    warm = _session(tmp_path)
    got = warm.prepare(param_query(), FROID).execute_many(plist)
    for i, (e, g) in enumerate(zip(expected, got)):
        assert_rows_equal(e, g, f"warm many[{i}]")
    assert warm.cache_stats["persist_hits"] >= 1


# ---------------------------------------------------------------------------
# cost-table persistence
# ---------------------------------------------------------------------------


def _route_waves(s, waves=2):
    from conformance_util import fusion_calls_spec, fusion_queries
    from repro.serve.scheduler import CoalescingScheduler

    stmts = [s.prepare(q, ROUTED) for q in fusion_queries()]
    sched = CoalescingScheduler(max_batch=256, window_s=10.0,
                                clock=lambda: 0.0, fuse=True)
    for _ in range(waves):
        ts = [sched.submit(stmts[i], p) for i, p in fusion_calls_spec()]
        sched.flush()
        [t.result() for t in ts]


def test_cost_tables_roundtrip(tmp_path):
    s1 = _session(tmp_path)
    _route_waves(s1)
    assert s1.cost_stats["samples"] >= 1
    assert s1.save_costs()
    assert s1.persist_stats["costs_saved"] == 1

    s2 = _session(tmp_path)
    s2._ensure_router()
    assert s2.persist_stats["costs_loaded"] >= 1
    # measured tables arrived without any execution on s2
    state = s2.cost_router.export_state()
    assert state["measured"]
    for key_repr, *_ in state["measured"]:
        assert parse_key(key_repr)  # strict round-trip on every row


def test_cost_tables_corrupt_degrades_to_empty(tmp_path):
    s1 = _session(tmp_path)
    _route_waves(s1)
    assert s1.save_costs()
    from repro.persist.costs import costs_key
    path = s1.store.path_for(costs_key(s1._content_env_token()))
    raw = open(path, "rb").read()
    with open(path, "wb") as f:  # valid envelope, garbage JSON payload
        f.write(raw[: len(raw) // 2])
    s2 = _session(tmp_path)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PlanCacheWarning)
        s2._ensure_router()
    assert s2.persist_stats["costs_loaded"] == 0
    assert s2.persist_stats["rejects"] >= 1
    # routing still works from scratch
    _route_waves(s2, waves=1)
    assert s2.cost_stats["samples"] >= 1


def test_persist_stats_shape(tmp_path):
    s = _session(tmp_path)
    ps = s.persist_stats
    assert ps["enabled"] and "store" in ps
    assert {"hits", "misses", "rejects", "saves"} <= ps.keys()
    assert Session().persist_stats == {"enabled": False}


def test_schema_version_is_stamped(tmp_path):
    s = _session(tmp_path)
    s.execute(param_query(), FROID, params=PARAMS)
    entry = glob.glob(os.path.join(str(tmp_path), "*.plan"))[0]
    raw = open(entry, "rb").read()
    hdr = json.loads(raw[12:12 + int.from_bytes(raw[8:12], "little")])
    assert hdr["stamp"]["schema"] == PERSIST_SCHEMA_VERSION
    assert hdr["stamp"]["jax"] == runtime_stamp()["jax"]
