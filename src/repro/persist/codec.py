"""Serialize compiled JAX executables for the persistent plan tier.

The fast path is native XLA executable serialization
(``jax.experimental.serialize_executable``): a ``jit(...).lower(...).compile()``
artifact round-trips to bytes and loads back in milliseconds with **no
re-tracing and no re-compilation** — measured two orders of magnitude faster
than a cold trace for the statements in this repo.  The flip side is that the
payload is a native artifact, so the store's runtime stamp (jax/jaxlib,
backend, device count) gates every load; a mismatch degrades to recompile.

The blob is a pickle of ``(payload, in_tree, out_tree)`` exactly as returned
by ``serialize_executable.serialize`` (the two ``PyTreeDef``s are not part of
the payload and pickle round-trips them faithfully).  Host-side row metadata
(dictionary-encoded output vocabularies, trace-time stats) travels in the
JSON entry header via :func:`encode_dicts`/:func:`decode_dicts` so a warm
load can rebuild ``QueryResult`` decoding state without tracing.
"""
from __future__ import annotations

import pickle
from typing import Any, Callable, Mapping

from jax.experimental import serialize_executable as _se

from repro.tables.table import DictEncoding


def pack_compiled(compiled: Any) -> bytes:
    """Serialize a ``jax.stages.Compiled`` to an opaque blob."""
    payload, in_tree, out_tree = _se.serialize(compiled)
    return pickle.dumps((payload, in_tree, out_tree), protocol=pickle.HIGHEST_PROTOCOL)


def load_compiled(blob: bytes) -> Callable:
    """Rehydrate a callable executable from :func:`pack_compiled` bytes."""
    payload, in_tree, out_tree = pickle.loads(blob)
    return _se.deserialize_and_load(payload, in_tree, out_tree)


def encode_dicts(out_dicts: Mapping[str, DictEncoding | None] | None) -> dict | None:
    """Output dictionaries -> JSON-safe ``{column: vocab-list-or-None}``."""
    if out_dicts is None:
        return None
    return {
        name: (list(enc.vocab) if enc is not None else None)
        for name, enc in out_dicts.items()
    }


def decode_dicts(encoded: Mapping[str, list | None] | None) -> dict | None:
    """Inverse of :func:`encode_dicts`."""
    if encoded is None:
        return None
    return {
        name: (DictEncoding(vocab) if vocab is not None else None)
        for name, vocab in encoded.items()
    }


def jsonable_stats(stats: Mapping[str, Any] | None) -> dict:
    """Copy trace-time stats, keeping only JSON-representable scalars."""
    out = {}
    for k, v in (stats or {}).items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        elif isinstance(v, (list, tuple)):
            out[k] = [x for x in v if isinstance(x, (str, int, float, bool))]
    return out
