"""Persistent plan-cache tier: store format, key stability, session
integration, and cost-table persistence.

The tier's contract is *costs only*: whatever the store serves — a hit, a
miss, a stale stamp, a truncated file, a concurrent writer — the session
answers identically to a store-less run.  Every degradation path here
asserts both the typed signal (counter/warning/exception) and result
parity.
"""
from __future__ import annotations

import glob
import json
import os
import threading
import warnings

import numpy as np
import pytest

from conformance_util import (
    FIXED_PROGRAMS,
    assert_rows_equal,
    build_udf,
    make_session,
    param_query,
    populate_session,
)
from repro.core import FROID, ROUTED, Session
from repro.persist import (
    PERSIST_SCHEMA_VERSION,
    PlanCacheCorruptError,
    PlanCacheVersionError,
    PlanCacheWarning,
    PlanStore,
    assert_stable_key,
    parse_key,
    runtime_stamp,
)

PARAMS = {"cut": 5, "shift": 0.5}


def _session(tmp_path, seed=7, n_rows=23, store=True):
    s = Session(store=str(tmp_path) if store else None)
    populate_session(s, seed, n_rows)
    s.create_function(build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    return s


# ---------------------------------------------------------------------------
# store unit tests: entry format, atomicity, typed degradation
# ---------------------------------------------------------------------------


def test_store_put_get_roundtrip(tmp_path):
    st = PlanStore(str(tmp_path))
    key = ("plan", "exec", ("fp",), (True, "python"), (), 0)
    st.put(key, {"kind": "exec"}, b"payload-bytes")
    got = st.get(key)
    assert got is not None
    meta, blob = got
    assert meta["kind"] == "exec" and blob == b"payload-bytes"
    assert st.get(("plan", "other")) is None  # clean miss
    assert st.stats()["entries"] == 1


def test_store_corrupt_entry_raises_typed(tmp_path):
    st = PlanStore(str(tmp_path))
    key = ("k", 1)
    st.put(key, {}, b"x" * 64)
    path = st.path_for(key)
    # truncation at several depths: magic, header length, header, blob
    for size in (3, 10, 12, 70):
        with open(path, "r+b") as f:
            f.truncate(size)
        with pytest.raises(PlanCacheCorruptError):
            st.get(key)
        st.put(key, {}, b"x" * 64)  # restore for next depth
    # flipped payload byte: digest mismatch
    raw = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(raw[:-1] + bytes([raw[-1] ^ 0xFF]))
    with pytest.raises(PlanCacheCorruptError):
        st.get(key)


def test_store_version_stamp_mismatch(tmp_path):
    st = PlanStore(str(tmp_path))
    st.put(("k",), {}, b"blob")
    stale = PlanStore(str(tmp_path),
                      stamp={**runtime_stamp(), "jax": "0.0.0"})
    with pytest.raises(PlanCacheVersionError):
        stale.get(("k",))
    # same-stamp reader still loads
    assert PlanStore(str(tmp_path)).get(("k",)) is not None


def test_store_concurrent_writers_atomic(tmp_path):
    """N threads racing puts on one key: readers always see a complete
    entry (one writer's whole blob, never a torn mix)."""
    st = PlanStore(str(tmp_path))
    key = ("contended",)
    payloads = [bytes([i]) * 4096 for i in range(8)]
    errs = []

    def write(i):
        try:
            for _ in range(20):
                st.put(key, {"w": i}, payloads[i])
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=write, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for _ in range(50):
        got = st.get(key)
        if got is not None:
            meta, blob = got
            assert blob == payloads[meta["w"]]
    for t in threads:
        t.join()
    assert not errs
    meta, blob = st.get(key)
    assert blob == payloads[meta["w"]]
    # no leaked tempfiles
    assert not [p for p in os.listdir(tmp_path) if p.startswith("tmp")]


def test_store_rejects_unstable_keys(tmp_path):
    st = PlanStore(str(tmp_path))

    class Opaque:
        pass

    for bad in ((Opaque(),), (("x", [1, 2]),), ({"a": 1},)):
        with pytest.raises(TypeError):
            st.put(bad, {}, b"")


# ---------------------------------------------------------------------------
# eviction: byte budget, LRU-by-recency, degradation-to-miss only
# ---------------------------------------------------------------------------


def test_store_eviction_lru_by_mtime(tmp_path):
    st = PlanStore(str(tmp_path))  # unbudgeted writer: fill freely
    for i in range(10):
        p = st.put((f"k{i}",), {}, b"x" * 1024)
        os.utime(p, (1000 + i, 1000 + i))  # deterministic recency order
    full = st.nbytes()
    budgeted = PlanStore(str(tmp_path), max_bytes=full // 2)
    n = budgeted.sweep()
    assert n >= 1
    assert budgeted.nbytes() <= budgeted.max_bytes
    # oldest-recency entries went first; the newest survived
    assert budgeted.get(("k0",)) is None
    assert budgeted.get(("k9",)) is not None
    s = budgeted.stats()
    assert s["evictions"] == n and s["sweeps"] == 1
    assert s["evicted_bytes"] >= n * 1024
    assert s["max_bytes"] == full // 2


def test_store_get_refreshes_recency(tmp_path):
    """A read protects an entry: the LRU victim is the *unread* old entry,
    not the oldest-written one."""
    st = PlanStore(str(tmp_path))
    for i in range(4):
        p = st.put((f"k{i}",), {}, b"x" * 1024)
        os.utime(p, (1000 + i, 1000 + i))
    assert st.get(("k0",)) is not None  # touch: k0 becomes most recent
    budgeted = PlanStore(str(tmp_path), max_bytes=st.nbytes() - 1024)
    assert budgeted.sweep() == 1
    assert budgeted.get(("k0",)) is not None  # read-protected
    assert budgeted.get(("k1",)) is None  # the true LRU victim


def test_store_put_sweeps_back_under_budget(tmp_path):
    st = PlanStore(str(tmp_path), max_bytes=4096)
    for i in range(12):
        p = st.put((f"k{i}",), {}, b"y" * 1024)
        os.utime(p, (1000 + i, 1000 + i))
    assert st.nbytes() <= 4096
    assert st.get((f"k{11}",)) is not None  # a put never evicts itself
    assert st.eviction_stats["evictions"] >= 1


def test_session_budgeted_store_stays_correct(tmp_path):
    """A budget tight enough to churn on every save still answers every
    query identically to a store-less session — eviction degrades to
    recompile, never to a wrong result — and the directory stays bounded."""
    oracle = _session(tmp_path / "none", store=False)
    q = param_query()

    small = PlanStore(str(tmp_path / "s"), max_bytes=512)  # every entry over
    s = Session(store=small)
    populate_session(s, 7, 23)
    s.create_function(build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    # distinct parameter signatures (int vs float cut) force distinct
    # store entries, so each save churns the one before it out
    for cut in (3, 5.5, 5):
        params = {"cut": cut, "shift": 0.5}
        got = s.execute(q, FROID, params=params)
        assert_rows_equal(oracle.execute(q, FROID, params=params), got,
                          f"budgeted-store vs oracle (cut={cut})")
    # every entry alone exceeds the budget, so each save evicts all
    # predecessors: at most the just-written (never-self-evicted) survives
    assert len(small.entries()) <= 1
    ps = s.persist_stats
    assert ps["store"]["evictions"] >= 1
    assert ps["store"]["max_bytes"] == 512


# ---------------------------------------------------------------------------
# key stability: repr round-trip, cross-process determinism
# ---------------------------------------------------------------------------


def test_stable_key_scalars_and_nesting():
    key = ("plan", 1, 2.5, True, None, b"b", ("nested", ("deeper", 0)))
    assert_stable_key(key)
    assert parse_key(repr(key)) == key


def test_stable_key_rejects_process_local():
    with pytest.raises(TypeError):
        assert_stable_key((object(),))
    with pytest.raises(TypeError):
        assert_stable_key(("ok", ["lists", "are", "mutable"]))
    with pytest.raises(TypeError):
        assert_stable_key(({"dicts": "too"},))


def test_stable_key_rejects_id_shaped_slot_names():
    """The pre-PR-10 slot-parameter spelling embedded a process-local
    ``node_id``; any key (or key component) carrying that shape must be
    refused, while the canonical ordinal spelling passes."""
    from repro.fuse.merge import slot_param

    with pytest.raises(TypeError):
        assert_stable_key("__cse_slot_140235678901234")
    with pytest.raises(TypeError):
        assert_stable_key(("fused", ("__cse_slot_7", "f32")))
    assert_stable_key(slot_param(0))  # canonical: ordinal-spelled
    assert_stable_key(("fused", (slot_param(3), "f32")))


def test_persist_keys_identical_across_sessions(tmp_path):
    """Two independently-built same-content sessions must produce
    bit-identical persist identity — the whole point of the shared tier.
    An ``id()``-derived or dict-order-dependent token would diverge here,
    and the second session's store lookups would all miss."""
    tokens = []
    for _ in range(2):
        s = _session(tmp_path, store=False)
        tok = s._content_env_token()
        assert_stable_key(tok)
        assert parse_key(repr(tok)) == tok
        tokens.append(tok)
    assert tokens[0] == tokens[1]
    # end-to-end: the second session's first execute hits the first's entry
    a = _session(tmp_path)
    a.execute(param_query(), FROID, params=PARAMS)
    b = _session(tmp_path)
    b.execute(param_query(), FROID, params=PARAMS)
    assert b.cache_stats["persist_hits"] >= 1
    assert b.cache_stats["persist_misses"] == 0


def test_content_env_token_tracks_data(tmp_path):
    s = _session(tmp_path, store=False)
    t0 = s._content_env_token()
    assert s._content_env_token() == t0  # memoized + stable
    s.create_table("facts", fk=np.arange(4), val=np.ones(4, np.float32),
                   qty=np.arange(4))
    t1 = s._content_env_token()
    assert t1 != t0  # data changed -> token changed
    assert_stable_key(t1)


# ---------------------------------------------------------------------------
# session integration: hit/miss/invalidate, degradation parity
# ---------------------------------------------------------------------------


def test_session_cold_then_warm(tmp_path):
    cold = _session(tmp_path)
    q = param_query()
    expected = cold.execute(q, FROID, params=PARAMS)
    assert cold.cache_stats["persist_misses"] >= 1
    assert cold.persist_stats["saves"] >= 1

    warm = _session(tmp_path)
    got = warm.execute(q, FROID, params=PARAMS)
    assert_rows_equal(expected, got, "warm vs cold")
    assert warm.cache_stats["persist_hits"] >= 1
    assert warm.cache_stats["persist_misses"] == 0


def test_session_invalidate_by_content(tmp_path):
    cold = _session(tmp_path, seed=7)
    cold.execute(param_query(), FROID, params=PARAMS)

    other = _session(tmp_path, seed=8)  # different data, same store
    other.execute(param_query(), FROID, params=PARAMS)
    assert other.cache_stats["persist_hits"] == 0
    assert other.cache_stats["persist_misses"] >= 1


def test_session_corrupt_entry_recompiles_with_warning(tmp_path):
    cold = _session(tmp_path)
    q = param_query()
    expected = cold.execute(q, FROID, params=PARAMS)
    for p in glob.glob(os.path.join(str(tmp_path), "*.plan")):
        with open(p, "r+b") as f:
            f.truncate(16)
    warm = _session(tmp_path)
    with pytest.warns(PlanCacheWarning):
        got = warm.execute(q, FROID, params=PARAMS)
    assert_rows_equal(expected, got, "corrupt-store vs oracle")
    assert warm.cache_stats["persist_rejects"] >= 1
    assert warm.cache_stats["persist_hits"] == 0
    assert warm.persist_stats["saves"] >= 1  # evicted + re-saved behind
    # so a third session warm-starts from the repaired entry
    third = _session(tmp_path)
    third.execute(q, FROID, params=PARAMS)
    assert third.cache_stats["persist_hits"] >= 1


def test_session_stale_stamp_recompiles_silently(tmp_path):
    cold = _session(tmp_path)
    q = param_query()
    expected = cold.execute(q, FROID, params=PARAMS)
    stale = Session(store=PlanStore(
        str(tmp_path), stamp={**runtime_stamp(), "schema": -1}))
    populate_session(stale, 7, 23)
    stale.create_function(
        build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # version skew must NOT warn
        got = stale.execute(q, FROID, params=PARAMS)
    assert_rows_equal(expected, got, "stale-stamp vs oracle")
    assert stale.cache_stats["persist_rejects"] >= 1


def test_policy_opt_out(tmp_path):
    s = _session(tmp_path)
    s.execute(param_query(), FROID.persisted(False), params=PARAMS)
    assert s.cache_stats["persist_misses"] == 0
    assert s.persist_stats["saves"] == 0
    # identity unchanged: opted-out and opted-in policies share caches
    assert FROID.persisted(False).fingerprint() == FROID.fingerprint()


def _template_session(tmp_path):
    """Session over shared tables sized so a fused wave pools a
    parameter-unified template (same data every call: the content env
    token must match across sessions for the store to answer)."""
    s = Session(store=str(tmp_path))
    rng = np.random.default_rng(0)
    s.create_table(
        "detail",
        d_key=rng.integers(0, 40, 200),
        d_val=rng.uniform(0, 100, 200).astype(np.float32),
    )
    s.create_table("T", a=rng.integers(0, 40, 30))
    return s


def _template_calls(s):
    """Two distinct statements riding one parameter-unified aggregate
    subquery (unifies modulo param naming), three distinct bindings."""
    from repro.core.frontend import col, param, scalar_subquery, scan, sum_

    def q(pname, out):
        agg = (scan("detail").filter(col("d_val") > param(pname))
               .agg(s=sum_(col("d_val"))))
        return (scan("T")
                .compute(**{out: scalar_subquery(agg.node, "s")
                            + col("a") * 0.0})
                .project("a", out))

    s1 = s.prepare(q("x", "v1"), FROID)
    s2 = s.prepare(q("y", "v2"), FROID)
    return [(s1, {"x": 10.0}), (s2, {"y": 10.0}),
            (s1, {"x": 20.0}), (s2, {"y": 30.0})]


def test_fused_template_wave_roundtrips_fresh_session(tmp_path):
    """A fused wave carrying pooled templates AOT-persists, and a FRESH
    session serves the identical wave from the store.  This is the PR-9
    regression: slot parameters spelled by process-local node id made the
    fused argument pytree unreproducible, so template waves never
    persisted (and would have mis-bound if they had)."""
    cold = _template_session(tmp_path)
    expected = cold.execute_fused(_template_calls(cold))
    st = expected[0].stats
    assert st["fused"] and st["cse_template_groups"] >= 1
    assert st["cse_bindings"] == 3
    assert cold.persist_stats["saves"] >= 1

    warm = _template_session(tmp_path)
    got = warm.execute_fused(_template_calls(warm))
    gst = got[0].stats
    assert gst["fused"] and gst["cse_template_groups"] >= 1
    assert warm.cache_stats["persist_hits"] >= 1
    assert warm.persist_stats["saves"] == 0  # nothing recompiled
    for i, (e, g) in enumerate(zip(expected, got)):
        assert_rows_equal(e, g, f"fused template warm[{i}]")


def test_execute_many_warm_start(tmp_path):
    cold = _session(tmp_path)
    stmt = cold.prepare(param_query(), FROID)
    plist = [{"cut": c, "shift": 0.5} for c in (3, 5, 6)]
    expected = stmt.execute_many(plist)

    warm = _session(tmp_path)
    got = warm.prepare(param_query(), FROID).execute_many(plist)
    for i, (e, g) in enumerate(zip(expected, got)):
        assert_rows_equal(e, g, f"warm many[{i}]")
    assert warm.cache_stats["persist_hits"] >= 1


# ---------------------------------------------------------------------------
# cost-table persistence
# ---------------------------------------------------------------------------


def _route_waves(s, waves=2):
    from conformance_util import fusion_calls_spec, fusion_queries
    from repro.serve.scheduler import CoalescingScheduler

    stmts = [s.prepare(q, ROUTED) for q in fusion_queries()]
    sched = CoalescingScheduler(max_batch=256, window_s=10.0,
                                clock=lambda: 0.0, fuse=True)
    for _ in range(waves):
        ts = [sched.submit(stmts[i], p) for i, p in fusion_calls_spec()]
        sched.flush()
        [t.result() for t in ts]


def test_cost_tables_roundtrip(tmp_path):
    s1 = _session(tmp_path)
    _route_waves(s1)
    assert s1.cost_stats["samples"] >= 1
    assert s1.save_costs()
    assert s1.persist_stats["costs_saved"] == 1

    s2 = _session(tmp_path)
    s2._ensure_router()
    assert s2.persist_stats["costs_loaded"] >= 1
    # measured tables arrived without any execution on s2
    state = s2.cost_router.export_state()
    assert state["measured"]
    for key_repr, *_ in state["measured"]:
        assert parse_key(key_repr)  # strict round-trip on every row


def test_cost_tables_corrupt_degrades_to_empty(tmp_path):
    s1 = _session(tmp_path)
    _route_waves(s1)
    assert s1.save_costs()
    from repro.persist.costs import costs_key
    path = s1.store.path_for(costs_key(s1._content_env_token()))
    raw = open(path, "rb").read()
    with open(path, "wb") as f:  # valid envelope, garbage JSON payload
        f.write(raw[: len(raw) // 2])
    s2 = _session(tmp_path)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PlanCacheWarning)
        s2._ensure_router()
    assert s2.persist_stats["costs_loaded"] == 0
    assert s2.persist_stats["rejects"] >= 1
    # routing still works from scratch
    _route_waves(s2, waves=1)
    assert s2.cost_stats["samples"] >= 1


def test_persist_stats_shape(tmp_path):
    s = _session(tmp_path)
    ps = s.persist_stats
    assert ps["enabled"] and "store" in ps
    assert {"hits", "misses", "rejects", "saves"} <= ps.keys()
    assert Session().persist_stats == {"enabled": False}


def test_schema_version_is_stamped(tmp_path):
    s = _session(tmp_path)
    s.execute(param_query(), FROID, params=PARAMS)
    entry = glob.glob(os.path.join(str(tmp_path), "*.plan"))[0]
    raw = open(entry, "rb").read()
    hdr = json.loads(raw[12:12 + int.from_bytes(raw[8:12], "little")])
    assert hdr["stamp"]["schema"] == PERSIST_SCHEMA_VERSION
    assert hdr["stamp"]["jax"] == runtime_stamp()["jax"]
