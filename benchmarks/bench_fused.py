"""Multi-statement fusion: per-statement drain vs fused drain of one
mixed-statement queue.

A serving queue holds tickets for K *different* prepared statements over
the same tables.  The per-statement arm drains it the PR-2/3 way — one
``execute_many`` device program per statement (K dispatch+sync round
trips); the fused arm drains the same queue through one fused device
program (``CoalescingScheduler(fuse=True)`` → ``Session.execute_fused``:
shared scans execute once, outputs come back tagged per statement).

    PYTHONPATH=src python -m benchmarks.bench_fused [--quick]

Rows:
    fused/serial/<n>          — serial `execute` loop reference
    fused/perstmt/<n>         — per-statement drain (K execute_many programs)
    fused/fused/<n>           — fused drain (1 device program)
    fused/overlap_perstmt/<n> — per-statement drain, overlap-heavy queue
    fused/overlap_fused/<n>   — fused drain, overlap-heavy queue

The overlap-heavy variant (PR-5) drains six statements that all share one
correlated subquery body (the same UDF aggregate, decorrelated into a
shared GroupAgg) plus a parameter-unified filter template — cutoffs drawn
from a small value pool, so the template binding pool evaluates d << k
times.  Its fused row's `derived` carries the cse evidence
(`cse_shared_nodes` / `cse_bindings`) the CI fused smoke asserts on.

`derived` on the fused rows records speedup vs the per-statement arm plus
statements / shared-subtree / host-CPU counts — the margin comes from
amortizing dispatch+sync overhead and deduplicating the shared catalog
work, so it grows with statement count and shrinks as per-statement
compute dominates (big tables, huge batches).  Element-wise identity
between all arms is asserted before timing; a parity failure fails the
suite.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core import (
    FROID,
    Session,
    UdfBuilder,
    col,
    lit,
    param,
    scan,
    sum_,
    udf,
    var,
)
from repro.serve.scheduler import CoalescingScheduler

M_ROWS = 20_000
N_T = 2_000
M_ROWS_QUICK = 5_000
N_T_QUICK = 500
#: tickets per statement in the mixed queue
PER_STMT = 64
PER_STMT_QUICK = 32
SERIAL_N = 48


def _setup(quick: bool) -> Session:
    m = M_ROWS_QUICK if quick else M_ROWS
    n = N_T_QUICK if quick else N_T
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 400, m),
        d_val=rng.uniform(0, 100, m).astype(np.float32),
    )
    db.create_table("T", a=rng.integers(0, 400, n))
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    with u.if_(var("s").is_null()):
        u.return_(lit(0.0))
    u.return_(var("s"))
    db.create_function(u.build())
    return db


def _queries():
    """Six different statements over the shared tables: UDF-bearing,
    arithmetic, aggregating — all scanning T (and detail through the UDF),
    so a fused program has real work to dedup."""
    return [
        scan("T").filter(col("a") < param("cutoff"))
                 .compute(v=udf("key_total", col("a"))).project("v"),
        scan("T").filter(col("a") >= param("lo"))
                 .compute(w=col("a") * param("scale")).project("a", "w"),
        scan("T").compute(v=udf("key_total", col("a")) / param("div"))
                 .project("v"),
        scan("T").filter((col("a") > param("lo")) & (col("a") < param("hi")))
                 .compute(z=col("a") + param("off")).project("z"),
        scan("T").compute(b=col("a") * 2).project("b"),  # parameter-free
        scan("T").filter(col("a") % param("mod") == lit(0))
                 .compute(v=udf("key_total", col("a"))).project("a", "v"),
    ]


def _overlap_queries():
    """Six statements sharing one correlated subquery body: every one
    calls ``key_total`` (whose correlated aggregate decorrelates into the
    same shared GroupAgg-over-detail subtree) under a filter that is the
    same shape modulo its parameter slot — ``a < Param(c_i)`` unifies into
    one template across all six members."""
    def q(i):
        return (
            scan("T").filter(col("a") < param(f"c{i}"))
                     .compute(**{f"v{i}": udf("key_total", col("a"))})
                     .project(f"v{i}")
        )
    return [q(i) for i in range(6)]


def _overlap_queue(stmts, per_stmt: int, seed: int = 11):
    """Round-robin queue whose cutoffs come from a small value pool, so
    the unified template sees d << k distinct bindings."""
    rng = np.random.default_rng(seed)
    pool = rng.integers(1, 400, 8)
    waves = []
    for _ in range(per_stmt):
        for i, s in enumerate(stmts):
            waves.append((s, {f"c{i}": int(rng.choice(pool))}))
    return waves


def _mixed_queue(stmts, per_stmt: int, seed: int = 7):
    """Round-robin interleaved [(stmt, params)] — the serving queue shape."""
    rng = np.random.default_rng(seed)
    waves = []
    for i in range(per_stmt):
        waves.append((stmts[0], {"cutoff": int(rng.integers(1, 400))}))
        waves.append((stmts[1], {"lo": int(rng.integers(0, 200)),
                                 "scale": float(round(rng.uniform(0.5, 2), 2))}))
        waves.append((stmts[2], {"div": float(round(rng.uniform(1, 4), 2))}))
        waves.append((stmts[3], {"lo": int(rng.integers(0, 100)),
                                 "hi": int(rng.integers(200, 400)),
                                 "off": int(rng.integers(0, 10))}))
        waves.append((stmts[4], None))
        waves.append((stmts[5], {"mod": int(rng.integers(2, 6))}))
    return waves


def _check_identical(expected, got):
    for s, b in zip(expected, got):
        m = np.asarray(s.masked.mask)
        np.testing.assert_array_equal(m, np.asarray(b.masked.mask))
        for n, c in s.masked.table.columns.items():
            np.testing.assert_allclose(
                np.asarray(b.masked.table.columns[n].data)[m],
                np.asarray(c.data)[m], rtol=1e-5,
            )


def _drain_time(queue, fuse: bool, iters: int = 5) -> tuple[float, dict]:
    """Median wall seconds to drain the queue through a scheduler."""
    last_stats = {}
    ts = []
    for _ in range(iters):
        sched = CoalescingScheduler(max_batch=1024, window_s=10.0, fuse=fuse)
        t0 = time.perf_counter()
        tickets = [sched.submit(s, p) for s, p in queue]
        sched.flush()
        for t in tickets:
            t.result().masked  # deliver every row (fair: both arms slice)
        ts.append(time.perf_counter() - t0)
        last_stats = tickets[0].result().stats
    return float(np.median(ts)), last_stats


def run(quick: bool = False):
    db = _setup(quick)
    per_stmt = PER_STMT_QUICK if quick else PER_STMT
    cpus = os.cpu_count() or 1
    stmts = [db.prepare(q, FROID) for q in _queries()]
    queue = _mixed_queue(stmts, per_stmt)
    n = len(queue)

    # parity first (also pays both arms' jit)
    serial_ref = [s.execute(params=p) for s, p in queue[:SERIAL_N]]
    per_r = db.execute_fused([(s, dict(p) if p else {})
                              for s, p in queue])  # fused path warm-up
    _check_identical(serial_ref, per_r[:SERIAL_N])
    sched = CoalescingScheduler(max_batch=1024, window_s=10.0, fuse=False)
    tk = [sched.submit(s, p) for s, p in queue]
    sched.flush()
    _check_identical(serial_ref, [t.result() for t in tk][:SERIAL_N])

    t0 = time.perf_counter()
    for s, p in queue[:SERIAL_N]:
        s.execute(params=p)
    t_serial = time.perf_counter() - t0
    emit(f"fused/serial/{SERIAL_N}", t_serial / SERIAL_N * 1e6,
         f"{SERIAL_N} dispatch+sync round trips")

    t_per, _ = _drain_time(queue, fuse=False)
    emit(f"fused/perstmt/{n}", t_per / n * 1e6,
         f"statements={len(stmts)} programs={len(stmts)}")
    t_fused, st = _drain_time(queue, fuse=True)
    emit(
        f"fused/fused/{n}", t_fused / n * 1e6,
        f"speedup={t_per / t_fused:.2f}x statements={st.get('fused_statements')} "
        f"programs={st.get('fused_programs')} "
        f"shared_subtrees={st.get('shared_subtrees')} "
        f"cse_shared_nodes={st.get('cse_shared_nodes', 0)} "
        f"cse_bindings={st.get('cse_bindings', 0)} host_cpus={cpus} "
        f"fused={bool(st.get('fused'))}",
    )

    # overlap-heavy variant: 6 statements sharing a correlated subquery
    # body + a parameter-unified filter template (PR-5 cse evidence)
    ostmts = [db.prepare(q, FROID) for q in _overlap_queries()]
    oqueue = _overlap_queue(ostmts, per_stmt)
    on = len(oqueue)
    oserial_ref = [s.execute(params=p) for s, p in oqueue[:SERIAL_N]]
    owarm = db.execute_fused([(s, dict(p)) for s, p in oqueue])
    _check_identical(oserial_ref, owarm[:SERIAL_N])
    t_oper, _ = _drain_time(oqueue, fuse=False)
    emit(f"fused/overlap_perstmt/{on}", t_oper / on * 1e6,
         f"statements={len(ostmts)} programs={len(ostmts)}")
    t_ofused, ost = _drain_time(oqueue, fuse=True)
    emit(
        f"fused/overlap_fused/{on}", t_ofused / on * 1e6,
        f"speedup={t_oper / t_ofused:.2f}x "
        f"statements={ost.get('fused_statements')} "
        f"programs={ost.get('fused_programs')} "
        f"shared_subtrees={ost.get('shared_subtrees')} "
        f"cse_shared_nodes={ost.get('cse_shared_nodes', 0)} "
        f"cse_bindings={ost.get('cse_bindings', 0)} host_cpus={cpus} "
        f"fused={bool(ost.get('fused'))}",
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
