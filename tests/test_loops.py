"""Cursor/WHILE loop frontend and Aggify-style rewriting (ISSUE-6).

Four layers, deterministic (the generative layer rides in
``test_property_froid.py`` through the same ``conformance_util`` oracles):

* **Parser** — T-SQL ``DECLARE CURSOR FOR`` / ``OPEN`` / ``FETCH NEXT``
  / ``WHILE @@fetch_status = 0`` fold into one :class:`CursorLoop` IR
  node; everything off that shape raises
  :class:`UnsupportedConstructError` with the construct name and 1-based
  line/column of the offending token.
* **Analysis** — ``repro.loops.classify`` verdicts: commutative folds are
  ``reduce``, order-dependent/guarded/breaking bodies are ``scan``, and
  plain WHILE / nested loops / RETURN-in-body are explicitly
  non-rewritable (fallback, not an error).
* **Execution** — parsed cursor UDFs agree element-wise across
  FROID (LoopScan rewrite) / INTERPRETED (host loop) / HEKATON (traced
  ``lax.scan``), including empty cursors, extra guards, BREAK, and the
  interpreter fallback for non-rewritable loops.
* **Integration** — LoopScan plans ride ``explain()``, plan fingerprints,
  and the fusion engine like any other relational subtree.
"""
import numpy as np
import pytest

from repro.core import (
    FROID,
    HEKATON,
    INTERPRETED,
    CursorLoop,
    Session,
    UnsupportedConstructError,
    While,
    col,
    lit,
    param,
    parse_udf,
    scan,
    udf,
    var,
)
from repro.core import algebrizer as A
from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.optimizer import explain
from repro.core.session import plan_fingerprint
from repro.loops import LoopVerdict, classify
from conformance_util import (
    assert_rows_equal,
    build_loop_udf,
    check_loop_oracle,
    loop_param_query,
    make_session,
)

CURSOR_SUM = """
create function dbo.cursor_total(@x float) returns float as
begin
  declare @t float = 0.0;
  declare @v float;
  declare @q float;
  declare c cursor for select val, qty from facts where fk <= @x;
  open c;
  fetch next from c into @v, @q;
  while @@fetch_status = 0
  begin
    set @t = @t + @v;
    fetch next from c into @v, @q;
  end
  close c;
  deallocate c;
  return @t;
end
"""

CURSOR_GUARD_BREAK = """
create function dbo.cursor_capped(@x float) returns float as
begin
  declare @t float = 0.0;
  declare @v float;
  declare c cursor for select val from facts where fk <= @x;
  open c;
  fetch next from c into @v;
  while @@fetch_status = 0 and @t < 40.0
  begin
    set @t = @t + @v;
    if @t > 25.0
      break;
    fetch next from c into @v;
  end
  close c;
  return @t;
end
"""

PLAIN_WHILE = """
create function dbo.wsum(@x float) returns float as
begin
  declare @i float = 0.0;
  declare @t float = 0.0;
  while @i < @x
  begin
    set @i = @i + 1.0;
    set @t = @t + @i;
  end
  return @t;
end
"""


# ---------------------------------------------------------------------------
# parser: the supported shape
# ---------------------------------------------------------------------------


def test_parse_cursor_loop_shape():
    f = parse_udf(CURSOR_SUM)
    assert f.name == "cursor_total"
    loops = [s for s in f.body if isinstance(s, CursorLoop)]
    assert len(loops) == 1
    lp = loops[0]
    assert lp.targets == [("v", "val"), ("q", "qty")]
    assert lp.guard is None
    # the cursor's defining query parses to Filter-over-Scan
    assert isinstance(lp.plan, R.Filter)
    assert isinstance(lp.plan.child, R.Scan) and lp.plan.child.table == "facts"
    # priming + trailing FETCH folded away: the body is just the accumulate
    assert len(lp.body) == 1
    # OPEN/CLOSE/DEALLOCATE are lifecycle no-ops, not IR statements
    assert not any(isinstance(s, While) for s in f.body)


def test_parse_cursor_guard_conjunct():
    f = parse_udf(CURSOR_GUARD_BREAK)
    lp = next(s for s in f.body if isinstance(s, CursorLoop))
    # the non-status conjunct survives as the loop's extra guard
    assert lp.guard is not None
    assert isinstance(lp.guard, S.Cmp) and lp.guard.op == "<"


def test_parse_plain_while():
    f = parse_udf(PLAIN_WHILE)
    w = next(s for s in f.body if isinstance(s, While))
    assert len(w.body) == 2


# ---------------------------------------------------------------------------
# parser diagnostics: construct + line/col (ISSUE-6 satellite)
# ---------------------------------------------------------------------------


def _perr(src: str) -> UnsupportedConstructError:
    with pytest.raises(UnsupportedConstructError) as ei:
        parse_udf(src)
    return ei.value


def test_unknown_cursor_has_location():
    e = _perr(
        "create function dbo.f(@x int) returns float as\n"
        "begin\n"
        "  open c;\n"
        "  return 1.0;\n"
        "end\n"
    )
    assert e.construct == "cursor"
    assert (e.line, e.col) == (3, 8)
    assert "unknown cursor 'c'" in str(e)
    assert "line 3, col 8" in str(e)


def test_fetch_status_only_as_zero_check():
    e = _perr(
        "create function dbo.f(@x int) returns float as\n"
        "begin\n"
        "  while @@fetch_status < 1\n"
        "    set @x = 1;\n"
        "  return 1.0;\n"
        "end\n"
    )
    assert e.construct == "fetch-status"
    assert e.line == 3


def test_cursor_while_requires_priming_fetch():
    e = _perr(
        "create function dbo.f(@x int) returns float as\n"
        "begin\n"
        "  declare c cursor for select val from facts;\n"
        "  open c;\n"
        "  while @@fetch_status = 0\n"
        "    set @x = 1;\n"
        "  return 1.0;\n"
        "end\n"
    )
    assert e.construct == "cursor-while"
    assert "priming fetch" in str(e)
    assert e.line == 5


def test_cursor_body_must_end_with_fetch():
    e = _perr(
        "create function dbo.f(@x int) returns float as\n"
        "begin\n"
        "  declare @v float;\n"
        "  declare c cursor for select val from facts;\n"
        "  fetch next from c into @v;\n"
        "  while @@fetch_status = 0\n"
        "  begin\n"
        "    set @v = @v + 1.0;\n"
        "  end\n"
        "  return 1.0;\n"
        "end\n"
    )
    assert e.construct == "cursor-while"
    assert "must end with FETCH NEXT" in str(e)


def test_fetch_arity_mismatch():
    e = _perr(
        "create function dbo.f(@x int) returns float as\n"
        "begin\n"
        "  declare @v float;\n"
        "  declare c cursor for select val, qty from facts;\n"
        "  fetch next from c into @v;\n"
        "  return 1.0;\n"
        "end\n"
    )
    assert e.construct == "fetch"
    assert "binds 1 variables" in str(e) and "selects 2 columns" in str(e)


def test_cursor_select_list_must_be_columns():
    e = _perr(
        "create function dbo.f(@x int) returns float as\n"
        "begin\n"
        "  declare c cursor for select val + 1 from facts;\n"
        "  return 1.0;\n"
        "end\n"
    )
    assert e.construct == "cursor-select"


def test_unsupported_statement_names_construct():
    e = _perr(
        "create function dbo.f(@x int) returns float as\n"
        "begin\n"
        "  print @x;\n"
        "  return 1.0;\n"
        "end\n"
    )
    assert e.construct == "statement"
    assert e.line == 3


def test_tokenizer_error_has_location():
    e = _perr(
        "create function dbo.f(@x int) returns float as\n"
        "begin\n"
        "  set @x = #;\n"
        "  return 1.0;\n"
        "end\n"
    )
    assert e.construct == "token"
    assert e.line == 3


def test_unsupported_type_names_construct():
    e = _perr(
        "create function dbo.f(@x text) returns float as\n"
        "begin return 1.0; end\n"
    )
    assert e.construct == "type"


# ---------------------------------------------------------------------------
# analysis verdicts
# ---------------------------------------------------------------------------


def _loop_of(builder):
    f = builder.build()
    return next(s for s in f.body if isinstance(s, (While, CursorLoop)))


def test_verdict_reduce_for_commutative_fold():
    v = classify(_loop_of(build_loop_udf("sum")))
    assert v.rewritable and v.kind == "reduce"
    assert "t" in v.written
    assert "rewritable (reduce)" in str(v)


def test_verdict_scan_for_order_dependence_guard_break():
    for spec in (("running", None, None), ("sum", 40.0, None),
                 ("sum", None, 15.0)):
        v = classify(_loop_of(build_loop_udf(*spec)))
        assert v.rewritable and v.kind == "scan", (spec, v)


def test_verdict_plain_while_not_rewritable():
    v = classify(_loop_of(build_loop_udf("plain_while")))
    assert not v.rewritable
    assert "no driving relation" in v.reason
    assert "non-rewritable" in str(v)


def test_verdict_nested_loop_not_rewritable():
    lp = _loop_of(build_loop_udf("sum"))
    outer = CursorLoop("c2", scan("facts").node, [("w", "val")], [lp], None)
    v = classify(outer)
    assert not v.rewritable and "nested loop" in v.reason


def test_verdict_is_explicit_not_a_parse_error():
    """The fallback path is a verdict, not an exception: algebrization of
    the containing UDF raises AlgebrizeError naming the reason, and the
    binder leaves the call for the interpreter."""
    f = build_loop_udf("plain_while").build()
    with pytest.raises(A.AlgebrizeError, match="non-rewritable loop"):
        A.algebrize(f)


# ---------------------------------------------------------------------------
# execution: fixed T-SQL programs across policies
# ---------------------------------------------------------------------------


def _check_tsql_policies(src: str, fname: str, n_rows: int = 23):
    db = make_session(0, n_rows)
    db.create_function(parse_udf(src))
    q = (scan("keys").filter(col("k") < param("cut"))
         .compute(out=udf(fname, col("k") * 1.0 + param("shift")))
         .project("k", "out"))
    params = [{"cut": 5, "shift": 0.5}, {"cut": 7, "shift": -1.0}]
    base = db.prepare(q, FROID)
    serial = [base.execute(params=p) for p in params]
    for policy in (INTERPRETED, HEKATON):
        other = db.prepare(q, policy)
        for i, p in enumerate(params):
            assert_rows_equal(serial[i], other.execute(params=p),
                              f"{fname} FROID vs {policy.name}[{i}]")
    return db, base


def test_tsql_cursor_sum_policies_agree():
    _check_tsql_policies(CURSOR_SUM, "cursor_total")


def test_tsql_cursor_guard_break_policies_agree():
    _check_tsql_policies(CURSOR_GUARD_BREAK, "cursor_capped")


def test_tsql_cursor_empty_table():
    _check_tsql_policies(CURSOR_SUM, "cursor_total", n_rows=0)


def test_tsql_plain_while_falls_back_and_agrees():
    db, stmt = _check_tsql_policies(PLAIN_WHILE, "wsum")
    # fallback evidence: the FROID plan still carries the UdfCall
    calls = [e for n in R.walk_plan_deep(stmt.plan) for ex in n.exprs()
             for e in S.walk(ex) if isinstance(e, S.UdfCall)]
    assert calls, "non-rewritable loop should not inline"


def test_loop_oracle_fixed_replay():
    """Deterministic floor under the generative loop strategy: fixed
    samples of the spec space through the full loop oracle."""
    check_loop_oracle("sum_if", None, None, 0, 23,
                      params_list=[{"cut": 5, "shift": 0.5}])
    check_loop_oracle("running", 10.0, 75.0, 1, 23,
                      params_list=[{"cut": 6, "shift": -1.0},
                                   {"cut": 3, "shift": 2.0}])


# ---------------------------------------------------------------------------
# integration: LoopScan is a first-class relational subtree
# ---------------------------------------------------------------------------


def test_inlined_loop_plan_explains_loopscan():
    db = make_session(0, 23)
    db.create_function(parse_udf(CURSOR_SUM))
    stmt = db.prepare(
        scan("keys").compute(out=udf("cursor_total", col("k") * 1.0))
        .project("k", "out"), FROID)
    text = explain(stmt.plan)
    assert "LoopScan[" in text
    assert not any(isinstance(e, S.UdfCall)
                   for n in R.walk_plan_deep(stmt.plan)
                   for ex in n.exprs() for e in S.walk(ex))


def test_loop_plan_fingerprints_stable():
    """Two independently-parsed copies of the same UDF produce
    fingerprint-equal inlined plans (cache identity)."""
    q = (scan("keys").compute(out=udf("cursor_total", col("k") * 1.0))
         .project("k", "out"))
    fps = []
    for _ in range(2):
        db = make_session(0, 23)
        db.create_function(parse_udf(CURSOR_SUM))
        fps.append(plan_fingerprint(db.prepare(q, FROID).plan))
    assert fps[0] == fps[1]


def test_fused_members_share_loop_subtrees():
    """Two statements inlining the same cursor-loop UDF fuse (LoopScan is
    in PURE_NODES) and agree with the serial loop; the identical
    loop-bearing subtrees unify in the merge pass."""
    db = make_session(0, 23)
    db.create_function(parse_udf(CURSOR_SUM))
    q1 = (scan("keys").filter(col("k") < param("cut"))
          .compute(out=udf("cursor_total", col("k") * 1.0))
          .project("k", "out"))
    q2 = (scan("keys")
          .compute(w=udf("cursor_total", col("k") * 1.0) * 2.0)
          .project("k", "w"))
    s1 = db.prepare(q1, FROID)
    s2 = db.prepare(q2, FROID)
    calls = [(s1, {"cut": 5}), (s2, None), (s1, {"cut": 3})]
    serial = [s.execute(params=p) for s, p in calls]
    fused = db.execute_fused(calls)
    for i, (s, f) in enumerate(zip(serial, fused)):
        assert_rows_equal(s, f, f"loop-fused[{i}] vs serial")
    st = fused[0].stats
    assert st["fused"] and st["fused_statements"] == 2
