"""Pure-jnp oracle for the relagg kernel."""
import jax
import jax.numpy as jnp


def grouped_aggregate_ref(gid, mask, vals, num_groups):
    """sums: (G, n_aggs); counts: (G,) — NULL/filtered rows excluded."""
    sel = mask
    safe_gid = jnp.where(sel, gid, num_groups)  # overflow slot
    sums = jax.ops.segment_sum(
        jnp.where(sel[:, None], vals.astype(jnp.float32), 0.0),
        safe_gid,
        num_segments=num_groups + 1,
    )[:num_groups]
    counts = jax.ops.segment_sum(
        sel.astype(jnp.float32), safe_gid, num_segments=num_groups + 1
    )[:num_groups]
    return sums, counts
