"""Figure 8: total elapsed time including compilation (cold plan cache).

Froid adds binding/algebrization/rewrite + a bigger query tree to compile;
the paper's claim is that this overhead is dwarfed by execution gains.
We measure (bind+optimize+compile+run) cold for froid ON vs the iterative
baselines — ``Session.prepare`` is the bind step, the first ``execute``
pays jit, so cold = prepare + first execute.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from benchmarks.bench_factor import UDF_QUERIES, _register
from repro.core import FROID, INTERPRETED, Session

N_ROWS = 10_000
N_INTERP = 200


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    names = list(UDF_QUERIES)[:3] if quick else list(UDF_QUERIES)
    for name in names:
        db = Session()
        db.create_table(
            "detail",
            d_key=rng.integers(0, 400, 30_000),
            d_val=rng.uniform(0, 10, 30_000).astype(np.float32),
        )
        db.create_table(
            "T",
            d=rng.integers(8_000, 20_000, N_ROWS),
            diff=rng.integers(0, 60, N_ROWS),
            a=rng.integers(0, 500, N_ROWS),
            b=rng.integers(0, 500, N_ROWS),
            major=rng.integers(1, 20, N_ROWS),
            minor=rng.integers(0, 300, N_ROWS),
        )
        _register(db)
        q = UDF_QUERIES[name]()

        t0 = time.perf_counter()
        stmt = db.prepare(q, FROID)  # bind + rewrite
        r = stmt.execute()  # compile + run
        t_cold = time.perf_counter() - t0
        assert not r.cache_hit
        emit(f"fig8/{name}/froid_on_cold", t_cold * 1e6, "bind+compile+run")

        # iterative cold (per-statement plans compiled on first rows)
        from repro.tables.table import Column, Table

        t_tab = db.catalog["T"]
        db.catalog["T_sub"] = Table(
            {n: Column(c.data[:N_INTERP], None, c.dictionary)
             for n, c in t_tab.columns.items()}
        )
        q2 = UDF_QUERIES[name]()
        q2.node = _retarget(q2.node, "T", "T_sub")
        t0 = time.perf_counter()
        db.execute(q2, INTERPRETED)
        t_off = (time.perf_counter() - t0) * N_ROWS / N_INTERP
        emit(f"fig8/{name}/froid_off_cold", t_off * 1e6,
             f"gain={t_off/t_cold:.0f}x (extrapolated)")


def _retarget(plan, old, new):
    from repro.core import relalg as R

    def fix(node):
        if isinstance(node, R.Scan) and node.table == old:
            return R.Scan(new)
        return None

    return R.transform_plan(plan, fix)


if __name__ == "__main__":
    run()
