"""Authoring frontends: a Python-embedded UDF builder and a fluent query
builder.

The paper's framework is language-agnostic (§7.3): each imperative construct
is a pluggable class.  Here the "language" is a Python builder — the
constructs (DECLARE/SET/SELECT-assign/IF-ELSE/RETURN) map 1:1 onto
:mod:`repro.core.ir` statements; adding another surface syntax is a parser
plus calls into this module.
"""
from __future__ import annotations

import contextlib
from typing import Any, Sequence

from repro.core import ir as IR
from repro.core import relalg as R
from repro.core import scalar as S

# ---------------------------------------------------------------------------
# expression helpers (public API)
# ---------------------------------------------------------------------------


def col(name: str) -> S.ColRef:
    """Reference to a table column (inside queries / subqueries)."""
    return S.ColRef(name)


def var(name: str) -> S.Var:
    """Reference to a UDF local variable."""
    return S.Var(name)


def param(name: str) -> S.Param:
    """Reference to a UDF formal parameter."""
    return S.Param(name)


def lit(value: Any) -> S.Const:
    return S.Const(value)


def case(whens: Sequence[tuple], else_=None) -> S.Case:
    return S.Case(whens, S.Const(None) if else_ is None else else_)


def isnull(expr, fallback) -> S.Coalesce:
    """T-SQL ISNULL(a, b)."""
    return S.Coalesce([expr, fallback])


def coalesce(*args) -> S.Coalesce:
    return S.Coalesce(list(args))


def cast(expr, dtype) -> S.Cast:
    return S.Cast(expr, dtype)


def func(name: str, *args) -> S.Func:
    return S.Func(name, list(args))


def dateadd(part: str, n, d) -> S.Func:
    return S.Func("dateadd", [S.Const(part), S.wrap(n), S.wrap(d)])


def datepart(part: str, d) -> S.Func:
    return S.Func("datepart", [S.Const(part), S.wrap(d)])


def like(expr, pattern: str) -> S.Like:
    return S.Like(expr, pattern)


def in_list(expr, options) -> S.InList:
    return S.InList(expr, options)


def between(expr, lo, hi) -> S.Between:
    return S.Between(expr, lo, hi)


def udf(name: str, *args) -> S.UdfCall:
    return S.UdfCall(name, [S.wrap(a) for a in args])


def exists(plan) -> S.Exists:
    plan = plan.node if isinstance(plan, Q) else plan
    return S.Exists(plan)


def not_exists(plan) -> S.Exists:
    plan = plan.node if isinstance(plan, Q) else plan
    return S.Exists(plan, negated=True)


def scalar_subquery(plan, column=None) -> S.ScalarSubquery:
    plan = plan.node if isinstance(plan, Q) else plan
    return S.ScalarSubquery(plan, column)


# aggregate markers, legal only inside UdfBuilder.select / Q.agg ------------


class _Agg:
    def __init__(self, fn: str, expr):
        self.fn = fn
        self.expr = None if expr is None else S.wrap(expr)


def sum_(expr) -> _Agg:
    return _Agg("sum", expr)


def avg_(expr) -> _Agg:
    return _Agg("avg", expr)


def min_(expr) -> _Agg:
    return _Agg("min", expr)


def max_(expr) -> _Agg:
    return _Agg("max", expr)


def count_(expr=None) -> _Agg:
    return _Agg("count" if expr is not None else "count_star", expr)


# ---------------------------------------------------------------------------
# Fluent query builder
# ---------------------------------------------------------------------------


class Q:
    """Thin fluent wrapper over relalg nodes."""

    def __init__(self, node: R.RelNode):
        self.node = node

    def filter(self, pred) -> "Q":
        return Q(R.Filter(self.node, pred))

    def compute(self, **exprs) -> "Q":
        return Q(R.Compute(self.node, exprs))

    def project(self, *cols, **renames) -> "Q":
        mapping = {c: c for c in cols}
        mapping.update({new: old for new, old in renames.items()})
        return Q(R.Project(self.node, mapping))

    def join(self, other, on, kind="inner") -> "Q":
        other = other.node if isinstance(other, Q) else other
        if isinstance(on, tuple):
            on = [on]
        return Q(R.Join(self.node, other, on, kind))

    def group_by(self, *keys, capacity=None, **aggs) -> "Q":
        specs = {}
        for name, a in aggs.items():
            if isinstance(a, _Agg):
                specs[name] = R.AggSpec(a.fn, a.expr)
            else:
                raise TypeError(f"{name}: use sum_/count_/min_/max_/avg_")
        return Q(R.GroupAgg(self.node, list(keys), specs, capacity))

    def agg(self, **aggs) -> "Q":
        return self.group_by(**aggs)

    def sort(self, *keys, limit=None) -> "Q":
        norm = [(k, True) if isinstance(k, str) else k for k in keys]
        return Q(R.Sort(self.node, norm, limit))


def scan(table: str) -> Q:
    return Q(R.Scan(table))


# ---------------------------------------------------------------------------
# UDF builder
# ---------------------------------------------------------------------------


class UdfBuilder:
    """Builds a :class:`repro.core.ir.UdfDef`.

    Example (the paper's Figure 1 ``total_price``)::

        u = UdfBuilder("total_price", [("key", "int32")], "float32")
        u.declare("price", "float32")
        u.declare("rate", "float32")
        u.declare("pref_currency", "str")
        u.declare("default_currency", "str", lit("USD"))
        u.select({"price": sum_(col("o_totalprice"))},
                 frm=scan("orders").filter(col("o_custkey") == param("key")))
        u.select({"pref_currency": col("currency")},
                 frm=scan("customer_prefs").filter(col("custkey") == param("key")))
        with u.if_(var("pref_currency") != var("default_currency")):
            u.set("rate", udf("xchg_rate", var("default_currency"),
                              var("pref_currency")))
            u.set("price", var("price") * var("rate"))
        u.return_(var("price"))
        f = u.build()
    """

    def __init__(self, name: str, params: list[tuple[str, str]], returns: str):
        self.name = name
        self.params = params
        self.returns = returns
        self._stack: list[list[IR.Statement]] = [[]]
        self._last_if: list[IR.IfElse | None] = [None]

    # -- statements ----------------------------------------------------------
    def declare(self, name: str, dtype: str = "float32", init=None) -> "UdfBuilder":
        init = None if init is None else S.wrap(init)
        self._stack[-1].append(IR.Declare(name, dtype, init))
        self._last_if[-1] = None
        return self

    def set(self, name: str, expr) -> "UdfBuilder":
        self._stack[-1].append(IR.Assign(name, S.wrap(expr)))
        self._last_if[-1] = None
        return self

    def select(self, assigns: dict[str, Any], frm: Q | R.RelNode | None = None,
               where=None) -> "UdfBuilder":
        """SELECT @v1 = e1, @v2 = e2 [FROM plan [WHERE pred]].

        Lowered to one Assign per variable whose RHS is a ScalarSubquery
        sharing the same plan node (the shared node is what lets CSE remove
        the duplication — paper §4.2.1)."""
        plan = None
        if frm is not None:
            plan = frm.node if isinstance(frm, Q) else frm
            if where is not None:
                plan = R.Filter(plan, where)
        for vname, expr in assigns.items():
            if plan is None:
                assert not isinstance(expr, _Agg)
                self.set(vname, expr)
                continue
            if isinstance(expr, _Agg):
                sub = R.GroupAgg(plan, [], {vname: R.AggSpec(expr.fn, expr.expr)})
                rhs = S.ScalarSubquery(sub, vname)
            else:
                sub = R.Compute(plan, {f"__{vname}_prj": S.wrap(expr)})
                rhs = S.ScalarSubquery(sub, f"__{vname}_prj")
            self.set(vname, rhs)
        return self

    @contextlib.contextmanager
    def if_(self, pred):
        self._stack.append([])
        self._last_if.append(None)
        try:
            yield self
        finally:
            body = self._stack.pop()
            self._last_if.pop()
            node = IR.IfElse(S.wrap(pred), body, [])
            self._stack[-1].append(node)
            self._last_if[-1] = node

    @contextlib.contextmanager
    def else_(self):
        node = self._last_if[-1]
        if node is None:
            raise SyntaxError("else_() without a preceding if_()")
        self._stack.append([])
        self._last_if.append(None)
        try:
            yield self
        finally:
            node.else_body = self._stack.pop()
            self._last_if.pop()
            self._last_if[-1] = None

    def return_(self, expr) -> "UdfBuilder":
        self._stack[-1].append(IR.Return(S.wrap(expr)))
        self._last_if[-1] = None
        return self

    # -- loops ----------------------------------------------------------------
    def break_(self) -> "UdfBuilder":
        self._stack[-1].append(IR.Break())
        self._last_if[-1] = None
        return self

    def fetch_(self, cursor: str, targets: list[tuple[str, str]]) -> "UdfBuilder":
        """FETCH NEXT marker (parser-internal; see :class:`ir.Fetch`)."""
        self._stack[-1].append(IR.Fetch(cursor, list(targets)))
        self._last_if[-1] = None
        return self

    @contextlib.contextmanager
    def _capture(self):
        """Collect statements into a fresh list without emitting a node —
        the parser uses this to parse loop bodies before deciding the loop
        shape."""
        self._stack.append([])
        self._last_if.append(None)
        holder: list[IR.Statement] = []
        try:
            yield holder
        finally:
            holder.extend(self._stack.pop())
            self._last_if.pop()
            self._last_if[-1] = None

    @contextlib.contextmanager
    def while_(self, pred):
        """WHILE pred BEGIN ... END."""
        self._stack.append([])
        self._last_if.append(None)
        try:
            yield self
        finally:
            body = self._stack.pop()
            self._last_if.pop()
            self._stack[-1].append(IR.While(S.wrap(pred), body))
            self._last_if[-1] = None

    @contextlib.contextmanager
    def cursor_loop(self, fetch: dict[str, str], frm, where=None, guard=None,
                    cursor: str = "c"):
        """Cursor loop over ``frm``'s rows in order.

        ``fetch`` maps loop variables to cursor columns (FETCH ... INTO);
        ``guard`` is an optional extra termination conjunct evaluated after
        each fetch (loop stops when it is not true)."""
        plan = frm.node if isinstance(frm, Q) else frm
        if where is not None:
            plan = R.Filter(plan, S.wrap(where))
        self._stack.append([])
        self._last_if.append(None)
        try:
            yield self
        finally:
            body = self._stack.pop()
            self._last_if.pop()
            targets = [(v, c) for v, c in fetch.items()]
            g = None if guard is None else S.wrap(guard)
            self._stack[-1].append(
                IR.CursorLoop(cursor, plan, targets, body, g))
            self._last_if[-1] = None

    # -- finish ---------------------------------------------------------------
    def build(self) -> IR.UdfDef:
        assert len(self._stack) == 1, "unclosed if_/else_ block"
        return IR.UdfDef(self.name, self.params, self.returns, self._stack[0])
