"""Fault-tolerant checkpointing.

Survival requirements at 1000+ nodes (DESIGN.md §6):

* **atomicity** — write to ``<dir>/.tmp-<step>``, fsync files, then a
  single atomic ``rename`` to ``step_<n>``; a crash mid-write can never
  leave a checkpoint that ``latest_step`` would pick up;
* **resume** — ``restore_latest`` walks newest → oldest, skipping
  checkpoints that fail verification (truncated shard, bad manifest);
* **keep-N** — bounded disk; oldest checkpoints garbage-collected after a
  successful save;
* **async** — the device→host copy happens on the caller thread (cheap),
  serialization happens on a background thread so the train loop overlaps
  the write with the next steps;
* **multi-host** — each process writes only its addressable shards into
  ``proc<k>`` files; the manifest stores the global tree structure, so a
  restore on a *different* topology re-shards from the per-leaf global
  arrays (elastic restart, see train/elastic.py).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}__{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v

    def fix(node):
        if isinstance(node, dict) and node and all(
            k.startswith("__") for k in node
        ):
            return tuple(
                fix(node[f"__{i}"]) for i in range(len(node))
            )
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, async_write: bool = True,
                 process_index: int | None = None):
        self.dir = directory
        self.keep_n = keep_n
        self.async_write = async_write
        self.process_index = (
            process_index if process_index is not None else jax.process_index()
        )
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._worker = None
        self._err = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree):
        """Snapshot to host, then serialize (async by default)."""
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        if self.async_write:
            self._ensure_worker()
            self._q.put((step, host))
        else:
            self._write(step, host)

    def _ensure_worker(self):
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                self._write(*item)
            except Exception as e:  # surfaced on next wait()
                self._err = e

    def wait(self):
        """Block until queued writes finish (used before shutdown/tests)."""
        if self._worker is not None and self._worker.is_alive():
            self._q.put(None)
            self._worker.join()
            self._worker = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def _write(self, step: int, host: dict):
        tmp = os.path.join(self.dir, f".tmp-{step}-p{self.process_index}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {}
        for key, arr in host.items():
            fname = f"{key.replace('/', '.')}.p{self.process_index}.npy"
            path = os.path.join(tmp, fname)
            dtype_name = str(arr.dtype)
            to_save = arr
            if arr.dtype.kind == "V" or dtype_name == "bfloat16":
                # ml_dtypes (bf16/f8): persist as a same-width uint view
                to_save = arr.view(f"u{arr.dtype.itemsize}")
            with open(path, "wb") as f:
                np.save(f, to_save)
                f.flush()
                os.fsync(f.fileno())
            manifest[key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": dtype_name,
            }
        with open(os.path.join(tmp, f"manifest.p{self.process_index}.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int):
        d = os.path.join(self.dir, f"step_{step:08d}")
        mpath = os.path.join(d, f"manifest.p{self.process_index}.json")
        with open(mpath) as f:
            manifest = json.load(f)
        flat = {}
        for key, info in manifest["leaves"].items():
            arr = np.load(os.path.join(d, info["file"]))
            if list(arr.shape) != info["shape"]:
                raise IOError(f"shard {key} corrupt: {arr.shape} != {info['shape']}")
            if str(arr.dtype) != info["dtype"]:
                # re-view uint-persisted ml_dtypes (bf16/f8) leaves
                import ml_dtypes

                target = np.dtype(getattr(ml_dtypes, info["dtype"], info["dtype"]))
                arr = arr.view(target)
            flat[key] = arr
        return _unflatten(flat)

    def restore_latest(self):
        """Newest verifiable checkpoint (skips corrupt ones) or None."""
        for step in reversed(self.all_steps()):
            try:
                return step, self.restore(step)
            except Exception:
                continue
        return None, None
