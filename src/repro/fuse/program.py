"""Fused device programs: N statements, one trace, shared scans.

The fusion engine's back half.  Given the member descriptors the session
assembled (plan, parameter signature, batch bucket per member) this module
builds the single **raw closure** the session jits into the fused
executable:

1. rebuild the catalog from the (broadcast) table arguments — exactly as
   the per-statement closure in ``Session._executable`` does;
2. execute every shared subtree the merge pass found **once**, on an
   ordinary executor, into a ``fingerprint -> MaskedTable`` pool;
3. ``vmap`` each member's plan over its own stacked parameter axis, with a
   :class:`SharedScanExecutor` that answers marked subtrees straight from
   the pool (the pool entries are loop-invariant w.r.t. the parameter
   axis, so they enter each member's trace as broadcast constants);
4. return one ``(mask, columns)`` pair per member — the tagged fused
   result the session slices per-ticket.

Members with an empty parameter signature skip the batch axis entirely
(their tickets are all the same execution): the plan runs once, unbatched,
and every ticket shares the single result — mirroring ``execute_many``'s
parameter-free group handling.
"""
from __future__ import annotations

import jax

from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.executor import Executor
from repro.core.interpreter import Interpreter
from repro.fuse.merge import merge_plans
from repro.tables.table import Column, Table

#: reserved stacked-parameter name (filtered out before the executor binds
#: params) — kept for callers that need a dummy batch axis; the leading
#: underscores keep it out of any legal identifier's way
FUSE_PAD = "__fuse_pad__"


class SharedScanExecutor(Executor):
    """An :class:`Executor` that serves marked subtrees from the fused
    program's shared-result pool instead of re-executing them.

    ``shared_ids`` is the merge pass's ``node_id -> fingerprint`` map;
    ``shared_results`` the pool built in step 2 of the fused closure.  Any
    node not in the map executes normally — including everything *inside*
    a shared subtree, which only ever runs under the pool builder.
    """

    def __init__(self, catalog, shared_ids, shared_results, **kwargs):
        super().__init__(catalog, **kwargs)
        self._shared_ids = shared_ids
        self._shared_results = shared_results

    def _exec(self, node, ctx, memo):
        fp = self._shared_ids.get(node.node_id)
        if fp is not None:
            hit = self._shared_results.get(fp)
            if hit is not None:
                return hit
        return super()._exec(node, ctx, memo)


def _plans_have_udf_calls(plans) -> bool:
    return any(
        isinstance(e, S.UdfCall)
        for p in plans
        for n in R.walk_plan(p)
        for ex in n.exprs()
        for e in S.walk(ex)
    )


def build_fused_raw(session, members, policy):
    """Build the fused raw closure for ``members`` (see module docstring).

    Returns ``(raw, out_dicts, trace_stats, merged)``: the untraced
    closure, the per-member output-dictionary captures, the trace-time
    stats dict (both filled on first execution, like the per-statement
    executable's), and the :class:`~repro.fuse.merge.FusedPlan`.
    """
    plans = [m.plan for m in members]
    merged = merge_plans(plans)

    # iterative hook for UDF calls left in the plans (froid OFF / hybrid);
    # 'scan' mode is the only jit-traceable interpreter (see _executable)
    hook = None
    if _plans_have_udf_calls(plans):
        interp = Interpreter(session.catalog, session.registry, mode="scan")
        hook = interp.eval_udf_call

    meta = {
        tname: {c: col.dictionary for c, col in t.columns.items()}
        for tname, t in session.catalog.items()
    }
    out_dicts: list[dict] = [{} for _ in members]
    trace_stats: dict = {}

    def raw(table_args, pargs_tuple):
        catalog = {
            tname: Table(
                {
                    c: Column(data, valid, meta[tname][c])
                    for c, (data, valid) in cols.items()
                }
            )
            for tname, cols in table_args.items()
        }
        # step 2: the shared pool — each distinct cross-statement subtree
        # executes once, outside every member's vmap
        shared_ex = Executor(catalog, udf_column_evaluator=hook,
                             use_pallas_agg=policy.pallas_agg)
        shared_results = {
            fp: shared_ex.execute(sub) for fp, sub in merged.shared
        }
        scanned = shared_ex.stats
        outs = []
        for i, (m, pargs) in enumerate(zip(members, pargs_tuple)):
            # hoisted out of the traced per-row closure (executor state is
            # batch-independent)
            ex = SharedScanExecutor(
                catalog, merged.shared_ids, shared_results,
                udf_column_evaluator=hook, use_pallas_agg=policy.pallas_agg,
            )

            def one(pa, i=i, m=m, ex=ex):
                pvals = {
                    name: S.Value(data, valid, m.pdicts.get(name))
                    for name, (data, valid) in pa.items()
                    if name != FUSE_PAD
                }
                out = ex.execute(m.plan, params=pvals)
                for cname, c in out.table.columns.items():
                    out_dicts[i][cname] = c.dictionary  # host metadata
                cols = {
                    cname: (c.data, c.validity())
                    for cname, c in out.table.columns.items()
                }
                return out.mask, cols

            if m.sig:
                outs.append(jax.vmap(one)(pargs))
            else:
                # parameter-free member: one unbatched execution serves
                # every ticket (no per-ticket slicing at delivery)
                outs.append(one({}))
            for k, v in ex.stats.items():
                scanned[k] = scanned.get(k, 0) + v
        trace_stats.update(scanned)
        trace_stats.update(merged.stats)
        return tuple(outs)

    return raw, out_dicts, trace_stats, merged
