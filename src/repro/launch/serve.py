"""Serving launcher: batched requests through the slot engine with
Froid-compiled admission rules.

    PYTHONPATH=src python -m repro.launch.serve --arch granite3_2b --smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import config_for, smoke_config_for
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite3_2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--admission", default="froid",
                    choices=["froid", "interpreted", "hekaton"],
                    help="ExecutionPolicy preset for the admission rules")
    args = ap.parse_args()

    cfg = smoke_config_for(args.arch) if args.smoke else config_for(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = ServeEngine(model, params, slots=args.slots, max_len=args.max_len,
                      admission_policy=args.admission)

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, rng.integers(4, 16)).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=float(rng.choice([0.0, 0.7, 1.0])),
            tier=int(rng.integers(0, 3)),
        )
        for i in range(args.requests)
    ]
    done = eng.run(reqs)
    for c in sorted(done, key=lambda c: c.rid):
        print(f"req {c.rid}: {len(c.tokens)} tokens ({c.reason}) {c.tokens[:8]}…")


if __name__ == "__main__":
    main()
