"""Shared layers: RMSNorm, SwiGLU MLP, MoE, rotary embeddings, losses.

Pure-functional: params are nested dicts of jnp arrays; init functions
take a PRNG key and return the dict.  Compute dtype is bf16 with f32
accumulation (norms/softmax/loss in f32) — the TPU-native mixed precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16


def _dense_init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) == 2 else shape[-2]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale)


# ------------------------------------------------------------------ norms
def rmsnorm(x, w, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(
        x.dtype
    )


def init_rmsnorm(d):
    return jnp.zeros((d,), jnp.float32)


# ------------------------------------------------------------------ rope
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, D) with D even; positions: broadcastable to (..., S)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLP
def init_mlp(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d_model, d_ff)),
        "w_up": _dense_init(k2, (d_model, d_ff)),
        "w_down": _dense_init(k3, (d_ff, d_model)),
    }


def mlp(params, x):
    dt = x.dtype
    g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dt))
    u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(dt))


# ------------------------------------------------------------------ MoE
def init_moe(key, d_model, d_ff, n_experts, storage_experts=None):
    """``storage_experts`` >= n_experts pads the expert axis for clean
    expert-parallel sharding; pad experts hold zeros and are never routed
    to (router width stays n_experts)."""
    E = storage_experts or n_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    def padded(k, shape):
        w = _dense_init(k, (n_experts,) + shape)
        if E > n_experts:
            w = jnp.concatenate(
                [w, jnp.zeros((E - n_experts,) + shape, w.dtype)], axis=0
            )
        return w
    return {
        "router": _dense_init(k0, (d_model, n_experts)),
        "w_gate": padded(k1, (d_model, d_ff)),
        "w_up": padded(k2, (d_model, d_ff)),
        "w_down": padded(k3, (d_ff, d_model)),
    }


def moe(params, x, top_k: int):
    """Dense one-hot dispatch MoE (EP-shardable einsum form).

    Every token's activation is contracted against every expert with a
    top-k one-hot combine weight — dropless routing whose dispatch is two
    einsums (MXU-friendly; the expert axis shards over the model axis for
    expert parallelism).  FLOP cost is n_experts/top_k higher than ideal
    a2a dispatch; see EXPERIMENTS.md §Perf for the a2a-free trade-off."""
    dt = x.dtype
    logits = jnp.einsum(
        "...d,de->...e", x.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    weights = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(weights, top_k)  # (..., k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    n_storage = params["w_gate"].shape[0]  # >= router width when padded
    combine = jnp.sum(
        jax.nn.one_hot(top_i, n_storage, dtype=jnp.float32)
        * top_w[..., None],
        axis=-2,
    )  # (..., e) sparse combine weights (pad experts get weight 0)

    g = jnp.einsum("...d,edf->...ef", x, params["w_gate"].astype(dt))
    u = jnp.einsum("...d,edf->...ef", x, params["w_up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    y = jnp.einsum("...ef,efd->...ed", h, params["w_down"].astype(dt))
    return jnp.einsum("...ed,...e->...d", y, combine.astype(dt))


def moe_aux_loss(params, x):
    """Load-balancing auxiliary loss (Switch-style)."""
    logits = jnp.einsum(
        "...d,de->...e", x.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    hard = jax.nn.one_hot(jnp.argmax(probs, -1), probs.shape[-1])
    load = jnp.mean(hard, axis=tuple(range(hard.ndim - 1)))
    return probs.shape[-1] * jnp.sum(frac * load)


# ------------------------------------------------------------------ losses
def chunked_softmax_xent(x, w_head, labels, mask=None, chunk: int = 512):
    """Next-token CE without materializing (B, S, V) logits: the sequence
    is processed in chunks (lax.map), each chunk computing logits ->
    logsumexp -> label logit and discarding the logits.  Differentiable
    (map lowers to scan); with remat the backward recomputes per chunk.

    x: (B, S, D); w_head: (D, V); labels: (B, S) int32.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = (
            jnp.pad(mask, ((0, 0), (0, pad)))
            if mask is not None
            else jnp.pad(jnp.ones((B, S), bool), ((0, 0), (0, pad)))
        )
    elif mask is None:
        mask = jnp.ones((B, S), bool)
    n_chunks = (S + pad) // chunk
    xc = x.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint  # backward recomputes each chunk's logits (O(chunk·V))
    def one(args):
        xi, li, mi = args  # (B, chunk, D), (B, chunk)
        logits = jnp.einsum(
            "bsd,dv->bsv", xi.astype(jnp.float32), w_head.astype(jnp.float32)
        )
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mi
        return jnp.sum(nll), jnp.sum(mi)

    losses, counts = jax.lax.map(one, (xc, lc, mc))
    total = jnp.sum(losses)
    denom = jnp.maximum(jnp.sum(counts), 1.0)
    return total / denom
