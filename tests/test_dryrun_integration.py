"""Multi-pod dry-run integration: one real cell through lower+compile in a
subprocess (the 512-device XLA flag must not leak into this process), plus
artifact-shape checks on the committed sweep results."""
import glob
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2_370m",
         "--shape", "decode_32k", "--mesh", "single", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT,
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    (path,) = glob.glob(str(tmp_path / "*.json"))
    rec = json.load(open(path))
    assert rec["status"] == "ok"
    assert rec["roofline"]["flops"] > 0
    assert rec["memory"]["per_device_total"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")


def test_sweep_artifacts_complete():
    """The committed 80-cell sweep: every (arch × shape × mesh) present,
    nothing failed, skips are exactly the documented long_500k cells."""
    d = os.path.join(ROOT, "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("sweep artifacts not present")
    recs = [json.load(open(p)) for p in glob.glob(os.path.join(d, "*.json"))
            if not p.endswith("int8kv.json")]
    assert len(recs) >= 80
    by_status = {}
    for r in recs:
        by_status.setdefault(r["status"], []).append(r)
    assert "fail" not in by_status, [
        (r["arch"], r["shape"], r.get("error")) for r in by_status["fail"]
    ]
    skips = {(r["arch"], r["shape"]) for r in by_status.get("skip", [])}
    assert all(s == "long_500k" for _, s in skips)
    full_attn = {"llama32_vision_90b", "granite3_2b", "minicpm3_4b",
                 "phi3_mini_38b", "granite_moe_3b_a800m",
                 "seamless_m4t_large_v2"}
    assert {a for a, _ in skips} == full_attn
