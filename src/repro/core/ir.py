"""Imperative UDF IR: statements, regions, and function definitions.

Mirrors the paper's supported constructs (§3.4, Table 1):
DECLARE / SET / SELECT-assign / IF-ELSE (arbitrary nesting) / RETURN
(single or multiple) / nested UDF calls / EXISTS / ISNULL.  Loops are
deliberately unsupported (the paper disabled them too, §4.2.1).

Region construction (§4.1): a statement list splits into a hierarchy of
*sequential* regions (maximal runs of straight-line statements) and
*conditional* regions (IF-ELSE), each of which the algebrizer turns into one
single-row derived table.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import scalar as S


# ---------------------------------------------------------------------------
# Statements
# ---------------------------------------------------------------------------


class Statement:
    pass


@dataclasses.dataclass
class Declare(Statement):
    name: str
    dtype: str = "float32"  # float32 | int32 | bool | str | date
    init: S.Scalar | None = None  # None => NULL (paper §4.2.1)


@dataclasses.dataclass
class Assign(Statement):
    """SET @name = expr  (also models single-variable SELECT-assign; the
    frontend lowers multi-assign SELECTs to several Assigns — paper §4.2.1
    notes Froid does exactly this and relies on CSE for the duplication)."""

    name: str
    expr: S.Scalar


@dataclasses.dataclass
class IfElse(Statement):
    pred: S.Scalar
    then_body: list[Statement]
    else_body: list[Statement]


@dataclasses.dataclass
class Return(Statement):
    expr: S.Scalar


# ---------------------------------------------------------------------------
# Regions (paper §4.1)
# ---------------------------------------------------------------------------


class Region:
    pass


@dataclasses.dataclass
class SeqRegion(Region):
    """A maximal straight-line run of Declare/Assign/Return statements."""

    statements: list[Statement]


@dataclasses.dataclass
class CondRegion(Region):
    pred: S.Scalar
    then_regions: list[Region]
    else_regions: list[Region]


def build_regions(body: Sequence[Statement]) -> list[Region]:
    """Single pass over the UDF body (paper: 'Regions can be constructed in
    a single pass')."""
    out: list[Region] = []
    run: list[Statement] = []

    def flush():
        nonlocal run
        if run:
            out.append(SeqRegion(run))
            run = []

    for st in body:
        if isinstance(st, IfElse):
            flush()
            out.append(
                CondRegion(
                    st.pred, build_regions(st.then_body), build_regions(st.else_body)
                )
            )
        else:
            run.append(st)
            if isinstance(st, Return):
                # statements after an unconditional RETURN are unreachable —
                # drop them (dead-code elimination at region construction)
                flush()
                return out
    flush()
    return out


# ---------------------------------------------------------------------------
# Function definition
# ---------------------------------------------------------------------------

_DTYPES = {"float32", "int32", "bool", "str", "date"}


@dataclasses.dataclass
class UdfDef:
    name: str
    params: list[tuple[str, str]]  # (name, dtype)
    return_dtype: str
    body: list[Statement]

    def __post_init__(self):
        for _, dt in self.params:
            assert dt in _DTYPES, dt
        assert self.return_dtype in _DTYPES

    def regions(self) -> list[Region]:
        return build_regions(self.body)

    # -- analyses ------------------------------------------------------------
    def all_exprs(self):
        def rec(stmts):
            for st in stmts:
                if isinstance(st, Declare) and st.init is not None:
                    yield st.init
                elif isinstance(st, Assign):
                    yield st.expr
                elif isinstance(st, Return):
                    yield st.expr
                elif isinstance(st, IfElse):
                    yield st.pred
                    yield from rec(st.then_body)
                    yield from rec(st.else_body)

        yield from rec(self.body)

    def is_deterministic(self) -> bool:
        return all(S.is_deterministic(e) for e in self.all_exprs())

    def called_udfs(self) -> set[str]:
        out = set()
        for e in self.all_exprs():
            for node in S.walk(e):
                if isinstance(node, S.UdfCall):
                    out.add(node.name)
        return out

    def statement_count(self) -> int:
        def count(stmts):
            n = 0
            for st in stmts:
                n += 1
                if isinstance(st, IfElse):
                    n += count(st.then_body) + count(st.else_body)
            return n

        return count(self.body)
