"""Public jit'd wrapper for flash_attention (auto-interpret off-TPU)."""
import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import (
    flash_attention_chunked,
    flash_attention_ref,
)

# above this many score-matrix elements per (batch, head), off-TPU lowering
# switches to the chunked online-softmax form (kernel-like O(S·bk) memory)
_CHUNKED_THRESHOLD = 512 * 512


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "sm_scale", "bq", "bk",
                     "interpret", "use_kernel"),
)
def flash_attention(q, k, v, causal=True, window=None, q_offset=0,
                    sm_scale=None, bq=128, bk=128, interpret=None,
                    use_kernel=None):
    """Attention entry point used by the model zoo.

    On TPU: the Pallas kernel.  Off-TPU (this container): the jnp reference
    for small shapes, the chunked online-softmax form for big ones — so
    CPU dry-run lowering shows kernel-like memory (never (Sq, Sk) scores).
    The Pallas kernel itself is validated by the interpret-mode sweeps.
    Pass use_kernel=True to force the kernel."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        if q.shape[2] * k.shape[2] > _CHUNKED_THRESHOLD:
            return flash_attention_chunked(
                q, k, v, causal=causal, window=window, q_offset=q_offset,
                sm_scale=sm_scale, bk=max(bk, 512),
            )
        return flash_attention_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            sm_scale=sm_scale,
        )
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        sm_scale=sm_scale, bq=bq, bk=bk, interpret=interpret,
    )
