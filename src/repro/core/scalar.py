"""Scalar expression IR with SQL NULL semantics, vectorized over columns.

Every expression evaluates to a whole column (a :class:`Value`: data array +
validity + optional string dictionary).  This is the "set-oriented" scalar
subsystem: where SQL Server's scalar evaluator is invoked once per row
(paper §2.2), ours evaluates each expression once per *column* on the VPU.

Three-valued logic (Kleene) is implemented for AND/OR/NOT; WHERE treats
NULL as false, exactly as in SQL.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.tables.table import (
    DictEncoding,
    date_add,
    date_part,
)

# ---------------------------------------------------------------------------
# Runtime value
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Value:
    """A vectorized scalar value: data + validity (+ dictionary for strings).

    ``data`` has shape ``()`` (a not-yet-broadcast constant) or ``(n,)``.
    ``valid`` is None (all valid), or a bool array broadcastable to data.
    """

    data: jnp.ndarray
    valid: jnp.ndarray | None = None
    dictionary: DictEncoding | None = None

    def validity(self) -> jnp.ndarray:
        if self.valid is None:
            return jnp.ones(jnp.shape(self.data), dtype=bool)
        return jnp.broadcast_to(self.valid, jnp.shape(self.data))

    def broadcast(self, n: int) -> "Value":
        data = jnp.broadcast_to(self.data, (n,) if jnp.ndim(self.data) == 0 else jnp.shape(self.data))
        valid = None
        if self.valid is not None:
            valid = jnp.broadcast_to(self.valid, jnp.shape(data))
        return Value(data, valid, self.dictionary)


def null_value(dtype=jnp.float32) -> Value:
    return Value(jnp.zeros((), dtype=dtype), jnp.zeros((), dtype=bool))


def _and_valid(*vals: Value) -> jnp.ndarray | None:
    masks = [v.valid for v in vals if v.valid is not None]
    if not masks:
        return None
    out = masks[0]
    for m in masks[1:]:
        out = out & m
    return out


# ---------------------------------------------------------------------------
# Expression nodes
# ---------------------------------------------------------------------------


class Scalar:
    """Base class.  Operator overloads build the IR fluently."""

    def __add__(self, o):
        return BinOp("+", self, wrap(o))

    def __radd__(self, o):
        return BinOp("+", wrap(o), self)

    def __sub__(self, o):
        return BinOp("-", self, wrap(o))

    def __rsub__(self, o):
        return BinOp("-", wrap(o), self)

    def __mul__(self, o):
        return BinOp("*", self, wrap(o))

    def __rmul__(self, o):
        return BinOp("*", wrap(o), self)

    def __truediv__(self, o):
        return BinOp("/", self, wrap(o))

    def __rtruediv__(self, o):
        return BinOp("/", wrap(o), self)

    def __floordiv__(self, o):
        return BinOp("//", self, wrap(o))

    def __mod__(self, o):
        return BinOp("%", self, wrap(o))

    def __neg__(self):
        return BinOp("-", Const(0), self)

    def __eq__(self, o):  # type: ignore[override]
        return Cmp("==", self, wrap(o))

    def __ne__(self, o):  # type: ignore[override]
        return Cmp("!=", self, wrap(o))

    def __lt__(self, o):
        return Cmp("<", self, wrap(o))

    def __le__(self, o):
        return Cmp("<=", self, wrap(o))

    def __gt__(self, o):
        return Cmp(">", self, wrap(o))

    def __ge__(self, o):
        return Cmp(">=", self, wrap(o))

    def __and__(self, o):
        return BoolOp("and", [self, wrap(o)])

    def __or__(self, o):
        return BoolOp("or", [self, wrap(o)])

    def __invert__(self):
        return BoolOp("not", [self])

    def __hash__(self):  # nodes are identity-hashed (needed since __eq__ builds IR)
        return id(self)

    def is_null(self):
        return IsNull(self)

    def children(self) -> list["Scalar"]:
        return []

    def with_children(self, kids: list["Scalar"]) -> "Scalar":
        assert not kids
        return self


def wrap(x) -> Scalar:
    if isinstance(x, Scalar):
        return x
    return Const(x)


class Const(Scalar):
    def __init__(self, value: Any, dtype=None):
        self.value = value
        self.dtype = dtype

    def __repr__(self):
        return f"Const({self.value!r})"


class ColRef(Scalar):
    """Reference to a column of the current row environment."""

    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return f"Col({self.name})"


class Outer(Scalar):
    """Correlated reference: a column of the *outer* row inside an Apply /
    correlated subquery (the paper's correlating parameter)."""

    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return f"Outer({self.name})"


class Param(Scalar):
    """UDF formal parameter; replaced by actual argument at substitution."""

    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return f"Param({self.name})"


class Var(Scalar):
    """UDF local variable reference (imperative scope).  The algebrizer
    rewrites these into ColRef/Outer column references; the iterative
    interpreter binds them from its variable environment."""

    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return f"Var({self.name})"


class BinOp(Scalar):
    def __init__(self, op: str, l: Scalar, r: Scalar):
        self.op, self.l, self.r = op, l, r

    def children(self):
        return [self.l, self.r]

    def with_children(self, kids):
        return BinOp(self.op, *kids)

    def __repr__(self):
        return f"({self.l!r} {self.op} {self.r!r})"


class Cmp(Scalar):
    def __init__(self, op: str, l: Scalar, r: Scalar):
        self.op, self.l, self.r = op, l, r

    def children(self):
        return [self.l, self.r]

    def with_children(self, kids):
        return Cmp(self.op, *kids)

    def __repr__(self):
        return f"({self.l!r} {self.op} {self.r!r})"


class BoolOp(Scalar):
    def __init__(self, op: str, args: Sequence[Scalar]):
        self.op = op
        self.args = list(args)

    def children(self):
        return list(self.args)

    def with_children(self, kids):
        return BoolOp(self.op, kids)

    def __repr__(self):
        return f"{self.op}({', '.join(map(repr, self.args))})"


class Case(Scalar):
    """CASE WHEN p1 THEN v1 [WHEN p2 THEN v2 ...] ELSE e END."""

    def __init__(self, whens: Sequence[tuple[Scalar, Scalar]], else_: Scalar):
        self.whens = [(wrap(p), wrap(v)) for p, v in whens]
        self.else_ = wrap(else_)

    def children(self):
        out = []
        for p, v in self.whens:
            out += [p, v]
        out.append(self.else_)
        return out

    def with_children(self, kids):
        n = len(self.whens)
        whens = [(kids[2 * i], kids[2 * i + 1]) for i in range(n)]
        return Case(whens, kids[-1])

    def __repr__(self):
        w = "; ".join(f"{p!r}->{v!r}" for p, v in self.whens)
        return f"Case({w}; else {self.else_!r})"


class Cast(Scalar):
    def __init__(self, expr: Scalar, dtype):
        self.expr, self.dtype = wrap(expr), dtype

    def children(self):
        return [self.expr]

    def with_children(self, kids):
        return Cast(kids[0], self.dtype)

    def __repr__(self):
        name = getattr(self.dtype, "__name__", None) or getattr(
            self.dtype, "name", str(self.dtype))
        return f"Cast({self.expr!r} as {name})"


class Func(Scalar):
    """Intrinsic function call (deterministic unless listed otherwise)."""

    NON_DETERMINISTIC = {"rand", "getdate", "newid"}

    def __init__(self, name: str, args: Sequence[Scalar]):
        self.name = name.lower()
        self.args = [wrap(a) for a in args]

    def children(self):
        return list(self.args)

    def with_children(self, kids):
        return Func(self.name, kids)

    def __repr__(self):
        return f"{self.name}({', '.join(map(repr, self.args))})"


class IsNull(Scalar):
    def __init__(self, expr: Scalar):
        self.expr = wrap(expr)

    def children(self):
        return [self.expr]

    def with_children(self, kids):
        return IsNull(kids[0])


class Coalesce(Scalar):
    def __init__(self, args: Sequence[Scalar]):
        self.args = [wrap(a) for a in args]

    def children(self):
        return list(self.args)

    def with_children(self, kids):
        return Coalesce(kids)


class Like(Scalar):
    def __init__(self, expr: Scalar, pattern: str):
        self.expr, self.pattern = wrap(expr), pattern

    def children(self):
        return [self.expr]

    def with_children(self, kids):
        return Like(kids[0], self.pattern)


class InList(Scalar):
    def __init__(self, expr: Scalar, options: Sequence[Any]):
        self.expr = wrap(expr)
        self.options = list(options)

    def children(self):
        return [self.expr]

    def with_children(self, kids):
        return InList(kids[0], self.options)


class Between(Scalar):
    def __init__(self, expr: Scalar, lo, hi):
        self.expr, self.lo, self.hi = wrap(expr), wrap(lo), wrap(hi)

    def children(self):
        return [self.expr, self.lo, self.hi]

    def with_children(self, kids):
        return Between(*kids)


class ScalarSubquery(Scalar):
    """A relational plan producing a single column; evaluated to one scalar
    per outer row (correlated via Outer refs) or once (uncorrelated)."""

    def __init__(self, plan, column: str | None = None, agg_default=None):
        self.plan = plan
        self.column = column  # None: the plan's single output column
        # value when the subquery yields zero rows (SQL: NULL)
        self.agg_default = agg_default

    def children(self):
        return []

    def with_children(self, kids):
        return self

    def __repr__(self):
        return f"ScalarSubquery({self.plan!r})"


class Exists(Scalar):
    def __init__(self, plan, negated: bool = False):
        self.plan = plan
        self.negated = negated

    def children(self):
        return []

    def with_children(self, kids):
        return self

    def __repr__(self):
        return f"{'Not' if self.negated else ''}Exists({self.plan!r})"


class UdfCall(Scalar):
    """Call of a registered scalar UDF.  The binder (froid ON) replaces this
    with the algebrized body; the iterative interpreter (froid OFF)
    evaluates it row by row."""

    def __init__(self, name: str, args: Sequence[Scalar]):
        self.name = name
        self.args = [wrap(a) for a in args]

    def children(self):
        return list(self.args)

    def with_children(self, kids):
        return UdfCall(self.name, kids)

    def __repr__(self):
        return f"UdfCall({self.name}, {self.args!r})"


# ---------------------------------------------------------------------------
# Traversal helpers
# ---------------------------------------------------------------------------


def walk(expr: Scalar):
    yield expr
    for c in expr.children():
        yield from walk(c)
    if isinstance(expr, (ScalarSubquery, Exists)):
        # walk into subquery scalar expressions too
        from repro.core import relalg

        for node in relalg.walk_plan(expr.plan):
            for e in relalg.node_exprs(node):
                yield from walk(e)


def transform(expr: Scalar, fn: Callable[[Scalar], Scalar | None]) -> Scalar:
    """Bottom-up rewrite: fn returns replacement or None to keep.

    NB: comparison must be by identity — ``Scalar.__eq__`` builds IR."""
    old = expr.children()
    kids = [transform(c, fn) for c in old]
    if any(a is not b for a, b in zip(kids, old)):
        expr = expr.with_children(kids)
    out = fn(expr)
    return expr if out is None else out


def free_cols(expr: Scalar) -> set[str]:
    return {e.name for e in walk(expr) if isinstance(e, ColRef)}


def free_outer(expr: Scalar) -> set[str]:
    return {e.name for e in walk(expr) if isinstance(e, Outer)}


def contains_subquery(expr: Scalar) -> bool:
    return any(isinstance(e, (ScalarSubquery, Exists)) for e in walk(expr))


def is_deterministic(expr: Scalar) -> bool:
    return not any(
        isinstance(e, Func) and e.name in Func.NON_DETERMINISTIC for e in walk(expr)
    )


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------

_ARITH = {
    "+": jnp.add,
    "-": jnp.subtract,
    "*": jnp.multiply,
    "%": jnp.mod,
}

_CMPS = {
    "==": jnp.equal,
    "!=": jnp.not_equal,
    "<": jnp.less,
    "<=": jnp.less_equal,
    ">": jnp.greater,
    ">=": jnp.greater_equal,
}


def _encode_const_for(dictionary: DictEncoding | None, value):
    if dictionary is not None and isinstance(value, str):
        return jnp.asarray(dictionary.lookup(value), jnp.int32)
    return None


def _harmonize(values: list[Value]) -> list[Value]:
    """Give string Values a shared dictionary (union + remap)."""
    dicts = [v.dictionary for v in values if v.dictionary is not None]
    if not dicts:
        return values
    union = DictEncoding()
    for d in dicts:
        for i in range(len(d)):
            union.code(d.decode(i))
    out = []
    for v in values:
        if v.dictionary is None or v.dictionary is union:
            out.append(Value(v.data, v.valid, union))
            continue
        remap = np.array(
            [union.code(v.dictionary.decode(i)) for i in range(len(v.dictionary))],
            dtype=np.int32,
        )
        out.append(Value(jnp.take(jnp.asarray(remap), v.data, mode="clip"), v.valid, union))
    return out


class EvalContext:
    """Everything scalar evaluation needs from the engine."""

    def __init__(
        self, executor=None, num_rows: int = 1, params=None, outer=None, vars=None
    ):
        self.executor = executor  # repro.core.executor.Executor (for subqueries)
        self.num_rows = num_rows
        self.params = params or {}  # name -> Value (scalar)
        self.outer = outer or {}  # name -> Value (for correlated refs)
        self.vars = vars or {}  # name -> Value (interpreter variable frame)


def eval_scalar(expr: Scalar, env: dict[str, Value], ctx: EvalContext) -> Value:
    """Vectorized evaluation of ``expr`` over the row environment ``env``."""
    memo: dict[int, Value] = {}

    def ev(e: Scalar) -> Value:
        key = id(e)
        if key in memo:
            return memo[key]
        out = _eval(e)
        memo[key] = out
        return out

    def _eval(e: Scalar) -> Value:
        if isinstance(e, Const):
            if e.value is None:
                return null_value()
            if isinstance(e.value, str):
                enc = DictEncoding([e.value])
                return Value(jnp.asarray(0, jnp.int32), None, enc)
            if isinstance(e.value, bool):
                return Value(jnp.asarray(e.value, bool))
            if isinstance(e.value, int):
                return Value(jnp.asarray(e.value, jnp.int32))
            return Value(jnp.asarray(e.value, e.dtype or jnp.float32))
        if isinstance(e, ColRef):
            if e.name not in env:
                raise KeyError(f"unbound column {e.name!r}; have {sorted(env)}")
            return env[e.name]
        if isinstance(e, Outer):
            if e.name not in ctx.outer:
                raise KeyError(f"unbound outer ref {e.name!r}")
            return ctx.outer[e.name]
        if isinstance(e, Param):
            if e.name not in ctx.params:
                raise KeyError(f"unbound parameter {e.name!r}")
            return ctx.params[e.name]
        if isinstance(e, Var):
            if e.name in ctx.vars:
                return ctx.vars[e.name]
            if e.name in ctx.params:  # T-SQL: @params share the namespace
                return ctx.params[e.name]
            raise KeyError(f"unbound variable {e.name!r}")
        if isinstance(e, BinOp):
            l, r = ev(e.l), ev(e.r)
            if e.op == "+" and (l.dictionary is not None or r.dictionary is not None):
                raise NotImplementedError(
                    "dynamic string concatenation is not supported on device; "
                    "return components separately (see DESIGN.md)"
                )
            if e.op == "/":
                # SQL: x / 0 yields NULL (we fold divide-by-zero into validity)
                ld = l.data.astype(jnp.float32)
                rd = r.data.astype(jnp.float32)
                zero = jnp.broadcast_to(rd == 0, jnp.shape(ld + rd))
                data = ld / jnp.where(rd == 0, 1.0, rd)
                valid = _and_valid(l, r)
                base = (
                    jnp.ones(jnp.shape(data), bool)
                    if valid is None
                    else jnp.broadcast_to(valid, jnp.shape(data))
                )
                return Value(data, base & ~zero)
            if e.op == "//":
                rd = jnp.where(r.data == 0, 1, r.data)
                return Value(l.data // rd, _and_valid(l, r))
            fn = _ARITH[e.op]
            return Value(fn(l.data, r.data), _and_valid(l, r))
        if isinstance(e, Cmp):
            l, r = _harmonize([ev(e.l), ev(e.r)])
            return Value(_CMPS[e.op](l.data, r.data), _and_valid(l, r))
        if isinstance(e, BoolOp):
            vals = [ev(a) for a in e.args]
            if e.op == "not":
                (v,) = vals
                return Value(~v.data.astype(bool), v.valid)
            datas = [v.data.astype(bool) for v in vals]
            valids = [v.validity() for v in vals]
            if e.op == "and":
                known_false = False
                for d, m in zip(datas, valids):
                    known_false = known_false | (m & ~d)
                all_known = valids[0]
                for m in valids[1:]:
                    all_known = all_known & m
                res = datas[0]
                for d in datas[1:]:
                    res = res & d
                return Value(res & ~known_false, all_known | known_false)
            if e.op == "or":
                known_true = False
                for d, m in zip(datas, valids):
                    known_true = known_true | (m & d)
                all_known = valids[0]
                for m in valids[1:]:
                    all_known = all_known & m
                res = datas[0]
                for d in datas[1:]:
                    res = res | d
                return Value(res | known_true, all_known | known_true)
            raise ValueError(e.op)
        if isinstance(e, Case):
            vals = [ev(v) for _, v in e.whens] + [ev(e.else_)]
            vals = _harmonize(vals)
            preds = [ev(p) for p, _ in e.whens]
            out = vals[-1]
            # fold right-to-left so earlier WHENs win
            for p, v in zip(reversed(preds), reversed(vals[:-1])):
                hit = p.data.astype(bool) & p.validity()  # NULL pred == false
                data = jnp.where(hit, v.data, out.data)
                valid = jnp.where(hit, v.validity(), out.validity())
                out = Value(data, valid, vals[-1].dictionary)
            return out
        if isinstance(e, Cast):
            v = ev(e.expr)
            return Value(v.data.astype(e.dtype), v.valid, None)
        if isinstance(e, IsNull):
            v = ev(e.expr)
            return Value(~v.validity(), None)
        if isinstance(e, Coalesce):
            vals = _harmonize([ev(a) for a in e.args])
            out = vals[-1]
            for v in reversed(vals[:-1]):
                ok = v.validity()
                out = Value(
                    jnp.where(ok, v.data, out.data),
                    ok | out.validity(),
                    vals[-1].dictionary,
                )
            return out
        if isinstance(e, Like):
            v = ev(e.expr)
            if v.dictionary is None:
                raise TypeError("LIKE requires a string (dictionary) column")
            mask = jnp.asarray(v.dictionary.like_mask(e.pattern))
            safe = jnp.clip(v.data, 0, len(v.dictionary) - 1)
            return Value(jnp.take(mask, safe), v.valid)
        if isinstance(e, InList):
            v = ev(e.expr)
            acc = None
            for opt in e.options:
                enc = _encode_const_for(v.dictionary, opt)
                c = enc if enc is not None else jnp.asarray(opt)
                hit = v.data == c
                acc = hit if acc is None else (acc | hit)
            return Value(acc, v.valid)
        if isinstance(e, Between):
            v, lo, hi = ev(e.expr), ev(e.lo), ev(e.hi)
            return Value(
                (v.data >= lo.data) & (v.data <= hi.data), _and_valid(v, lo, hi)
            )
        if isinstance(e, Func):
            return _eval_func(e)
        if isinstance(e, ScalarSubquery):
            if ctx.executor is None:
                raise RuntimeError("subquery evaluation requires an executor")
            return ctx.executor.eval_scalar_subquery(e, env, ctx)
        if isinstance(e, Exists):
            if ctx.executor is None:
                raise RuntimeError("subquery evaluation requires an executor")
            return ctx.executor.eval_exists(e, env, ctx)
        if isinstance(e, UdfCall):
            if ctx.executor is None:
                raise RuntimeError(
                    f"UDF {e.name!r} reached the vectorized executor without "
                    "being inlined; run the binder (froid) or the interpreter"
                )
            return ctx.executor.eval_udf_call(e, env, ctx)
        raise TypeError(f"unknown scalar node {type(e).__name__}")

    def _eval_func(e: Func) -> Value:
        args = [ev(a) for a in e.args]
        n = e.name
        if n == "abs":
            return Value(jnp.abs(args[0].data), args[0].valid)
        if n == "floor":
            return Value(jnp.floor(args[0].data), args[0].valid)
        if n == "ceiling":
            return Value(jnp.ceil(args[0].data), args[0].valid)
        if n == "round":
            return Value(jnp.round(args[0].data), args[0].valid)
        if n == "sqrt":
            return Value(jnp.sqrt(jnp.maximum(args[0].data, 0)), args[0].valid)
        if n == "exp":
            return Value(jnp.exp(args[0].data), args[0].valid)
        if n == "log":
            return Value(jnp.log(jnp.maximum(args[0].data, 1e-30)), args[0].valid)
        if n == "power":
            return Value(jnp.power(args[0].data, args[1].data), _and_valid(*args))
        if n == "sign":
            return Value(jnp.sign(args[0].data), args[0].valid)
        if n in ("min2", "least"):
            return Value(jnp.minimum(args[0].data, args[1].data), _and_valid(*args))
        if n in ("max2", "greatest"):
            return Value(jnp.maximum(args[0].data, args[1].data), _and_valid(*args))
        if n == "dateadd":
            part = e.args[0].value  # must be a literal part
            return Value(date_add(part, args[1].data, args[2].data), _and_valid(args[1], args[2]))
        if n == "datepart":
            part = e.args[0].value
            return Value(date_part(part, args[1].data), args[1].valid)
        if n == "datediff_days":
            return Value(
                args[2].data.astype(jnp.int32) - args[1].data.astype(jnp.int32),
                _and_valid(args[1], args[2]),
            )
        raise NotImplementedError(f"intrinsic {n!r}")

    return ev(expr)
