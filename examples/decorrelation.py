"""Decorrelation walkthrough: a correlated scalar subquery rewritten into
a keyed build + join, with parity against the per-row apply and the cost
profile the router uses to know when NOT to bother.

    PYTHONPATH=src python examples/decorrelation.py

The PR-10 optimizer pass in four acts:

  1. A correlated subquery (``SUM(val) over facts WHERE fk = outer.k``)
     naively re-runs its body once per outer row.  ``explain()`` before
     (decorrelation rules disabled) and after: the rewrite turns the
     per-row apply into ONE keyed ``GroupAgg`` build over ``facts``
     left-joined back on the correlation key.
  2. Parity: the rewritten plan answers element-wise exactly like the
     per-row apply — including NULL for outer rows whose binding matches
     no group.  Non-rewritable shapes (non-equi correlation) keep the
     per-row apply, never an error.
  3. Shared-scan materialization: several subqueries over the same body
     share ONE build and ONE join.
  4. The cost model prices both arms honestly — per-row scales with
     outer-N × body, the build with the fact scan + distinct-binding
     cardinality d — so the routing layer's comparison collapses toward
     per-row only when d ≈ N and the body is tiny.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (FROID, Session, col, lit, param, scalar_subquery,
                        scan, sum_)
from repro.core import optimizer as O
from repro.core import relalg as R
from repro.core import scalar as S
from repro.cost.model import estimate_plan

#: the same optimizer stack with only the decorrelation rules removed —
#: the honest "before" arm for both explain() and parity.
PER_ROW_RULES = tuple(r for r in O.DEFAULT_RULES
                      if r not in (O.decorrelate_in_computes,
                                   O.decorrelate_filters))


def fresh(n_facts=512, n_keys=64, domain=7, seed=3):
    db = Session()
    rng = np.random.default_rng(seed)
    db.create_table("facts",
                    fk=rng.integers(0, domain, n_facts),
                    val=rng.normal(size=n_facts).astype(np.float32),
                    qty=rng.integers(0, 9, n_facts))
    db.create_table("keys", k=np.arange(n_keys) % domain)
    return db


def correlated_total(shift=0):
    """Per outer key: total fact val where fk matches (k + shift)."""
    pred = col("fk") == (S.Outer("k") + lit(shift) if shift
                         else S.Outer("k"))
    body = (scan("facts").filter(pred & (col("qty") >= param("minq")))
            .agg(total=sum_(col("val"))))
    return (scan("keys").compute(total=scalar_subquery(body, "total"))
            .project("k", "total"))


def per_row_plan(db, q):
    node = q.node
    wanted = set(R.output_columns(node, db.catalog))
    return O.optimize(node, db.catalog, required=wanted,
                      rules=PER_ROW_RULES)


# ---------------------------------------------------------------- act 1
print("== act 1: explain() before and after the rewrite ==")
db = fresh()
q = correlated_total()
stmt = db.prepare(q, FROID)
print("-- before (decorrelation rules disabled): per-row apply --")
print(O.explain(per_row_plan(db, q)))
print("-- after: keyed build + left join --")
print(stmt.explain())

# ---------------------------------------------------------------- act 2
print("== act 2: parity with the per-row apply ==")
from repro.core.executor import Executor
from repro.core.session import _param_value


def run_per_row(db, q, params):
    return Executor(db.catalog).execute(
        per_row_plan(db, q),
        params={n: _param_value(v) for n, v in params.items()})


def col_of(mt, name):
    """(values, validity) of one column, masked rows excluded."""
    c = mt.table.columns[name]
    valid = np.asarray(c.valid) & np.asarray(mt.mask)
    return np.asarray(c.data), valid


params = {"minq": 4}
dv, dm = col_of(stmt.execute(params=params).masked, "total")
rv, rm = col_of(run_per_row(db, q, params), "total")
assert np.array_equal(dm, rm)
assert np.allclose(np.where(dm, dv, 0.0), np.where(rm, rv, 0.0), atol=1e-5)
print(f"  decorrelated == per-row on {dv.shape[0]} rows "
      f"({int((~dm).sum())} NULLs match too)")

# shifting the key off the fk domain makes missing groups: NULL, like
# the per-row apply aggregating an empty relation
q_miss = correlated_total(shift=3)
s_miss = db.prepare(q_miss, FROID)
gv, gm = col_of(s_miss.execute(params=params).masked, "total")
ev, em = col_of(run_per_row(db, q_miss, params), "total")
assert np.array_equal(gm, em) and (~gm).any()
print(f"  k+3 walks off the fk domain: {int((~gm).sum())} "
      f"missing-group NULLs, identical to per-row")

# non-equi correlation is not rewritable: the per-row apply stays, the
# answer is still right
q_ne = (scan("keys")
        .compute(total=scalar_subquery(
            scan("facts").filter(col("fk") <= S.Outer("k"))
            .agg(total=sum_(col("val"))), "total"))
        .project("k", "total"))
s_ne = db.prepare(q_ne, FROID)
assert "Join[left]" not in s_ne.explain()
print(f"  non-equi body kept per-row (no join in explain), "
      f"still answers: {s_ne.execute().table.num_rows} rows")

# ---------------------------------------------------------------- act 3
print("== act 3: shared-scan materialization ==")


def body():
    return (scan("facts").filter(col("fk") == S.Outer("k"))
            .agg(s=sum_(col("val"))))


q3 = (scan("keys")
      .compute(a=scalar_subquery(body(), "s"),
               b=scalar_subquery(body(), "s") * lit(2.0),
               c=scalar_subquery(body(), "s") + lit(1.0))
      .project("k", "a", "b", "c"))
s3 = db.prepare(q3, FROID)
joins = [n for n in R.walk_plan(s3.plan) if isinstance(n, R.Join)]
builds = [n for n in R.walk_plan(s3.plan)
          if isinstance(n, R.GroupAgg) and n.keys]
print(f"  3 subqueries over one body -> {len(builds)} build, "
      f"{len(joins)} join")

# ---------------------------------------------------------------- act 4
print("== act 4: the router's arm comparison, two regimes ==")


def arms(db, q):
    node = q.node
    wanted = set(R.output_columns(node, db.catalog))
    dec = O.optimize(node, db.catalog, required=wanted)
    row = O.optimize(node, db.catalog, required=wanted, rules=PER_ROW_RULES)
    return estimate_plan(dec, db.catalog), estimate_plan(row, db.catalog)


# regime A: N=1024 outer rows, d=7 distinct bindings, 4096-row body —
# the decorrelated build is cheaper by an algorithmic margin
big = fresh(n_facts=4096, n_keys=1024, domain=7)
e_dec, e_row = arms(big, correlated_total())
print(f"  d=7 << N=1024:  per-row {e_row.flops:.2e} flops vs "
      f"decorrelated {e_dec.flops:.2e}  "
      f"({e_row.flops / e_dec.flops:.0f}x apart)")

# regime B: every binding distinct (d == N) over a tiny body — the
# margin collapses; this is where ROUTED keeps the per-row arm
tiny = Session()
tiny.create_table("facts",
                  fk=np.arange(8),
                  val=np.ones(8, np.float32),
                  qty=np.zeros(8, np.int64))
tiny.create_table("keys", k=np.arange(8))
e_dec, e_row = arms(tiny, correlated_total())
ratio = e_row.flops / e_dec.flops
print(f"  d == N == 8, 8-row body:  per-row {e_row.flops:.2e} flops vs "
      f"decorrelated {e_dec.flops:.2e}  ({ratio:.1f}x)")
print("  the margin is what the cost router consumes: three orders of "
      "magnitude at d << N, collapsing toward parity (where the fixed "
      "dispatch overhead dominates and per-row is kept) as d -> N")
