"""Static cost estimates per (plan, configuration).

The estimator walks a bound plan bottom-up carrying textbook cardinality
rules (catalog row counts at the leaves, fixed selectivities at the
predicates) and accumulates a roofline-style work profile — scalar flops
and bytes touched — which :class:`PlanProfile.seconds` turns into a time
estimate using the hardware constants from ``repro.launch.roofline``
(``PEAK_FLOPS`` / ``HBM_BW``).  Absolute numbers are nominal for the
accelerator target, not this host; the router only ever compares
estimates against each other (and hands control to measured wave costs as
soon as they exist), so the *ratios* are what matter:

* a per-row interpreted UDF call costs a large per-row penalty relative
  to inlined arithmetic — the FROID-vs-HEKATON axis;
* a cold configuration pays an estimated compile cost proportional to
  plan size, dwarfing one wave of padded compute — the ride-a-warm-bucket
  axis;
* every dispatched program pays a fixed launch overhead — the
  fuse-or-not axis (one fused program saves per-statement dispatches).

Estimates are intentionally cheap (one memoizable plan walk, no tracing,
no device work) so the router can consult them on the prepare/dispatch
path.
"""
from __future__ import annotations

import dataclasses

from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.executor import _plan_outer_refs
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

#: fixed launch cost of one device program dispatch (host → runtime →
#: device round trip); the term fusion amortizes
DISPATCH_OVERHEAD_S = 50e-6

#: estimated jit/compile seconds per plan node — the cold-configuration
#: penalty (riding an already-compiled larger bucket beats compiling a
#: fresh one unless the padded compute is enormous)
COMPILE_S_PER_NODE = 3e-3

#: flops charged per surviving row for a UDF call the plan interprets
#: per-row (HEKATON-style scan-mode evaluation) instead of inlining
UDF_CALL_ROW_FLOPS = 256.0

#: filter selectivity when no statistics apply (System-R's 1/3)
FILTER_SELECTIVITY = 0.33

#: join output selectivity over the cross product
JOIN_SELECTIVITY = 0.1

#: distinct-group guess for aggregations without key statistics
GROUP_CARDINALITY = 64.0

#: fallback table cardinality when the scanned name is not in the catalog
DEFAULT_TABLE_ROWS = 1024.0

_BYTES_PER_CELL = 4.0  # engine dtypes are int32/float32/bool


@dataclasses.dataclass(frozen=True)
class PlanProfile:
    """Estimated work of one plan execution: output cardinality plus the
    roofline terms accumulated over the whole tree."""

    rows: float
    flops: float
    bytes: float
    nodes: int

    def seconds(self, devices: int = 1) -> float:
        d = max(1, devices)
        return max(self.flops / (d * PEAK_FLOPS),
                   self.bytes / (d * HBM_BW)) + DISPATCH_OVERHEAD_S


def _expr_ops(e: S.Scalar) -> tuple[float, int]:
    """(scalar ops per row, UDF calls per row) of one expression tree."""
    ops, udfs = 0.0, 0
    for sub in S.walk(e):
        ops += 1.0
        if isinstance(sub, S.UdfCall):
            udfs += 1
    return ops, udfs


def _node_exprs_cost(node: R.RelNode, rows: float) -> float:
    """Flops this node's own expressions add at cardinality ``rows``."""
    flops = 0.0
    for e in node.exprs():
        ops, udfs = _expr_ops(e)
        flops += rows * (ops + udfs * UDF_CALL_ROW_FLOPS)
    return flops


def estimate_plan(plan: R.RelNode, catalog) -> PlanProfile:
    """Bottom-up work profile of ``plan`` against ``catalog`` (a name →
    Table mapping; only ``num_rows``/column counts are read).  Unknown
    node types pass their child cardinality through and charge one op per
    row, so a new operator degrades the estimate, never the walk."""
    kids = [estimate_plan(c, catalog) for c in plan.children()]
    embedded = [(p, estimate_plan(p, catalog)) for p in R.embedded_plans(plan)]
    flops = sum(k.flops for k in kids)
    bytes_ = sum(k.bytes for k in kids)
    nodes = 1 + sum(k.nodes for k in kids) + sum(e.nodes for _, e in embedded)
    in_rows = kids[0].rows if kids else 1.0
    for p, e in embedded:
        if _plan_outer_refs(p):
            # correlated subquery the optimizer left in place: the per-row
            # apply re-runs the body once per consuming row (vmap), so work
            # and reads scale with this node's input cardinality — the
            # honest price the decorrelated alternative (one keyed build of
            # ~distinct-binding rows + a join) is compared against
            flops += in_rows * max(1.0, e.flops)
            bytes_ += in_rows * e.bytes
        else:
            flops += e.flops
            bytes_ += e.bytes

    name = type(plan).__name__
    if name == "Scan":
        t = catalog.get(getattr(plan, "table", None)) if catalog else None
        rows = float(t.num_rows) if t is not None else DEFAULT_TABLE_ROWS
        ncols = len(t.columns) if t is not None else 4
        bytes_ += rows * ncols * _BYTES_PER_CELL
    elif name == "ConstantScan":
        rows = 1.0
    elif name == "Filter":
        flops += _node_exprs_cost(plan, in_rows)
        rows = max(1.0, in_rows * FILTER_SELECTIVITY)
    elif name == "Compute":
        flops += _node_exprs_cost(plan, in_rows)
        rows = in_rows
        bytes_ += in_rows * len(getattr(plan, "computed", ())) * _BYTES_PER_CELL
    elif name == "Project":
        rows = in_rows
    elif name == "Join":
        l = kids[0].rows if kids else 1.0
        r = kids[1].rows if len(kids) > 1 else 1.0
        # the executor lowers to gather / sort-merge, not a cross product:
        # charge sort-ish work on both sides, not l*r
        flops += (l + r) * 8.0
        rows = max(1.0, l * max(1.0, r * JOIN_SELECTIVITY / max(r, 1.0)))
        if plan.kind in ("inner", "left"):
            rows = l if plan.kind == "left" else max(1.0, l * JOIN_SELECTIVITY)
    elif name == "GroupAgg":
        naggs = max(1, len(getattr(plan, "aggs", ()) or ()))
        flops += in_rows * naggs * 2.0 + _node_exprs_cost(plan, in_rows)
        if getattr(plan, "keys", None):
            # distinct-binding cardinality: statistics-derived capacity
            # (annotate_group_stats) when present, else the System-R guess.
            # This is what prices a decorrelated build: d distinct bindings
            # flow into the join, so per-row wins only when d ≈ N and the
            # body is tiny.
            cap = getattr(plan, "capacity", None)
            rows = min(in_rows, float(cap) if cap else GROUP_CARDINALITY)
        else:
            rows = 1.0
    elif name == "Sort":
        flops += in_rows * 16.0
        rows = in_rows
    elif name == "LoopScan":
        # a rewritten cursor loop folds/scans the driving relation once
        flops += in_rows * 8.0 + _node_exprs_cost(plan, in_rows)
        rows = 1.0
    elif name == "Apply":
        # correlated apply re-evaluates the inner side per outer row in
        # the relational semantics; the vectorized executor batches it,
        # but the work still scales with the outer cardinality
        inner = kids[1] if len(kids) > 1 else (
            embedded[0][1] if embedded else None)
        if inner is not None:
            flops += in_rows * max(1.0, inner.flops / max(inner.rows, 1.0))
        rows = in_rows
    else:
        flops += _node_exprs_cost(plan, in_rows) + in_rows
        rows = max(1.0, in_rows)
    return PlanProfile(rows, flops, bytes_, nodes)


def estimate_node_s(node: R.RelNode, catalog) -> float:
    """Per-execution seconds of one subtree — the chunking weight the
    cost-aware fusion splitter uses (a shared aggregate over a big scan is
    worth more overlap than a shared literal filter)."""
    return estimate_plan(node, catalog).seconds()


def estimate_statement_s(plan: R.RelNode, catalog, *, bucket: int = 1,
                         devices: int = 1) -> float:
    """Per-wave seconds for ``bucket`` stacked executions of ``plan``
    spread over ``devices`` data-parallel shards."""
    p = estimate_plan(plan, catalog)
    return PlanProfile(p.rows, p.flops * bucket, p.bytes * bucket,
                       p.nodes).seconds(devices)


def estimate_compile_s(plan: R.RelNode) -> float:
    """Estimated one-time jit cost of specializing ``plan`` for a new
    configuration (bucket/signature/shard layout)."""
    return R.plan_size(plan) * COMPILE_S_PER_NODE
