"""Set-oriented, vectorized plan executor.

Design (TPU adaptation of the paper's set-oriented plans, DESIGN.md §2):

* **Selection vectors, not compaction** — a plan value is a
  :class:`MaskedTable` (full-width columns + bool row mask).  Filters AND
  into the mask; no operator has a data-dependent output shape, so whole
  plans trace under ``jax.jit`` / ``vmap`` (which is how correlated Apply
  falls back to vectorized evaluation instead of a row loop).
* **Joins** — sort + ``searchsorted`` (sort-merge) on the build side; the
  build side must be key-unique (dimension semantics).  No hash tables: TPU
  sorts are fast, random scatter is not.
* **Group-by** — sort-based segmenting + ``jax.ops.segment_sum`` with a
  *static* group capacity (default: the row count), or the fused Pallas
  ``relagg`` kernel for the single-pass filter+project+aggregate hot path.
* **CSE for free** — node results are memoized per execution, which is the
  relational version of common-subexpression elimination (paper §6).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import relalg as R
from repro.core import scalar as S
from repro.tables.table import Column, Table

_F32_MAX = jnp.finfo(jnp.float32).max
_I32_MAX = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass
class MaskedTable:
    table: Table
    mask: jnp.ndarray  # bool (n,)

    @property
    def num_rows(self) -> int:
        return int(self.mask.shape[0])

    def env(self) -> dict[str, S.Value]:
        return {
            n: S.Value(c.data, c.valid, c.dictionary)
            for n, c in self.table.columns.items()
        }

    def compact(self) -> Table:
        """Host-side materialization of selected rows (not jit-safe; used
        only at result-delivery time)."""
        import numpy as np

        idx = np.nonzero(np.asarray(self.mask))[0]
        return self.table.gather(jnp.asarray(idx))


def _value_to_column(v: S.Value, n: int) -> Column:
    b = v.broadcast(n)
    return Column(b.data, b.valid, b.dictionary)


def _scalar_value(v: S.Value) -> S.Value:
    """Coerce a Value to scalar (shape ``()``) leaves — loop-carry state
    is rank-0 regardless of how broadcasting shaped the evaluation."""
    d = jnp.asarray(v.data)
    if d.ndim > 0:
        d = d.reshape(-1)[0]
    val = jnp.asarray(v.validity())
    if val.ndim > 0:
        val = val.reshape(-1)[0]
    return S.Value(d, val, v.dictionary)


def _sort_key_for(col: Column, mask: jnp.ndarray) -> jnp.ndarray:
    """Key array with masked/NULL rows pushed to the end (+inf sentinel)."""
    data = col.data
    ok = mask & col.validity()
    if jnp.issubdtype(data.dtype, jnp.floating):
        return jnp.where(ok, data, _F32_MAX)
    return jnp.where(ok, data.astype(jnp.int32), _I32_MAX)


def _union_dense_rank(left: "MaskedTable", right: "MaskedTable", on):
    """Composite-key equality via one synthetic int32 key per side.

    Lexicographically sorts the *union* of both sides' key tuples
    (stable argsort composition, least-significant key first), marks run
    boundaries, and cumsums them into dense group ids — equal tuples get
    equal ids regardless of side, so the ordinary single-key sort-merge
    applies.  Rows with any masked/NULL key component map to the int32
    sentinel and never match (matching single-key NULL semantics)."""
    nl = left.num_rows
    n = nl + right.num_rows
    parts = []
    lvalid = left.mask
    rvalid = right.mask
    for lc, rc in on:
        lk = left.table.columns[lc]
        rk = right.table.columns[rc]
        lvalid = lvalid & lk.validity()
        rvalid = rvalid & rk.validity()
        parts.append(jnp.concatenate([
            _sort_key_for(lk, left.mask), _sort_key_for(rk, right.mask),
        ]))
    order = jnp.arange(n)
    for u in reversed(parts):
        order = jnp.take(order, jnp.argsort(jnp.take(u, order), stable=True))
    newgrp = jnp.zeros((n,), bool).at[0].set(True)
    for u in parts:
        su = jnp.take(u, order)
        newgrp = newgrp | (su != jnp.roll(su, 1)).at[0].set(True)
    gid = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.cumsum(newgrp.astype(jnp.int32)) - 1
    )
    lkeys = jnp.where(lvalid, gid[:nl], _I32_MAX)
    rkeys = jnp.where(rvalid, gid[nl:], _I32_MAX)
    return lkeys, rkeys


class Executor:
    """Evaluates relational plans over a catalog of named Tables."""

    def __init__(
        self,
        catalog: dict[str, Table],
        udf_column_evaluator: Callable | None = None,
        use_pallas_agg: bool = False,
    ):
        self.catalog = catalog
        # froid-OFF hook: computes a whole column by iterating the UDF per
        # row (repro.core.interpreter wires this in)
        self.udf_column_evaluator = udf_column_evaluator
        self.use_pallas_agg = use_pallas_agg
        self._stats = {"bytes_scanned": 0, "rows_scanned": 0}

    @property
    def stats(self) -> dict:
        """Logical-read counters (copy; accumulates across executions)."""
        return dict(self._stats)

    def _sub_executor(self) -> "Executor":
        """Executor used for nested plan evaluation (correlated applies,
        scalar subqueries, EXISTS).  Subclasses override to propagate extra
        state — the fused SharedScanExecutor carries its shared-result
        pools into subquery bodies this way."""
        return Executor(self.catalog, self.udf_column_evaluator,
                        self.use_pallas_agg)

    # -- public API --------------------------------------------------------
    def execute(self, plan: R.RelNode, params=None, outer=None, vars=None) -> MaskedTable:
        ctx = S.EvalContext(
            executor=self, params=params or {}, outer=outer or {}, vars=vars or {}
        )
        memo: dict[int, MaskedTable] = {}
        return self._exec(plan, ctx, memo)

    # -- node dispatch -----------------------------------------------------
    def _exec(self, node: R.RelNode, ctx, memo) -> MaskedTable:
        key = node.node_id
        if key in memo:
            return memo[key]
        out = self._exec_node(node, ctx, memo)
        memo[key] = out
        return out

    def _exec_node(self, node: R.RelNode, ctx, memo) -> MaskedTable:
        if isinstance(node, R.Scan):
            t = self.catalog[node.table]
            self._stats["bytes_scanned"] += t.nbytes()
            self._stats["rows_scanned"] += t.num_rows
            n = t.num_rows
            return MaskedTable(t, jnp.ones((n,), bool))

        if isinstance(node, R.ConstantScan):
            return MaskedTable(Table({}), jnp.ones((1,), bool))

        if isinstance(node, R.Compute):
            child = self._exec(node.child, ctx, memo)
            n = child.num_rows
            env = child.env()
            cctx = S.EvalContext(self, n, ctx.params, ctx.outer, ctx.vars)
            cctx.row_mask = child.mask  # for subquery short-circuits
            table = child.table
            for name, expr in node.computed.items():
                v = S.eval_scalar(expr, env, cctx)
                col = _value_to_column(v, n)
                table = table.with_column(name, col)
                env[name] = S.Value(col.data, col.valid, col.dictionary)
            return MaskedTable(table, child.mask)

        if isinstance(node, R.Project):
            child = self._exec(node.child, ctx, memo)
            cols = {new: child.table.columns[old] for new, old in node.cols.items()}
            return MaskedTable(Table(cols), child.mask)

        if isinstance(node, R.Filter):
            child = self._exec(node.child, ctx, memo)
            cctx = S.EvalContext(self, child.num_rows, ctx.params, ctx.outer, ctx.vars)
            cctx.row_mask = child.mask
            v = S.eval_scalar(node.pred, child.env(), cctx)
            b = v.broadcast(child.num_rows)
            pred = b.data.astype(bool) & b.validity()  # NULL -> false
            return MaskedTable(child.table, child.mask & pred)

        if isinstance(node, R.Join):
            return self._exec_join(node, ctx, memo)

        if isinstance(node, R.Apply):
            return self._exec_apply(node, ctx, memo)

        if isinstance(node, R.GroupAgg):
            return self._exec_groupagg(node, ctx, memo)

        if isinstance(node, R.LoopScan):
            return self._exec_loopscan(node, ctx, memo)

        if isinstance(node, R.Sort):
            child = self._exec(node.child, ctx, memo)
            n = child.num_rows
            order = jnp.arange(n)
            for colname, asc in reversed(node.keys):
                col = child.table.columns[colname]
                k = _sort_key_for(col, child.mask)
                k = jnp.take(k, order)
                if not asc:
                    if jnp.issubdtype(k.dtype, jnp.floating):
                        k = jnp.where(k == _F32_MAX, k, -k)
                    else:
                        k = jnp.where(k == _I32_MAX, k, -k)
                order = jnp.take(order, jnp.argsort(k, stable=True))
            # push masked-out rows last regardless of key values
            mask_sorted = jnp.take(child.mask, order)
            order = jnp.take(order, jnp.argsort(~mask_sorted, stable=True))
            t = child.table.gather(order)
            m = jnp.take(child.mask, order)
            if node.limit is not None:
                keep = jnp.arange(n) < node.limit
                m = m & keep
            return MaskedTable(t, m)

        raise TypeError(f"unknown plan node {type(node).__name__}")

    # -- join --------------------------------------------------------------
    def _exec_join(self, node: R.Join, ctx, memo) -> MaskedTable:
        left = self._exec(node.left, ctx, memo)
        right = self._exec(node.right, ctx, memo)

        if len(node.on) == 1:
            lcol, rcol = node.on[0]
            lkeys = _sort_key_for(left.table.columns[lcol], left.mask)
            rkeys = _sort_key_for(right.table.columns[rcol], right.mask)
        else:
            # composite keys: dense-rank the union of both sides' key
            # tuples into one synthetic int32 key, then merge as usual
            lkeys, rkeys = _union_dense_rank(left, right, node.on)
        perm = jnp.argsort(rkeys, stable=True)
        sorted_keys = jnp.take(rkeys, perm)

        pos = jnp.searchsorted(sorted_keys, lkeys)
        pos = jnp.clip(pos, 0, sorted_keys.shape[0] - 1)
        hit = (jnp.take(sorted_keys, pos) == lkeys) & (lkeys != _key_sentinel(lkeys))
        ridx = jnp.take(perm, pos)

        if node.kind == "semi":
            return MaskedTable(left.table, left.mask & hit)
        if node.kind == "anti":
            return MaskedTable(left.table, left.mask & ~hit)

        rgathered = right.table.gather(ridx, valid=hit)
        cols = dict(left.table.columns)
        shared = {rc for lc, rc in node.on if lc == rc}
        rkeycols = {rc for _, rc in node.on}
        for name, col in rgathered.columns.items():
            if name in shared:
                continue
            if name in cols and name not in rkeycols:
                raise ValueError(f"join column collision: {name}")
            cols[name] = col
        mask = left.mask & hit if node.kind == "inner" else left.mask
        return MaskedTable(Table(cols), mask)

    # -- apply -------------------------------------------------------------
    def _exec_apply(self, node: R.Apply, ctx, memo) -> MaskedTable:
        left = self._exec(node.left, ctx, memo)
        n = left.num_rows
        correlated = _plan_has_outer(node.right)

        if not correlated:
            right = self._exec(node.right, ctx, memo)
            if right.num_rows != 1:
                raise NotImplementedError("uncorrelated Apply with multi-row right")
            cols = dict(left.table.columns)
            rvalid = right.mask[0]
            for name, c in right.table.columns.items():
                data = jnp.broadcast_to(c.data[0], (n,) + c.data.shape[1:])
                valid = jnp.broadcast_to(c.validity()[0] & rvalid, (n,))
                cols[name] = Column(data, valid, c.dictionary)
            return MaskedTable(Table(cols), left.mask)

        # Correlated right side rooted at ConstantScan (the algebrizer's
        # region derived-tables): evaluate its Computes directly against the
        # left columns — this is exactly "apply removal" performed at
        # execution time, fully vectorized.
        if _is_scalar_region(node.right):
            return self._exec_region_apply(node, left, ctx, memo)

        # Generic correlated apply: vmap the right plan over left rows.
        return self._exec_vmap_apply(node, left, ctx, memo)

    def _exec_region_apply(self, node, left: MaskedTable, ctx, memo) -> MaskedTable:
        """Vectorized evaluation of a single-row derived table (an algebrized
        region) against every left row at once: Outer(c) binds to the left
        column c, ColRef(c) binds to region-local computed columns.  This is
        the set-oriented execution of ``Apply`` — no per-row loop exists."""
        n = left.num_rows
        chain: list[R.RelNode] = []
        cur = node.right
        while isinstance(cur, (R.Compute, R.Project)):
            chain.append(cur)
            cur = cur.child
        assert isinstance(cur, R.ConstantScan)

        pt = None
        if node.passthrough is not None:
            v = S.eval_scalar(
                node.passthrough,
                left.env(),
                S.EvalContext(self, n, ctx.params, ctx.outer, ctx.vars),
            )
            b = v.broadcast(n)
            pt = b.data.astype(bool) & b.validity()

        outer = {**ctx.outer, **left.env()}
        env: dict[str, S.Value] = {}
        cctx = S.EvalContext(self, n, ctx.params, outer, ctx.vars)
        cctx.row_mask = left.mask
        for nd in reversed(chain):
            if isinstance(nd, R.Compute):
                for name, expr in nd.computed.items():
                    env[name] = S.eval_scalar(expr, env, cctx).broadcast(n)
            else:  # Project
                env = {new: env[old] for new, old in nd.cols.items()}

        cols = dict(left.table.columns)
        for name, v in env.items():
            b = v.broadcast(n)
            valid = b.validity()
            if pt is not None:  # pass-through rows keep NULL right side
                valid = valid & ~pt
            cols[name] = Column(b.data, valid, b.dictionary)
        return MaskedTable(Table(cols), left.mask)

    def _exec_vmap_apply(self, node, left: MaskedTable, ctx, memo) -> MaskedTable:
        n = left.num_rows
        lenv = left.env()
        names = list(lenv)
        dicts = {m: lenv[m].dictionary for m in names}

        captured_dicts: dict = {}
        # hoisted: executor state is row-independent, so building it inside
        # the traced closure would rebuild it once per traced row
        sub = self._sub_executor()

        def one_row(scalars):
            outer = {
                m: S.Value(scalars[m][0], scalars[m][1], dicts[m]) for m in names
            }
            outer = {**ctx.outer, **outer}
            res = sub.execute(node.right, params=ctx.params, outer=outer, vars=ctx.vars)
            out = {}
            for cname, c in res.table.columns.items():
                found = jnp.any(res.mask)
                idx = jnp.argmax(res.mask)
                captured_dicts[cname] = c.dictionary  # host metadata
                out[cname] = (
                    jnp.take(c.data, idx, axis=0),
                    jnp.take(c.validity(), idx) & found,
                )
            out["__exists"] = (jnp.any(res.mask), jnp.ones((), bool))
            return out

        args = {
            m: (lenv[m].broadcast(n).data, lenv[m].broadcast(n).validity())
            for m in names
        }
        mapped = jax.vmap(one_row)(args)

        if node.kind == "semi":
            return MaskedTable(left.table, left.mask & mapped["__exists"][0])
        if node.kind == "anti":
            return MaskedTable(left.table, left.mask & ~mapped["__exists"][0])

        cols = dict(left.table.columns)
        for cname, (data, valid) in mapped.items():
            if cname == "__exists":
                continue
            cols[cname] = Column(data, valid, captured_dicts.get(cname))
        mask = left.mask
        if node.kind == "cross":
            mask = mask & mapped["__exists"][0]
        return MaskedTable(Table(cols), mask)

    # -- group-by ----------------------------------------------------------
    def _exec_groupagg(self, node: R.GroupAgg, ctx, memo) -> MaskedTable:
        child = self._exec(node.child, ctx, memo)
        n = child.num_rows
        env = child.env()
        cctx = S.EvalContext(self, n, ctx.params, ctx.outer, ctx.vars)

        # Pre-evaluate aggregate input expressions (vectorized).
        agg_inputs: dict[str, S.Value] = {}
        for name, spec in node.aggs.items():
            if spec.expr is not None:
                agg_inputs[name] = S.eval_scalar(spec.expr, env, cctx).broadcast(n)

        if n == 0:
            # zero-row child (empty table or statically-empty scan): pad to
            # one all-invalid row so the reductions below keep a nonzero
            # static extent (jnp.min/.at[0] reject size 0).  The pad row is
            # masked out, so aggregates see no data and every group slot
            # comes back unoccupied — same results as a masked-empty input.
            child = MaskedTable(
                Table({
                    c: Column(
                        jnp.zeros((1,) + tuple(cc.data.shape[1:]), cc.data.dtype),
                        jnp.zeros((1,), bool), cc.dictionary,
                    )
                    for c, cc in child.table.columns.items()
                }),
                jnp.zeros((1,), bool),
            )
            agg_inputs = {
                name: S.Value(
                    jnp.zeros((1,) + tuple(v.data.shape[1:]), v.data.dtype),
                    jnp.zeros((1,), bool), v.dictionary,
                )
                for name, v in agg_inputs.items()
            }
            n = 1

        if not node.keys:
            # full-table aggregate -> single row
            cols = {}
            for name, spec in node.aggs.items():
                cols[name] = _full_agg(spec.fn, agg_inputs.get(name), child.mask)
            return MaskedTable(Table(cols), jnp.ones((1,), bool))

        # batch-mode path (paper §8.2.6): single dictionary/dense-int key and
        # matmul-friendly aggregates -> fused relagg Pallas kernel (one-hot ×
        # MXU partial aggregation; no sort)
        if self.use_pallas_agg and len(node.keys) == 1:
            out = self._try_relagg(node, child, agg_inputs)
            if out is not None:
                return out

        # stats-driven dense-key path (§Perf hillclimb 3): key densely
        # covers [lo, hi] -> gid = key - lo segmenting, NO sort
        if node.dense_range is not None and len(node.keys) == 1:
            out = self._dense_groupagg(node, child, agg_inputs)
            if out is not None:
                return out

        # sort-based grouping with static capacity
        cap = node.capacity or n
        order = jnp.arange(n)
        for k in reversed(node.keys):
            keys = _sort_key_for(child.table.columns[k], child.mask)
            keys = jnp.take(keys, order)
            order = jnp.take(order, jnp.argsort(keys, stable=True))
        mask_o = jnp.take(child.mask, order)
        order = jnp.take(order, jnp.argsort(~mask_o, stable=True))
        mask_o = jnp.take(child.mask, order)

        sorted_keys = [
            jnp.take(_sort_key_for(child.table.columns[k], child.mask), order)
            for k in node.keys
        ]
        newgrp = jnp.zeros((n,), bool).at[0].set(True)
        for sk in sorted_keys:
            newgrp = newgrp | (sk != jnp.roll(sk, 1)).at[0].set(True)
        newgrp = newgrp & mask_o
        gid = jnp.cumsum(newgrp.astype(jnp.int32)) - 1
        gid = jnp.where(mask_o, jnp.clip(gid, 0, cap - 1), cap)  # overflow slot

        num_groups = jnp.max(jnp.where(mask_o, gid, -1)) + 1
        occupied = jnp.arange(cap) < num_groups

        cols: dict[str, Column] = {}
        ones = jnp.ones((n,), jnp.float32)
        for kname in node.keys:
            kc = child.table.columns[kname]
            kdata = jnp.take(kc.data, order)
            if jnp.issubdtype(kdata.dtype, jnp.floating):
                fill = jnp.asarray(-jnp.inf, kdata.dtype)
            else:
                fill = jnp.asarray(jnp.iinfo(kdata.dtype).min, kdata.dtype)
            slot = jax.ops.segment_max(
                jnp.where(mask_o, kdata, fill), gid, num_segments=cap + 1
            )[:cap]
            cols[kname] = Column(slot, occupied, kc.dictionary)

        for name, spec in node.aggs.items():
            if spec.fn == "count_star":
                cnt = jax.ops.segment_sum(
                    jnp.where(mask_o, ones, 0.0), gid, num_segments=cap + 1
                )[:cap]
                cols[name] = Column(cnt.astype(jnp.int32), occupied)
                continue
            v = agg_inputs[name]
            data = jnp.take(v.data, order)
            vvalid = jnp.take(v.validity(), order) & mask_o
            if spec.fn in ("sum", "avg", "count"):
                s = jax.ops.segment_sum(
                    jnp.where(vvalid, data.astype(jnp.float32), 0.0),
                    gid,
                    num_segments=cap + 1,
                )[:cap]
                c = jax.ops.segment_sum(
                    jnp.where(vvalid, 1.0, 0.0), gid, num_segments=cap + 1
                )[:cap]
                if spec.fn == "sum":
                    cols[name] = Column(s, occupied & (c > 0))
                elif spec.fn == "count":
                    cols[name] = Column(c.astype(jnp.int32), occupied)
                else:
                    cols[name] = Column(
                        s / jnp.where(c == 0, 1.0, c), occupied & (c > 0)
                    )
            elif spec.fn in ("min", "max"):
                seg = jax.ops.segment_min if spec.fn == "min" else jax.ops.segment_max
                sent = jnp.inf if spec.fn == "min" else -jnp.inf
                m = seg(
                    jnp.where(vvalid, data.astype(jnp.float32), sent),
                    gid,
                    num_segments=cap + 1,
                )[:cap]
                any_v = (
                    jax.ops.segment_sum(
                        jnp.where(vvalid, 1.0, 0.0), gid, num_segments=cap + 1
                    )[:cap]
                    > 0
                )
                cols[name] = Column(m, occupied & any_v)
            else:
                raise NotImplementedError(spec.fn)
        return MaskedTable(Table(cols), occupied)

    def _dense_groupagg(self, node: R.GroupAgg, child: MaskedTable, agg_inputs):
        """Sort-free grouped aggregation for a dense int key range
        [lo, hi]: gid = key - lo, segment ops sized to the range."""
        key = node.keys[0]
        kc = child.table.columns[key]
        if not jnp.issubdtype(kc.dtype, jnp.integer):
            return None
        lo, hi = node.dense_range
        cap = hi - lo + 1
        n = child.num_rows
        gid = kc.data.astype(jnp.int32) - lo
        inside = (gid >= 0) & (gid < cap) & child.mask & kc.validity()
        gid = jnp.where(inside, gid, cap)  # overflow slot

        cols: dict[str, Column] = {}
        cnt_rows = jax.ops.segment_sum(
            inside.astype(jnp.float32), gid, num_segments=cap + 1
        )[:cap]
        occupied = cnt_rows > 0
        cols[key] = Column(
            (jnp.arange(cap, dtype=jnp.int32) + lo).astype(kc.data.dtype),
            occupied,
            kc.dictionary,
        )
        for name, spec in node.aggs.items():
            if spec.fn == "count_star":
                cols[name] = Column(cnt_rows.astype(jnp.int32), occupied)
                continue
            v = agg_inputs[name]
            vvalid = v.validity() & inside
            data = v.data
            if spec.fn in ("sum", "avg", "count"):
                s = jax.ops.segment_sum(
                    jnp.where(vvalid, data.astype(jnp.float32), 0.0),
                    gid, num_segments=cap + 1,
                )[:cap]
                c = jax.ops.segment_sum(
                    jnp.where(vvalid, 1.0, 0.0), gid, num_segments=cap + 1
                )[:cap]
                if spec.fn == "sum":
                    cols[name] = Column(s, occupied & (c > 0))
                elif spec.fn == "count":
                    cols[name] = Column(c.astype(jnp.int32), occupied)
                else:
                    cols[name] = Column(
                        s / jnp.where(c == 0, 1.0, c), occupied & (c > 0)
                    )
            elif spec.fn in ("min", "max"):
                seg = jax.ops.segment_min if spec.fn == "min" else jax.ops.segment_max
                sent = jnp.inf if spec.fn == "min" else -jnp.inf
                m = seg(
                    jnp.where(vvalid, data.astype(jnp.float32), sent),
                    gid, num_segments=cap + 1,
                )[:cap]
                any_v = jax.ops.segment_sum(
                    jnp.where(vvalid, 1.0, 0.0), gid, num_segments=cap + 1
                )[:cap] > 0
                cols[name] = Column(m, occupied & any_v)
            else:
                return None
        return MaskedTable(Table(cols), occupied)

    def _try_relagg(self, node: R.GroupAgg, child: MaskedTable, agg_inputs):
        """Fused group-by via the relagg kernel.  Applicable when the key is
        dictionary-encoded (G = vocab size) or a capacity hint bounds a
        non-negative int key, and all aggs are sum/avg/count/count_star."""
        from repro.kernels.relagg.ops import grouped_aggregate

        key = node.keys[0]
        kc = child.table.columns[key]
        if kc.dictionary is not None:
            G = len(kc.dictionary)
        elif node.capacity is not None and jnp.issubdtype(kc.dtype, jnp.integer):
            G = int(node.capacity)
        else:
            return None
        if not all(a.fn in ("sum", "avg", "count", "count_star")
                   for a in node.aggs.values()):
            return None

        n = child.num_rows
        mask = child.mask & kc.validity() & (kc.data >= 0) & (kc.data < G)
        cols_spec: list[tuple[str, str, int, int]] = []  # (name, fn, vi, ci)
        mats = []
        for name, spec in node.aggs.items():
            if spec.fn == "count_star":
                cols_spec.append((name, spec.fn, -1, -1))
                continue
            v = agg_inputs[name]
            vv = v.validity()
            data = jnp.where(vv, v.data.astype(jnp.float32), 0.0)
            mats.append(data)
            vi = len(mats) - 1
            mats.append(jnp.where(vv, 1.0, 0.0))  # per-agg valid count
            cols_spec.append((name, spec.fn, vi, vi + 1))
        vals = (
            jnp.stack(mats, axis=1)
            if mats
            else jnp.zeros((n, 1), jnp.float32)
        )
        sums, counts = grouped_aggregate(
            kc.data.astype(jnp.int32), mask, vals, G
        )
        occupied = counts > 0
        out_cols: dict[str, Column] = {
            key: Column(jnp.arange(G, dtype=kc.data.dtype), occupied, kc.dictionary)
        }
        for name, fn, vi, ci in cols_spec:
            if fn == "count_star":
                out_cols[name] = Column(counts.astype(jnp.int32), occupied)
            elif fn == "count":
                out_cols[name] = Column(sums[:, ci].astype(jnp.int32), occupied)
            elif fn == "sum":
                out_cols[name] = Column(sums[:, vi], occupied & (sums[:, ci] > 0))
            else:  # avg
                c = sums[:, ci]
                out_cols[name] = Column(
                    sums[:, vi] / jnp.where(c == 0, 1.0, c),
                    occupied & (c > 0),
                )
        return MaskedTable(Table(out_cols), occupied)

    # -- loop scan (rewritten cursor loops, repro.loops) --------------------
    def _exec_loopscan(self, node: R.LoopScan, ctx, memo) -> MaskedTable:
        child = self._exec(node.child, ctx, memo)
        n = child.num_rows
        ictx = S.EvalContext(self, 1, ctx.params, ctx.outer, ctx.vars)
        init = {
            name: _scalar_value(S.eval_scalar(e, {}, ictx))
            for name, e in node.carry.items()
        }
        if node.kind == "reduce":
            return self._loopscan_reduce(node, child, init, ctx)
        return self._loopscan_scan(node, child, init, ctx)

    def _loopscan_reduce(self, node, child, init, ctx) -> MaskedTable:
        """Commutative fold: masked sum/prod over the whole relation —
        no sequential dependence, fully vectorized."""
        n = child.num_rows
        env = child.env()
        cctx = S.EvalContext(self, n, ctx.params, ctx.outer, ctx.vars)
        cctx.row_mask = child.mask
        active = child.mask
        cols: dict[str, Column] = {}
        for name in node.outputs:
            mode, op, term, pred = node.reductions[name]
            iv = init[name]
            if mode == "last":
                # final fetch-variable value: the last active row's column
                # (or the loop-entry value when the cursor is empty)
                col = child.table.columns[op]
                if n == 0:
                    out = iv
                else:
                    has = jnp.any(active)
                    idx = (n - 1) - jnp.argmax(active[::-1])
                    out = S.Value(
                        jnp.where(has, jnp.take(col.data, idx, axis=0),
                                  iv.data.astype(col.data.dtype)),
                        jnp.where(has, jnp.take(col.validity(), idx),
                                  iv.validity()),
                        col.dictionary,
                    )
            else:  # fold
                tv = S.eval_scalar(term, env, cctx).broadcast(max(n, 1))
                g = active
                if pred is not None:
                    pv = S.eval_scalar(pred, env, cctx).broadcast(max(n, 1))
                    g = g & pv.data.astype(bool) & pv.validity()
                common = jnp.result_type(iv.data.dtype, tv.data.dtype)
                td = tv.data.astype(common)
                if n == 0:
                    out = iv
                elif op == "+":
                    out = S.Value(
                        iv.data.astype(common)
                        + jnp.sum(jnp.where(g, td, jnp.zeros((), common))),
                        # NULL is sticky: any accumulated NULL term poisons
                        # the fold, matching per-row +/* NULL propagation
                        iv.validity() & ~jnp.any(g & ~tv.validity()),
                    )
                else:  # "*"
                    out = S.Value(
                        iv.data.astype(common)
                        * jnp.prod(jnp.where(g, td, jnp.ones((), common))),
                        iv.validity() & ~jnp.any(g & ~tv.validity()),
                    )
            cols[name] = _value_to_column(_scalar_value(out), 1)
        return MaskedTable(Table(cols), jnp.ones((1,), bool))

    def _loopscan_scan(self, node, child, init, ctx) -> MaskedTable:
        """Order-dependent fold: ``lax.scan`` over the relation's rows,
        evaluating the predicated step list per row.  Masked-out rows are
        skipped (their steps see ``__live`` false); ``__done`` makes BREAK
        and failed guards sticky."""
        from repro.loops.rewrite import DONE, LIVE

        dicts = {c: col.dictionary for c, col in child.table.columns.items()}
        col_arrays = {
            c: (col.data, col.validity())
            for c, col in child.table.columns.items()
        }
        init_leaves = {
            name: (v.data, v.validity()) for name, v in init.items()
        }

        def step(carry, xs):
            mask_bit, row_cols = xs
            done = carry[DONE][0]
            vars_env = {
                name: S.Value(d, v) for name, (d, v) in carry.items()
            }
            vars_env[LIVE] = S.Value(mask_bit & ~done)
            env = {
                c: S.Value(d, v, dicts[c]) for c, (d, v) in row_cols.items()
            }
            sctx = S.EvalContext(executor=self, num_rows=1,
                                 params=ctx.params, outer=ctx.outer,
                                 vars=vars_env)
            for name, expr in node.steps:
                vars_env[name] = S.eval_scalar(expr, env, sctx)
            out = {}
            for name, (d0, v0) in carry.items():
                nv = _scalar_value(vars_env[name])
                # cast back to the loop-entry dtype: the carry structure
                # must be invariant across scan iterations
                out[name] = (nv.data.astype(d0.dtype), nv.validity())
            return out, None

        final, _ = jax.lax.scan(step, init_leaves, (child.mask, col_arrays))
        cols = {
            name: Column(final[name][0][None], final[name][1][None])
            for name in node.outputs
        }
        return MaskedTable(Table(cols), jnp.ones((1,), bool))

    # -- scalar-subquery hooks (called from scalar.eval_scalar) -------------
    def eval_scalar_subquery(self, expr: S.ScalarSubquery, env, ctx) -> S.Value:
        correlated = _plan_has_outer(expr.plan)
        if not correlated:
            res = self.execute(expr.plan, params=ctx.params, outer=ctx.outer, vars=ctx.vars)
            return _extract_scalar(res, expr.column)
        # correlated: vmap the whole subplan over outer rows
        n = ctx.num_rows
        names = sorted(
            _plan_outer_refs(expr.plan) & set(env.keys() | ctx.outer.keys())
        )
        dicts = {}
        cols = {}
        for m in names:
            v = env.get(m, ctx.outer.get(m))
            b = v.broadcast(n)
            cols[m] = (b.data, b.validity())
            dicts[m] = v.dictionary

        captured: dict = {}
        sub = self._sub_executor()

        def one(scalars):
            outer = {m: S.Value(scalars[m][0], scalars[m][1], dicts[m]) for m in names}
            outer = {**ctx.outer, **outer}
            res = sub.execute(expr.plan, params=ctx.params, outer=outer, vars=ctx.vars)
            v = _extract_scalar(res, expr.column)
            captured["dict"] = v.dictionary  # host metadata, set at trace time
            return v.data, v.validity()

        data, valid = jax.vmap(one)(cols)
        return S.Value(data, valid, captured.get("dict"))

    def eval_exists(self, expr: S.Exists, env, ctx) -> S.Value:
        correlated = _plan_has_outer(expr.plan)
        if not correlated:
            res = self.execute(expr.plan, params=ctx.params, outer=ctx.outer, vars=ctx.vars)
            v = jnp.any(res.mask)
            return S.Value(~v if expr.negated else v)
        n = ctx.num_rows
        names = sorted(
            _plan_outer_refs(expr.plan) & set(env.keys() | ctx.outer.keys())
        )
        dicts = {m: env.get(m, ctx.outer.get(m)).dictionary for m in names}
        cols = {}
        for m in names:
            v = env.get(m, ctx.outer.get(m))
            b = v.broadcast(n)
            cols[m] = (b.data, b.validity())

        sub = self._sub_executor()

        def one(scalars):
            outer = {m: S.Value(scalars[m][0], scalars[m][1], dicts[m]) for m in names}
            outer = {**ctx.outer, **outer}
            res = sub.execute(expr.plan, params=ctx.params, outer=outer, vars=ctx.vars)
            return jnp.any(res.mask)

        data = jax.vmap(one)(cols)
        return S.Value(~data if expr.negated else data)

    def eval_udf_call(self, expr: S.UdfCall, env, ctx) -> S.Value:
        if self.udf_column_evaluator is None:
            raise RuntimeError(
                f"UDF {expr.name!r} not inlined and no iterative evaluator "
                "attached (enable froid, or run via the interpreter)"
            )
        return self.udf_column_evaluator(expr, env, ctx)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _key_sentinel(keys: jnp.ndarray):
    return _F32_MAX if jnp.issubdtype(keys.dtype, jnp.floating) else _I32_MAX


def _full_agg(fn: str, v: S.Value | None, mask: jnp.ndarray) -> Column:
    n = mask.shape[0]
    if fn == "count_star":
        return Column(jnp.sum(mask).astype(jnp.int32)[None], jnp.ones((1,), bool))
    assert v is not None
    sel = mask & v.validity()
    data = v.data
    if fn == "count":
        return Column(jnp.sum(sel).astype(jnp.int32)[None], jnp.ones((1,), bool))
    if fn == "sum":
        s = jnp.sum(jnp.where(sel, data.astype(jnp.float32), 0.0))
        return Column(s[None], jnp.any(sel)[None])
    if fn == "avg":
        s = jnp.sum(jnp.where(sel, data.astype(jnp.float32), 0.0))
        c = jnp.sum(sel)
        return Column((s / jnp.where(c == 0, 1, c))[None], (c > 0)[None])
    if fn == "min":
        m = jnp.min(jnp.where(sel, data.astype(jnp.float32), jnp.inf))
        return Column(m[None], jnp.any(sel)[None])
    if fn == "max":
        m = jnp.max(jnp.where(sel, data.astype(jnp.float32), -jnp.inf))
        return Column(m[None], jnp.any(sel)[None])
    raise NotImplementedError(fn)


def _extract_scalar(res: MaskedTable, column: str | None) -> S.Value:
    names = res.table.names()
    if column is None:
        if len(names) != 1:
            raise ValueError(f"scalar subquery must produce 1 column, got {names}")
        column = names[0]
    c = res.table.columns[column]
    found = jnp.any(res.mask)
    idx = jnp.argmax(res.mask)
    return S.Value(
        jnp.take(c.data, idx, axis=0),
        jnp.take(c.validity(), idx) & found,
        c.dictionary,
    )


def _plan_has_outer(plan: R.RelNode) -> bool:
    return len(_plan_outer_refs(plan)) > 0


def _plan_outer_refs(plan: R.RelNode) -> set[str]:
    out: set[str] = set()
    for node in R.walk_plan(plan):
        for e in node.exprs():
            out |= S.free_outer(e)
        if isinstance(node, R.Compute):
            for e in node.computed.values():
                out |= S.free_outer(e)
                for sub in S.walk(e):
                    if isinstance(sub, (S.ScalarSubquery, S.Exists)):
                        out |= _plan_outer_refs(sub.plan)
        for e in node.exprs():
            for sub in S.walk(e):
                if isinstance(sub, (S.ScalarSubquery, S.Exists)):
                    out |= _plan_outer_refs(sub.plan)
    return out


def _is_scalar_region(plan: R.RelNode) -> bool:
    """True if ``plan`` is Compute/Project/Filter-over-ConstantScan — i.e. a
    single-row derived table (an algebrized region)."""
    node = plan
    while isinstance(node, (R.Compute, R.Project)):
        node = node.child
    return isinstance(node, R.ConstantScan)

