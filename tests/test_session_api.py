"""Session / PreparedStatement / ExecutionPolicy API tests.

Covers the prepare-once-execute-many contract: policy presets map onto the
legacy kwarg combinations, the plan cache warm-hits on (query, policy),
changed parameters re-specialize only when the signature changes, DDL
invalidates, and the Database shim stays equivalent to the Session."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    FROID,
    HEKATON,
    INTERPRETED,
    Database,
    ExecutionPolicy,
    QueryResult,
    RunResult,
    Session,
    UdfBuilder,
    col,
    lit,
    param,
    plan_fingerprint,
    resolve_policy,
    scan,
    sum_,
    udf,
    var,
)


def _populate(db, n_cust=40, n_ord=200, seed=0):
    rng = np.random.default_rng(seed)
    db.create_table("customer", c_custkey=np.arange(n_cust))
    db.create_table(
        "orders",
        o_custkey=rng.integers(0, n_cust, n_ord),
        o_totalprice=rng.uniform(10, 1000, n_ord).astype(np.float32),
    )
    u = UdfBuilder("total_price", [("key", "int32")], "float32")
    u.declare("price", "float32")
    u.select({"price": sum_(col("o_totalprice"))}, frm=scan("orders"),
             where=col("o_custkey") == param("key"))
    with u.if_(var("price").is_null()):
        u.return_(lit(0.0))
    u.return_(var("price"))
    db.create_function(u.build())


def _query():
    return scan("customer").compute(total=udf("total_price", col("c_custkey")))


def _totals(res) -> np.ndarray:
    return np.asarray(res.table.columns["total"].data)


# ---------------------------------------------------------------------------
# policy presets
# ---------------------------------------------------------------------------


def test_presets_map_to_legacy_kwargs():
    """The named presets are exactly the old kwarg combinations."""
    assert FROID == ExecutionPolicy.from_kwargs(froid=True, mode="python",
                                                compiled=True)
    assert INTERPRETED == ExecutionPolicy.from_kwargs(froid=False,
                                                      mode="python")
    assert HEKATON == ExecutionPolicy.from_kwargs(froid=False, mode="scan",
                                                  compiled=True)
    # names are labels, not identity
    assert FROID == ExecutionPolicy(name="renamed")
    assert resolve_policy("hekaton") is HEKATON
    assert resolve_policy(FROID) is FROID
    with pytest.raises(KeyError):
        resolve_policy("no_such_preset")


def test_policy_rejects_python_mode_compilation():
    with pytest.raises(ValueError):
        ExecutionPolicy(inline_udfs=False, udf_mode="python", compile_plan=True)
    with pytest.raises(ValueError):
        ExecutionPolicy(udf_mode="nope")


def test_policy_eager_variant():
    e = FROID.eager()
    assert not e.compile_plan and e.inline_udfs
    assert INTERPRETED.eager() is INTERPRETED


def test_presets_agree_on_results(rng):
    s = Session()
    _populate(s)
    q = _query()
    a = _totals(s.execute(q, FROID))
    b = _totals(s.execute(q, INTERPRETED))
    c = _totals(s.execute(q, HEKATON))
    np.testing.assert_allclose(a, b, rtol=1e-4)
    np.testing.assert_allclose(a, c, rtol=1e-4)


# ---------------------------------------------------------------------------
# plan-cache behaviour
# ---------------------------------------------------------------------------


def test_warm_execute_hits_cache_and_skips_planning():
    s = Session()
    _populate(s)
    stmt = s.prepare(_query(), FROID)
    r1 = stmt.execute()
    misses = dict(s.cache_stats)
    r2 = stmt.execute()
    assert not r1.cache_hit and r2.cache_hit
    # warm call did not build a plan or an executable
    assert s.cache_stats["plan_misses"] == misses["plan_misses"]
    assert s.cache_stats["exec_misses"] == misses["exec_misses"]
    assert s.cache_stats["exec_hits"] == misses["exec_hits"] + 1
    np.testing.assert_allclose(_totals(r1), _totals(r2))
    # warm should be much faster than the jit-paying cold call
    assert r2.elapsed_s < r1.elapsed_s


def test_same_query_new_prepare_shares_cache():
    s = Session()
    _populate(s)
    s.prepare(_query(), FROID).execute()
    r = s.prepare(_query(), FROID).execute()  # structurally equal, new objects
    assert r.cache_hit


def test_distinct_policies_do_not_share_executables():
    s = Session()
    _populate(s)
    s.prepare(_query(), FROID).execute()
    r = s.prepare(_query(), HEKATON).execute()
    assert not r.cache_hit


def test_plan_fingerprint_structural():
    q1, q2 = _query(), _query()
    assert q1 is not q2
    assert plan_fingerprint(q1.node) == plan_fingerprint(q2.node)
    q3 = scan("customer").compute(total=udf("total_price", col("c_custkey") + 1))
    assert plan_fingerprint(q1.node) != plan_fingerprint(q3.node)


def test_ddl_invalidates_plan_cache():
    s = Session()
    _populate(s)
    stmt = s.prepare(_query(), FROID)
    stmt.execute()
    assert stmt.execute().cache_hit
    s.create_table("customer", c_custkey=np.arange(55))  # DDL
    r = stmt.execute()
    assert not r.cache_hit
    assert r.masked.num_rows == 55


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def test_param_value_change_stays_warm_signature_change_respecializes():
    s = Session()
    _populate(s)
    q = (scan("customer").filter(col("c_custkey") < param("k"))
         .compute(total=udf("total_price", col("c_custkey"))))
    stmt = s.prepare(q, FROID)
    r10 = stmt.execute(params={"k": 10})
    r20 = stmt.execute(params={"k": 20})  # same signature: warm
    assert not r10.cache_hit and r20.cache_hit
    assert int(np.asarray(r10.masked.mask).sum()) == 10
    assert int(np.asarray(r20.masked.mask).sum()) == 20
    rf = stmt.execute(params={"k": 20.0})  # dtype change: re-specialize
    assert not rf.cache_hit
    assert int(np.asarray(rf.masked.mask).sum()) == 20


def test_string_value_params_with_distinct_dictionaries():
    """Two S.Value params with the same codes but different dictionaries
    must not share a compiled executable (the dictionary is host metadata
    baked into the trace)."""
    import jax.numpy as jnp

    from repro.core import scalar as S
    from repro.tables.table import DictEncoding

    s = Session()
    s.create_table("p", cur=np.array(["USD", "EUR", "USD"]), v=np.arange(3))
    q = scan("p").filter(col("cur") == param("c"))
    stmt = s.prepare(q, FROID)

    def val(currency):
        return S.Value(jnp.asarray(0, jnp.int32), None, DictEncoding([currency]))

    n_usd = int(np.asarray(stmt.execute(params={"c": val("USD")}).masked.mask).sum())
    n_eur = int(np.asarray(stmt.execute(params={"c": val("EUR")}).masked.mask).sum())
    assert (n_usd, n_eur) == (2, 1)
    # plain-string params likewise
    assert int(np.asarray(stmt.execute(params={"c": "EUR"}).masked.mask).sum()) == 1


def test_params_on_eager_policy():
    s = Session()
    _populate(s)
    q = (scan("customer").filter(col("c_custkey") < param("k"))
         .compute(total=udf("total_price", col("c_custkey"))))
    r = s.execute(q, INTERPRETED, params={"k": 7})
    assert int(np.asarray(r.masked.mask).sum()) == 7


# ---------------------------------------------------------------------------
# QueryResult surface
# ---------------------------------------------------------------------------


def test_query_result_surface():
    s = Session()
    _populate(s)
    r = s.execute(_query(), FROID)
    assert isinstance(r, QueryResult)
    assert RunResult is QueryResult  # legacy alias
    assert "Scan" in r.explain and "customer" in r.explain
    assert r.policy == FROID
    assert r.stats.get("compiled") is True
    assert r.stats["rows_scanned"] > 0 and r.stats["bytes_scanned"] > 0
    r2 = s.execute(_query(), INTERPRETED)
    assert "invocations" in r2.stats and r2.stats["invocations"] > 0


def test_executor_public_stats():
    from repro.core import Executor

    s = Session()
    _populate(s)
    plan = s.prepare(scan("orders"), INTERPRETED).plan
    ex = Executor(s.catalog)
    ex.execute(plan)
    st = ex.stats
    assert st["rows_scanned"] == 200
    st["rows_scanned"] = -1  # the property returns a copy
    assert ex.stats["rows_scanned"] == 200


# ---------------------------------------------------------------------------
# Database shim equivalence
# ---------------------------------------------------------------------------


def test_database_shim_matches_session_quickstart():
    db = Database()
    _populate(db)
    s = Session()
    _populate(s)
    q = _query()
    r_db = db.run(q, froid=True)
    r_s = s.execute(q, FROID.eager())
    np.testing.assert_allclose(_totals(r_db), _totals(r_s), rtol=1e-5)
    r_db_off = db.run(q, froid=False, mode="scan")
    r_s_off = s.execute(q, HEKATON.eager())
    np.testing.assert_allclose(_totals(r_db_off), _totals(r_s_off), rtol=1e-5)
    # legacy compiled interface: (callable, plan)
    fn, plan = db.run_compiled(q, froid=True)
    mask, cols = fn()
    assert "total" in cols
    assert plan is not None


def test_wholesale_catalog_rebind_refreshes_interpreter():
    """Rebinding db.catalog to a new dict must not leave the cached eager
    interpreter reading the old tables through its captured reference."""
    db = Database()
    _populate(db, n_ord=100, seed=1)
    q = _query()
    db.run(q, froid=False, mode="python")  # caches the interpreter
    fresh = Database()
    _populate(fresh, n_cust=40, n_ord=100, seed=2)  # different orders data
    db.catalog = dict(fresh.catalog)
    r = db.run(q, froid=False, mode="python")
    expect = fresh.run(q, froid=False, mode="python")
    np.testing.assert_allclose(_totals(r), _totals(expect), rtol=1e-5)


def test_table_reload_never_serves_stale_plan():
    """Per-tick table reloads (identical schema/rows) must re-key the
    caches even though the old table object is garbage."""
    s = Session()
    _populate(s)
    q = _query()
    first = _totals(s.execute(q, FROID))
    rng = np.random.default_rng(9)
    for _ in range(3):  # exercises allocator reuse of dead Table objects
        s.create_table(
            "orders",
            o_custkey=rng.integers(0, 40, 200),
            o_totalprice=rng.uniform(10, 1000, 200).astype(np.float32),
        )
        r = s.execute(q, FROID)
        assert not r.cache_hit
    assert not np.allclose(_totals(r), first)


def test_cache_eviction_bounded():
    s = Session(cache_cap=4)
    _populate(s)
    for i in range(10):
        s.execute(scan("customer").filter(col("c_custkey") < lit(i)),
                  HEKATON)
    assert len(s._plans) <= 4 and len(s._execs) <= 4 and len(s._prepared) <= 4


def test_fingerprint_distinguishes_large_array_constants():
    from repro.core import scalar as S
    from repro.core.session import _norm

    a = np.arange(2000, dtype=np.float32)
    b = a.copy()
    b[1000] = -1.0
    assert _norm(S.Const(a)) != _norm(S.Const(b))
    assert _norm(S.Const(a)) == _norm(S.Const(a.copy()))


def test_database_shim_attribute_surface():
    db = Database()
    _populate(db)
    assert "customer" in db.catalog and "total_price" in db.registry
    # benchmarks assign these wholesale
    db.catalog = dict(db.catalog)
    db.constraints = dataclasses.replace(db.constraints, max_plan_size=10)
    assert db.session.constraints.max_plan_size == 10
    assert db.explain(_query(), froid=True)
