from repro.models.config import SHAPES, ArchConfig, LayerSpec, ShapeConfig
from repro.models.model_zoo import Model, build_model, input_specs

__all__ = ["SHAPES", "ArchConfig", "LayerSpec", "ShapeConfig", "Model",
           "build_model", "input_specs"]
