"""Roofline HLO parsing + optimizer rewrite-rule semantics preservation."""
import numpy as np
import pytest

from repro.core import Database, col, count_, lit, scan, sum_
from repro.core import optimizer as O
from repro.core import relalg as R
from repro.core import scalar as S
from repro.launch.roofline import Roofline, parse_collectives


HLO = """
HloModule test
ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[2048,256]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[2048,256]{1,0} all-reduce(%ag), to_apply=%add
  %rs = f32[128,256]{1,0} reduce-scatter(%ar), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%rs), source_target_pairs={{0,1}}
  ROOT %out = f32[128,256]{1,0} add(%cp, %p0)
}
"""


def test_parse_collectives_operand_bytes():
    stats = parse_collectives(HLO)
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.count_by_kind["all-reduce"] == 1
    assert stats.count_by_kind["reduce-scatter"] == 1
    assert stats.count_by_kind["collective-permute"] == 1
    # all-gather operand = p0 = 128*256*4
    assert stats.bytes_by_kind["all-gather"] == 128 * 256 * 4
    # all-reduce operand = ag = 2048*256*4
    assert stats.bytes_by_kind["all-reduce"] == 2048 * 256 * 4


def test_roofline_terms_and_dominant():
    r = Roofline(flops=197e12, hbm_bytes=819e9 * 2, collective_bytes=50e9,
                 chips=256)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(1.0)
    assert r.dominant == "memory"


# ---------------------------------------------------------------- rules
def _db(rng):
    db = Database()
    db.create_table(
        "t",
        k=rng.integers(0, 20, 300),
        v=rng.uniform(-5, 5, 300).astype(np.float32),
    )
    return db


def _run_plan(db, plan):
    from repro.core.executor import Executor

    out = Executor(db.catalog).execute(plan)
    return (
        {n: np.asarray(c.data) for n, c in out.table.columns.items()},
        np.asarray(out.mask),
    )


def _equal(db, p1, p2, cols):
    a, ma = _run_plan(db, p1)
    b, mb = _run_plan(db, p2)
    np.testing.assert_array_equal(ma, mb)
    for c in cols:
        np.testing.assert_allclose(a[c][ma], b[c][mb], rtol=1e-5)


def test_rule_remove_applies_preserves_semantics(rng):
    db = _db(rng)
    region = R.Compute(R.ConstantScan(), {"y": S.Outer("v") * S.Const(2.0)})
    plan = R.Apply(R.Scan("t"), region, kind="outer")
    rewritten, changed = O.remove_applies(plan, db.catalog)
    assert changed
    assert not any(isinstance(n, R.Apply) for n in R.walk_plan(rewritten))
    _equal(db, plan, rewritten, ["y"])


def test_rule_fold_constants_dynamic_slicing(rng):
    db = _db(rng)
    expr = S.Case([(S.Const(5) > S.Const(3), S.ColRef("v"))], S.Const(0.0))
    plan = R.Compute(R.Scan("t"), {"o": expr + (S.Const(2) * S.Const(3))})
    rewritten, changed = O.fold_constants(plan, db.catalog)
    assert changed
    # the CASE folded away; the 2*3 folded to 6
    comp = next(n for n in R.walk_plan(rewritten) if isinstance(n, R.Compute))
    reprs = repr(list(comp.computed.values()))
    assert "Case" not in reprs and "Const(6)" in reprs
    _equal(db, plan, rewritten, ["o"])


def test_rule_decorrelate_matches_vmap_fallback(rng):
    db = _db(rng)
    sub = R.GroupAgg(
        R.Filter(R.Scan("t"), S.ColRef("k") == S.Outer("k")),
        [],
        {"s": R.AggSpec("sum", S.ColRef("v"))},
    )
    plan = R.Compute(R.Scan("t"), {"tot": S.ScalarSubquery(sub, "s")})
    rewritten, changed = O.decorrelate_in_computes(plan, db.catalog)
    assert changed
    assert any(isinstance(n, R.Join) for n in R.walk_plan(rewritten))
    _equal(db, plan, rewritten, ["tot"])


def test_rule_dense_group_stats_matches_sort_path(rng):
    db = _db(rng)
    plan = R.GroupAgg(R.Scan("t"), ["k"], {"s": R.AggSpec("sum", S.ColRef("v")),
                                           "c": R.AggSpec("count_star", None)})
    annotated, changed = O.annotate_group_stats(plan, db.catalog)
    assert changed
    ga = next(n for n in R.walk_plan(annotated) if isinstance(n, R.GroupAgg))
    assert ga.dense_range is not None
    a, ma = _run_plan(db, plan)
    b, mb = _run_plan(db, annotated)
    key_a = {int(k): i for i, k in enumerate(a["k"][ma])}
    key_b = {int(k): i for i, k in enumerate(b["k"][mb])}
    assert set(key_a) == set(key_b)
    for k in key_a:
        np.testing.assert_allclose(
            a["s"][ma][key_a[k]], b["s"][mb][key_b[k]], rtol=1e-5
        )
        assert a["c"][ma][key_a[k]] == b["c"][mb][key_b[k]]


def test_rule_prune_removes_dead_compute(rng):
    db = _db(rng)
    plan = R.Compute(R.Scan("t"), {"dead": S.ColRef("v") * S.Const(3.0),
                                   "live": S.ColRef("v") + S.Const(1.0)})
    pruned, changed = O.prune_columns(plan, db.catalog, required={"live", "k"})
    assert changed
    comp = next(n for n in R.walk_plan(pruned) if isinstance(n, R.Compute))
    assert "dead" not in comp.computed and "live" in comp.computed
