"""Multi-worker serving fleet over a shared persistent plan tier.

One :class:`FleetEngine` owns N workers.  Each worker is an independent
(session, scheduler) pair — its own catalog, caches, and coalescing
microbatches — but every worker's :class:`~repro.core.session.Session`
is attached to the *same* :class:`~repro.persist.PlanStore`, so the first
worker to compile an executable pays for it and the rest warm-start from
disk (``persist_hits`` instead of re-tracing).  That is the fleet shape
the paper's prepare-once-execute-many argument scales to: compilation is
a fleet-wide cost, not a per-process one.

Workers are built by a caller-supplied ``setup(session) -> {name: stmt}``
callback that registers the catalog/UDFs on the worker's fresh session
and returns its named :class:`PreparedStatement` handles — every worker
runs the same setup, so same-named statements are the same statement (the
fleet conformance oracle depends on this).

Intake is round-robin across workers by default (``submit(name, params)``);
``drain()`` flushes every worker's scheduler and returns results **in
arrival order** regardless of which worker served each request —
element-wise comparable against a single-worker serial drain of the same
queue (``tests/conformance_util.check_fleet_oracle``).  ``parallel=True``
drains workers on threads (safe: workers share no mutable state — the
PlanStore is append-only files behind atomic renames).

DDL does not replicate automatically: ``broadcast(fn)`` applies a catalog
mutation to every worker's session, keeping the fleet's content-derived
persist keys in lockstep (a half-broadcast fleet still answers correctly
— stale workers just miss the persistent tier, they never load plans for
data they don't hold).
"""
from __future__ import annotations

import threading
from typing import Any, Callable

from repro.core.session import Session
from repro.serve.scheduler import CoalescingScheduler, Ticket


class FleetWorker:
    """One worker: a Session (shared store) + its coalescing scheduler."""

    __slots__ = ("wid", "session", "statements", "scheduler")

    def __init__(self, wid: int, session: Session,
                 statements: dict, scheduler: CoalescingScheduler):
        self.wid = wid
        self.session = session
        self.statements = statements
        self.scheduler = scheduler


class FleetEngine:
    """N (session, scheduler) workers sharing one persistent plan store.

    ``setup(session)`` must return the worker's statements as
    ``{name: PreparedStatement}``; ``store`` is a
    :class:`~repro.persist.PlanStore` or a directory path (None = no
    persistence — workers still serve, each compiling for itself).
    ``scheduler_factory`` builds each worker's scheduler (default: a plain
    :class:`CoalescingScheduler`); ``parallel`` drains workers on threads.
    """

    def __init__(self, setup: Callable[[Session], dict], *,
                 workers: int = 2, store=None, parallel: bool = False,
                 scheduler_factory: Callable[[], CoalescingScheduler]
                 | None = None):
        if workers < 1:
            raise ValueError(f"need at least one worker, got {workers}")
        if store is not None and not hasattr(store, "get"):
            from repro.persist.store import PlanStore

            store = PlanStore(store)  # one shared instance, not per worker
        self.store = store
        self.parallel = parallel
        self.workers: list[FleetWorker] = []
        for wid in range(workers):
            session = Session(store=store)
            stmts = setup(session)
            if not isinstance(stmts, dict) or not stmts:
                raise TypeError(
                    "setup(session) must return a non-empty "
                    f"{{name: PreparedStatement}} dict, got {stmts!r}")
            sched = (scheduler_factory() if scheduler_factory is not None
                     else CoalescingScheduler())
            self.workers.append(FleetWorker(wid, session, stmts, sched))
        self._rr = 0
        self._lock = threading.Lock()
        # arrival-order intake log: drained in submit order, not worker order
        self._inflight: list[Ticket] = []
        #: submit-to-fill seconds of every drained ticket (scheduler clock),
        #: appended at drain — the bench's p50/p99 source
        self.latencies_s: list[float] = []

    # -- intake ------------------------------------------------------------
    def submit(self, name: str, params: dict | None = None, *,
               worker: int | None = None,
               timeout_s: float | None = None) -> Ticket:
        """Queue one execution of statement ``name`` on the next worker
        (round-robin; ``worker`` pins one).  Returns the ticket — callers
        may wait on it directly, or let ``drain()`` collect it."""
        with self._lock:
            if worker is None:
                worker = self._rr % len(self.workers)
                self._rr += 1
            w = self.workers[worker]
            try:
                stmt = w.statements[name]
            except KeyError:
                raise KeyError(
                    f"unknown statement {name!r}; worker {w.wid} has "
                    f"{sorted(w.statements)}") from None
            t = w.scheduler.submit(stmt, params, timeout_s=timeout_s)
            self._inflight.append(t)
        return t

    # -- drain -------------------------------------------------------------
    def drain(self) -> list:
        """Flush every worker and return results **in arrival order**.
        A ticket that failed (resilience errors included) re-raises here —
        the fleet never papers over a wrong or missing answer."""
        with self._lock:
            tickets, self._inflight = self._inflight, []
        if self.parallel and len(self.workers) > 1:
            threads = [threading.Thread(target=w.scheduler.flush)
                       for w in self.workers]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
        else:
            for w in self.workers:
                w.scheduler.flush()
        out = [t.result() for t in tickets]
        self.latencies_s.extend(
            t.latency_s for t in tickets if t.latency_s is not None)
        return out

    # -- fleet-wide control ------------------------------------------------
    def broadcast(self, fn: Callable[[Session], Any]) -> list:
        """Apply a catalog mutation (DDL, data reload, UDF swap) to every
        worker's session; returns the per-worker results in worker order."""
        return [fn(w.session) for w in self.workers]

    def save_costs(self) -> int:
        """Persist each worker's measured routing costs to the shared
        store; returns how many workers had a model worth saving."""
        return sum(1 for w in self.workers if w.session.save_costs())

    # -- observability -----------------------------------------------------
    @property
    def stats(self) -> dict:
        """Per-worker cache/persist/scheduler stats plus fleet aggregates
        (summed persist traffic, total drained, shared-store footprint)."""
        per_worker = [
            {
                "wid": w.wid,
                "cache": dict(w.session.cache_stats),
                "persist": w.session.persist_stats,
                "scheduler": dict(w.scheduler.stats),
            }
            for w in self.workers
        ]
        agg = {
            k: sum(pw["cache"].get(k, 0) for pw in per_worker)
            for k in ("persist_hits", "persist_misses", "persist_rejects")
        }
        agg["submitted"] = sum(pw["scheduler"]["submitted"]
                               for pw in per_worker)
        agg["drained"] = sum(pw["scheduler"]["drained"] for pw in per_worker)
        out = {"workers": per_worker, "fleet": agg}
        if self.store is not None:
            out["store"] = self.store.stats()
        return out


__all__ = ["FleetEngine", "FleetWorker"]
