from repro.kernels.relagg.ops import grouped_aggregate

__all__ = ["grouped_aggregate"]
