"""Figures 11/12: factor of improvement per UDF (W1/W2-style workloads).

UDFs adapted from the paper's §9 real-world examples (structure preserved):
date bucketing (BeginOfHour/DayOfWeek), report bracketing (RptBracket),
threshold flags with EXISTS lookups (F1/F2 style), and numeric parsing
stand-ins.  Factor = iterative (interpreted, per-row) / froid ON, measured
at N rows.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_run
from repro.core import (
    FROID,
    HEKATON,
    INTERPRETED,
    Session,
    UdfBuilder,
    case,
    col,
    count_,
    datepart,
    exists,
    func,
    lit,
    param,
    scan,
    sum_,
    udf,
    var,
)

N_ROWS = 20_000
N_INTERP = 300  # interpreted-mode sample size


def _register(db):
    # dbo.DayOfWeek
    u = UdfBuilder("day_of_week", [("d", "date")], "int32")
    u.return_(datepart("dw", param("d")))
    db.create_function(u.build())

    # dbo.RptBracket (two RETURNs + arithmetic)
    u = UdfBuilder("rpt_bracket", [("mydiff", "int32"), ("ndays", "int32")],
                   "int32")
    with u.if_(param("mydiff") >= 5 * param("ndays")):
        u.return_(5 * param("ndays"))
    u.return_((param("mydiff") // param("ndays")) * param("ndays"))
    db.create_function(u.build())

    # F2-style lookup flag (EXISTS over a detail table)
    u = UdfBuilder("has_rows", [("k", "int32")], "bool")
    u.declare("flag", "bool")
    with u.if_(exists(scan("detail").filter(col("d_key") == param("k")))
               | param("k").is_null()):
        u.set("flag", lit(True))
    with u.else_():
        u.set("flag", lit(False))
    u.return_(var("flag"))
    db.create_function(u.build())

    # F1-style conjunction of nested calls
    u = UdfBuilder("all_present", [("a", "int32"), ("b", "int32")], "bool")
    with u.if_((udf("has_rows", param("a")) == lit(True))
               & (udf("has_rows", param("b")) == lit(True))):
        u.return_(lit(True))
    u.return_(lit(False))
    db.create_function(u.build())

    # version-as-float stand-in (pure arithmetic slicing)
    u = UdfBuilder("ver_float", [("major", "int32"), ("minor", "int32")],
                   "float32")
    with u.if_(param("major").is_null()):
        u.return_(lit(0.0))
    u.declare("m", "float32", param("minor") * 1.0)
    with u.if_(var("m") >= 100.0):
        u.set("m", var("m") / 100.0)
    with u.else_():
        with u.if_(var("m") >= 10.0):
            u.set("m", var("m") / 10.0)
    u.return_(param("major") + var("m") / 10.0)
    db.create_function(u.build())

    # aggregating UDF (inner query per row — the expensive class)
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    u.return_(func("least", var("s"), lit(1e6)))
    db.create_function(u.build())


UDF_QUERIES = {
    "day_of_week": lambda: scan("T").compute(v=udf("day_of_week", col("d"))),
    "rpt_bracket": lambda: scan("T").compute(
        v=udf("rpt_bracket", col("diff"), lit(7))
    ),
    "has_rows": lambda: scan("T").compute(v=udf("has_rows", col("a"))),
    "all_present": lambda: scan("T").compute(
        v=udf("all_present", col("a"), col("b"))
    ),
    "ver_float": lambda: scan("T").compute(
        v=udf("ver_float", col("major"), col("minor"))
    ),
    "key_total": lambda: scan("T").compute(v=udf("key_total", col("a"))),
}


def run(quick: bool = False, n_rows: int = N_ROWS):
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 400, 30_000),
        d_val=rng.uniform(0, 10, 30_000).astype(np.float32),
    )
    db.create_table(
        "T",
        d=rng.integers(8_000, 20_000, n_rows),
        diff=rng.integers(0, 60, n_rows),
        a=rng.integers(0, 500, n_rows),
        b=rng.integers(0, 500, n_rows),
        major=rng.integers(1, 20, n_rows),
        minor=rng.integers(0, 300, n_rows),
    )
    _register(db)

    names = list(UDF_QUERIES)[:3] if quick else list(UDF_QUERIES)
    for name in names:
        q = UDF_QUERIES[name]()
        fn_on = db.prepare(q, FROID)
        t_on = time_run(fn_on)

        # interpreted per-row cost from a sample, extrapolated
        sub = Session()
        sub.catalog = dict(db.catalog)
        from repro.tables.table import Column, Table

        t_tab = db.catalog["T"]
        sub.catalog["T"] = Table(
            {n: Column(c.data[:N_INTERP], None, c.dictionary)
             for n, c in t_tab.columns.items()}
        )
        _register(sub)
        r = sub.execute(q, INTERPRETED)
        t_off = r.elapsed_s * n_rows / N_INTERP

        fn_nat = db.prepare(q, HEKATON)
        t_nat = time_run(fn_nat, warmup=1, iters=1)
        emit(f"fig11/{name}", t_on * 1e6,
             f"factor_vs_interpreted={t_off/t_on:.0f}x "
             f"factor_vs_native_iter={t_nat/t_on:.1f}x")


if __name__ == "__main__":
    run()
