"""Multi-statement fusion engine: plan merge, fusability analysis, the
fused-executable session cache tier, the scheduler's fusion drain mode,
and the fusion conformance oracle (ISSUE-4 contract).

Runs everywhere; the CI sharded-8dev job re-runs it under a forced
8-device CPU mesh so the sharded fused program exercises real placement.
"""
import numpy as np
import pytest

import jax

from repro.core import (
    FROID,
    HEKATON,
    INTERPRETED,
    Session,
    UdfBuilder,
    col,
    lit,
    param,
    scan,
    sum_,
    udf,
    var,
)
from repro.fuse import (
    is_fusable,
    merge_plans,
    partition_calls,
    plan_is_pure,
    subtree_is_constant,
)
from repro.serve.scheduler import CoalescingScheduler
from conformance_util import check_fusion_oracle


def _populate(db, n_detail=2000, n_t=200, seed=0):
    rng = np.random.default_rng(seed)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 50, n_detail),
        d_val=rng.uniform(0, 100, n_detail).astype(np.float32),
    )
    db.create_table("T", a=rng.integers(0, 50, n_t))
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    with u.if_(var("s").is_null()):
        u.return_(lit(0.0))
    u.return_(var("s"))
    db.create_function(u.build())


def _q_udf():
    return (
        scan("T")
        .filter(col("a") < param("cutoff"))
        .compute(v=udf("key_total", col("a")))
        .project("v")
    )


def _q_arith():
    return (
        scan("T")
        .filter(col("a") >= param("lo"))
        .compute(w=col("a") * param("scale"))
        .project("a", "w")
    )


def _q_paramfree():
    return scan("T").compute(z=col("a") * 2).project("z")


def _assert_same(serial, fused):
    assert len(serial) == len(fused)
    for s, f in zip(serial, fused):
        m = np.asarray(s.masked.mask)
        np.testing.assert_array_equal(m, np.asarray(f.masked.mask))
        for n, c in s.masked.table.columns.items():
            np.testing.assert_allclose(
                np.asarray(f.masked.table.columns[n].data)[m],
                np.asarray(c.data)[m], rtol=1e-5,
            )


@pytest.fixture
def db():
    s = Session()
    _populate(s)
    return s


def _mixed_calls(s1, s2, s3):
    return [
        (s1, {"cutoff": 10}), (s2, {"lo": 5, "scale": 2.0}), (s3, None),
        (s1, {"cutoff": 30}), (s2, {"lo": 20, "scale": 0.5}),
        (s1, {"cutoff": 7.5}),  # mixed signature member for s1
        (s3, {}),
    ]


# ---------------------------------------------------------------------------
# plan-merge pass
# ---------------------------------------------------------------------------


def test_merge_dedups_shared_scans(db):
    p1 = db.prepare(_q_udf(), FROID).plan
    p2 = db.prepare(_q_arith(), FROID).plan
    p3 = db.prepare(_q_paramfree(), FROID).plan
    merged = merge_plans([p1, p2, p3])
    # every plan scans T; the scan is param-free, so it dedups
    assert merged.stats["shared_subtrees"] >= 1
    assert merged.stats["shared_refs"] > merged.stats["shared_subtrees"]
    assert merged.stats["total_scans"] >= 3
    # marked ids resolve to fingerprints present in the shared list
    shared_fps = {fp for fp, _ in merged.shared}
    assert set(merged.shared_ids.values()) <= shared_fps


def test_merge_shares_nested_subtrees():
    """Every shared occurrence is marked and pooled — the repeated Filter
    *and* its repeated Scan child.  The pool is ordered innermost-first, so
    the Filter's pool build answers the Scan from the pool (nested
    sharing), while member traces are intercepted at the topmost mark and
    count maximal coverage only."""
    from repro.core import relalg as R

    scan_t = R.Scan("T")
    f1 = R.Filter(scan_t, col("a") < lit(5))
    # independently-built structurally-equal twin under a *different* root
    f2 = R.Filter(R.Scan("T"), col("a") < lit(5))
    merged = merge_plans([R.Project(f1, ["a"]), R.Compute(f2, {"b": col("a")})])
    fps = dict(merged.shared)
    assert len(fps) == 2  # the Filter and its shared Scan child
    assert merged.shared_ids[f1.node_id] == merged.shared_ids[f2.node_id]
    assert scan_t.node_id in merged.shared_ids  # nested occurrence pooled
    # innermost-first pool order: the Scan precedes the Filter that uses it
    order = [fp for fp, _ in merged.shared]
    assert order.index(merged.shared_ids[scan_t.node_id]) \
        < order.index(merged.shared_ids[f1.node_id])
    # coverage counts maximal marks only: two Filter refs, Scan subsumed
    assert merged.stats["shared_refs"] == 2
    assert merged.stats["cse_shared_nodes"] == 4  # 2 refs x 2-node subtree
    # identical whole plans share at the root (coverage goes all the way)
    whole = merge_plans([R.Project(f1, ["a"]), R.Project(f2, ["a"])])
    assert whole.stats["shared_refs"] == 2
    assert whole.stats["cse_shared_nodes"] == 6  # 2 refs x 3-node plan


def test_subtree_constness():
    from repro.core import relalg as R

    assert subtree_is_constant(R.Scan("T"))
    assert not subtree_is_constant(
        R.Filter(R.Scan("T"), col("a") < param("c"))
    )
    assert plan_is_pure(R.Project(R.Scan("T"), ["a"]))


# ---------------------------------------------------------------------------
# fusability analysis
# ---------------------------------------------------------------------------


def test_fusability_gates(db):
    s_froid = db.prepare(_q_udf(), FROID)
    s_eager = db.prepare(_q_udf(), INTERPRETED)
    s_nofuse = db.prepare(_q_arith(), FROID.fused(fuse=False))
    other = Session()
    _populate(other)
    s_foreign = other.prepare(_q_arith(), FROID)
    assert is_fusable(db, s_froid)
    assert not is_fusable(db, s_eager)       # no compiled plan to merge
    assert not is_fusable(db, s_nofuse)      # knob off
    assert not is_fusable(db, s_foreign)     # foreign session state
    groups, fallbacks = partition_calls(db, [
        (s_froid, {"cutoff": 1}), (s_eager, {"cutoff": 1}),
        (s_nofuse, {"lo": 1, "scale": 1.0}), (s_foreign, {"lo": 1, "scale": 1.0}),
    ])
    assert groups == []  # a single fusable statement gains nothing
    assert len(fallbacks) == 4


def test_max_fused_statements_splits(db):
    policy = FROID.fused(max_fused_statements=2)
    s1 = db.prepare(_q_udf(), policy)
    s2 = db.prepare(_q_arith(), policy)
    s3 = db.prepare(_q_paramfree(), policy)
    calls = [(s1, {"cutoff": 5}), (s2, {"lo": 1, "scale": 1.0}), (s3, {})]
    groups, fallbacks = partition_calls(db, calls)
    # 3 distinct statements, cap 2 -> one fused pair + one singleton fallback
    assert len(groups) == 1 and len({s._query_fp for _, s, _ in groups[0]}) == 2
    assert len(fallbacks) == 1
    rs = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], rs)
    assert rs[0].stats["fused_statements"] == 2
    assert "fused" not in rs[2].stats


def test_fuse_policy_knobs_are_not_identity():
    assert FROID.fused(fuse=False) == FROID
    assert FROID.fused(fuse=False).fingerprint() == FROID.fingerprint()
    assert FROID.fused(max_fused_statements=2).max_fused_statements == 2
    assert FROID.fuse and FROID.max_fused_statements == 8


# ---------------------------------------------------------------------------
# execute_fused: parity + tagged stats
# ---------------------------------------------------------------------------


def test_execute_fused_matches_serial(db):
    s1 = db.prepare(_q_udf(), FROID)
    s2 = db.prepare(_q_arith(), FROID)
    s3 = db.prepare(_q_paramfree(), FROID)
    calls = _mixed_calls(s1, s2, s3)
    fused = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], fused)
    st = fused[0].stats
    assert st["fused"] and st["fused_programs"] == 1
    assert st["fused_programs"] < st["fused_statements"] == 3
    assert st["fused_members"] == 4  # s1 contributes two signatures
    assert st["shared_subtrees"] >= 1
    assert st["batch_size"] == 2 and st["batch_bucket"] == 2


def test_execute_fused_hekaton(db):
    s1 = db.prepare(_q_udf(), HEKATON)
    s2 = db.prepare(_q_arith(), HEKATON)
    calls = [(s1, {"cutoff": 10}), (s2, {"lo": 5, "scale": 2.0}),
             (s1, {"cutoff": 44})]
    fused = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], fused)
    assert fused[0].stats["fused"]


def test_execute_fused_empty_and_single(db):
    assert db.execute_fused([]) == []
    s1 = db.prepare(_q_udf(), FROID)
    rs = db.execute_fused([(s1, {"cutoff": 5}), (s1, {"cutoff": 9})])
    _assert_same([s1.execute(params={"cutoff": 5}),
                  s1.execute(params={"cutoff": 9})], rs)
    assert "fused" not in rs[0].stats  # single statement: per-statement path


def test_fused_cache_tier(db):
    s1 = db.prepare(_q_udf(), FROID)
    s2 = db.prepare(_q_arith(), FROID)
    calls = [(s1, {"cutoff": 5}), (s2, {"lo": 3, "scale": 1.0}),
             (s1, {"cutoff": 8})]
    r1 = db.execute_fused(calls)
    assert db.cache_stats["fuse_misses"] == 1 and not r1[0].cache_hit
    # warm wave, different param values and arrival order: same program
    calls2 = [(s2, {"lo": 9, "scale": 4.0}), (s1, {"cutoff": 40}),
              (s1, {"cutoff": 2})]
    r2 = db.execute_fused(calls2)
    assert db.cache_stats["fuse_hits"] == 1
    assert db.cache_stats["fuse_misses"] == 1 and r2[0].cache_hit
    _assert_same([s.execute(params=p) for s, p in calls2], r2)


def test_fused_cache_invalidates_on_ddl(db):
    s1 = db.prepare(_q_udf(), FROID)
    s2 = db.prepare(_q_arith(), FROID)
    calls = [(s1, {"cutoff": 49}), (s2, {"lo": 0, "scale": 1.0})]
    r1 = db.execute_fused(calls)
    misses = db.cache_stats["fuse_misses"]
    rng = np.random.default_rng(99)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 50, 2000),
        d_val=rng.uniform(0, 100, 2000).astype(np.float32),
    )
    r2 = db.execute_fused(calls)
    assert db.cache_stats["fuse_misses"] == misses + 1 and not r2[0].cache_hit
    _assert_same([s.execute(params=p) for s, p in calls], r2)
    # the UDF aggregates over detail: new data must actually flow through
    m = np.asarray(r2[0].masked.mask)
    assert not np.allclose(
        np.asarray(r1[0].masked.table.columns["v"].data)[m],
        np.asarray(r2[0].masked.table.columns["v"].data)[m],
    )


def test_fused_group_honors_strictest_max_batch(db):
    """max_batch is non-identity, so fingerprint-equal members may carry
    different bounds — the fused wave must honor the strictest one (and
    stay arrival-order independent), not whichever statement arrived
    first."""
    s_big = db.prepare(_q_udf(), FROID)                     # max_batch 1024
    s_small = db.prepare(_q_arith(), FROID.batched(max_batch=2))
    calls = ([(s_big, {"cutoff": int(k)}) for k in range(3)]
             + [(s_small, {"lo": int(k), "scale": 1.0}) for k in range(3)])
    rs = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], rs)
    fused_rs = [r for r in rs if "fused" in r.stats]
    assert fused_rs and all(r.stats["batch_bucket"] <= 2 for r in fused_rs)
    # arrival order reversed -> same buckets, warm fused-cache hit
    hits = db.cache_stats["fuse_hits"]
    rs2 = db.execute_fused(list(reversed(calls)))
    assert db.cache_stats["fuse_hits"] > hits
    _assert_same([s.execute(params=p) for s, p in reversed(calls)], rs2)


def test_merge_blocks_nondeterministic_subtrees():
    """A param-free subtree containing rand() must evaluate per statement,
    never once per pool."""
    from repro.core import relalg as R
    from repro.core import scalar as S

    det = R.Filter(R.Scan("T"), col("a") < lit(5))
    rnd = R.Compute(R.Scan("T"), {"r": S.Func("rand", [])})
    assert subtree_is_constant(det)
    assert not subtree_is_constant(rnd)
    merged = merge_plans([R.Project(rnd, ["r"]), R.Compute(rnd, {"b": col("r")})])
    assert rnd.node_id not in merged.shared_ids


def test_fused_overflow_spills_to_per_statement_path(db):
    policy = FROID.batched(max_batch=4)
    s1 = db.prepare(_q_udf(), policy)
    s2 = db.prepare(_q_arith(), policy)
    calls = ([(s1, {"cutoff": int(k)}) for k in range(6)]   # > max_batch
             + [(s2, {"lo": 5, "scale": 2.0})])
    rs = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], rs)
    assert rs[0].stats["fused"]          # first wave rides the fused program
    assert rs[5].stats.get("batched") and "fused" not in rs[5].stats  # spill


# ---------------------------------------------------------------------------
# scheduler fusion drain mode
# ---------------------------------------------------------------------------


def test_scheduler_fused_drain(db):
    s1 = db.prepare(_q_udf(), FROID)
    s2 = db.prepare(_q_arith(), FROID)
    s3 = db.prepare(_q_paramfree(), FROID)
    calls = _mixed_calls(s1, s2, s3)
    sched = CoalescingScheduler(max_batch=64, window_s=10.0,
                                clock=lambda: 0.0, fuse=True)
    tickets = [sched.submit(s, p) for s, p in calls]
    assert sched.flush() == len(calls)
    _assert_same([s.execute(params=p) for s, p in calls],
                 [t.result() for t in tickets])
    assert sched.stats["batches"] == 1  # one fused wave, not 3 drains
    assert sched.stats["fused_batches"] == 1
    assert sched.stats["fused_statements"] == 3
    assert tickets[0].result().stats["fused"]


def test_scheduler_fuse_off_drains_per_statement(db):
    s1 = db.prepare(_q_udf(), FROID)
    s2 = db.prepare(_q_arith(), FROID)
    sched = CoalescingScheduler(max_batch=64, window_s=10.0,
                                clock=lambda: 0.0)
    t1 = sched.submit(s1, {"cutoff": 5})
    t2 = sched.submit(s2, {"lo": 1, "scale": 1.0})
    sched.flush()
    assert sched.stats["batches"] == 2 and sched.stats["fused_batches"] == 0
    assert "fused" not in t1.result().stats
    assert "fused" not in t2.result().stats


def test_scheduler_fused_single_group_skips_fusion(db):
    s1 = db.prepare(_q_udf(), FROID)
    sched = CoalescingScheduler(max_batch=64, window_s=10.0,
                                clock=lambda: 0.0, fuse=True)
    ts = [sched.submit(s1, {"cutoff": k}) for k in (5, 9)]
    sched.flush()
    assert sched.stats["fused_batches"] == 0
    assert "fused" not in ts[0].result().stats


# ---------------------------------------------------------------------------
# conformance oracle (fixed entry points; CI re-runs under 8 forced devices)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", [FROID, HEKATON],
                         ids=["froid", "hekaton"])
def test_fusion_oracle_modes(policy):
    check_fusion_oracle(11, 23, policy)


def test_fusion_oracle_interpreted_falls_back():
    check_fusion_oracle(12, 23, INTERPRETED, expect_fused=False)


def test_fusion_oracle_fuse_knob_off_falls_back():
    fused = check_fusion_oracle(13, 23, FROID.fused(fuse=False))
    assert all("fused" not in r.stats for r in fused)


def test_fusion_oracle_empty_table():
    check_fusion_oracle(14, 0, FROID)


def test_fusion_oracle_ddl_between_submit_and_drain():
    """DDL landing while mixed-statement tickets sit in the queue must
    re-specialize the fused program at drain time (env token is read at
    drain, invalidating every member at once)."""
    check_fusion_oracle(15, 23, FROID, ddl=True)


def test_fusion_oracle_sharded():
    """Fused programs still place over the mesh: 8 tickets per statement
    make every member bucket divisible on the CI mesh (on fewer devices
    the same spec exercises divisibility gating / replication)."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    spec = ([(0, {"cut": int(k % 6), "shift": 0.5}) for k in range(8)]
            + [(1, {"minq": int(k % 4), "scale": 2.0}) for k in range(8)]
            + [(2, None) for _ in range(8)])
    fused = check_fusion_oracle(16, 23, FROID.sharded(mesh), spec)
    if len(jax.devices()) > 1:
        st = next(r.stats for r in fused if r.stats.get("fused"))
        assert st.get("sharded") and st["shard_devices"] == len(jax.devices())


def test_fusion_oracle_sharded_mixed_divisibility():
    """Regression (ISSUE-8 bugfix): one batched member's bucket divides
    the data axes (8 tickets) and another's does not (3 tickets → bucket
    4 on the forced-8-device CI mesh).  The wave must still shard — the
    non-dividing member pads its parameter axis up to the next multiple
    of the data-axis size instead of demoting the whole fused program to
    replicated — and results stay element-wise equal to the serial loop
    (the padding rows are discarded, exactly like power-of-two bucket
    padding)."""
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    spec = ([(0, {"cut": int(k % 6), "shift": 0.5}) for k in range(8)]
            + [(1, {"minq": int(k % 4), "scale": 2.0}) for k in range(3)]
            + [(2, None) for _ in range(2)])
    fused = check_fusion_oracle(17, 23, FROID.sharded(mesh), spec)
    if n_dev > 1:
        sts = [r.stats for r in fused if r.stats.get("fused")]
        # every fused ticket ran in the one sharded program
        assert all(st.get("sharded") and st["shard_devices"] == n_dev
                   for st in sts), sts[0]
        # the 3-ticket member's bucket padded up to a mesh multiple
        member1 = fused[8].stats
        assert member1["batch_bucket"] % n_dev == 0, member1
        assert member1["batch_bucket"] >= 3
        # the dividing member kept its natural bucket
        assert fused[0].stats["batch_bucket"] == 8


# ---------------------------------------------------------------------------
# serving pass-through
# ---------------------------------------------------------------------------


def test_admission_policy_fuse_adaptive_passthrough():
    from repro.serve.admission import AdmissionPolicy

    ap = AdmissionPolicy(froid=True, fuse=True, adaptive=True)
    assert ap.scheduler.fuse and ap.scheduler.adaptive
    # the default admission workload (one request statement) still drains
    # correctly through the fusion-mode scheduler
    reqs = {
        "tier": np.array([0, 2]),
        "prompt_len": np.array([100, 9000]),
        "max_new_tokens": np.array([50, 800]),
        "temperature": np.array([0.5, 0.7], np.float32),
    }
    tick = ap.evaluate(reqs)
    co = ap.evaluate_coalesced(reqs)
    np.testing.assert_array_equal(tick["admit"], co["admit"])
    np.testing.assert_array_equal(tick["granted"], co["granted"])


def test_serve_engine_fuse_passthrough():
    from repro.serve.engine import ServeEngine

    class _Model:
        def decode_step(self, params, cache, tok):  # never invoked here
            return None, cache

    eng = ServeEngine(_Model(), params={}, admission_fuse=True,
                      admission_adaptive=True)
    assert eng.admission.scheduler.fuse
    assert eng.admission.scheduler.adaptive
