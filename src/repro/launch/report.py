"""Aggregate dry-run JSON artifacts into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HBM_PER_CHIP = 16 * 2**30  # v5e


def load(dirname):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def fmt_time(s):
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.1f}ms"
    return f"{s*1e6:.0f}µs"


def roofline_table(recs, mesh="16x16"):
    out = [
        "| arch | shape | mb | mem/chip GiB | fits | t_comp | t_mem | t_coll |"
        " dominant | t_model | MODEL/HLO | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skip":
            out.append(
                f"| {r['arch']} | {r['shape']} |  |  |  |  |  |  | SKIP |  |  |"
                f" {r.get('reason','')} |"
            )
            continue
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} |  |  |  |  |  |  | FAIL |  |  |"
                f" {r.get('error','')[:60]} |"
            )
            continue
        roof = r["roofline"]
        mem = r["memory"]["per_device_total"]
        fits = "yes" if mem <= HBM_PER_CHIP else "NO"
        ratio = r.get("useful_flop_ratio")
        t_model = r.get("model_flops_per_chip", 0) / 197e12
        out.append(
            "| {arch} | {shape} | {mb} | {mem} | {fits} | {tc} | {tm} | {tl} |"
            " {dom} | {tmod} | {ratio} | |".format(
                arch=r["arch"], shape=r["shape"],
                mb=r.get("microbatches") or "",
                mem=fmt_bytes(mem), fits=fits,
                tc=fmt_time(roof["t_compute_s"]),
                tm=fmt_time(roof["t_memory_s"]),
                tl=fmt_time(roof["t_collective_s"]),
                dom=roof["dominant"],
                tmod=fmt_time(t_model),
                ratio=f"{ratio:.2f}" if ratio else "",
            )
        )
    return "\n".join(out)


def summary(recs):
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    fail = [r for r in recs if r["status"] == "fail"]
    lines = [f"cells: {len(recs)}  ok: {len(ok)}  skip: {len(skip)}  "
             f"fail: {len(fail)}"]
    worst = sorted(
        (r for r in ok if r.get("useful_flop_ratio") and r["shape"] != "decode_32k"),
        key=lambda r: r["useful_flop_ratio"],
    )
    if worst:
        lines.append("worst useful-flop ratios (model/HLO):")
        for r in worst[:5]:
            lines.append(
                f"  {r['arch']}.{r['shape']}.{r['mesh']}: "
                f"{r['useful_flop_ratio']:.3f}"
            )
    coll = sorted(
        ok, key=lambda r: -(r["roofline"]["t_collective_s"]
                            / max(r["roofline"]["t_compute_s"]
                                  + r["roofline"]["t_memory_s"], 1e-12)),
    )
    lines.append("most collective-bound:")
    for r in coll[:5]:
        roof = r["roofline"]
        lines.append(
            f"  {r['arch']}.{r['shape']}.{r['mesh']}: "
            f"t_coll={fmt_time(roof['t_collective_s'])} vs "
            f"t_comp={fmt_time(roof['t_compute_s'])} "
            f"t_mem={fmt_time(roof['t_memory_s'])} dom={roof['dominant']}"
        )
    over = [r for r in ok
            if r["memory"]["per_device_total"] > HBM_PER_CHIP]
    lines.append(f"cells over 16GiB/chip (CPU buffer-assignment bound): "
                 f"{[(r['arch'] + '.' + r['shape'] + '.' + r['mesh']) for r in over]}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--table", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    print(summary(recs))
    if args.table:
        print()
        print(roofline_table(recs, args.mesh))


if __name__ == "__main__":
    main()
