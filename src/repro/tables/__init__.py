from repro.tables.table import Column, Table, DictEncoding, days_from_civil, civil_from_days

__all__ = ["Column", "Table", "DictEncoding", "days_from_civil", "civil_from_days"]
