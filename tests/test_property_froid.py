"""Property-based test of the paper's core claim (§4.4): for ANY UDF built
from the supported constructs, the algebrized + optimized + set-oriented
froid execution equals the iterative per-tuple interpretation.

A hypothesis strategy generates random imperative programs over the
supported grammar (DECLARE/SET/SELECT-assign/IF-ELSE/RETURN, scalar
subqueries with aggregates, arithmetic/comparison/CASE expressions), random
data, and compares froid ON vs the interpreter bit-for-bit on validity and
within float tolerance on values.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (
    Database,
    UdfBuilder,
    avg_,
    case,
    col,
    count_,
    lit,
    max_,
    min_,
    param,
    scan,
    sum_,
    udf,
    var,
)
from repro.core import scalar as S

N_ROWS = 23
N_KEYS = 7


def make_db(seed: int) -> Database:
    rng = np.random.default_rng(seed)
    db = Database()
    db.create_table(
        "facts",
        fk=rng.integers(0, N_KEYS, N_ROWS),
        val=np.round(rng.uniform(-10, 10, N_ROWS), 2).astype(np.float32),
        qty=rng.integers(0, 9, N_ROWS),
    )
    db.create_table("keys", k=np.arange(N_KEYS))
    return db


# --------------------------------------------------------------------------
# expression strategy (over declared variables + the parameter)
# --------------------------------------------------------------------------


def expr_strategy(varnames: list[str], depth: int = 2):
    leaves = [st.just(None).map(lambda _: param("p") * 1.0)]
    if varnames:
        names = list(varnames)
        leaves.append(st.sampled_from(names).map(var))
    leaves.append(
        st.floats(-5, 5, allow_nan=False, width=32).map(lambda v: lit(round(v, 2)))
    )
    leaf = st.one_of(leaves)
    if depth == 0:
        return leaf

    sub = expr_strategy(varnames, depth - 1)

    def combine(args):
        op, a, b = args
        if op == "+":
            return a + b
        if op == "-":
            return a - b
        if op == "*":
            return a * b
        if op == "/":
            return a / b
        if op == "case":
            return case([(a > b, a)], b)
        if op == "coalesce":
            return S.Coalesce([a, b])
        raise AssertionError(op)

    return st.one_of(
        leaf,
        st.tuples(
            st.sampled_from(["+", "-", "*", "/", "case", "coalesce"]), sub, sub
        ).map(combine),
    )


AGGS = {
    "sum": lambda e: sum_(e),
    "min": lambda e: min_(e),
    "max": lambda e: max_(e),
    "avg": lambda e: avg_(e),
    "count": lambda e: count_(e),
}


@st.composite
def udf_programs(draw):
    """Generate (builder-ops, n_vars) for a random supported UDF."""
    ops = []
    varnames: list[str] = []
    n_stmts = draw(st.integers(2, 7))
    has_return = False

    def new_var():
        name = f"v{len(varnames)}"
        varnames.append(name)
        return name

    # always declare at least one variable first
    ops.append(("declare", new_var(), draw(expr_strategy(varnames[:-1], 1))))

    for _ in range(n_stmts):
        kind = draw(
            st.sampled_from(
                ["declare", "set", "select_agg", "ifelse", "maybe_return"]
            )
        )
        if kind == "declare":
            init = draw(st.one_of(st.none(), expr_strategy(varnames, 1)))
            ops.append(("declare", new_var(), init))
        elif kind == "set" and varnames:
            tgt = draw(st.sampled_from(varnames))
            ops.append(("set", tgt, draw(expr_strategy(varnames, 2))))
        elif kind == "select_agg" and varnames:
            tgt = draw(st.sampled_from(varnames))
            agg = draw(st.sampled_from(sorted(AGGS)))
            corr = draw(st.booleans())
            thresh = draw(st.integers(0, 8))
            ops.append(("select_agg", tgt, agg, corr, thresh))
        elif kind == "ifelse" and varnames:
            pred = draw(expr_strategy(varnames, 1))
            t_tgt = draw(st.sampled_from(varnames))
            t_expr = draw(expr_strategy(varnames, 1))
            has_else = draw(st.booleans())
            e_tgt = draw(st.sampled_from(varnames)) if has_else else None
            e_expr = draw(expr_strategy(varnames, 1)) if has_else else None
            ret_in_then = draw(st.booleans())
            ops.append(
                ("ifelse", pred, t_tgt, t_expr, e_tgt, e_expr, ret_in_then)
            )
        elif kind == "maybe_return":
            ops.append(("return", draw(expr_strategy(varnames, 1))))
            has_return = True
            break
    if not has_return:
        ops.append(("return", draw(expr_strategy(varnames, 2))))
    return ops


def build_udf(ops) -> UdfBuilder:
    u = UdfBuilder("f", [("p", "float32")], "float32")
    for op in ops:
        if op[0] == "declare":
            _, name, init = op
            u.declare(name, "float32", init)
        elif op[0] == "set":
            _, name, e = op
            u.set(name, e)
        elif op[0] == "select_agg":
            _, tgt, agg, corr, thresh = op
            pred = (
                col("fk") == param("p")
                if corr
                else col("qty") >= lit(thresh)
            )
            u.select({tgt: AGGS[agg](col("val"))}, frm=scan("facts"), where=pred)
        elif op[0] == "ifelse":
            _, pred, t_tgt, t_expr, e_tgt, e_expr, ret_in_then = op
            with u.if_(pred):
                u.set(t_tgt, t_expr)
                if ret_in_then:
                    u.return_(var(t_tgt) + 1.0)
            if e_tgt is not None:
                with u.else_():
                    u.set(e_tgt, e_expr)
        elif op[0] == "return":
            u.return_(op[1])
    return u


@settings(
    max_examples=40,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
@given(ops=udf_programs(), seed=st.integers(0, 3))
def test_froid_equals_interpreter(ops, seed):
    db = make_db(seed)
    try:
        f = build_udf(ops).build()
    except AssertionError:
        pytest.skip("builder rejected program")
    db.create_function(f)
    q = scan("keys").compute(out=udf("f", col("k") * 1.0)).project("k", "out")

    r_on = db.run(q, froid=True)
    r_off = db.run(q, froid=False, mode="python")

    a = np.asarray(r_on.table.columns["out"].data, dtype=np.float64)
    av = np.asarray(r_on.table.columns["out"].validity())
    b = np.asarray(r_off.table.columns["out"].data, dtype=np.float64)
    bv = np.asarray(r_off.table.columns["out"].validity())

    assert (av == bv).all(), f"validity mismatch: {av} vs {bv}"
    both = av & bv
    np.testing.assert_allclose(a[both], b[both], rtol=2e-3, atol=1e-3)
