"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256; cross-attention image layers every 5th layer.
The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings.  [hf:meta-llama/Llama-3.2-90B-Vision]"""
import dataclasses

from repro.models.config import ArchConfig, LayerSpec


def config() -> ArchConfig:
    attn = LayerSpec(mixer="attn", mlp="dense")
    cross = LayerSpec(mixer="cross", mlp="dense")
    return ArchConfig(
        name="llama-3.2-vision-90b",
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab=128256,
        head_dim=128,
        super_block=(attn, attn, attn, attn, cross),
        n_repeats=20,  # 100 layers total, 20 cross
        vision_tokens=1601,
        rope_theta=500_000.0,
        max_seq_len=131_072,
        subquadratic=False,  # full attention -> long_500k skipped
    )


def smoke_config() -> ArchConfig:
    c = config()
    return dataclasses.replace(
        c,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=128,
        head_dim=16,
        n_repeats=1,
        vision_tokens=8,
        max_seq_len=128,
    )
