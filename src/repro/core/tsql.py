"""A T-SQL-subset parser frontend (paper §7.3: the framework is
language-agnostic — adding a surface language is a parser plus calls into
the construct classes).

Supported grammar (enough for the paper's §9 example shapes)::

    CREATE FUNCTION name(@p TYPE, ...) RETURNS TYPE AS
    BEGIN
        DECLARE @v TYPE [= expr];
        SET @v = expr;
        SELECT @v = AGG(col) FROM table WHERE pred;
        IF (pred) BEGIN ... END [ELSE BEGIN ... END]
        RETURN expr;
    END

Expressions: numbers, 'strings', @vars, identifiers (columns), + - * /,
comparisons (= <> < <= > >=), AND/OR/NOT, parentheses, CASE WHEN ... THEN
... ELSE ... END, and function calls (intrinsics).  Types: INT, FLOAT,
BIT, DATE, VARCHAR/CHAR(n).
"""
from __future__ import annotations

import re

from repro.core import frontend as F
from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.ir import UdfDef

_TOKEN = re.compile(
    r"\s*(?:(?P<num>\d+\.\d+|\d+)|(?P<str>'[^']*')|(?P<var>@\w+)"
    r"|(?P<id>[A-Za-z_][\w.]*)|(?P<op><=|>=|<>|!=|[=<>+\-*/(),;]))"
)

_TYPES = {
    "int": "int32", "bigint": "int32", "bit": "bool", "float": "float32",
    "real": "float32", "decimal": "float32", "money": "float32",
    "date": "date", "datetime": "date", "varchar": "str", "char": "str",
    "nvarchar": "str",
}

_AGGS = {"sum": F.sum_, "count": F.count_, "min": F.min_, "max": F.max_,
         "avg": F.avg_}


def _tokenize(src: str):
    out, pos = [], 0
    src = re.sub(r"--[^\n]*", "", src)
    while pos < len(src):
        m = _TOKEN.match(src, pos)
        if not m:
            if src[pos:].strip() == "":
                break
            raise SyntaxError(f"bad token at: {src[pos:pos+40]!r}")
        pos = m.end()
        for kind in ("num", "str", "var", "id", "op"):
            v = m.group(kind)
            if v is not None:
                out.append((kind, v.lower() if kind == "id" else v))
                break
    out.append(("eof", ""))
    return out


class _Parser:
    def __init__(self, tokens):
        self.toks = tokens
        self.i = 0

    def peek(self, k=0):
        return self.toks[self.i + k]

    def next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, value=None, kind=None):
        k, v = self.next()
        if value is not None and v.lower() != value.lower():
            raise SyntaxError(f"expected {value!r}, got {v!r}")
        if kind is not None and k != kind:
            raise SyntaxError(f"expected {kind}, got {k}:{v}")
        return v

    def accept(self, value):
        if self.peek()[1].lower() == value.lower():
            self.next()
            return True
        return False

    # ---------------------------------------------------------------- types
    def parse_type(self) -> str:
        name = self.expect(kind="id")
        if self.accept("("):  # char(50), decimal(12,2)
            while not self.accept(")"):
                self.next()
        if name not in _TYPES:
            raise SyntaxError(f"unsupported type {name!r}")
        return _TYPES[name]

    # ----------------------------------------------------------- expressions
    def parse_expr(self) -> S.Scalar:
        return self._or()

    def _or(self):
        left = self._and()
        while self.peek()[1].lower() == "or":
            self.next()
            left = S.BoolOp("or", [left, self._and()])
        return left

    def _and(self):
        left = self._not()
        while self.peek()[1].lower() == "and":
            self.next()
            left = S.BoolOp("and", [left, self._not()])
        return left

    def _not(self):
        if self.peek()[1].lower() == "not":
            self.next()
            return S.BoolOp("not", [self._not()])
        return self._cmp()

    def _cmp(self):
        left = self._add()
        k, v = self.peek()
        ops = {"=": "==", "<>": "!=", "!=": "!=", "<": "<", "<=": "<=",
               ">": ">", ">=": ">="}
        if v in ops:
            self.next()
            return S.Cmp(ops[v], left, self._add())
        if v.lower() == "is":
            self.next()
            neg = self.accept("not")
            self.expect("null")
            e = S.IsNull(left)
            return S.BoolOp("not", [e]) if neg else e
        if v.lower() == "between":
            self.next()
            lo = self._add()
            self.expect("and")
            return S.Between(left, lo, self._add())
        if v.lower() == "in":
            self.next()
            self.expect("(")
            opts = [self._literal_value()]
            while self.accept(","):
                opts.append(self._literal_value())
            self.expect(")")
            return S.InList(left, opts)
        if v.lower() == "like":
            self.next()
            pat = self.expect(kind="str")
            return S.Like(left, pat.strip("'"))
        return left

    def _literal_value(self):
        k, v = self.next()
        if k == "num":
            return float(v) if "." in v else int(v)
        if k == "str":
            return v.strip("'")
        raise SyntaxError(f"expected literal, got {v!r}")

    def _add(self):
        left = self._mul()
        while self.peek()[1] in ("+", "-"):
            op = self.next()[1]
            left = S.BinOp(op, left, self._mul())
        return left

    def _mul(self):
        left = self._unary()
        while self.peek()[1] in ("*", "/"):
            op = self.next()[1]
            left = S.BinOp(op, left, self._unary())
        return left

    def _unary(self):
        if self.peek()[1] == "-":
            self.next()
            return S.BinOp("-", S.Const(0), self._unary())
        return self._atom()

    def _atom(self) -> S.Scalar:
        k, v = self.next()
        if k == "num":
            return S.Const(float(v) if "." in v else int(v))
        if k == "str":
            return S.Const(v.strip("'"))
        if k == "var":
            return S.Var(v[1:])
        if v == "(":
            e = self.parse_expr()
            self.expect(")")
            return e
        if k == "id":
            name = v
            if name == "null":
                return S.Const(None)
            if name == "case":
                return self._case()
            if self.peek()[1] == "(":  # function call
                self.next()
                args = []
                if self.peek()[1] != ")":
                    args.append(self.parse_expr())
                    while self.accept(","):
                        args.append(self.parse_expr())
                self.expect(")")
                base = name.split(".")[-1]
                if base in ("dateadd", "datepart"):
                    # first arg is a part keyword parsed as ColRef
                    part = args[0]
                    pname = part.name if isinstance(part, S.ColRef) else part.value
                    return S.Func(base, [S.Const(pname)] + args[1:])
                if "." in name:  # dbo.func -> UDF call
                    return S.UdfCall(base, args)
                return S.Func(base, args)
            return S.ColRef(name)
        raise SyntaxError(f"unexpected {v!r}")

    def _case(self) -> S.Scalar:
        whens = []
        while self.accept("when"):
            p = self.parse_expr()
            self.expect("then")
            whens.append((p, self.parse_expr()))
        else_ = S.Const(None)
        if self.accept("else"):
            else_ = self.parse_expr()
        self.expect("end")
        return S.Case(whens, else_)

    # ------------------------------------------------------------ statements
    def parse_block(self, u: F.UdfBuilder):
        self.expect("begin")
        while not self.accept("end"):
            self.parse_statement(u)

    def parse_statement(self, u: F.UdfBuilder):
        k, v = self.peek()
        word = v.lower()
        if word == "declare":
            self.next()
            name = self.expect(kind="var")[1:]
            dtype = self.parse_type()
            init = None
            if self.accept("="):
                init = self.parse_expr()
            self.accept(";")
            u.declare(name, dtype, init)
        elif word == "set":
            self.next()
            name = self.expect(kind="var")[1:]
            self.expect("=")
            u.set(name, self.parse_expr())
            self.accept(";")
        elif word == "select":
            self.next()
            name = self.expect(kind="var")[1:]
            self.expect("=")
            expr = self.parse_expr()
            frm = None
            where = None
            if self.accept("from"):
                table = self.expect(kind="id").split(".")[-1]
                frm = F.scan(table)
                if self.accept("where"):
                    where = self.parse_expr()
            self.accept(";")
            if frm is None:
                u.set(name, expr)
            else:
                agg = self._as_agg(expr)
                u.select({name: agg}, frm=frm, where=where)
        elif word == "if":
            self.next()
            pred = self.parse_expr()
            with u.if_(pred):
                if self.peek()[1].lower() == "begin":
                    self.parse_block(u)
                else:
                    self.parse_statement(u)
            if self.accept("else"):
                with u.else_():
                    if self.peek()[1].lower() == "begin":
                        self.parse_block(u)
                    else:
                        self.parse_statement(u)
        elif word == "return":
            self.next()
            u.return_(self.parse_expr())
            self.accept(";")
        elif v == ";":
            self.next()
        else:
            raise SyntaxError(f"unsupported statement at {v!r}")

    def _as_agg(self, expr: S.Scalar):
        if isinstance(expr, S.Func) and expr.name in _AGGS:
            arg = expr.args[0] if expr.args else None
            if expr.name == "count":
                return F.count_(arg)
            return _AGGS[expr.name](arg)
        return expr


def parse_udf(src: str) -> UdfDef:
    """Parse a CREATE FUNCTION statement into a UdfDef.

    In the UDF body, bare identifiers inside FROM/WHERE are table columns;
    @names are variables/parameters — matching T-SQL scoping."""
    p = _Parser(_tokenize(src))
    p.expect("create")
    p.expect("function")
    name = p.expect(kind="id").split(".")[-1]
    p.expect("(")
    params = []
    while not p.accept(")"):
        pname = p.expect(kind="var")[1:]
        ptype = p.parse_type()
        params.append((pname, ptype))
        p.accept(",")
    p.expect("returns")
    rtype = p.parse_type()
    p.accept("as")
    u = F.UdfBuilder(name, params, rtype)
    p.parse_block(u)
    return u.build()
