"""Fusability analysis: which calls of a mixed-statement queue may share
one fused device program, and which must fall back.

A call ``(stmt, params)`` is **fusable** when:

* the statement belongs to the session doing the fusing (a foreign
  session has its own catalog/registry state — its calls fall back to
  that session's own per-statement path);
* its policy compiles whole plans (eager policies have no device program
  to merge) and has ``fuse`` enabled;
* its bound plan is side-effect free (:func:`repro.fuse.merge.plan_is_pure`
  — true of every operator the executor knows today; the gate exists so a
  future effectful node degrades to the per-statement path instead of
  silently re-ordering effects across statements).

Fusable calls group by **compatible policy**: equal identity fingerprints
(the plans must agree on inlining/optimization/compilation) and equal
sharding placement (one fused program has one mesh layout).  Groups wider
than ``policy.max_fused_statements`` distinct statements split — and the
split considers **template overlap**: statements are chunked greedily so
that those sharing subtree/template fingerprints (the CSE engine's
sharing currency, :func:`shareable_fingerprints`) land in the same fused
program, instead of whatever first-appearance order the queue happened to
arrive in.  A split remainder (or a group) holding a single distinct
statement gains nothing from fusion and falls back to ``execute_many``.
"""
from __future__ import annotations

from repro.core import relalg as R
from repro.core.session import parametric_fingerprint
from repro.fuse.merge import plan_is_pure, subtree_shape


def fusion_group_key(stmt) -> tuple:
    """Compatibility key: calls fuse only within one of these."""
    p = stmt.policy
    return (p.fingerprint(), p.shard_devices(), p.shard_token())


def _plan_pure_cached(stmt) -> bool:
    """Purity of the statement's *current* plan, memoized per plan object
    (the plan changes identity on DDL, refreshing the verdict; the walk
    itself must not run once per ticket on the drain hot path)."""
    plan = stmt._ensure_plan()
    cached = getattr(stmt, "_fuse_pure", None)
    if cached is not None and cached[0] is plan:
        return cached[1]
    ok = plan_is_pure(plan)
    stmt._fuse_pure = (plan, ok)
    return ok


def is_fusable(session, stmt) -> bool:
    """Per-statement gate (see module docstring)."""
    if stmt.session is not session:
        return False
    p = stmt.policy
    if not (p.compile_plan and p.fuse):
        return False
    return _plan_pure_cached(stmt)


def shareable_fingerprints(stmt) -> frozenset:
    """Canonical fingerprints of every shareable subtree of the statement's
    current plan — constant subtrees, parameter-unified templates and
    correlated templates alike (the things the merge pass can dedup when
    another member brings a matching one).  Memoized per plan object, like
    the purity verdict — the classification deliberately repeats what
    merge_plans will do (only on the cold path, and only when a group is
    wide enough to split); sharing a per-node memo with the merge pass is
    not worth coupling the two layers yet."""
    plan = stmt._ensure_plan()
    cached = getattr(stmt, "_fuse_fps", None)
    if cached is not None and cached[0] is plan:
        return cached[1]
    fps = set()
    for n in R.walk_plan_deep(plan):
        if subtree_shape(n) is not None:
            fps.add(parametric_fingerprint(n)[0])
    out = frozenset(fps)
    stmt._fuse_fps = (plan, out)
    return out


def shareable_fingerprint_costs(session, stmt) -> dict:
    """``fp -> estimated per-execution seconds`` of each shareable subtree
    of the statement's plan — the cost model's chunking weight: sharing an
    aggregate over a big scan saves real work, sharing a literal filter
    saves almost none, and the greedy splitter should know the
    difference.  Memoized per plan object like the fingerprint set."""
    plan = stmt._ensure_plan()
    cached = getattr(stmt, "_fuse_fpw", None)
    if cached is not None and cached[0] is plan:
        return cached[1]
    from repro.cost.model import estimate_node_s

    weights: dict = {}
    for n in R.walk_plan_deep(plan):
        if subtree_shape(n) is not None:
            fp = parametric_fingerprint(n)[0]
            if fp not in weights:
                weights[fp] = estimate_node_s(n, session.catalog)
    stmt._fuse_fpw = (plan, weights)
    return weights


def _overlap_order(order: list, fp_sets: dict, cap: int,
                   weights: dict | None = None) -> list:
    """Reorder distinct-statement fingerprints so overlap-sharing
    statements chunk together: greedy — seed each chunk with the earliest
    unplaced statement, then repeatedly pull the unplaced statement with
    the largest fingerprint overlap against the chunk's accumulated set
    (earliest arrival breaks ties, keeping the result deterministic).
    With ``weights`` (fp → estimated seconds), overlap is scored by the
    estimated work the sharing avoids instead of a bare fingerprint
    count — two statements sharing one expensive aggregate chunk together
    ahead of two sharing three trivial literals."""
    remaining = list(order)
    out: list = []
    while remaining:
        chunk = [remaining.pop(0)]
        acc = set(fp_sets.get(chunk[0], ()))
        while len(chunk) < cap and remaining:
            best_i, best_n = 0, -1.0
            for i, fp in enumerate(remaining):
                shared = acc & fp_sets.get(fp, frozenset())
                if weights is not None:
                    n = sum(weights.get(f, 0.0) for f in shared)
                else:
                    n = len(shared)
                if n > best_n:
                    best_i, best_n = i, n
            pick = remaining.pop(best_i)
            chunk.append(pick)
            acc |= fp_sets.get(pick, frozenset())
        out.extend(chunk)
    return out


def partition_calls(session, calls):
    """Split an indexed call list into fused groups and fallbacks.

    ``calls`` is ``[(stmt, params), ...]``; returns ``(groups, fallbacks)``
    where each group is ``[(index, stmt, params), ...]`` destined for one
    fused program, and ``fallbacks`` is ``[(stmt, [(index, params), ...])]``
    in first-appearance order for the per-statement path.  Input order is
    carried by the indices; callers scatter results back through them.
    """
    fallback_by_stmt: dict[int, tuple] = {}  # id(stmt) -> (stmt, items)
    grouped: dict[tuple, list] = {}
    verdicts: dict[int, tuple | None] = {}  # id(stmt) -> group key | fallback

    def fall_back(idx, stmt, params):
        ent = fallback_by_stmt.get(id(stmt))
        if ent is None:
            ent = fallback_by_stmt[id(stmt)] = (stmt, [])
        ent[1].append((idx, params))

    for idx, (stmt, params) in enumerate(calls):
        # one fusability verdict + group key per distinct statement, not
        # per ticket (queues repeat statements thousands of times)
        v = verdicts.get(id(stmt), "unseen")
        if v == "unseen":
            v = (fusion_group_key(stmt) if is_fusable(session, stmt)
                 else None)
            verdicts[id(stmt)] = v
        if v is not None:
            grouped.setdefault(v, []).append((idx, stmt, params))
        else:
            fall_back(idx, stmt, params)

    groups = []
    for items in grouped.values():
        # distinct statements in first-appearance order
        order: list[tuple] = []
        by_fp: dict[tuple, list] = {}
        for idx, stmt, params in items:
            fp = stmt._query_fp
            if fp not in by_fp:
                by_fp[fp] = []
                order.append(fp)
            by_fp[fp].append((idx, stmt, params))
        cap = max(1, min(s.policy.max_fused_statements for _, s, _ in items))
        if len(order) > cap:
            # the group must split: chunk overlap-sharing statements
            # together so the CSE engine has something to dedup per
            # program, weighing each shared fingerprint by its estimated
            # cost (cost-aware chunking — see shareable_fingerprint_costs)
            fp_sets = {fp: shareable_fingerprints(by_fp[fp][0][1])
                       for fp in order}
            weights: dict = {}
            for fp in order:
                for f, w in shareable_fingerprint_costs(
                        session, by_fp[fp][0][1]).items():
                    if f not in weights:
                        weights[f] = w
            order = _overlap_order(order, fp_sets, cap, weights)
        for s in range(0, len(order), cap):
            chunk_fps = order[s:s + cap]
            chunk = [it for fp in chunk_fps for it in by_fp[fp]]
            if len(chunk_fps) < 2:
                # fusing one statement is the per-statement path with extra
                # steps — route it there directly
                for idx, stmt, params in chunk:
                    fall_back(idx, stmt, params)
            else:
                groups.append(chunk)
    return groups, list(fallback_by_stmt.values())
