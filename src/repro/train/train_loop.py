"""Training step + loop: pjit'd step (donated state), microbatch gradient
accumulation (lax.scan), optional cross-pod int8-EF gradient compression,
straggler tracking, and fault-tolerant checkpoint/resume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.dist.activations import shard_batch
from repro.dist.compress import compress_tree, decompress_tree, init_error_tree
from repro.train.optim import AdamWConfig, adamw_init, adamw_update


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    ef_error: Any = None  # error-feedback buffers (compression on)


def make_train_step(
    model,
    opt_cfg: AdamWConfig,
    microbatches: int = 1,
    compress: bool = False,
    remat: bool = True,
):
    """Returns step(state, batch) -> (state, metrics).

    ``microbatches`` > 1 splits the per-step batch on the leading axis and
    accumulates grads in a lax.scan (activation memory / HBM trade-off).
    ``compress`` applies int8 error-feedback quantization to the grads
    before the optimizer (the wire format of the cross-pod reduction)."""

    def loss_fn(params, batch):
        # mixed precision: cast the f32 master weights to bf16 ONCE per
        # step (sharded, elementwise) so every FSDP weight all-gather moves
        # bf16 — halves both the collective bytes and the gathered-weight
        # temp memory.  Grads flow back in f32 through the cast's VJP.
        compute_params = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if (p.dtype == jnp.float32 and p.ndim >= 2)
            else p,
            params,
        )
        return model.loss_fn(compute_params, batch, remat=remat)

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

        mb = jax.tree.map(split, batch)

        def body(carry, one):
            acc_loss, acc_g = carry
            # re-pin each microbatch to the data axes: without this the
            # partitioner reshards the scan slice against the sharded
            # embedding gather (invalid dynamic-slice under SPMD)
            one = jax.tree.map(shard_batch, one)
            l, g = jax.value_and_grad(loss_fn)(params, one)
            return (acc_loss + l, jax.tree.map(jnp.add, acc_g, g)), 0

        zero_g = jax.tree.map(jnp.zeros_like, params)
        (loss, grads), _ = jax.lax.scan(body, (0.0, zero_g), mb)
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def step(state: TrainState, batch):
        loss, grads = grads_of(state.params, batch)
        ef = state.ef_error
        if compress:
            payload, ef = compress_tree(grads, ef)
            grads = decompress_tree(payload)
        params, opt, metrics = adamw_update(state.params, grads, state.opt, opt_cfg)
        metrics["loss"] = loss
        return TrainState(params, opt, ef), metrics

    return step


def init_state(model, key, opt_cfg: AdamWConfig, compress: bool = False):
    params = model.init(key)
    opt = adamw_init(params, opt_cfg)
    ef = init_error_tree(params) if compress else None
    return TrainState(params, opt, ef)


def train_loop(
    model,
    state: TrainState,
    batches,
    opt_cfg: AdamWConfig,
    *,
    steps: int,
    checkpoint_mgr=None,
    checkpoint_every: int = 50,
    straggler=None,
    log_every: int = 10,
    microbatches: int = 1,
    compress: bool = False,
    jit: bool = True,
    log: Callable[[str], None] = print,
):
    """Drives ``steps`` optimizer steps; checkpoints / resumes; tracks
    per-step wall time for straggler mitigation."""
    step_fn = make_train_step(model, opt_cfg, microbatches, compress)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=0)

    start = int(state.opt["step"])
    it = iter(batches)
    for i in range(start, steps):
        batch = next(it)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if straggler is not None:
            straggler.record(host=0, step=i, seconds=dt)
        if log_every and (i + 1) % log_every == 0:
            log(
                f"step {i+1}: loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms"
            )
        if checkpoint_mgr is not None and (i + 1) % checkpoint_every == 0:
            checkpoint_mgr.save(i + 1, {"params": state.params, "opt": state.opt})
    return state
