"""Cursor-loop → :class:`~repro.core.relalg.LoopScan` compilation (Aggify
§4: the loop becomes a custom aggregate over the cursor's query).

``compile_loop`` turns a rewritable :class:`~repro.core.ir.CursorLoop`
into the relational operator.  The caller (the algebrizer) supplies the
scope glue:

* ``fix_free(expr, carried)`` — resolve every ``Var`` whose name is NOT
  in ``carried`` to ``Outer``/``Param`` per the enclosing scope (raising
  on undeclared names);
* ``null_for(dtype)`` — a typed NULL constant for loop-local declares.

Scan-kind lowering compiles the body to an *ordered predicated step
list*: every assignment is guarded by its control context (a boolean
expression over the reserved ``__live`` flag and per-branch snapshot
temps), so BREAK and failed guards become sticky ``__done`` state rather
than control flow — the same predication discipline the algebrizer uses
for early RETURNs, applied per cursor row.
"""
from __future__ import annotations

from repro.core import ir as IR
from repro.core import relalg as R
from repro.core import scalar as S
from repro.loops.analysis import LoopVerdict, reduce_info

#: reserved carried flag: row has permanently exited the loop
DONE = "__done"
#: reserved per-row pseudo-variable: row is active this iteration
LIVE = "__live"


def _and(a: S.Scalar, b: S.Scalar) -> S.Scalar:
    return S.BoolOp("and", [a, b])


def _not(a: S.Scalar) -> S.Scalar:
    return S.BoolOp("not", [a])


def compile_loop(loop: IR.CursorLoop, verdict: LoopVerdict, fix_free,
                 null_for) -> R.LoopScan:
    assert verdict.rewritable, verdict
    fetch_vars = [v for v, _ in loop.targets]
    fetch_cols = dict(loop.targets)
    outputs = sorted(set(verdict.written) | set(fetch_vars))
    carried = set(outputs) | set(verdict.locals) | {DONE, LIVE}

    def fix(e: S.Scalar) -> S.Scalar:
        return fix_free(e, carried)

    # loop-entry state: every live-out variable starts at its enclosing-
    # scope value; loop-locals start NULL; __done starts False
    carry: dict[str, S.Scalar] = {
        name: fix_free(S.Var(name), set()) for name in outputs
    }
    local_dtypes = {
        st.name: st.dtype
        for st in loop.body
        if isinstance(st, IR.Declare)
    }
    for name in verdict.locals:
        carry[name] = null_for(local_dtypes.get(name, "float32"))
    carry[DONE] = S.Const(False)

    if verdict.kind == "reduce":
        reds = reduce_info(loop)
        assert reds is not None

        def to_cols(e: S.Scalar) -> S.Scalar:
            def f(x):
                if isinstance(x, S.Var) and x.name in fetch_cols:
                    return S.ColRef(fetch_cols[x.name])
                return None

            return fix(S.transform(e, f))

        reductions: dict[str, tuple] = {}
        for acc, (op, term, pred) in reds.items():
            reductions[acc] = ("fold", op, to_cols(term),
                               None if pred is None else to_cols(pred))
        for v in fetch_vars:
            if v not in reductions:
                reductions[v] = ("last", fetch_cols[v], None, None)
        return R.LoopScan(loop.plan, carry, [], "reduce", reductions,
                          outputs)

    # ---- scan kind: ordered predicated steps --------------------------
    steps: list[tuple[str, S.Scalar]] = []
    temp_n = [0]

    def temp(base: str) -> str:
        temp_n[0] += 1
        return f"__{base}{temp_n[0]}"

    # 1. fetch binds: active rows take the cursor row's columns
    for v, c in loop.targets:
        steps.append((v, S.Case([(S.Var(LIVE), S.ColRef(c))], S.Var(v))))

    # 2. extra termination guard: a live row whose guard is not TRUE exits
    #    the loop *before* the body (matching WHILE's re-check position)
    if loop.guard is not None:
        gok = temp("gok")
        steps.append((gok, S.Case([(_and(S.Var(LIVE), fix(loop.guard)),
                                    S.Const(True))], S.Const(False))))
        steps.append((DONE, S.Case([(_and(S.Var(LIVE), _not(S.Var(gok))),
                                     S.Const(True))], S.Var(DONE))))
        steps.append((LIVE, S.Case([(_not(S.Var(gok)), S.Const(False))],
                                   S.Var(LIVE))))

    # 3. body statements, each guarded by its control context; branch
    #    predicates snapshot into temps *before* the branch body runs, so
    #    a branch that mutates variables its own predicate read cannot
    #    flip lanes mid-branch
    def ctx_expr(flag: str | None) -> S.Scalar:
        if flag is None:
            return S.Var(LIVE)
        return _and(S.Var(flag), S.Var(LIVE))

    def emit(stmts, flag):
        for st in stmts:
            sc = ctx_expr(flag)
            if isinstance(st, IR.Assign):
                steps.append((st.name,
                              S.Case([(sc, fix(st.expr))], S.Var(st.name))))
            elif isinstance(st, IR.Declare):
                init = (null_for(st.dtype) if st.init is None
                        else fix(st.init))
                steps.append((st.name, S.Case([(sc, init)], S.Var(st.name))))
            elif isinstance(st, IR.IfElse):
                pc, ec = temp("p"), temp("e")
                steps.append((pc, S.Case([(_and(sc, fix(st.pred)),
                                           S.Const(True))], S.Const(False))))
                steps.append((ec, S.Case([(_and(sc, _not(S.Var(pc))),
                                           S.Const(True))], S.Const(False))))
                emit(st.then_body, pc)
                emit(st.else_body, ec)
            elif isinstance(st, IR.Break):
                # DONE first: its guard reads __live, which the second step
                # clears — the reverse order would never stick
                steps.append((DONE, S.Case([(sc, S.Const(True))],
                                           S.Var(DONE))))
                steps.append((LIVE, S.Case([(sc, S.Const(False))],
                                           S.Var(LIVE))))
            else:  # pragma: no cover — classify() rejects everything else
                raise AssertionError(
                    f"unloweredable statement {type(st).__name__}")

    emit(loop.body, None)
    return R.LoopScan(loop.plan, carry, steps, "scan", None, outputs)
