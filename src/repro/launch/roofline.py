"""Roofline term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes; collective bytes are
parsed out of the optimized HLO text (operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute).

Hardware model (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_TYPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op in optimized HLO."""
    sizes: dict[str, int] = {}
    # pass 1: def-site sizes
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, type_str, _ = m.groups()
            sizes[name] = _type_bytes(type_str)
    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    # pass 2: collective call sites; operands are %names inside parens
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        kind = next((k for k in _COLLECTIVES if op.startswith(k)), None)
        if kind is None:
            continue
        count_by[kind] += 1
        args = re.search(r"\((.*)\)", line)
        operand_bytes = 0
        if args:
            for ref in re.findall(r"%([\w.\-]+)", args.group(1)):
                operand_bytes += sizes.get(ref, 0)
        if operand_bytes == 0:
            # fall back to the result type (exact for all-reduce)
            operand_bytes = _type_bytes(type_str)
        bytes_by[kind] += operand_bytes
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device bytes accessed
    collective_bytes: float  # per-device collective operand bytes
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self):
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
        }


def roofline_from_compiled(compiled, chips: int) -> tuple[Roofline, CollectiveStats]:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # pre-0.5 jax: one dict per device
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    return Roofline(flops, hbm, colls.total_bytes, chips), colls


def model_flops_train(cfg, tokens: int) -> float:
    """6·N_active·D analytic model FLOPs for one training step."""
    return 6.0 * cfg.active_param_count() * tokens


def model_flops_decode(cfg, tokens: int) -> float:
    """2·N_active per generated token (forward only)."""
    return 2.0 * cfg.active_param_count() * tokens
