"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536; Mamba+attention 1:7 interleave, MoE 16 experts
top-2 on alternate layers.  [arXiv:2403.19887]"""
import dataclasses

from repro.models.config import ArchConfig, LayerSpec, MoEConfig, SSMConfig


def _sb():
    # 8-layer super-block: attention at index 3 (1:7), MoE every other layer
    layers = []
    for i in range(8):
        mixer = "attn" if i == 3 else "mamba"
        mlp = "moe" if i % 2 == 1 else "dense"
        layers.append(LayerSpec(mixer=mixer, mlp=mlp))
    return tuple(layers)


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b",
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab=65536,
        head_dim=128,
        super_block=_sb(),
        n_repeats=9,  # 72 layers
        moe=MoEConfig(n_experts=16, top_k=2),
        ssm=SSMConfig(state_dim=128, head_dim=128, n_groups=8, conv_kernel=4,
                      expand=2),
        subquadratic=True,
        max_seq_len=262_144,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        config(),
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=128,
        head_dim=16,
        n_repeats=1,
        moe=MoEConfig(n_experts=4, top_k=2),
        ssm=SSMConfig(state_dim=16, head_dim=16, n_groups=2, conv_kernel=4,
                      expand=2),
        max_seq_len=128,
    )
