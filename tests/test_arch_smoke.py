"""Per-architecture smoke tests: reduced same-family config, one forward +
one train(loss/grad-lite) + one decode step on CPU; asserts shapes + finite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, smoke_config_for
from repro.models import build_model
from repro.models.layers import COMPUTE_DTYPE


def _batch_for(cfg, B=2, S=32):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.vision_tokens:
        batch["memory"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.d_model)) * 0.02,
            COMPUTE_DTYPE,
        )
    elif cfg.n_encoder_layers:
        batch["memory"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)) * 0.02, COMPUTE_DTYPE
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_forward_loss_decode(arch):
    cfg = smoke_config_for(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)

    # forward: shape + finite
    x = model.forward(params, batch["tokens"], batch.get("memory"))
    assert x.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(x.astype(jnp.float32)).all()), arch

    # loss: finite scalar
    loss = model.loss_fn(params, batch, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, loss)

    # prefill + one decode step
    logits, cache = model.prefill(
        params, batch["tokens"], batch.get("memory"), max_len=S + 8
    )
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, nxt)
    assert logits2.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all()), arch
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["granite3_2b", "mamba2_370m"])
def test_arch_grad_step(arch):
    """Full grad through the reduced model (one SGD step, loss finite)."""
    cfg = smoke_config_for(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, batch))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = model.loss_fn(new_params, batch)
    assert bool(jnp.isfinite(loss2))


def test_param_counts_match_published_sizes():
    """Analytic parameter counts are in range of the published sizes."""
    from repro.configs import config_for

    expect = {
        "mamba2_370m": (0.25e9, 0.55e9),
        "llama32_vision_90b": (75e9, 105e9),
        "jamba15_large_398b": (330e9, 430e9),
        "granite3_2b": (1.6e9, 3.3e9),
        "minicpm3_4b": (2.8e9, 5.2e9),
        "phi3_mini_38b": (3.0e9, 4.6e9),
        "gemma3_12b": (9e9, 15e9),
        "mixtral_8x7b": (40e9, 52e9),
        "granite_moe_3b_a800m": (2.0e9, 4.2e9),
        "seamless_m4t_large_v2": (0.9e9, 2.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = config_for(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_decode_matches_prefill_continuation():
    """Teacher-forced decode equals forward() logits (cache correctness)."""
    cfg = smoke_config_for("granite3_2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    x = model.forward(params, toks)
    full_logits = model.lm_head(params, x)  # (B, S, V)

    logits_p, cache = model.prefill(params, toks[:, :16], max_len=S)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, 15]), atol=2e-2, rtol=2e-2
    )
    logits_d, cache = model.decode_step(params, cache, toks[:, 16:17])
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(full_logits[:, 16]), atol=2e-2, rtol=2e-2
    )
    logits_d2, _ = model.decode_step(params, cache, toks[:, 17:18])
    np.testing.assert_allclose(
        np.asarray(logits_d2), np.asarray(full_logits[:, 17]), atol=2e-2, rtol=2e-2
    )


def test_int8_kv_cache_decode_close_to_bf16():
    """§Perf hillclimb 2: int8 KV decode matches bf16 decode closely."""
    import dataclasses

    import jax

    cfg = smoke_config_for("gemma3_12b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)

    logits_a, cache_a = model.prefill(params, toks, max_len=24)
    cfg8 = dataclasses.replace(cfg, kv_cache_int8=True)
    model8 = build_model(cfg8)
    logits_b, cache_b = model8.prefill(params, toks, max_len=24)

    a = np.asarray(logits_a, np.float32)
    b = np.asarray(logits_b, np.float32)
    assert np.max(np.abs(a - b)) < 0.05 * (np.abs(a).max() + 1e-3)

    nxt = jnp.argmax(logits_a, -1)[:, None].astype(jnp.int32)
    da, _ = model.decode_step(params, cache_a, nxt)
    db, _ = model8.decode_step(params, cache_b, nxt)
    assert np.max(np.abs(np.asarray(da) - np.asarray(db))) < 0.05 * (
        np.abs(np.asarray(da)).max() + 1e-3
    )
