# Distribution layer: activation sharding constraints, parameter/batch/
# cache sharding rules for the production meshes, and the int8
# error-feedback gradient compression used on the cross-pod reduction.
from repro.dist.activations import (
    clear_activation_mesh,
    current_activation_mesh,
    set_activation_mesh,
    shard_batch,
)
from repro.dist.compress import (
    compress_tree,
    decompress_tree,
    dequantize_int8,
    ef_quantize,
    init_error_tree,
    quantize_int8,
)
from repro.dist.sharding import (
    batch_sharding,
    batch_specs,
    cache_specs,
    data_axis_size,
    param_specs,
    pick_data_axes,
    replicated_sharding,
    shardings_for,
)

__all__ = [
    "set_activation_mesh", "clear_activation_mesh", "current_activation_mesh",
    "shard_batch", "param_specs", "batch_specs", "cache_specs",
    "shardings_for", "pick_data_axes", "data_axis_size", "batch_sharding",
    "replicated_sharding", "compress_tree", "decompress_tree",
    "init_error_tree", "quantize_int8", "dequantize_int8", "ef_quantize",
]
