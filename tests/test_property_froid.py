"""Hypothesis-driven differential conformance harness (§4.4 of the paper,
grown into an oracle suite for the engine's invocation surfaces).

A hypothesis strategy generates random imperative programs over the
supported grammar (DECLARE/SET/SELECT-assign/IF-ELSE/RETURN, scalar
subqueries with aggregates, arithmetic/comparison/CASE expressions),
random data (including zero-row tables), and random parameter sets, then
feeds them to the shared oracles in ``conformance_util``:

* **Mode oracle** — FROID == INTERPRETED == HEKATON element-wise.
* **Invocation oracle** — ``execute_many`` (sharded over whatever device
  mesh exists, and unsharded) == the serial ``execute`` loop, including
  mixed-signature parameter lists, empty lists, and empty tables.
* **Fusion oracle** — a generated multi-statement queue with deliberately
  overlapping subtrees (shared scans, shared filters modulo parameter
  values, nested shared aggregates), drained fused through the scheduler,
  == the per-statement serial loop — across FROID/HEKATON, sharded and
  unsharded, with DDL optionally landing between submit and drain.

``tests/test_conformance_oracle.py`` runs fixed programs through the same
checks without hypothesis, and ``tests/test_fuse_cse.py`` replays fixed
samples of the overlap-queue spec space; this module is the generative
layer on top (CI installs hypothesis — the module skips where it is
absent).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

import jax

from conformance_util import (
    AGGS,
    LOOP_BODIES,
    N_KEYS,
    N_ROWS,
    OVERLAP_BODIES,
    OVERLAP_FILTERS,
    OVERLAP_PNAMES,
    build_udf,
    check_chaos_oracle,
    check_fleet_oracle,
    check_fusion_oracle,
    check_invocation_oracle,
    check_loop_oracle,
    check_mode_oracle,
    check_routing_oracle,
    overlap_queue,
)
from repro.core import FROID, HEKATON, Database, case, col, lit, param, scan, udf, var
from repro.core import scalar as S

ORACLE_SETTINGS = dict(
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


def make_db(seed: int) -> Database:
    rng = np.random.default_rng(seed)
    db = Database()
    db.create_table(
        "facts",
        fk=rng.integers(0, N_KEYS, N_ROWS),
        val=np.round(rng.uniform(-10, 10, N_ROWS), 2).astype(np.float32),
        qty=rng.integers(0, 9, N_ROWS),
    )
    db.create_table("keys", k=np.arange(N_KEYS))
    return db


# --------------------------------------------------------------------------
# expression strategy (over declared variables + the parameter)
# --------------------------------------------------------------------------


def expr_strategy(varnames: list[str], depth: int = 2):
    leaves = [st.just(None).map(lambda _: param("p") * 1.0)]
    if varnames:
        names = list(varnames)
        leaves.append(st.sampled_from(names).map(var))
    leaves.append(
        st.floats(-5, 5, allow_nan=False, width=32).map(lambda v: lit(round(v, 2)))
    )
    leaf = st.one_of(leaves)
    if depth == 0:
        return leaf

    sub = expr_strategy(varnames, depth - 1)

    def combine(args):
        op, a, b = args
        if op == "+":
            return a + b
        if op == "-":
            return a - b
        if op == "*":
            return a * b
        if op == "/":
            return a / b
        if op == "case":
            return case([(a > b, a)], b)
        if op == "coalesce":
            return S.Coalesce([a, b])
        raise AssertionError(op)

    return st.one_of(
        leaf,
        st.tuples(
            st.sampled_from(["+", "-", "*", "/", "case", "coalesce"]), sub, sub
        ).map(combine),
    )


@st.composite
def udf_programs(draw):
    """Generate (builder-ops, n_vars) for a random supported UDF."""
    ops = []
    varnames: list[str] = []
    n_stmts = draw(st.integers(2, 7))
    has_return = False

    def new_var():
        name = f"v{len(varnames)}"
        varnames.append(name)
        return name

    # always declare at least one variable first
    ops.append(("declare", new_var(), draw(expr_strategy(varnames[:-1], 1))))

    for _ in range(n_stmts):
        kind = draw(
            st.sampled_from(
                ["declare", "set", "select_agg", "ifelse", "maybe_return"]
            )
        )
        if kind == "declare":
            init = draw(st.one_of(st.none(), expr_strategy(varnames, 1)))
            ops.append(("declare", new_var(), init))
        elif kind == "set" and varnames:
            tgt = draw(st.sampled_from(varnames))
            ops.append(("set", tgt, draw(expr_strategy(varnames, 2))))
        elif kind == "select_agg" and varnames:
            tgt = draw(st.sampled_from(varnames))
            agg = draw(st.sampled_from(sorted(AGGS)))
            corr = draw(st.booleans())
            thresh = draw(st.integers(0, 8))
            ops.append(("select_agg", tgt, agg, corr, thresh))
        elif kind == "ifelse" and varnames:
            pred = draw(expr_strategy(varnames, 1))
            t_tgt = draw(st.sampled_from(varnames))
            t_expr = draw(expr_strategy(varnames, 1))
            has_else = draw(st.booleans())
            e_tgt = draw(st.sampled_from(varnames)) if has_else else None
            e_expr = draw(expr_strategy(varnames, 1)) if has_else else None
            ret_in_then = draw(st.booleans())
            ops.append(
                ("ifelse", pred, t_tgt, t_expr, e_tgt, e_expr, ret_in_then)
            )
        elif kind == "maybe_return":
            ops.append(("return", draw(expr_strategy(varnames, 1))))
            has_return = True
            break
    if not has_return:
        ops.append(("return", draw(expr_strategy(varnames, 2))))
    return ops


@settings(
    max_examples=40,
    **ORACLE_SETTINGS,
)
@given(ops=udf_programs(), seed=st.integers(0, 3))
def test_froid_equals_interpreter(ops, seed):
    db = make_db(seed)
    try:
        f = build_udf(ops).build()
    except AssertionError:
        pytest.skip("builder rejected program")
    db.create_function(f)
    q = scan("keys").compute(out=udf("f", col("k") * 1.0)).project("k", "out")

    r_on = db.run(q, froid=True)
    r_off = db.run(q, froid=False, mode="python")

    a = np.asarray(r_on.table.columns["out"].data, dtype=np.float64)
    av = np.asarray(r_on.table.columns["out"].validity())
    b = np.asarray(r_off.table.columns["out"].data, dtype=np.float64)
    bv = np.asarray(r_off.table.columns["out"].validity())

    assert (av == bv).all(), f"validity mismatch: {av} vs {bv}"
    both = av & bv
    np.testing.assert_allclose(a[both], b[both], rtol=2e-3, atol=1e-3)


# --------------------------------------------------------------------------
# differential oracles: FROID == INTERPRETED == HEKATON, and
# execute_many (sharded + unsharded) == the serial execute loop
# --------------------------------------------------------------------------


@settings(max_examples=15, **ORACLE_SETTINGS)
@given(ops=udf_programs(), seed=st.integers(0, 3),
       n_rows=st.sampled_from([0, N_ROWS]))
def test_all_policies_agree_elementwise(ops, seed, n_rows):
    """Mode oracle: the paper's three Table-5 execution modes are
    indistinguishable element-wise on any supported program."""
    try:
        build_udf(ops).build()
    except AssertionError:
        pytest.skip("builder rejected program")
    check_mode_oracle(ops, seed, n_rows)


_param_sets = st.lists(
    st.fixed_dictionaries({
        "cut": st.integers(0, N_KEYS + 1),
        # int vs float shifts have different param signatures, so drawn
        # lists exercise mixed-signature sub-batching
        "shift": st.one_of(
            st.integers(-2, 2),
            st.floats(-2, 2, allow_nan=False, width=32),
        ),
    }),
    min_size=0, max_size=10,
)


@settings(max_examples=10, **ORACLE_SETTINGS)
@given(ops=udf_programs(), seed=st.integers(0, 3),
       n_rows=st.sampled_from([0, N_ROWS]), params_list=_param_sets)
def test_execute_many_equals_serial_loop_oracle(ops, seed, n_rows, params_list):
    """Invocation oracle: one vmapped/sharded device program over the
    stacked parameter axis returns exactly what N serial executions do —
    for any supported UDF, any mixed-signature parameter list, and empty
    tables."""
    try:
        build_udf(ops).build()
    except AssertionError:
        pytest.skip("builder rejected program")
    check_invocation_oracle(ops, seed, n_rows, params_list)


# --------------------------------------------------------------------------
# generative loop oracle (ISSUE-6): Aggify-rewritten cursor loops ==
# per-row interpreted loops, across policies and invocation surfaces
# --------------------------------------------------------------------------

#: loop spec space: body shape × extra termination guard × early-exit
#: BREAK threshold.  Guard/break force scan-kind lowering on commutative
#: bodies; ``plain_while`` exercises the explicit non-rewritable fallback.
_loop_specs = st.tuples(
    st.sampled_from(LOOP_BODIES),
    st.one_of(st.none(), st.sampled_from([5.0, 40.0])),
    st.one_of(st.none(), st.sampled_from([15.0, 75.0])),
)

#: shifts below -1 drive the cursor's ``fk <= @x`` filter empty for small
#: ``k`` — the empty-cursor rows ride inside non-empty invocations
_loop_param_sets = st.lists(
    st.fixed_dictionaries({
        "cut": st.integers(0, N_KEYS + 1),
        "shift": st.one_of(
            st.integers(-2, 2),
            st.floats(-2, 2, allow_nan=False, width=32),
        ),
    }),
    min_size=1, max_size=4,
)


@settings(max_examples=25, **ORACLE_SETTINGS)
@given(spec=_loop_specs, seed=st.integers(0, 3),
       n_rows=st.sampled_from([0, N_ROWS]), params_list=_loop_param_sets)
def test_loop_udf_policies_and_invocation_agree(spec, seed, n_rows,
                                                params_list):
    """Loop oracle: for any generated loop spec, FROID's LoopScan rewrite,
    the host interpreter, and the traced scan interpreter agree
    element-wise, and execute_many (sharded + unsharded) equals the serial
    loop — empty cursor relations and early-exit loops included."""
    body, guard_cap, break_cap = spec
    check_loop_oracle(body, guard_cap, break_cap, seed, n_rows, params_list)


# --------------------------------------------------------------------------
# generative fusion oracle: multi-statement queues with deliberately
# overlapping subtrees (ISSUE-5) — fused drain == per-statement serial loop
# --------------------------------------------------------------------------

#: 2-3 statements per queue, drawn from the overlap spec space: every
#: statement scans ``facts`` (shared scans); parameterized filters drawn
#: with colliding and non-colliding names exercise parameter-unified
#: templates; ``nested`` bodies put shared aggregates inside scalar
#: subqueries; ``lit``/``none`` shapes mix in constant sharing and
#: parameter-free members
_overlap_specs = st.lists(
    st.tuples(
        st.sampled_from(OVERLAP_BODIES),
        st.sampled_from(OVERLAP_FILTERS),
        st.sampled_from(OVERLAP_PNAMES),
    ),
    min_size=2, max_size=3,
)

#: int vs float ticket values split members by signature (mixed-signature
#: sub-batching inside the fused program); the narrow range makes repeated
#: values likely, so binding pools see d < k distinct bindings
_ticket_values = st.lists(
    st.one_of(
        st.integers(0, 6),
        st.floats(0, 8, allow_nan=False, width=32),
    ),
    min_size=2, max_size=8,
)


@settings(max_examples=200, **ORACLE_SETTINGS)
@given(specs=_overlap_specs, values=_ticket_values, seed=st.integers(0, 3),
       n_rows=st.sampled_from([0, N_ROWS]),
       policy_kind=st.sampled_from(["froid", "hekaton", "froid_sharded"]),
       ddl=st.booleans())
def test_fusion_queue_equals_serial_loop_oracle(specs, values, seed, n_rows,
                                                policy_kind, ddl):
    """Fusion oracle, generative layer: a fused drain of a random
    overlapping multi-statement queue is element-wise identical to the
    per-statement serial loop — FROID/HEKATON, sharded (over whatever
    device mesh exists) and unsharded, empty tables, and DDL landing
    between submit and drain."""
    queries, calls = overlap_queue(specs, values)
    if policy_kind == "froid_sharded":
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        policy = FROID.sharded(mesh)
    else:
        policy = FROID if policy_kind == "froid" else HEKATON
    check_fusion_oracle(seed, n_rows, policy, calls, queries=queries,
                        ddl=ddl, expect_fused="auto")


# --------------------------------------------------------------------------
# routing oracle, generative layer (ISSUE-8): random overlap queues drained
# repeatedly under the ROUTED preset — whatever configuration the cost
# router flips to between waves, results equal the static FROID serial
# oracle element-wise
# --------------------------------------------------------------------------


@settings(max_examples=40, **ORACLE_SETTINGS)
@given(specs=_overlap_specs, values=_ticket_values, seed=st.integers(0, 3),
       n_rows=st.sampled_from([0, N_ROWS]),
       fuse=st.booleans(), shard=st.booleans(),
       waves=st.integers(1, 3))
def test_routing_oracle_random_queues(specs, values, seed, n_rows, fuse,
                                      shard, waves):
    """Routing oracle, generative layer: for any overlap queue, any wave
    count (routes flip as measurements accrue), fused or unfused drains,
    sharded or not — cost-based routing changes costs, never results."""
    queries, calls = overlap_queue(specs, values)
    check_routing_oracle(seed, n_rows, fuse=fuse, shard=shard, waves=waves,
                         calls_spec=calls, queries=queries)


# --------------------------------------------------------------------------
# fleet oracle, generative layer (ISSUE-9): random mixed-statement
# multi-tenant queues over a fleet sharing one persistent plan store —
# fleet drain == single-worker serial drain, wherever round-robin lands
# each request and whatever the store serves
# --------------------------------------------------------------------------

#: mixed-statement queue over the fusion statements: q0 (UDF + params,
#: int vs float ``cut`` re-specializes), q1 (arithmetic filter), q2
#: (parameter-free) — multi-tenant in the sense that interleaved tenants'
#: requests hit different statements with different signatures
_fleet_calls = st.lists(
    st.one_of(
        st.tuples(st.just(0), st.fixed_dictionaries({
            "cut": st.one_of(
                st.integers(0, N_KEYS + 1),
                st.floats(0, N_KEYS, allow_nan=False, width=32),
            ),
            "shift": st.floats(-2, 2, allow_nan=False, width=32),
        })),
        st.tuples(st.just(1), st.fixed_dictionaries({
            "minq": st.integers(0, 8),
            "scale": st.floats(-2, 2, allow_nan=False, width=32),
        })),
        st.tuples(st.just(2), st.none()),
    ),
    min_size=1, max_size=8,
)


@settings(max_examples=10, **ORACLE_SETTINGS)
@given(calls=_fleet_calls, seed=st.integers(0, 3),
       n_rows=st.sampled_from([0, N_ROWS]),
       workers=st.integers(1, 3), waves=st.integers(1, 2),
       ddl=st.booleans(), persist=st.booleans())
def test_fleet_oracle_random_queues(calls, seed, n_rows, workers, waves,
                                    ddl, persist):
    """Fleet oracle, generative layer: any mixed-statement multi-tenant
    queue, any worker count, with or without a shared persistent store,
    with DDL broadcasts landing mid-wave — the fleet drain equals the
    single-worker serial drain element-wise."""
    import tempfile

    store = tempfile.mkdtemp() if persist else None
    check_fleet_oracle(seed, n_rows, workers=workers, store=store,
                       calls_spec=calls, ddl=ddl, waves=waves)


# --------------------------------------------------------------------------
# chaos oracle, generative layer (ISSUE-7): random seeded fault schedules
# through the same check the fixed suite (tests/test_resilience.py) drives
# --------------------------------------------------------------------------

#: which executor seams a schedule may fault; every combination keeps the
#: oracle's contract, but only schedules excluding "interp" must end with
#: every ticket carrying the fault-free answer (the ladder's floor)
_chaos_sites = st.sampled_from([
    ("compile",),
    ("dispatch",),
    ("sync",),
    ("compile", "dispatch"),
    ("dispatch", "sync"),
    ("compile", "dispatch", "sync"),
    ("compile", "dispatch", "sync", "interp"),
])


@settings(max_examples=40, **ORACLE_SETTINGS)
@given(chaos_seed=st.integers(0, 10**6),
       rate=st.floats(0.05, 0.8),
       sites=_chaos_sites,
       seed=st.integers(0, 3),
       n_rows=st.sampled_from([0, N_ROWS]),
       max_faults=st.one_of(st.none(), st.integers(1, 6)))
def test_chaos_oracle_random_fault_schedules(chaos_seed, rate, sites, seed,
                                             n_rows, max_faults):
    """Chaos oracle, generative layer: under ANY seeded deterministic
    fault schedule — any seam subset, any rate, bounded or unbounded —
    every ticket of a fused mixed-statement drain gets either the
    fault-free oracle's answer or an explicit typed error; never wrong
    data, never a hung ticket.  Schedules that spare the interp floor
    must recover every ticket (asserted inside the check)."""
    check_chaos_oracle(seed, n_rows, chaos_seed=chaos_seed, rate=rate,
                       sites=sites, max_faults=max_faults)
