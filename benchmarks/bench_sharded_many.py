"""Mesh-sharded batched invocation: single-device `execute_many` vs the
same batch sharded over every available device (param axis over the mesh's
data axes, catalog replicated).

Run under a forced host-device count so a CPU-only box exposes a mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.bench_sharded_many [--quick]

Rows:
    shardmany/serial/256      — serial `execute` loop reference
    shardmany/1dev/N          — single-device `execute_many` (PR-2 path)
    shardmany/sharded/N       — mesh-sharded `execute_many`

`derived` on the sharded rows records speedup vs the 1dev arm plus the
shard/device/host-CPU counts the run actually had — a CPU host mesh shares
cores and memory bandwidth between its forced devices, so the sharded
margin scales with physical parallelism (on a 2-core container the two
arms nearly tie; accelerator meshes and many-core hosts are where the
sharded path pulls away).  Element-wise identity between all three arms is
asserted before timing; a parity failure fails the suite.
"""
from __future__ import annotations

import os
import time

import numpy as np

import jax

from benchmarks.common import emit
from repro.core import (
    FROID,
    Session,
    UdfBuilder,
    col,
    lit,
    param,
    scan,
    sum_,
    udf,
    var,
)

M_ROWS = 20_000
N_T = 2_000
M_ROWS_QUICK = 5_000
N_T_QUICK = 500
SERIAL_N = 256
# the CI gate reads the N=4096 row
SWEEP = (1024, 4096)


def _setup(quick: bool) -> Session:
    m = M_ROWS_QUICK if quick else M_ROWS
    n = N_T_QUICK if quick else N_T
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 400, m),
        d_val=rng.uniform(0, 100, m).astype(np.float32),
    )
    db.create_table("T", a=rng.integers(0, 400, n))
    u = UdfBuilder("key_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    with u.if_(var("s").is_null()):
        u.return_(lit(0.0))
    u.return_(var("s"))
    db.create_function(u.build())
    return db


def _q():
    return (
        scan("T")
        .filter(col("a") < param("cutoff"))
        .compute(v=udf("key_total", col("a")))
        .project("v")
    )


def _check_identical(expected, got):
    for s, b in zip(expected, got):
        m = np.asarray(s.masked.mask)
        np.testing.assert_array_equal(m, np.asarray(b.masked.mask))
        # surviving rows only: dead lanes carry arbitrary values and may
        # legitimately differ between compilations/partitionings
        np.testing.assert_allclose(
            np.asarray(s.masked.table.columns["v"].data)[m],
            np.asarray(b.masked.table.columns["v"].data)[m],
            rtol=1e-5,
        )


def _time_many(stmt, params_list, iters: int = 5) -> float:
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        stmt.execute_many(params_list)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(quick: bool = False):
    db = _setup(quick)
    devices = len(jax.devices())
    mesh = jax.make_mesh((devices,), ("data",))
    cpus = os.cpu_count() or 1
    rng = np.random.default_rng(7)

    one = db.prepare(_q(), FROID.batched(max_batch=1024))
    # max_batch bounds the per-device batch: cap the global mesh dispatch
    # at the largest sweep point so N=4096 goes down in one program
    sharded = db.prepare(
        _q(),
        FROID.sharded(mesh).batched(max_batch=max(1, max(SWEEP) // devices)),
    )
    one.execute(params={"cutoff": 1})  # unbatched jit

    serial_params = [
        {"cutoff": int(c)} for c in rng.integers(1, 400, SERIAL_N)
    ]
    t0 = time.perf_counter()
    serial_r = [one.execute(params=p) for p in serial_params]
    t_serial = time.perf_counter() - t0
    emit(f"shardmany/serial/{SERIAL_N}", t_serial / SERIAL_N * 1e6,
         f"{SERIAL_N} dispatch+sync round trips")
    # the serial loop is the ground truth: both batched arms must match it
    _check_identical(serial_r, one.execute_many(serial_params))
    _check_identical(serial_r, sharded.execute_many(serial_params))

    for n in SWEEP:
        params_list = [{"cutoff": int(c)} for c in rng.integers(1, 400, n)]
        # parity first (also pays both arms' vmapped/sharded jit)
        r1 = one.execute_many(params_list)
        r8 = sharded.execute_many(params_list)
        _check_identical(r1, r8)

        t_one = _time_many(one, params_list)
        emit(f"shardmany/1dev/{n}", t_one / n * 1e6,
             f"bucket={r1[0].stats.get('batch_bucket')}")
        t_shard = _time_many(sharded, params_list)
        st = r8[0].stats
        emit(
            f"shardmany/sharded/{n}", t_shard / n * 1e6,
            f"speedup={t_one / t_shard:.2f}x "
            f"devices={devices} host_cpus={cpus} "
            f"sharded={st.get('sharded', False)} "
            f"bucket={st.get('batch_bucket')}",
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
