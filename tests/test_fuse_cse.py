"""Cross-statement CSE engine (ISSUE-5 contract): parameter-unified
templates, nested sharing, binding-pooled evaluation, template cache
keying, correlated-template identity, explain surfacing, and per-group
error isolation in fused drains.

The metamorphic layer: merge-stats monotonicity, exact pool-evaluation
counts (a subtree shared by k members with d distinct bindings evaluates
exactly d times), and arrival-order-independent template cache keys.
Runs everywhere (no hypothesis needed — the generative strategy in
``test_property_froid.py`` drives the same oracles in CI); the
deterministic overlap-queue driver at the bottom replays fixed samples of
the generative spec space.
"""
import numpy as np
import pytest

from repro.core import (
    FROID,
    HEKATON,
    Session,
    col,
    lit,
    param,
    scan,
    sum_,
)
from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.frontend import scalar_subquery
from repro.core.session import parametric_fingerprint, plan_fingerprint
from repro.fuse import (
    merge_plans,
    rewrite_params,
    subtree_shape,
)
from repro.serve.scheduler import CoalescingScheduler
from conformance_util import (
    OVERLAP_BODIES,
    OVERLAP_FILTERS,
    OVERLAP_PNAMES,
    check_fusion_oracle,
    overlap_queue,
)


def _populate(db, n_detail=600, n_t=80, seed=0):
    rng = np.random.default_rng(seed)
    db.create_table(
        "detail",
        d_key=rng.integers(0, 40, n_detail),
        d_val=rng.uniform(0, 100, n_detail).astype(np.float32),
    )
    db.create_table("T", a=rng.integers(0, 40, n_t))


@pytest.fixture
def db():
    s = Session()
    _populate(s)
    return s


def _assert_same(serial, fused):
    assert len(serial) == len(fused)
    for s, f in zip(serial, fused):
        m = np.asarray(s.masked.mask)
        np.testing.assert_array_equal(m, np.asarray(f.masked.mask))
        for n, c in s.masked.table.columns.items():
            np.testing.assert_allclose(
                np.asarray(f.masked.table.columns[n].data)[m],
                np.asarray(c.data)[m], rtol=1e-5,
            )


def _agg_filtered(pname: str, out: str = "s"):
    """GroupAgg-over-filter subtree parameterized by ``pname`` — the
    canonical param-unified template of this suite."""
    return (scan("detail").filter(col("d_val") > param(pname))
            .agg(**{out: sum_(col("d_val"))}))


def _q_template(pname: str, out_col: str):
    """Statement whose compute rides the shared parameterized aggregate."""
    return (
        scan("T")
        .compute(**{out_col: scalar_subquery(_agg_filtered(pname).node, "s")
                    + col("a") * 0.0})
        .project("a", out_col)
    )


# ---------------------------------------------------------------------------
# parametric fingerprints (unification rules)
# ---------------------------------------------------------------------------


def test_parametric_fingerprint_unifies_modulo_param_names():
    p1 = R.Filter(R.Scan("detail"), col("d_val") > param("x"))
    p2 = R.Filter(R.Scan("detail"), col("d_val") > param("y"))
    assert plan_fingerprint(p1) != plan_fingerprint(p2)
    fp1, holes1 = parametric_fingerprint(p1)
    fp2, holes2 = parametric_fingerprint(p2)
    assert fp1 == fp2
    assert holes1 == (("param", "x"),) and holes2 == (("param", "y"),)


def test_parametric_fingerprint_repetition_pattern():
    """``Param(a) + Param(a)`` must not unify with ``Param(x) + Param(y)``
    — hole numbering is per distinct name."""
    twice = R.Filter(R.Scan("T"), param("a") + param("a") > col("a"))
    mixed = R.Filter(R.Scan("T"), param("x") + param("y") > col("a"))
    assert parametric_fingerprint(twice)[0] != parametric_fingerprint(mixed)[0]
    # and the repetition shape itself is name-insensitive
    twice2 = R.Filter(R.Scan("T"), param("b") + param("b") > col("a"))
    assert parametric_fingerprint(twice)[0] == parametric_fingerprint(twice2)[0]


def test_parametric_fingerprint_kinds_are_distinct():
    """A param hole never unifies with an outer hole."""
    viap = R.Filter(R.Scan("detail"), col("d_key") <= param("k"))
    viao = R.Filter(R.Scan("detail"), col("d_key") <= S.Outer("k"))
    assert parametric_fingerprint(viap)[0] != parametric_fingerprint(viao)[0]
    # hole-free trees fingerprint exactly like plan_fingerprint
    free = R.Filter(R.Scan("detail"), col("d_key") <= lit(5))
    assert parametric_fingerprint(free)[0] == plan_fingerprint(free)


def test_subtree_shape_classes():
    assert subtree_shape(R.Scan("T")) == "const"
    assert subtree_shape(
        R.Filter(R.Scan("T"), col("a") < param("c"))) == "param"
    assert subtree_shape(
        R.Filter(R.Scan("T"), col("a") < S.Outer("o"))) == "corr"
    assert subtree_shape(
        R.Compute(R.Scan("T"), {"r": S.Func("rand", [])})) is None
    assert subtree_shape(
        R.Filter(R.Scan("T"), col("a") < S.Var("v"))) is None


def test_rewrite_params_descends_into_subquery_plans():
    inner = R.Filter(R.Scan("detail"), col("d_val") > param("x"))
    const_side = R.Scan("T")
    plan = R.Compute(const_side, {"v": S.ScalarSubquery(inner, None)})
    out = rewrite_params(plan, {"x": "__cse_s0"})
    names = {
        s.name for n in R.walk_plan_deep(out)
        for e in n.exprs() for s in S.walk(e) if isinstance(s, S.Param)
    }
    assert names == {"__cse_s0"}
    # untouched subtrees keep identity (their node_id marks stay valid)
    assert out.child is const_side


# ---------------------------------------------------------------------------
# merge pass: templates, correlated identity, monotonicity
# ---------------------------------------------------------------------------


def test_merge_extracts_parameter_unified_templates(db):
    p1 = db.prepare(_q_template("x", "v1"), FROID).plan
    p2 = db.prepare(_q_template("y", "v2"), FROID).plan
    merged = merge_plans([p1, p2])
    assert merged.stats["cse_templates"] >= 1
    assert merged.stats["cse_template_refs"] >= 2
    # occurrence bindings map the canonical hole back to each actual name
    actuals = {
        tuple(b.values()) for b in merged.template_binds.values()
    }
    assert ("x",) in actuals and ("y",) in actuals
    # canonical template subtrees carry the canonical hole spelling
    tnames = {
        s.name for t in merged.templates
        for n in R.walk_plan_deep(t.node)
        for e in n.exprs() for s in S.walk(e) if isinstance(s, S.Param)
    }
    assert tnames and all(n.startswith("__cse_s") for n in tnames)


def test_merge_corr_templates_unify_modulo_outer_binding(db):
    """Correlated subquery bodies differing only in their outer binding
    route through the same template path (one unified identity)."""
    body_a = (scan("detail").filter(col("d_key") <= S.Outer("a"))
              .agg(s=sum_(col("d_val"))))
    body_b = (scan("detail").filter(col("d_key") <= S.Outer("b"))
              .agg(s=sum_(col("d_val"))))
    qa = scan("T").compute(v=scalar_subquery(body_a.node, "s")).project("a", "v")
    qb = (scan("T").compute(b=col("a") * 1)
          .compute(w=scalar_subquery(body_b.node, "s")).project("b", "w"))
    pa = db.prepare(qa, FROID).plan
    pb = db.prepare(qb, FROID).plan
    merged = merge_plans([pa, pb])
    assert merged.stats["cse_corr_templates"] >= 1
    assert merged.stats["cse_corr_refs"] >= 2
    assert "correlated templates" in merged.explain()


def test_merge_stats_monotonic_in_members(db):
    """Adding an overlapping member never decreases cse_shared_nodes (and
    the count is arrival-order independent)."""
    plans = [
        db.prepare(_q_template("x", "v1"), FROID).plan,
        db.prepare(_q_template("y", "v2"), FROID).plan,
        db.prepare(scan("T").compute(z=col("a") * 2).project("z"), FROID).plan,
        db.prepare(_q_template("z", "v3"), FROID).plan,
    ]
    prev = 0
    for k in range(1, len(plans) + 1):
        cur = merge_plans(plans[:k]).stats["cse_shared_nodes"]
        assert cur >= prev, (k, cur, prev)
        prev = cur
    assert prev > 0
    for perm in ([1, 0, 3, 2], [3, 2, 1, 0]):
        permuted = merge_plans([plans[i] for i in perm])
        assert permuted.stats["cse_shared_nodes"] == prev


# ---------------------------------------------------------------------------
# binding-pooled evaluation: exact counts
# ---------------------------------------------------------------------------


def _template_eval_counts(entry):
    """Template pool keys in an executable's eval counter: ``(fp, sig)``
    pairs, distinguishable from constant keys (whose first element is the
    node-kind string)."""
    return {k: v for k, v in entry.eval_counts.items()
            if isinstance(k, tuple) and k and isinstance(k[0], tuple)}


def test_pool_evaluates_exactly_d_distinct_bindings(db):
    """A subtree shared by k members with d distinct bindings evaluates
    exactly d times — the acceptance criterion, asserted through the
    SharedScanExecutor eval counter and the per-wave stats."""
    s1 = db.prepare(_q_template("x", "v1"), FROID)
    s2 = db.prepare(_q_template("y", "v2"), FROID)
    values = [10.0, 30.0, 10.0, 55.0, 30.0, 10.0]  # d = 3 distinct
    calls = [((s1, {"x": v}) if i % 2 == 0 else (s2, {"y": v}))
             for i, v in enumerate(values)]
    fused = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], fused)
    st = fused[0].stats
    assert st["fused"] and st["cse_template_groups"] >= 1
    assert st["cse_bindings"] == 3
    entry = next(iter(db._fuse_execs.values()))
    tcounts = _template_eval_counts(entry)
    assert tcounts and sum(tcounts.values()) == 3
    # constant pool entries evaluated exactly once each
    ccounts = {k: v for k, v in entry.eval_counts.items()
               if k not in tcounts}
    assert ccounts and all(v == 1 for v in ccounts.values())


def test_pool_count_insensitive_to_padding(db):
    """Bucket padding repeats the last ticket; the pad rows must reuse its
    pool slot, never mint extra bindings."""
    s1 = db.prepare(_q_template("x", "v1"), FROID)
    s2 = db.prepare(_q_template("y", "v2"), FROID)
    # 3 tickets for s1 -> bucket 4 (one pad row); 1 ticket for s2
    calls = [(s1, {"x": 10.0}), (s1, {"x": 20.0}), (s1, {"x": 10.0}),
             (s2, {"y": 20.0})]
    fused = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], fused)
    assert fused[0].stats["cse_bindings"] == 2  # {10.0, 20.0}, cross-member


def test_nested_shared_subtree_dedups_between_roots(db):
    """A shared sub-subtree beneath two distinct shared roots evaluates
    once — the roots' pool builds answer it from the pool."""
    base = lambda: scan("detail").filter(col("d_val") > lit(50.0))  # noqa: E731
    q1 = base().group_by("d_key", s=sum_(col("d_val")))
    q2 = base().compute(w=col("d_val") * 2.0).project("d_key", "w")
    s1 = db.prepare(q1, FROID)
    s2 = db.prepare(q2, FROID)
    calls = [(s1, None), (s2, None), (s1, {}), (s2, {})]
    fused = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], fused)
    entry = next(iter(db._fuse_execs.values()))
    # every pool entry (roots AND the nested Filter/Scan beneath them)
    # evaluated exactly once
    assert entry.eval_counts and all(
        v == 1 for v in entry.eval_counts.values())
    assert len(entry.eval_counts) >= 2


def test_correlated_bodies_share_interior_subtrees(db):
    """Interior constant work of correlated subquery bodies dedups via the
    sub-executor propagation, and parity holds for surviving (non-equi)
    correlated subqueries under fusion."""
    body_a = (scan("detail").filter(col("d_key") <= S.Outer("a"))
              .agg(s=sum_(col("d_val"))))
    body_b = (scan("detail").filter(col("d_key") <= S.Outer("b"))
              .agg(s=sum_(col("d_val"))))
    qa = scan("T").compute(v=scalar_subquery(body_a.node, "s")).project("a", "v")
    qb = (scan("T").compute(b=col("a") * 1)
          .compute(w=scalar_subquery(body_b.node, "s")).project("b", "w"))
    sa = db.prepare(qa, FROID)
    sb = db.prepare(qb, FROID)
    calls = [(sa, None), (sb, None)]
    fused = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], fused)
    st = fused[0].stats
    assert st["fused"] and st["cse_corr_templates"] >= 1


# ---------------------------------------------------------------------------
# const-vs-param unification (ISSUE-6 satellite): ``a < 5`` joins the
# ``a < Param(x)`` template pool as one more distinct binding
# ---------------------------------------------------------------------------


def _q_const_template(value, out_col: str):
    """The ``_q_template`` shape with a literal where the param goes."""
    inner = (scan("detail").filter(col("d_val") > lit(value))
             .agg(s=sum_(col("d_val"))))
    return (
        scan("T")
        .compute(**{out_col: scalar_subquery(inner.node, "s")
                    + col("a") * 0.0})
        .project("a", out_col)
    )


def test_lifted_fingerprint_unifies_const_and_param():
    p = R.Filter(R.Scan("detail"), col("d_val") > param("x"))
    c = R.Filter(R.Scan("detail"), col("d_val") > lit(5.0))
    # plain fingerprints differ; lifted fingerprints unify
    assert parametric_fingerprint(p)[0] != parametric_fingerprint(c)[0]
    fp_p, holes_p = parametric_fingerprint(p, lift_consts=True)
    fp_c, holes_c = parametric_fingerprint(c, lift_consts=True)
    assert fp_p == fp_c
    assert holes_p == (("param", "x"),)
    assert holes_c == (("const", ("float", 5.0)),)
    # lifted fps live in their own namespace: never equal to plain fps
    assert fp_p != parametric_fingerprint(p)[0]


def test_lifted_fingerprint_is_dtype_aware():
    """int 5 and float 5.0 hash equal as dict keys but evaluate int32 vs
    float32 — they must number as distinct holes, so ``5 + 5.0`` never
    aliases into ``hole0 + hole0``."""
    mixed = R.Filter(R.Scan("T"), lit(5) + lit(5.0) > col("a"))
    same = R.Filter(R.Scan("T"), lit(5) + lit(5) > col("a"))
    _, holes_mixed = parametric_fingerprint(mixed, lift_consts=True)
    assert holes_mixed == (("const", ("int", 5)), ("const", ("float", 5.0)))
    assert (parametric_fingerprint(mixed, lift_consts=True)[0]
            != parametric_fingerprint(same, lift_consts=True)[0])


def test_merge_promotes_mixed_const_param_group(db):
    from repro.fuse import CONST_BIND

    pa = db.prepare(_q_template("p", "v1"), FROID).plan
    pb = db.prepare(_q_const_template(30.0, "v2"), FROID).plan
    merged = merge_plans([pa, pb])
    assert merged.stats["cse_lifted_templates"] >= 1
    const_binds = [
        b for b in merged.template_binds.values()
        if any(isinstance(v, tuple) and v[0] == CONST_BIND
               for v in b.values())
    ]
    assert const_binds
    assert any(v == (CONST_BIND, 30.0)
               for b in const_binds for v in b.values())
    assert "__const__" in merged.explain()


def test_merge_does_not_promote_all_param_or_all_const_groups(db):
    """Promotion needs the mixed group: all-param groups already unify
    plainly, all-const groups are better served by the constant pool."""
    pa = db.prepare(_q_template("x", "v1"), FROID).plan
    pb = db.prepare(_q_template("y", "v2"), FROID).plan
    m1 = merge_plans([pa, pb])
    assert m1.stats["cse_templates"] >= 1
    assert m1.stats["cse_lifted_templates"] == 0
    pc = db.prepare(_q_const_template(30.0, "v3"), FROID).plan
    pd = db.prepare(_q_const_template(30.0, "v4"), FROID).plan
    m2 = merge_plans([pc, pd])
    assert m2.stats["cse_lifted_templates"] == 0
    assert m2.stats["shared_subtrees"] >= 1  # const pool takes it


def test_lifted_pool_coinciding_binding_evaluates_once(db):
    """The acceptance criterion: when a ticket binds the param to the
    literal's value, the const-shaped member joins the same pool slot —
    exactly one template evaluation for the whole wave."""
    s1 = db.prepare(_q_template("p", "v1"), FROID)
    s2 = db.prepare(_q_const_template(30.0, "v2"), FROID)
    calls = [(s1, {"p": 30.0}), (s2, None), (s1, {"p": 30.0})]
    fused = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], fused)
    st = fused[0].stats
    assert st["fused"] and st["cse_lifted_templates"] >= 1
    assert st["cse_bindings"] == 1
    entry = next(iter(db._fuse_execs.values()))
    tcounts = _template_eval_counts(entry)
    assert tcounts and sum(tcounts.values()) == 1


def test_lifted_pool_distinct_bindings_evaluate_d_times(db):
    """Param value differing from the literal: two distinct bindings, two
    evaluations — no more."""
    s1 = db.prepare(_q_template("p", "v1"), FROID)
    s2 = db.prepare(_q_const_template(30.0, "v2"), FROID)
    calls = [(s1, {"p": 55.0}), (s2, None), (s1, {"p": 55.0}), (s2, {})]
    fused = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], fused)
    assert fused[0].stats["cse_bindings"] == 2
    entry = next(iter(db._fuse_execs.values()))
    tcounts = _template_eval_counts(entry)
    assert tcounts and sum(tcounts.values()) == 2


# ---------------------------------------------------------------------------
# template cache keying
# ---------------------------------------------------------------------------


def test_template_cache_key_arrival_order_independent(db):
    """Same templates, same distinct-binding counts, different arrival
    order — the fused cache must hit."""
    s1 = db.prepare(_q_template("x", "v1"), FROID)
    s2 = db.prepare(_q_template("y", "v2"), FROID)
    wave1 = [(s1, {"x": 10.0}), (s2, {"y": 20.0}), (s1, {"x": 20.0})]
    r1 = db.execute_fused(wave1)
    assert db.cache_stats["fuse_misses"] == 1 and not r1[0].cache_hit
    # reversed arrival, different values, same distinct-binding count (2)
    wave2 = [(s1, {"x": 70.0}), (s2, {"y": 5.0}), (s1, {"x": 5.0})]
    r2 = db.execute_fused(list(reversed(wave2)))
    assert db.cache_stats["fuse_hits"] == 1
    assert db.cache_stats["fuse_misses"] == 1 and r2[0].cache_hit
    _assert_same([s.execute(params=p) for s, p in reversed(wave2)], r2)


def test_template_cache_respecializes_on_binding_count(db):
    """A changed distinct-binding count is a different device program — it
    must surface as a miss, not hide a retrace behind a warm hit."""
    s1 = db.prepare(_q_template("x", "v1"), FROID)
    s2 = db.prepare(_q_template("y", "v2"), FROID)
    db.execute_fused([(s1, {"x": 10.0}), (s2, {"y": 10.0})])   # d = 1
    misses = db.cache_stats["fuse_misses"]
    rs = db.execute_fused([(s1, {"x": 10.0}), (s2, {"y": 99.0})])  # d = 2
    assert db.cache_stats["fuse_misses"] == misses + 1
    assert rs[0].stats["cse_bindings"] == 2


def test_template_cache_d_bucketing_above_threshold(db):
    """Regression (ISSUE-8 bugfix): above ``CSE_EXACT_D`` the pool pads
    ``d`` to the next power of two, so a drifting distinct-binding count
    (9 → 10, both inside the 16-slot bucket) reuses the compiled fused
    program instead of recompiling per wave — while ``cse_bindings``
    stays the *exact* distinct count and ``cse_pool_slots`` reports the
    padded pool actually evaluated."""
    from repro.core.session import CSE_EXACT_D, _pool_pad

    assert CSE_EXACT_D == 8 and _pool_pad(9) == _pool_pad(10) == 16
    s1 = db.prepare(_q_template("x", "v1"), FROID)
    s2 = db.prepare(_q_template("y", "v2"), FROID)
    # wave 1: d = 9 distinct bindings (5 via s1 + 4 via s2)
    wave1 = ([(s1, {"x": float(10 * i)}) for i in range(5)]
             + [(s2, {"y": float(10 * i + 5)}) for i in range(4)])
    r1 = db.execute_fused(wave1)
    misses = db.cache_stats["fuse_misses"]
    assert r1[0].stats["cse_bindings"] == 9
    assert r1[0].stats["cse_pool_slots"] == 16
    # wave 2: d = 10, same per-member batch buckets — must be a fuse HIT
    wave2 = ([(s1, {"x": float(7 * i + 1)}) for i in range(6)]
             + [(s2, {"y": float(7 * i + 3)}) for i in range(4)])
    r2 = db.execute_fused(wave2)
    assert db.cache_stats["fuse_misses"] == misses and r2[0].cache_hit
    assert r2[0].stats["cse_bindings"] == 10  # exact, not padded
    assert r2[0].stats["cse_pool_slots"] == 16
    _assert_same([s.execute(params=p) for s, p in wave2], r2)


def test_template_cache_exact_d_below_threshold(db):
    """At or below ``CSE_EXACT_D`` the pool stays exact: d = 8 → 9
    crosses the threshold and recompiles (8 exact slots vs a padded 16),
    so small pools never pay padding overhead."""
    from repro.core.session import _pool_pad

    assert _pool_pad(8) == 8 and _pool_pad(9) == 16
    s1 = db.prepare(_q_template("x", "v1"), FROID)
    s2 = db.prepare(_q_template("y", "v2"), FROID)
    wave8 = ([(s1, {"x": float(10 * i)}) for i in range(5)]
             + [(s2, {"y": float(10 * i + 5)}) for i in range(3)])
    r8 = db.execute_fused(wave8)
    assert r8[0].stats["cse_bindings"] == 8
    assert r8[0].stats["cse_pool_slots"] == 8  # no padding below threshold
    misses = db.cache_stats["fuse_misses"]
    wave9 = ([(s1, {"x": float(10 * i)}) for i in range(5)]
             + [(s2, {"y": float(10 * i + 5)}) for i in range(4)])
    r9 = db.execute_fused(wave9)
    assert db.cache_stats["fuse_misses"] == misses + 1
    assert r9[0].stats["cse_pool_slots"] == 16
    _assert_same([s.execute(params=p) for s, p in wave9], r9)


def test_d_bucketing_threshold_is_tunable(db, monkeypatch):
    """``CSE_EXACT_D`` is a module knob: dropping it to 2 makes d = 3 → 4
    share one padded 4-slot program (the bench's padded-overhead arm
    tunes it the same way)."""
    from repro.core import session as sess_mod

    monkeypatch.setattr(sess_mod, "CSE_EXACT_D", 2)
    s1 = db.prepare(_q_template("x", "v1"), FROID)
    s2 = db.prepare(_q_template("y", "v2"), FROID)
    r3 = db.execute_fused([(s1, {"x": 10.0}), (s1, {"x": 20.0}),
                           (s2, {"y": 30.0})])
    assert r3[0].stats["cse_bindings"] == 3
    assert r3[0].stats["cse_pool_slots"] == 4
    misses = db.cache_stats["fuse_misses"]
    r4 = db.execute_fused([(s1, {"x": 11.0}), (s1, {"x": 21.0}),
                           (s2, {"y": 31.0})])
    assert db.cache_stats["fuse_misses"] == misses and r4[0].cache_hit


# ---------------------------------------------------------------------------
# explain + session stats surfacing
# ---------------------------------------------------------------------------


def test_fused_explain_surfaces_templates(db):
    s1 = db.prepare(_q_template("x", "v1"), FROID)
    s2 = db.prepare(_q_template("y", "v2"), FROID)
    # both members bind the template to the same value: one pool slot
    # serves two ticket refs, which is a counted cse hit
    rs = db.execute_fused([(s1, {"x": 10.0}), (s2, {"y": 10.0})])
    text = rs[0].stats["fused_explain"]
    assert "parameter-unified templates" in text
    assert "__cse_s0" in text and "'x'" in text and "'y'" in text
    assert "shared constant subtrees" in text
    assert db.cache_stats["cse_shared_nodes"] > 0
    assert db.cache_stats["cse_hits"] > 0


# ---------------------------------------------------------------------------
# per-group error isolation in fused drains
# ---------------------------------------------------------------------------


def test_fused_drain_isolates_failing_member(db):
    """One member referencing a dropped table mid-queue fails only its own
    tickets; every other ticket of the wave still resolves."""
    db.create_table("doomed", x=np.arange(8))
    s_ok1 = db.prepare(_q_template("x", "v1"), FROID)
    s_ok2 = db.prepare(scan("T").compute(z=col("a") * 2).project("z"), FROID)
    s_bad = db.prepare(scan("doomed").compute(y=col("x") + 1).project("y"),
                       FROID)
    sched = CoalescingScheduler(max_batch=64, window_s=10.0,
                                clock=lambda: 0.0, fuse=True)
    t1 = sched.submit(s_ok1, {"x": 10.0})
    tb = sched.submit(s_bad, {})
    t2 = sched.submit(s_ok2, {})
    t3 = sched.submit(s_ok1, {"x": 30.0})
    del db.catalog["doomed"]  # DDL lands between submit and drain
    sched.flush()
    with pytest.raises(KeyError):
        tb.result()
    r1, r2, r3 = t1.result(), t2.result(), t3.result()
    _assert_same(
        [s_ok1.execute(params={"x": 10.0}), s_ok2.execute(),
         s_ok1.execute(params={"x": 30.0})],
        [r1, r2, r3],
    )
    assert sched.stats["fused_isolated_retries"] >= 2
    assert sched.stats["fused_isolated_errors"] == 1


# ---------------------------------------------------------------------------
# overlap-aware chunking (fusability considers template overlap)
# ---------------------------------------------------------------------------


def test_partition_chunks_by_template_overlap(db):
    """When a group must split, statements sharing templates land in the
    same fused program instead of splitting by arrival order."""
    from repro.fuse import partition_calls

    policy = FROID.fused(max_fused_statements=2)
    s_t1 = db.prepare(_q_template("x", "v1"), policy)
    s_c1 = db.prepare(
        scan("detail").filter(col("d_val") > lit(50.0))
        .group_by("d_key", s=sum_(col("d_val"))), policy)
    s_t2 = db.prepare(_q_template("y", "v2"), policy)
    s_c2 = db.prepare(
        scan("detail").filter(col("d_val") > lit(50.0))
        .compute(w=col("d_val") * 2.0).project("d_key", "w"), policy)
    # arrival order interleaves the two overlap families
    calls = [(s_t1, {"x": 1.0}), (s_c1, {}), (s_t2, {"y": 2.0}), (s_c2, {})]
    groups, fallbacks = partition_calls(db, calls)
    assert len(groups) == 2 and not fallbacks
    families = [
        {id(stmt) for _, stmt, _ in g} for g in groups
    ]
    assert {id(s_t1), id(s_t2)} in families
    assert {id(s_c1), id(s_c2)} in families
    rs = db.execute_fused(calls)
    _assert_same([s.execute(params=p) for s, p in calls], rs)


# ---------------------------------------------------------------------------
# deterministic overlap-queue driver (fixed samples of the generative
# spec space; the hypothesis strategy in test_property_froid.py draws from
# the same space in CI)
# ---------------------------------------------------------------------------

FIXED_OVERLAP_QUEUES = [
    # param-unified filters, different names, mixed bodies
    ([("proj", "qty_ge", "p"), ("agg", "qty_ge", "q")],
     [2, 5, 2, 7, 5]),
    # nested shared aggregates modulo parameter values
    ([("nested", "none", "p"), ("nested", "val_gt", "q"), ("proj", "lit", "p")],
     [1.5, 3.0, 1.5, 8.0]),
    # constant sharing + parameter-free members
    ([("agg", "lit", "p"), ("proj", "lit", "q"), ("proj", "none", "p")],
     [0, 0, 0]),
    # same spec twice (distinct statements via the output-column salt,
    # maximal template overlap) plus a parameter-free third
    ([("proj", "val_gt", "p"), ("proj", "val_gt", "p"), ("agg", "none", "q")],
     [4.0, 9.0, 4.0, 2.0]),
]


@pytest.mark.parametrize("policy", [FROID, HEKATON], ids=["froid", "hekaton"])
@pytest.mark.parametrize("case_i", range(len(FIXED_OVERLAP_QUEUES)))
def test_fixed_overlap_queues(policy, case_i):
    specs, values = FIXED_OVERLAP_QUEUES[case_i]
    queries, calls = overlap_queue(specs, values)
    check_fusion_oracle(20 + case_i, 23, policy, calls, queries=queries,
                        expect_fused="auto")


def test_overlap_spec_space_is_covered():
    """The fixed queues sample every body/filter axis the generative
    strategy draws from."""
    bodies = {b for specs, _ in FIXED_OVERLAP_QUEUES for b, _, _ in specs}
    filters = {f for specs, _ in FIXED_OVERLAP_QUEUES for _, f, _ in specs}
    names = {p for specs, _ in FIXED_OVERLAP_QUEUES for _, _, p in specs}
    assert bodies == set(OVERLAP_BODIES)
    assert filters == set(OVERLAP_FILTERS)
    assert names == set(OVERLAP_PNAMES)
