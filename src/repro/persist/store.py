"""On-disk plan store: atomic, version-stamped, corruption-typed.

Entry layout (one file per key, named ``<sha256(key)>.plan``)::

    MAGIC (8 bytes)  b"RPRPLAN\\x01"
    u32              header length (little-endian)
    header           UTF-8 JSON: {"stamp": .., "key": repr(key),
                                  "meta": .., "blob_len": .., "blob_sha256": ..}
    blob             opaque payload (serialized executable, cost table, ...)

Integrity is end-to-end: the header carries the blob's length and sha256, so
truncation or bit-rot anywhere in the file surfaces as a typed
:class:`PlanCacheCorruptError` — callers degrade to recompile, never consume
a partial plan.  Writes go through a temp file in the same directory followed
by ``os.replace``, so a reader can never observe a half-written entry and the
last concurrent writer wins cleanly.
"""
from __future__ import annotations

import hashlib
import json
import os
import struct
import tempfile
from pathlib import Path

from repro.persist.keys import assert_stable_key, key_digest

#: Bump on any incompatible change to entry payloads or key layout; old
#: entries are then rejected (recompile) instead of misread.
PERSIST_SCHEMA_VERSION = 1

_MAGIC = b"RPRPLAN\x01"
_LEN = struct.Struct("<I")


class PlanCacheError(Exception):
    """Base class for persistent plan-tier failures."""


class PlanCacheCorruptError(PlanCacheError):
    """Entry bytes are damaged (bad magic, truncation, digest mismatch)."""


class PlanCacheVersionError(PlanCacheError):
    """Entry was written under an incompatible runtime/schema stamp."""


class PlanCacheWarning(UserWarning):
    """Emitted when a session degrades to recompile after a bad entry."""


def runtime_stamp() -> dict:
    """The compatibility stamp embedded in (and checked against) every entry.

    Serialized XLA executables are native artifacts: they are only valid for
    the jax/jaxlib pair, backend and device count that produced them, so all
    of those participate in the stamp alongside the repro schema version.
    """
    import jax
    import jaxlib

    return {
        "schema": PERSIST_SCHEMA_VERSION,
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "platform": jax.default_backend(),
        "devices": jax.device_count(),
    }


class PlanStore:
    """A directory of version-stamped, atomically-written cache entries.

    The store is deliberately dumb: it maps stable keys to ``(meta, blob)``
    pairs and enforces integrity/compatibility.  What the blob *means* (a
    serialized executable, a cost table) is the caller's business — see
    ``repro/persist/codec.py`` and ``repro/persist/costs.py``.
    """

    def __init__(self, root: str | os.PathLike, *, stamp: dict | None = None,
                 max_bytes: int | None = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._stamp = dict(stamp) if stamp is not None else runtime_stamp()
        #: byte budget for the whole directory (None = unbounded, the
        #: historical behavior).  Every ``put`` sweeps back under budget by
        #: evicting least-recently-*used* entries — ``get`` touches an
        #: entry's mtime on a hit, so recency means reads, not just writes.
        self.max_bytes = max_bytes
        self.eviction_stats = {"evictions": 0, "evicted_bytes": 0, "sweeps": 0}

    # -- paths ------------------------------------------------------------
    def path_for(self, key: tuple) -> Path:
        return self.root / f"{key_digest(key)}.plan"

    # -- io ---------------------------------------------------------------
    def put(self, key: tuple, meta: dict, blob: bytes) -> Path:
        """Atomically write an entry (last concurrent writer wins)."""
        assert_stable_key(key)
        header = json.dumps(
            {
                "stamp": self._stamp,
                "key": repr(key),
                "meta": meta,
                "blob_len": len(blob),
                "blob_sha256": hashlib.sha256(blob).hexdigest(),
            },
            sort_keys=True,
        ).encode("utf-8")
        path = self.path_for(key)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(_MAGIC)
                f.write(_LEN.pack(len(header)))
                f.write(header)
                f.write(blob)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._sweep(keep=path)
        return path

    def get(self, key: tuple) -> tuple[dict, bytes] | None:
        """Return ``(meta, blob)``, or ``None`` on a clean miss.

        Raises :class:`PlanCacheVersionError` on a stamp mismatch and
        :class:`PlanCacheCorruptError` on any structural damage.
        """
        path = self.path_for(key)
        try:
            raw = path.read_bytes()
        except FileNotFoundError:
            return None
        except OSError as e:
            raise PlanCacheCorruptError(f"unreadable entry {path.name}: {e}") from e
        if len(raw) < len(_MAGIC) + _LEN.size or raw[: len(_MAGIC)] != _MAGIC:
            raise PlanCacheCorruptError(f"bad magic in entry {path.name}")
        (hlen,) = _LEN.unpack_from(raw, len(_MAGIC))
        hstart = len(_MAGIC) + _LEN.size
        if len(raw) < hstart + hlen:
            raise PlanCacheCorruptError(f"truncated header in entry {path.name}")
        try:
            header = json.loads(raw[hstart : hstart + hlen].decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise PlanCacheCorruptError(
                f"undecodable header in entry {path.name}: {e}"
            ) from e
        blob = raw[hstart + hlen :]
        if len(blob) != header.get("blob_len"):
            raise PlanCacheCorruptError(
                f"truncated blob in entry {path.name}: "
                f"{len(blob)} bytes != {header.get('blob_len')} expected"
            )
        if hashlib.sha256(blob).hexdigest() != header.get("blob_sha256"):
            raise PlanCacheCorruptError(f"blob digest mismatch in entry {path.name}")
        if header.get("stamp") != self._stamp:
            raise PlanCacheVersionError(
                f"entry {path.name} written under stamp {header.get('stamp')}, "
                f"this runtime is {self._stamp}"
            )
        try:
            os.utime(path)  # LRU recency: a hit protects the entry
        except OSError:
            pass
        return header.get("meta", {}), blob

    def delete(self, key: tuple) -> bool:
        try:
            self.path_for(key).unlink()
            return True
        except OSError:
            return False

    # -- eviction ----------------------------------------------------------
    def sweep(self) -> int:
        """Evict least-recently-used entries until the directory fits
        ``max_bytes`` (no-op when unbudgeted).  Returns the entries
        removed.

        Collection is a plain ``unlink`` per victim — atomic at the
        filesystem level, so a concurrent reader either opened the file
        first (and reads the intact inode to the end) or opens after and
        sees a clean miss.  A reader that does catch a torn view on a
        non-POSIX filesystem gets the store's typed
        :class:`PlanCacheCorruptError` and degrades to recompile — the
        same contract as every other store failure; eviction can never
        produce a wrong result, only a miss."""
        return self._sweep()

    def _sweep(self, keep: Path | None = None) -> int:
        if not self.max_bytes:
            return 0
        entries = []
        for p in self.root.glob("*.plan"):
            try:
                st = p.stat()
            except OSError:
                continue  # already collected by a concurrent sweep
            entries.append((st.st_mtime, st.st_size, p))
        total = sum(size for _, size, _ in entries)
        if total <= self.max_bytes:
            return 0
        self.eviction_stats["sweeps"] += 1
        evicted = 0
        for _, size, p in sorted(entries, key=lambda e: (e[0], e[2].name)):
            if total <= self.max_bytes:
                break
            if keep is not None and p == keep:
                continue  # never evict the entry this put just wrote
            try:
                p.unlink()
            except OSError:
                continue  # lost the race to another worker's sweep
            total -= size
            evicted += 1
            self.eviction_stats["evictions"] += 1
            self.eviction_stats["evicted_bytes"] += size
        return evicted

    # -- introspection ----------------------------------------------------
    def entries(self) -> list[Path]:
        return sorted(self.root.glob("*.plan"))

    def nbytes(self) -> int:
        return sum(p.stat().st_size for p in self.entries())

    def stats(self) -> dict:
        entries = self.entries()
        return {
            "root": str(self.root),
            "entries": len(entries),
            "nbytes": sum(p.stat().st_size for p in entries),
            "max_bytes": self.max_bytes,
            **self.eviction_stats,
        }
