"""Public jit'd wrapper for the relagg kernel (auto-interpret off-TPU)."""
import functools

import jax

from repro.kernels.relagg.relagg import relagg_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("num_groups", "block_rows", "interpret"))
def grouped_aggregate(gid, mask, vals, num_groups, block_rows=1024, interpret=None):
    """Fused filter+group+aggregate.  Returns (sums (G, n_aggs), counts (G,)).

    ``interpret=None`` auto-selects: compiled on TPU, interpret elsewhere
    (this container is CPU-only; interpret mode executes the kernel body in
    Python for correctness validation)."""
    if interpret is None:
        interpret = not _on_tpu()
    return relagg_pallas(
        gid, mask, vals, num_groups, block_rows=block_rows, interpret=interpret
    )
