"""Cursor-loop UDFs: per-row interpreted loops vs the Aggify rewrite.

The loop-to-scan rewrite's set-oriented argument (ISSUE-6): a cursor loop
interpreted per invocation walks its cursor relation row by row on the
host — one Python-dispatched step per row per invocation — while the
rewritten plan runs the whole loop as ONE relational operator
(:class:`repro.core.relalg.LoopScan`, a predicated ``lax.scan`` over the
cursor relation) inside the inlined, vmapped, batched device program.

    PYTHONPATH=src python -m benchmarks.bench_cursor_loops [--quick]

Rows:
    cursorloop/interp/<I>         — INTERPRETED serial loop (per-row host
                                    interpretation of the cursor loop)
    cursorloop/rewrite/32         — FROID execute_many, 32 tickets
    cursorloop/rewrite_many/1024  — FROID execute_many, 1024 tickets

``derived`` on the rewrite rows carries speedup vs the interpreted arm
(us/call over us/ticket) plus the verdict kind and host CPU count — the
CI cursorloop gate reads the N=1024 row and requires >= 20x.  The margin
is algorithmic (per-row host stepping vs one device scan), not
parallelism, so the bar holds on small hosts too.  Element-wise identity
between the interpreted and rewritten arms is asserted before timing.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import emit
from repro.core import (
    FROID,
    INTERPRETED,
    CursorLoop,
    Session,
    UdfBuilder,
    col,
    lit,
    param,
    scan,
    udf,
    var,
)
from repro.loops import classify

M_FACTS = 256
M_FACTS_QUICK = 96
N_KEYS = 4
#: interpreted serial calls (each call interprets N_KEYS cursor loops)
INTERP_N = 8
INTERP_N_QUICK = 4
# quick mode keeps the full ticket sweep — the CI gate reads the 1024 row
SWEEP = (32, 1024)


def _setup(quick: bool) -> Session:
    m = M_FACTS_QUICK if quick else M_FACTS
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table(
        "facts",
        fk=rng.integers(0, 8, m),
        val=np.round(rng.uniform(-10, 10, m), 2).astype(np.float32),
        qty=rng.integers(0, 9, m),
    )
    db.create_table("keys", k=np.arange(N_KEYS))
    # order-dependent running fold with an early-exit BREAK: scan-kind
    # lowering (a predicated lax.scan), the rewrite's hardest shape
    u = UdfBuilder("floop", [("x", "float32")], "float32")
    u.declare("t", "float32", lit(0.0))
    u.declare("v", "float32", None)
    with u.cursor_loop({"v": "val"}, scan("facts"),
                       where=col("fk") <= param("x")):
        u.set("t", var("t") * 0.5 + var("v"))
        with u.if_(var("t") > lit(75.0)):
            u.break_()
    u.return_(var("t"))
    f = u.build()
    loop = next(s for s in f.body if isinstance(s, CursorLoop))
    assert classify(loop).kind == "scan"
    db.create_function(f)
    return db


def _q():
    return (
        scan("keys")
        .filter(col("k") < param("cut"))
        .compute(out=udf("floop", col("k") * 1.0 + param("shift")))
        .project("k", "out")
    )


def _params(n: int, seed: int = 7):
    rng = np.random.default_rng(seed)
    return [{"cut": int(c), "shift": float(round(s, 2))}
            for c, s in zip(rng.integers(1, N_KEYS + 1, n),
                            rng.uniform(-1, 2, n))]


def _check_identical(expected, got):
    for s, b in zip(expected, got):
        m = np.asarray(s.masked.mask)
        np.testing.assert_array_equal(m, np.asarray(b.masked.mask))
        np.testing.assert_allclose(
            np.asarray(b.masked.table.columns["out"].data)[m],
            np.asarray(s.masked.table.columns["out"].data)[m],
            rtol=2e-3, atol=1e-3,
        )


def run(quick: bool = False):
    db = _setup(quick)
    interp_n = INTERP_N_QUICK if quick else INTERP_N
    cpus = os.cpu_count() or 1
    s_interp = db.prepare(_q(), INTERPRETED)
    s_froid = db.prepare(_q(), FROID)

    # parity first (also pays both arms' warm-up): the rewritten LoopScan
    # plan must reproduce the per-row interpreted loop bit-for-bit on
    # masks/validity and within float tolerance on values
    pwarm = _params(interp_n)
    interp_r = [s_interp.execute(params=p) for p in pwarm]
    _check_identical(interp_r, [s_froid.execute(params=p) for p in pwarm])
    _check_identical(interp_r, s_froid.execute_many(pwarm))

    t0 = time.perf_counter()
    for p in pwarm:
        s_interp.execute(params=p)
    t_interp = (time.perf_counter() - t0) / interp_n
    emit(f"cursorloop/interp/{interp_n}", t_interp * 1e6,
         f"{interp_n} per-row interpreted cursor loops")

    for n in SWEEP:
        plist = _params(n)
        s_froid.execute_many(plist)  # pay the per-bucket vmapped jit
        t0 = time.perf_counter()
        rs = s_froid.execute_many(plist)
        t_many = (time.perf_counter() - t0) / n
        st = rs[0].stats
        tag = "rewrite" if n == SWEEP[0] else "rewrite_many"
        emit(
            f"cursorloop/{tag}/{n}", t_many * 1e6,
            f"speedup={t_interp / t_many:.1f}x kind=scan "
            f"bucket={st.get('batch_bucket')} host_cpus={cpus} "
            f"rewritten=True",
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
