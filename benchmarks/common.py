"""Benchmark helpers: timing + CSV emission."""
from __future__ import annotations

import time

import jax
import numpy as np

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_run(fn, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds over ``iters`` after ``warmup`` runs."""
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(jax.tree.leaves(r)) if r is not None else None
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn()
        if r is not None:
            jax.block_until_ready([x for x in jax.tree.leaves(r)
                                   if hasattr(x, "block_until_ready")] or [0])
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


