"""Architecture configuration.

A model is a stack of *super-blocks*: one super-block is a short list of
heterogeneous layers (e.g. Jamba's 7 mamba + 1 attention) and the stack
scans over ``n_repeats`` copies with stacked parameters — keeping the HLO
size O(super-block), not O(depth), which matters for 100-layer dry-runs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128  # N
    head_dim: int = 64  # P
    n_groups: int = 1  # G (B/C sharing groups)
    conv_kernel: int = 4
    expand: int = 2  # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0  # 0 -> use cfg.d_ff
    # §Perf: pad the expert axis to a multiple of the TP degree so expert
    # parallelism shards cleanly (pad experts hold zero weight and are
    # never routed to).  0 = no padding.
    pad_experts_to: int = 0

    @property
    def storage_experts(self) -> int:
        return max(self.n_experts, self.pad_experts_to)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"  # attn | mamba | cross | none
    mlp: str = "dense"  # dense | moe | none
    window: Optional[int] = None  # sliding-window size for attn
    cross_memory: bool = False  # extra cross-attn sublayer (enc-dec decoder)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # stacking
    super_block: tuple[LayerSpec, ...] = (LayerSpec(),)
    n_repeats: int = 1
    # families
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    moe: Optional[MoEConfig] = None
    # enc-dec (seamless): encoder stack config
    n_encoder_layers: int = 0
    encoder_frontend_dim: int = 0  # stub frontend embedding dim (0 = text)
    # vision cross-attention (llama-3.2-vision): stub patch embeddings
    vision_tokens: int = 0
    # misc
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 131_072
    # §Perf: int8 KV cache (per-token-per-head symmetric quantization);
    # halves decode cache reads/residency at <1e-2 logit error
    kv_cache_int8: bool = False
    # which shapes support sub-quadratic decode (long_500k eligibility)
    subquadratic: bool = False

    @property
    def n_layers(self) -> int:
        return len(self.super_block) * self.n_repeats

    def layer_at(self, i: int) -> LayerSpec:
        return self.super_block[i % len(self.super_block)]

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += D * V
        for spec in self.super_block:
            n = self.n_repeats
            if spec.mixer == "attn":
                if self.mla is not None:
                    m = self.mla
                    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += n * (
                        D * m.q_lora_rank
                        + m.q_lora_rank * self.n_heads * qh
                        + D * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank
                        * self.n_heads
                        * (m.qk_nope_head_dim + m.v_head_dim)
                        + self.n_heads * m.v_head_dim * D
                    )
                else:
                    hd = self.head_dim
                    total += n * (
                        D * self.n_heads * hd
                        + 2 * D * self.n_kv_heads * hd
                        + self.n_heads * hd * D
                    )
            elif spec.mixer == "cross":
                hd = self.head_dim
                total += n * (
                    D * self.n_heads * hd
                    + 2 * D * self.n_kv_heads * hd
                    + self.n_heads * hd * D
                )
            elif spec.mixer == "mamba":
                s = self.ssm
                d_in = s.expand * D
                H = d_in // s.head_dim
                conv_dim = d_in + 2 * s.n_groups * s.state_dim
                total += n * (
                    D * (2 * d_in + 2 * s.n_groups * s.state_dim + H)
                    + conv_dim * s.conv_kernel
                    + 3 * H
                    + d_in * D
                    + d_in  # gate norm
                )
            if spec.mlp == "dense":
                total += n * 3 * D * F
            elif spec.mlp == "moe":
                fe = self.moe.d_ff_expert or F
                total += n * (D * self.moe.n_experts + self.moe.n_experts * 3 * D * fe)
            total += n * 2 * D  # norms
        # encoder stack (enc-dec): attn + dense mlp + cross in decoder
        if self.n_encoder_layers:
            hd = self.head_dim
            total += self.n_encoder_layers * (
                D * self.n_heads * hd
                + 2 * D * self.n_kv_heads * hd
                + self.n_heads * hd * D
                + 3 * D * F
                + 2 * D
            )
        return total

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE uses top_k of n_experts."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        fe = self.moe.d_ff_expert or self.d_ff
        n_moe_layers = sum(
            self.n_repeats for s in self.super_block if s.mlp == "moe"
        )
        all_e = n_moe_layers * self.moe.n_experts * 3 * self.d_model * fe
        act_e = n_moe_layers * self.moe.top_k * 3 * self.d_model * fe
        return total - all_e + act_e


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
