from repro.train.optim import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.train.train_loop import TrainState, make_train_step, train_loop

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "lr_schedule",
    "TrainState", "make_train_step", "train_loop",
]
