"""End-to-end serving driver (the paper-kind deliverable): serve a small
model with batched requests; per-request admission/routing rules are
imperative UDFs compiled by Froid into one set-oriented plan per tick.

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import smoke_config_for
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine

cfg = smoke_config_for("granite3_2b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = ServeEngine(model, params, slots=4, max_len=96)

rng = np.random.default_rng(0)
requests = []
for i in range(10):
    requests.append(Request(
        rid=i,
        prompt=rng.integers(0, cfg.vocab, int(rng.integers(4, 24))).astype(np.int32),
        max_new_tokens=int(rng.integers(4, 12)),
        temperature=float(rng.choice([0.0, 0.7])),
        tier=int(rng.integers(0, 3)),
    ))
# one oversized request the admission UDF must reject
requests.append(Request(rid=99, prompt=np.zeros(40_000, np.int32)[:64],
                        max_new_tokens=4))
requests[-1].prompt = np.zeros(64, np.int32)  # small prompt...
requests.append(Request(rid=100, prompt=np.zeros(64, np.int32),
                        max_new_tokens=500, tier=0))  # budget-clamped

done = engine.run(requests)
for c in sorted(done, key=lambda c: c.rid):
    print(f"req {c.rid:3d}: {c.reason:8s} {len(c.tokens):3d} tokens "
          f"{c.tokens[:6]}{'…' if len(c.tokens) > 6 else ''}")
print("\ntier-0 request 100 was clamped to its token budget by the "
      "Froid-compiled admission UDFs (see repro/serve/admission.py).")

# Online intake: the same requests submitted one at a time coalesce into
# admission microbatches (execute_many) instead of per-request statements.
for r in requests:
    engine.submit(r)
done2 = engine.drain()
sched = engine.admission.scheduler
print(f"\nonline path: {sched.stats['submitted']} submits -> "
      f"{sched.stats['batches']} admission microbatch(es), "
      f"{len(done2)} completions (coalescing scheduler, "
      f"repro/serve/scheduler.py).")
