"""Correlated subqueries: the per-row apply vs the decorrelation rewrite.

The PR-10 optimizer pass's set-oriented argument: a correlated scalar
aggregate naively re-runs its body once per outer row — N scans of the
fact table per call — while the rewritten plan materializes ONE keyed
``GroupAgg`` build (one fact scan, d = distinct-binding rows out) and
left-joins it back.  The margin is algorithmic (N × body vs body + join),
like the cursor-loop gate, not parallelism-bound.

    PYTHONPATH=src python -m benchmarks.bench_decorrelate [--quick]

Rows:
    decorr/perrow_interp/<N>  — per-row apply through the interpreter
                                Executor (the oracle's reference arm)
    decorr/perrow/<N>         — per-row apply COMPILED (decorrelation
                                rules disabled, everything else identical:
                                same session path, same vmapped program) —
                                the strongest honest baseline
    decorr/decorrelated/<N>   — the rewritten keyed-build plan, FROID

``derived`` on the decorrelated rows carries speedup vs the compiled
per-row arm plus the rewrite evidence (builds/joins in the plan, the
distinct-binding pool size d) — the CI decorr gate reads the N=1024 row
and requires >= 10x.  Element-wise parity across all three arms —
including a parameter set that empties every group (NULL semantics) — is
asserted before timing.
"""
from __future__ import annotations

import os
import time
from contextlib import contextmanager

import numpy as np

from benchmarks.common import emit
from repro.core import (FROID, Session, col, param, scalar_subquery, scan,
                        sum_)
from repro.core import optimizer as O
from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.executor import Executor
from repro.core.session import _param_value

M_FACTS = 16384
DOMAIN = 7          # the small distinct-binding pool: d = 7 groups
SWEEP = (32, 1024)  # outer-key cardinalities; the CI gate reads 1024
#: parameter sets for parity: mid cut, empty cut (qty < 9 everywhere, so
#: minq=9 empties every group -> NULL totals), permissive cut
PARITY_PARAMS = ({"minq": 4}, {"minq": 9}, {"minq": 0})

#: the optimizer stack with ONLY the decorrelation rules removed — the
#: honest per-row arm (what every call paid before the rewrite existed)
PER_ROW_RULES = tuple(r for r in O.DEFAULT_RULES
                      if r not in (O.decorrelate_in_computes,
                                   O.decorrelate_filters))


@contextmanager
def per_row_optimizer():
    """Compile through the Session with decorrelation disabled."""
    orig = O.optimize

    def patched(plan, catalog=None, required=None, rules=None,
                max_passes=12):
        return orig(plan, catalog, required=required,
                    rules=PER_ROW_RULES, max_passes=max_passes)

    O.optimize = patched
    try:
        yield
    finally:
        O.optimize = orig


def _setup(n_keys: int) -> Session:
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table(
        "facts",
        fk=rng.integers(0, DOMAIN, M_FACTS),
        val=rng.normal(size=M_FACTS).astype(np.float32),
        qty=rng.integers(0, 9, M_FACTS),
    )
    db.create_table("keys", k=np.arange(n_keys) % DOMAIN)
    return db


def _q():
    body = (scan("facts")
            .filter((col("fk") == S.Outer("k"))
                    & (col("qty") >= param("minq")))
            .agg(total=sum_(col("val"))))
    return (scan("keys").compute(total=scalar_subquery(body, "total"))
            .project("k", "total"))


def _has_corr(plan) -> bool:
    for n in R.walk_plan_deep(plan):
        for e in n.exprs():
            for s in S.walk(e):
                if isinstance(s, (S.ScalarSubquery, S.Exists)):
                    from repro.core.executor import _plan_outer_refs
                    if _plan_outer_refs(s.plan):
                        return True
    return False


def _col(mt, name):
    c = mt.table.columns[name]
    return (np.asarray(c.data),
            np.asarray(c.valid) & np.asarray(mt.mask))


def _check_parity(dec_stmt, row_stmt, interp_plan, catalog):
    ex = Executor(catalog)
    for p in PARITY_PARAMS:
        dv, dm = _col(dec_stmt.execute(params=dict(p)).masked, "total")
        rv, rm = _col(row_stmt.execute(params=dict(p)).masked, "total")
        iv, im = _col(ex.execute(
            interp_plan,
            params={n: _param_value(v) for n, v in p.items()}), "total")
        np.testing.assert_array_equal(dm, rm)
        np.testing.assert_array_equal(dm, im)
        np.testing.assert_allclose(np.where(dm, dv, 0.0),
                                   np.where(rm, rv, 0.0),
                                   rtol=2e-3, atol=1e-3)
        np.testing.assert_allclose(np.where(dm, dv, 0.0),
                                   np.where(im, iv, 0.0),
                                   rtol=2e-3, atol=1e-3)


def _time_calls(stmt, iters: int) -> float:
    """Warm median us/call cycling the parity parameter sets."""
    stmt.execute(params=dict(PARITY_PARAMS[0]))  # pay compile per bucket
    stmt.execute(params=dict(PARITY_PARAMS[1]))
    samples = []
    for _ in range(3):
        t0 = time.perf_counter()
        for i in range(iters):
            stmt.execute(params=dict(PARITY_PARAMS[i % 2]))
        samples.append((time.perf_counter() - t0) / iters)
    return float(np.median(samples)) * 1e6


def run(quick: bool = False):
    iters = 3 if quick else 10
    cpus = os.cpu_count() or 1

    for n in SWEEP:
        db = _setup(n)
        q = _q()
        dec_stmt = db.prepare(q, FROID)
        assert not _has_corr(dec_stmt.plan), "rewrite did not fire"
        builds = sum(1 for nd in R.walk_plan(dec_stmt.plan)
                     if isinstance(nd, R.GroupAgg) and nd.keys)

        with per_row_optimizer():
            db_row = _setup(n)
            row_stmt = db_row.prepare(_q(), FROID)
        assert _has_corr(row_stmt.plan), "per-row arm was rewritten"

        node = q.node
        wanted = set(R.output_columns(node, db.catalog))
        interp_plan = O.optimize(node, db.catalog, required=wanted,
                                 rules=PER_ROW_RULES)

        # parity across all three arms first (also pays every warm-up)
        _check_parity(dec_stmt, row_stmt, interp_plan, db.catalog)

        pv = {k: _param_value(v) for k, v in PARITY_PARAMS[0].items()}
        ex = Executor(db.catalog)
        ex.execute(interp_plan, params=dict(pv))
        t0 = time.perf_counter()
        ex.execute(interp_plan, params=dict(pv))
        t_interp = (time.perf_counter() - t0) * 1e6
        emit(f"decorr/perrow_interp/{n}", t_interp,
             f"interpreter per-row apply, {M_FACTS}-row body")

        t_row = _time_calls(row_stmt, iters)
        emit(f"decorr/perrow/{n}", t_row,
             f"compiled per-row apply ({n}x{M_FACTS} work)")

        t_dec = _time_calls(dec_stmt, iters)
        emit(
            f"decorr/decorrelated/{n}", t_dec,
            f"speedup={t_row / t_dec:.1f}x interp_speedup="
            f"{t_interp / t_dec:.1f}x builds={builds} d={DOMAIN} "
            f"host_cpus={cpus} decorrelated=True parity=ok",
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
