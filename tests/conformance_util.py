"""Shared machinery for the differential conformance harness.

The oracle checks live here, outside any test module, so both suites drive
the exact same code:

* ``tests/test_conformance_oracle.py`` — deterministic fixed programs,
  runs everywhere (no extra deps), including the forced-8-device CI job.
* ``tests/test_property_froid.py`` — hypothesis generates random programs
  and parameter sets and feeds them to the same checks (CI installs
  hypothesis; the module skips where it is absent).

Oracles (both element-wise):

* **Mode oracle** — FROID == INTERPRETED == HEKATON on any supported
  program: identical masks/validity, values within float tolerance.
* **Invocation oracle** — ``execute_many`` (sharded over whatever device
  mesh exists, and unsharded) == the serial ``execute`` loop, including
  mixed-signature parameter lists, empty lists, and empty tables.
* **Fusion oracle** — a mixed-statement queue drained through the fusion
  scheduler (one fused device program, shared scans) == the per-statement
  serial loop, element-wise, across policies and sharding, including
  mixed-signature tickets, parameter-free tickets, non-fusable fallbacks,
  and DDL landing between submit and drain.
* **Routing oracle** — the same queue drained repeatedly under the
  ``ROUTED`` preset (the cost router free to flip policy, bucket, and
  fuse-or-not between waves) == the static FROID serial oracle on every
  wave: routing changes costs, never results.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.core import (
    FROID,
    HEKATON,
    INTERPRETED,
    CursorLoop,
    Session,
    UdfBuilder,
    While,
    avg_,
    case,
    col,
    count_,
    lit,
    max_,
    min_,
    param,
    scan,
    sum_,
    udf,
    var,
)
from repro.core import scalar as S
from repro.core.frontend import exists, not_exists, scalar_subquery
from repro.loops import classify

N_ROWS = 23
N_KEYS = 7

AGGS = {
    "sum": lambda e: sum_(e),
    "min": lambda e: min_(e),
    "max": lambda e: max_(e),
    "avg": lambda e: avg_(e),
    "count": lambda e: count_(e),
}


def facts_data(seed: int, n_rows: int = N_ROWS) -> dict:
    """The harness's ``facts`` columns for a seed (deterministic)."""
    rng = np.random.default_rng(seed)
    return dict(
        fk=rng.integers(0, N_KEYS, n_rows),
        val=np.round(rng.uniform(-10, 10, n_rows), 2).astype(np.float32),
        qty=rng.integers(0, 9, n_rows),
    )


def populate_session(s: Session, seed: int, n_rows: int = N_ROWS) -> Session:
    """Load the harness tables into an existing session — the fleet path:
    worker sessions are constructed by the engine, data arrives by setup."""
    s.create_table("facts", **facts_data(seed, n_rows))
    s.create_table("keys", k=np.arange(N_KEYS))
    return s


def make_session(seed: int, n_rows: int = N_ROWS) -> Session:
    """Session over random data; ``n_rows=0`` is the empty-table case."""
    return populate_session(Session(), seed, n_rows)


def build_udf(ops) -> UdfBuilder:
    """Materialize an ops list (the harness's program encoding) into a UDF.

    Ops: ("declare", name, init|None) · ("set", name, expr) ·
    ("select_agg", tgt, agg, correlated, thresh) ·
    ("ifelse", pred, then_tgt, then_expr, else_tgt|None, else_expr, ret_in_then)
    · ("return", expr)
    """
    u = UdfBuilder("f", [("p", "float32")], "float32")
    for op in ops:
        if op[0] == "declare":
            _, name, init = op
            u.declare(name, "float32", init)
        elif op[0] == "set":
            _, name, e = op
            u.set(name, e)
        elif op[0] == "select_agg":
            _, tgt, agg, corr, thresh = op
            pred = (
                col("fk") == param("p")
                if corr
                else col("qty") >= lit(thresh)
            )
            u.select({tgt: AGGS[agg](col("val"))}, frm=scan("facts"), where=pred)
        elif op[0] == "ifelse":
            _, pred, t_tgt, t_expr, e_tgt, e_expr, ret_in_then = op
            with u.if_(pred):
                u.set(t_tgt, t_expr)
                if ret_in_then:
                    u.return_(var(t_tgt) + 1.0)
            if e_tgt is not None:
                with u.else_():
                    u.set(e_tgt, e_expr)
        elif op[0] == "return":
            u.return_(op[1])
    return u


#: hand-picked programs mirroring the generator's shapes: correlated and
#: uncorrelated aggregates, NULL guards, early returns, CASE/COALESCE and
#: division arithmetic — the deterministic floor under the fuzzing suite
FIXED_PROGRAMS = {
    "correlated_min_null_guard": [
        ("declare", "v0", lit(1.5)),
        ("select_agg", "v0", "min", True, 0),
        ("ifelse", var("v0").is_null(), "v0", param("p") * 2.0,
         None, None, True),
        ("return", var("v0") + param("p")),
    ],
    "uncorrelated_sum_case": [
        ("declare", "v0", param("p") * 1.0),
        ("select_agg", "v0", "sum", False, 4),
        ("set", "v0", case([(var("v0") > param("p"), var("v0"))], lit(0.5))),
        ("return", S.Coalesce([var("v0"), lit(0.0)])),
    ],
    "avg_ifelse_branches": [
        ("declare", "v0", None),
        ("select_agg", "v0", "avg", True, 0),
        ("ifelse", var("v0") > lit(0.0), "v0", var("v0") / 2.0,
         "v0", param("p") - 3.0, False),
        ("return", var("v0") * 2.0 - 1.0),
    ],
    "count_max_division": [
        ("declare", "v0", lit(2.0)),
        ("declare", "v1", None),
        ("select_agg", "v1", "count", False, 7),
        ("set", "v0", param("p") / var("v0")),
        ("select_agg", "v1", "max", False, 7),
        ("return", S.Coalesce([var("v1"), var("v0"), lit(-1.0)])),
    ],
}


def param_query():
    """Parameterized calling query: query params feed both the filter and
    the UDF argument, so parameter sets change results, not just plans."""
    return (
        scan("keys")
        .filter(col("k") < param("cut"))
        .compute(out=udf("f", col("k") * 1.0 + param("shift")))
        .project("k", "out")
    )


def _rows(result):
    """(mask, {col: (values, validity)}) as host arrays for comparison."""
    masked = result.masked
    cols = {
        n: (np.asarray(c.data, dtype=np.float64), np.asarray(c.validity()))
        for n, c in masked.table.columns.items()
    }
    return np.asarray(masked.mask), cols


def assert_rows_equal(expected, got, label, rtol=2e-3, atol=1e-3):
    """Element-wise result identity: masks bit-equal, validity bit-equal on
    surviving rows, values within float tolerance where both valid."""
    em, ecols = _rows(expected)
    gm, gcols = _rows(got)
    np.testing.assert_array_equal(em, gm, err_msg=f"{label}: mask mismatch")
    assert ecols.keys() == gcols.keys(), f"{label}: schema mismatch"
    for n in ecols:
        ev, evalid = ecols[n]
        gv, gvalid = gcols[n]
        sel = em  # surviving rows only: dead rows carry arbitrary values
        np.testing.assert_array_equal(
            evalid[sel], gvalid[sel], err_msg=f"{label}: validity({n})"
        )
        live = sel & evalid & gvalid
        np.testing.assert_allclose(
            ev[live], gv[live], rtol=rtol, atol=atol,
            err_msg=f"{label}: values({n})",
        )


def check_mode_oracle(ops, seed: int, n_rows: int = N_ROWS) -> None:
    """FROID == INTERPRETED == HEKATON on the given program."""
    db = make_session(seed, n_rows)
    db.create_function(build_udf(ops).build())
    q = param_query()
    params = {"cut": 5, "shift": 0.5}
    baseline = db.execute(q, FROID, params=params)
    for policy in (INTERPRETED, HEKATON):
        r = db.execute(q, policy, params=params)
        assert_rows_equal(baseline, r, f"FROID vs {policy.name}")


def fusion_queries():
    """Three *different* statements over the shared tables: the UDF-bearing
    parameterized query, an arithmetic filter over ``facts``, and a
    parameter-free projection of ``keys``.  q1 and q3 both scan ``keys``,
    so a fused program of the three has at least one shared subtree."""
    q1 = param_query()
    q2 = (
        scan("facts")
        .filter(col("qty") >= param("minq"))
        .compute(w=col("val") * param("scale"))
        .project("fk", "w")
    )
    q3 = scan("keys").compute(z=col("k") * 2.0).project("k", "z")
    return [q1, q2, q3]


def fusion_calls_spec():
    """Interleaved mixed-statement queue: ``[(statement index, params)]``.
    Carries a mixed signature for q1 (float ``cut`` re-specializes) and
    parameter-free tickets for q3."""
    return [
        (0, {"cut": 5, "shift": 0.5}),
        (1, {"minq": 4, "scale": 2.0}),
        (2, None),
        (0, {"cut": 3, "shift": 1.5}),
        (1, {"minq": 1, "scale": 0.5}),
        (0, {"cut": 6.5, "shift": 2.0}),
        (2, {}),
    ]


def check_fusion_oracle(seed: int, n_rows: int, policy, calls_spec=None, *,
                        queries=None, ddl: bool = False, expect_fused=True):
    """Fused drain of a mixed-statement queue == per-statement serial loop.

    Submits the queue to a fusion-mode scheduler, optionally lands DDL
    between submit and drain (the drain must see the *new* catalog state),
    flushes, and compares every ticket element-wise against the serial
    ``execute`` loop run afterwards under the same catalog state.  For
    policies the fusability analysis accepts, also asserts the shared-scan
    evidence (fused program count < statement count, ≥ 1 shared subtree or
    pooled template); for non-fusable policies asserts the fallback ran
    instead.  Returns the fused results for extra caller assertions.

    ``queries`` substitutes the statement set (default:
    :func:`fusion_queries`); ``calls_spec`` is ``[(statement index,
    params)]``.  ``expect_fused="auto"`` derives the expectation from the
    queue itself — fused evidence is asserted only when the submitted
    tickets span ≥ 2 distinct statements under a fusable policy (the shape
    generative callers can't guarantee by construction)."""
    from repro.serve.scheduler import CoalescingScheduler

    db = make_session(seed, n_rows)
    db.create_function(build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    qs = queries if queries is not None else fusion_queries()
    stmts = [db.prepare(q, policy) for q in qs]
    spec = calls_spec if calls_spec is not None else fusion_calls_spec()
    sched = CoalescingScheduler(max_batch=256, window_s=10.0,
                                clock=lambda: 0.0, fuse=True)
    tickets = [sched.submit(stmts[i], p) for i, p in spec]
    if ddl:
        rng = np.random.default_rng(seed + 1)
        db.create_table(
            "facts",
            fk=rng.integers(0, N_KEYS, max(n_rows, 1)),
            val=np.round(rng.uniform(-10, 10, max(n_rows, 1)), 2)
                .astype(np.float32),
            qty=rng.integers(0, 9, max(n_rows, 1)),
        )
    sched.flush()
    fused = [t.result() for t in tickets]
    serial = [stmts[i].execute(params=p) for i, p in spec]
    for j, (s, f) in enumerate(zip(serial, fused)):
        assert_rows_equal(s, f, f"fused[{j}] vs serial")
    fusable = policy.compile_plan and policy.fuse
    if expect_fused == "auto":
        expect_fused = len({id(stmts[i]) for i, _ in spec}) >= 2
    if expect_fused and fusable:
        st = next(r.stats for r in fused if r.stats.get("fused"))
        assert st["fused_programs"] < st["fused_statements"], st
        assert st["shared_subtrees"] + st["cse_templates"] >= 1, st
        assert sched.stats["fused_batches"] >= 1
    elif not fusable:
        assert all("fused" not in r.stats for r in fused)
    return fused


# --------------------------------------------------------------------------
# overlap-queue generation (the generative fusion surface: statements built
# from compact specs so hypothesis and the deterministic fallback driver
# exercise the same construction)
# --------------------------------------------------------------------------

#: statement-shape axes: body × filter × parameter name.  Every generated
#: statement scans ``facts``, so any 2+ members share at least that subtree;
#: ``qty_ge``/``val_gt`` filters with different parameter names unify into
#: one template (parameter-unified sharing); ``lit`` filters share as
#: constants; ``nested`` rides a parameterized aggregate inside a scalar
#: subquery (nested shared aggregates).
OVERLAP_BODIES = ("proj", "agg", "nested")
OVERLAP_FILTERS = ("none", "qty_ge", "val_gt", "lit")
OVERLAP_PNAMES = ("p", "q")


def overlap_query(spec, idx: int):
    """Build one statement from ``spec = (body, filt, pname)``.  ``idx``
    salts output column names, so every queue position yields a distinct
    statement even when specs repeat — repeated specs exercise maximal
    template overlap between distinct members, not statement dedup."""
    body, filt, pname = spec
    q = scan("facts")
    if filt == "qty_ge":
        q = q.filter(col("qty") >= param(pname))
    elif filt == "val_gt":
        q = q.filter(col("val") > param(pname))
    elif filt == "lit":
        q = q.filter(col("qty") >= lit(3))
    if body == "proj":
        q = q.compute(**{f"w{idx}": col("val") * 2.0}).project("fk", f"w{idx}")
    elif body == "agg":
        q = q.group_by("fk", **{f"s{idx}": sum_(col("val"))})
    else:  # nested shared aggregate inside a scalar subquery
        inner = (scan("facts").filter(col("val") > param(pname))
                 .agg(s=sum_(col("val"))))
        q = q.compute(
            **{f"n{idx}": scalar_subquery(inner.node, "s") + col("val")}
        ).project("fk", f"n{idx}")
    return q


def overlap_param_names(spec) -> tuple:
    """Parameter names ``overlap_query(spec, …)`` expects at execution."""
    body, filt, pname = spec
    need = filt in ("qty_ge", "val_gt") or body == "nested"
    return (pname,) if need else ()


def overlap_queue(specs, ticket_values):
    """``(queries, calls_spec)`` for :func:`check_fusion_oracle`:
    ``specs`` is the statement list; ``ticket_values`` is a flat value
    list — ticket ``t`` goes to statement ``t % len(specs)`` carrying its
    value for every parameter the statement needs (values repeat across
    tickets, so template binding pools see d < k distinct bindings)."""
    queries = [overlap_query(s, i) for i, s in enumerate(specs)]
    calls = []
    for t, v in enumerate(ticket_values):
        i = t % len(specs)
        calls.append((i, {n: v for n in overlap_param_names(specs[i])}))
    return queries, calls


# --------------------------------------------------------------------------
# loop-UDF generation (ISSUE-6: cursor/WHILE loops through the same oracles —
# rewritten LoopScan plans must equal the per-row interpreted loops)
# --------------------------------------------------------------------------

#: loop body shapes: commutative fold (reduce kind), guarded fold (reduce
#: with predicate), order-dependent fold (scan kind), and a plain WHILE
#: with no driving relation (non-rewritable — interpreter fallback)
LOOP_BODIES = ("sum", "sum_if", "running", "plain_while")


def build_loop_udf(body: str, guard_cap=None, break_cap=None) -> UdfBuilder:
    """One loop UDF from the compact spec ``(body, guard_cap, break_cap)``.

    The cursor ranges over ``facts`` rows with ``fk <= @x`` (the call
    argument), so every invocation folds a different prefix of the table —
    including the empty cursor for ``@x < 0``.  ``guard_cap`` adds an extra
    WHILE conjunct ``@t < cap`` (re-checked after each fetch);
    ``break_cap`` adds ``IF @t > cap BREAK`` after the accumulate.  Either
    forces scan-kind lowering even for commutative bodies."""
    u = UdfBuilder("floop", [("x", "float32")], "float32")
    u.declare("t", "float32", lit(0.0))
    if body == "plain_while":
        # no cursor: WHILE has no driving relation, so the analysis issues
        # a non-rewritable verdict and FROID falls back to the interpreter
        u.declare("i", "float32", lit(0.0))
        with u.while_(var("i") < param("x")):
            u.set("i", var("i") + 1.0)
            u.set("t", var("t") + var("i"))
        u.return_(var("t"))
        return u
    u.declare("v", "float32", None)
    u.declare("q", "float32", None)
    guard = None if guard_cap is None else var("t") < lit(float(guard_cap))
    with u.cursor_loop({"v": "val", "q": "qty"}, scan("facts"),
                       where=col("fk") <= param("x"), guard=guard):
        if body == "sum":
            u.set("t", var("t") + var("v"))
        elif body == "sum_if":
            with u.if_(var("q") > lit(2.0)):
                u.set("t", var("t") + var("v"))
        else:  # running: order-dependent, never a commutative fold
            u.set("t", var("t") * 0.5 + var("v"))
        if break_cap is not None:
            with u.if_(var("t") > lit(float(break_cap))):
                u.break_()
    u.return_(var("t"))
    return u


def loop_param_query():
    """Calling query for the loop oracles: parameters feed the filter and
    the UDF argument, so every surviving row drives a distinct cursor."""
    return (
        scan("keys")
        .filter(col("k") < param("cut"))
        .compute(out=udf("floop", col("k") * 1.0 + param("shift")))
        .project("k", "out")
    )


def expected_loop_kind(body: str, guard_cap, break_cap) -> str | None:
    """The verdict the analysis pass must issue for a spec (None = the
    non-rewritable fallback)."""
    if body == "plain_while":
        return None
    if body in ("sum", "sum_if") and guard_cap is None and break_cap is None:
        return "reduce"
    return "scan"


def check_loop_oracle(body: str, guard_cap, break_cap, seed: int,
                      n_rows: int, params_list=None) -> None:
    """Loop conformance: the Aggify-rewritten LoopScan plan (FROID), the
    per-row host interpreter (INTERPRETED), and the traced scan
    interpreter (HEKATON) agree element-wise — and ``execute_many``
    (sharded and unsharded) equals the serial loop — on any loop spec,
    including empty cursors, early-exit guards/breaks, and the
    non-rewritable fallback."""
    f = build_loop_udf(body, guard_cap, break_cap).build()
    loop = next(s for s in f.body if isinstance(s, (While, CursorLoop)))
    verdict = classify(loop)
    kind = expected_loop_kind(body, guard_cap, break_cap)
    if kind is None:
        assert not verdict.rewritable, verdict
    else:
        assert verdict.rewritable and verdict.kind == kind, verdict

    db = make_session(seed, n_rows)
    db.create_function(f)
    q = loop_param_query()
    if params_list is None:
        params_list = [{"cut": 5, "shift": 0.5}]
    stmt = db.prepare(q, FROID)
    serial = [stmt.execute(params=p) for p in params_list]
    for policy in (INTERPRETED, HEKATON):
        other = db.prepare(q, policy)
        for i, p in enumerate(params_list):
            assert_rows_equal(serial[i], other.execute(params=p),
                              f"loop[{body}] FROID vs {policy.name}[{i}]")
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    for policy, label in ((FROID, "many"), (FROID.sharded(mesh), "sharded")):
        batched = db.prepare(q, policy).execute_many(params_list)
        assert len(batched) == len(serial)
        for i, (s, b) in enumerate(zip(serial, batched)):
            assert_rows_equal(s, b, f"loop[{body}] {label}[{i}] vs serial")


# --------------------------------------------------------------------------
# chaos oracle (ISSUE-7: resilience layer) — under ANY injected fault
# schedule, every ticket gets either the fault-free oracle's answer or an
# explicit typed error; never wrong data, never a hung ticket
# --------------------------------------------------------------------------


def check_chaos_oracle(seed: int, n_rows: int, fault_specs=(), *,
                       chaos_seed: int | None = None, rate: float = 0.3,
                       sites=("compile", "dispatch", "sync"),
                       max_faults: int | None = None,
                       policy=None, calls_spec=None, queries=None,
                       timeout_s: float | None = None, clock=None,
                       resilience=None) -> dict:
    """The resilience layer's conformance contract, differentially.

    Two same-seed sessions: the **oracle** session executes every call of
    the mixed-statement queue serially, fault-free; the **chaos** session
    gets a :class:`~repro.resilience.faults.FaultInjector` installed
    (explicit ``fault_specs``, or the seeded deterministic schedule when
    ``chaos_seed`` is given) and drains the same queue through a
    fusion-mode resilient scheduler.  Then, for every ticket:

    * it is ``done()`` after the flush — no hung ticket, ever;
    * ``result()`` either equals the oracle's answer element-wise
      (``assert_rows_equal``) or raises a typed
      :class:`~repro.resilience.faults.ResilienceError` — never silently
      wrong data, never an untyped internal error.

    When the injected sites exclude ``interp`` and no deadline is set,
    every ticket must carry the oracle answer (the INTERPRETED floor of
    the ladder is fault-free, and the mode oracle guarantees it agrees).
    Returns ``{"outcomes", "stats", "resilience", "injector"}`` for extra
    caller assertions (demotion counters, breaker transitions, fired
    faults)."""
    from repro.core import FROID
    from repro.resilience import FaultInjector, ResilienceError
    from repro.serve.scheduler import CoalescingScheduler

    policy = policy if policy is not None else FROID
    qs = queries if queries is not None else fusion_queries()
    spec = calls_spec if calls_spec is not None else fusion_calls_spec()

    # fault-free oracle: the serial execute loop on its own session
    oracle = make_session(seed, n_rows)
    oracle.create_function(
        build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    o_stmts = [oracle.prepare(q, policy) for q in qs]
    expected = [o_stmts[i].execute(params=p) for i, p in spec]

    # chaos run: same data, injector installed, resilient fused drain
    db = make_session(seed, n_rows)
    db.create_function(
        build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    stmts = [db.prepare(q, policy) for q in qs]
    if chaos_seed is not None:
        fi = FaultInjector.seeded(chaos_seed, rate, sites=sites,
                                  max_faults=max_faults)
        fi.specs = list(fault_specs)
    else:
        fi = FaultInjector(fault_specs)
    fi.install(db)
    kwargs = {} if resilience is None else {"resilience": resilience}
    if clock is not None:
        kwargs["clock"] = clock
    sched = CoalescingScheduler(max_batch=256, window_s=10.0, fuse=True,
                                default_timeout_s=timeout_s,
                                sleep=lambda s: None, **kwargs)
    tickets = [sched.submit(stmts[i], p) for i, p in spec]
    sched.flush()

    outcomes = []
    interp_faultable = "interp" in sites or any(
        getattr(s, "site", None) in ("interp", "*") for s in fault_specs)
    for j, t in enumerate(tickets):
        assert t.done(), f"chaos: ticket[{j}] not done after flush (hang)"
        try:
            r = t.result()
        except ResilienceError as e:
            outcomes.append(("error", e))
            continue
        except BaseException as e:  # untyped escape = contract violation
            raise AssertionError(
                f"chaos: ticket[{j}] raised untyped {type(e).__name__}: {e}"
            ) from e
        assert_rows_equal(expected[j], r, f"chaos[{j}] vs fault-free oracle")
        outcomes.append(("ok", r))
    if not interp_faultable and timeout_s is None:
        bad = [j for j, (kind, _) in enumerate(outcomes) if kind != "ok"]
        assert not bad, (
            f"chaos: tickets {bad} errored though the interp floor was "
            f"fault-free and no deadline was set"
        )
    return {
        "outcomes": outcomes,
        "stats": dict(sched.stats),
        "resilience": sched.resilience_stats,
        "injector": fi,
    }


# --------------------------------------------------------------------------
# routing oracle (ISSUE-8: cost-based routing) — whatever configuration the
# router picks, results must equal the FROID serial oracle element-wise
# --------------------------------------------------------------------------


def check_routing_oracle(seed: int, n_rows: int, *, fuse: bool = True,
                         shard: bool = False, waves: int = 3,
                         calls_spec=None, queries=None) -> dict:
    """Cost-based routing never changes results, only costs.

    Two same-seed sessions: the **oracle** session executes every call of
    the mixed-statement queue serially under static FROID; the **routed**
    session prepares the same statements under the ``ROUTED`` preset and
    drains the same queue ``waves`` times through a scheduler (fusion
    drain mode per ``fuse``, sharded over the live mesh per ``shard``).
    Repeated waves matter: the router flips configuration as measurements
    accrue (explore-fused → explore-unfused → measured winner; policy and
    bucket reroutes), and *every* wave must match the oracle element-wise
    regardless of which arm it landed on.  A final serial ``execute``
    pass exercises the per-statement policy-routing axis the scheduler
    path does not.  Returns the routed session's ``cost_stats`` for extra
    caller assertions (decision log, sample counters)."""
    from repro.core import ROUTED
    from repro.serve.scheduler import CoalescingScheduler

    qs = queries if queries is not None else fusion_queries()
    spec = calls_spec if calls_spec is not None else fusion_calls_spec()

    oracle = make_session(seed, n_rows)
    oracle.create_function(
        build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    o_stmts = [oracle.prepare(q, FROID) for q in qs]
    expected = [o_stmts[i].execute(params=p) for i, p in spec]

    db = make_session(seed, n_rows)
    db.create_function(
        build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    policy = ROUTED
    if shard:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        policy = ROUTED.sharded(mesh)
    stmts = [db.prepare(q, policy) for q in qs]
    sched = CoalescingScheduler(max_batch=256, window_s=10.0,
                                clock=lambda: 0.0, fuse=fuse)
    for w in range(waves):
        tickets = [sched.submit(stmts[i], p) for i, p in spec]
        sched.flush()
        for j, t in enumerate(tickets):
            assert_rows_equal(expected[j], t.result(),
                              f"routed[wave {w}][{j}] vs FROID serial oracle")
    for j, (i, p) in enumerate(spec):
        assert_rows_equal(expected[j], stmts[i].execute(params=p),
                          f"routed serial[{j}] vs FROID serial oracle")
    cs = db.cost_stats
    assert cs.get("enabled"), f"router never attached: {cs}"
    assert cs["samples"] >= 1, f"router saw no samples: {cs}"
    return cs


# --------------------------------------------------------------------------
# fleet oracle (ISSUE-9: persistent plan tier + multi-worker serving) — a
# fleet drain over N workers sharing one plan store == the single-worker
# serial drain of the same queue, element-wise, whatever the persistent tier
# served (hits, misses, stale stamps, corrupt entries) and wherever each
# request landed
# --------------------------------------------------------------------------


def fleet_setup(seed: int, n_rows: int, policy):
    """A :class:`~repro.serve.fleet.FleetEngine` setup callback closing over
    the harness data: every worker loads the same tables/UDF (so their
    content-derived persist keys agree) and exposes the fusion-oracle
    statements as ``q0``/``q1``/``q2``."""

    def setup(session):
        populate_session(session, seed, n_rows)
        session.create_function(
            build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
        return {f"q{i}": session.prepare(q, policy)
                for i, q in enumerate(fusion_queries())}

    return setup


def check_fleet_oracle(seed: int, n_rows: int, *, workers: int = 2,
                       store=None, policy=None, calls_spec=None,
                       ddl: bool = False, fault_specs=(), waves: int = 1,
                       parallel: bool = False) -> dict:
    """Fleet drain == single-worker serial drain, element-wise.

    The **oracle** is one plain session (no store) executing every call of
    the mixed-statement queue serially under static FROID.  The **fleet**
    is a :class:`FleetEngine` of ``workers`` workers over ``store`` (a
    PlanStore, a path, or None) running the same queue round-robin;
    ``drain()`` returns arrival order, so results compare positionally.
    The store's state is the caller's axis: pre-populated (warm-start),
    stale-stamped, or corrupted stores must all still yield oracle-equal
    answers — the persistent tier may only change *costs*.

    ``ddl=True`` lands a ``facts`` reload on every worker (``broadcast``)
    *and* the oracle between submit and drain of the first wave — the
    drain must see the new catalog state on every worker.  ``fault_specs``
    installs a deterministic :class:`FaultInjector` per worker session
    (non-interp sites: the resilient drain must still produce the oracle
    answer on every ticket).  Returns ``FleetEngine.stats`` for extra
    caller assertions (persist traffic, drained counts)."""
    from repro.serve.fleet import FleetEngine

    policy = policy if policy is not None else FROID
    spec = calls_spec if calls_spec is not None else fusion_calls_spec()

    oracle = make_session(seed, n_rows)
    oracle.create_function(
        build_udf(FIXED_PROGRAMS["uncorrelated_sum_case"]).build())
    o_stmts = [oracle.prepare(q, FROID) for q in fusion_queries()]

    fleet = FleetEngine(fleet_setup(seed, n_rows, policy), workers=workers,
                        store=store, parallel=parallel)
    if fault_specs:
        from repro.resilience import FaultInjector

        for w in fleet.workers:
            FaultInjector(list(fault_specs)).install(w.session)

    for wave in range(waves):
        for i, p in spec:
            fleet.submit(f"q{i}", p)
        if ddl and wave == 0:
            data = facts_data(seed + 1, max(n_rows, 1))
            fleet.broadcast(lambda s: s.create_table("facts", **data))
            oracle.create_table("facts", **data)
        got = fleet.drain()
        expected = [o_stmts[i].execute(params=p) for i, p in spec]
        assert len(got) == len(expected)
        for j, (e, g) in enumerate(zip(expected, got)):
            assert_rows_equal(
                e, g, f"fleet[wave {wave}][{j}] vs single-worker serial")
    stats = fleet.stats
    assert stats["fleet"]["drained"] >= len(spec) * waves, stats["fleet"]
    return stats


# --------------------------------------------------------------------------
# decorrelation oracle (ISSUE-10) — the decorrelated plan (keyed GroupAgg
# build + left/semi/anti join) == the per-row apply of the same correlated
# statement, element-wise, across execution modes and invocation surfaces,
# including empty inner relations and bindings with no matching group
# (NULL-vs-empty-group semantics)
# --------------------------------------------------------------------------

#: correlated-statement shape axes.  kinds: scalar aggregate subquery in a
#: Compute, EXISTS / NOT EXISTS in a Compute, EXISTS / NOT EXISTS as a
#: Filter (semi/anti join).  keys: direct outer column, arithmetic
#: expression of the outer column (shifts part of the key domain past the
#: facts, so some bindings have NO matching group — the NULL-semantics
#: case), two-key correlation through a computed outer column, and a
#: non-equi correlated predicate (NOT rewritable: the pass must leave the
#: per-row apply in place, never error).
DECORR_KINDS = ("agg", "exists", "not_exists", "semi", "anti")
DECORR_KEYSHAPES = ("direct", "expr", "multi", "nonequi")
DECORR_AGGS = ("sum", "min", "max", "avg", "count")


def decorr_query(kind: str, keyshape: str, agg: str = "sum"):
    """One correlated statement from the compact spec.  The inner body
    filters ``facts`` on the correlation predicate plus an uncorrelated
    parameterized conjunct (``qty >= @minq``), so parameter sets change
    results and the batched surfaces exercise real re-binding."""
    outer = scan("keys")
    if keyshape == "direct":
        pred = col("fk") == S.Outer("k")
    elif keyshape == "expr":
        # k+3 walks keys 4..6 off the fk domain: missing groups -> NULL
        pred = col("fk") == S.Outer("k") + lit(3)
    elif keyshape == "multi":
        outer = outer.compute(kk=col("k") + lit(1))
        pred = (col("fk") == S.Outer("k")) & (col("qty") == S.Outer("kk"))
    else:  # nonequi: correlated range predicate — not decorrelatable
        pred = col("fk") <= S.Outer("k")
    inner = scan("facts").filter(pred & (col("qty") >= param("minq")))
    if kind == "agg":
        body = inner.agg(s=AGGS[agg](col("val")))
        return outer.compute(out=scalar_subquery(body, "s")).project("k", "out")
    if kind == "exists":
        return outer.compute(out=exists(inner)).project("k", "out")
    if kind == "not_exists":
        return outer.compute(out=not_exists(inner)).project("k", "out")
    if kind == "semi":
        return (outer.filter(exists(inner))
                .compute(out=col("k") * 2.0).project("k", "out"))
    return (outer.filter(not_exists(inner))
            .compute(out=col("k") * 2.0).project("k", "out"))


def _plan_has_correlated_subquery(plan) -> bool:
    """True when any subquery plan anywhere in ``plan`` still references
    outer-row columns — i.e. a per-row apply the rewrite left in place."""
    from repro.core import relalg as R
    from repro.core.executor import _plan_outer_refs

    for n in R.walk_plan_deep(plan):
        for e in n.exprs():
            for s in S.walk(e):
                if isinstance(s, (S.ScalarSubquery, S.Exists)) and \
                        _plan_outer_refs(s.plan):
                    return True
    return False


def _per_row_reference(db, q, params):
    """Execute the statement with the decorrelation rules disabled — the
    per-row apply baseline every decorrelated shape must match.  Returns
    an object comparable by :func:`assert_rows_equal`."""
    import types

    from repro.core import optimizer as O
    from repro.core import relalg as R
    from repro.core.executor import Executor
    from repro.core.session import _param_value

    node = q.node
    wanted = R.output_columns(node, db.catalog)
    rules = tuple(r for r in O.DEFAULT_RULES
                  if r not in (O.decorrelate_in_computes,
                               O.decorrelate_filters))
    plan = O.optimize(node, db.catalog, required=set(wanted), rules=rules)
    if R.output_columns(plan, db.catalog) != wanted:
        plan = R.Project(plan, wanted)
    assert _plan_has_correlated_subquery(plan), (
        "per-row baseline lost its correlated subquery — the oracle "
        "would be comparing decorrelated against decorrelated")
    pvals = {n: _param_value(v) for n, v in (params or {}).items()}
    mt = Executor(db.catalog).execute(plan, params=pvals)
    return types.SimpleNamespace(masked=mt)


def check_decorrelation_oracle(kind: str, keyshape: str, agg: str,
                               seed: int, n_rows: int,
                               params_list=None, *, ddl: bool = False) -> None:
    """Decorrelated == per-row, element-wise, everywhere.

    Builds the spec's correlated statement, executes it under
    FROID / INTERPRETED / HEKATON serially and through ``execute_many``
    (unsharded and sharded over the live mesh), and compares every result
    against the per-row apply baseline (same optimizer rules minus the
    decorrelation passes, executed row-at-a-time semantics preserved).
    Covers empty inner relations (``n_rows=0``), bindings with no
    matching group ("expr" keyshape: NULL scalar / FALSE exists), and the
    non-rewritable "nonequi" keyshape (per-row apply left in place, same
    answers).  ``ddl=True`` reloads ``facts`` mid-oracle and re-checks —
    the decorrelated build must re-specialize, not serve stale groups."""
    db = make_session(seed, n_rows)
    q = decorr_query(kind, keyshape, agg)
    if params_list is None:
        params_list = [{"minq": 0}, {"minq": 4}, {"minq": 9}]

    stmt = db.prepare(q, FROID)
    if keyshape == "nonequi":
        assert _plan_has_correlated_subquery(stmt.plan), (
            "non-equi correlation must keep the per-row apply")
    else:
        assert not _plan_has_correlated_subquery(stmt.plan), (
            f"spec ({kind}, {keyshape}, {agg}) did not decorrelate:\n"
            + stmt.explain())

    def run_all(label_prefix: str) -> None:
        serial = []
        for i, p in enumerate(params_list):
            expected = _per_row_reference(db, q, p)
            got = stmt.execute(params=p)
            assert_rows_equal(expected, got,
                              f"{label_prefix}froid[{i}] vs per-row")
            serial.append(got)
        for policy in (INTERPRETED, HEKATON):
            other = db.prepare(q, policy)
            for i, p in enumerate(params_list):
                assert_rows_equal(serial[i], other.execute(params=p),
                                  f"{label_prefix}{policy.name}[{i}]")
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        for policy, label in ((FROID, "many"),
                              (FROID.sharded(mesh), "sharded")):
            batched = db.prepare(q, policy).execute_many(params_list)
            assert len(batched) == len(serial)
            for i, (s, b) in enumerate(zip(serial, batched)):
                assert_rows_equal(s, b, f"{label_prefix}{label}[{i}]")

    run_all("")
    if ddl:
        db.create_table("facts", **facts_data(seed + 1, max(n_rows, 1)))
        run_all("post-ddl ")


def check_invocation_oracle(ops, seed: int, n_rows: int,
                            params_list: list[dict]) -> None:
    """execute_many (unsharded, sharded, hekaton) == serial execute loop."""
    db = make_session(seed, n_rows)
    db.create_function(build_udf(ops).build())
    q = param_query()

    serial_stmt = db.prepare(q, FROID)
    serial = [serial_stmt.execute(params=p) for p in params_list]

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    for policy, label in (
        (FROID, "execute_many"),
        (FROID.sharded(mesh), "execute_many[sharded]"),
        (HEKATON, "execute_many[hekaton]"),
    ):
        batched = db.prepare(q, policy).execute_many(params_list)
        assert len(batched) == len(serial)
        for i, (s, b) in enumerate(zip(serial, batched)):
            assert_rows_equal(s, b, f"{label}[{i}] vs serial")
