"""Model facade + input specs for every (arch × shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for the dry-run; smoke tests use the same
specs with real arrays on reduced configs.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import SHAPES, ArchConfig, ShapeConfig
from repro.models.layers import COMPUTE_DTYPE

AUDIO_DECODE_MEMORY = 1536  # stub frame count for enc-dec decode shapes


@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    def init(self, key):
        return T.init_params(key, self.cfg)

    def init_shapes(self):
        return jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(0), self.cfg)
        )

    def loss_fn(self, params, batch, remat=True):
        return T.loss_fn(params, batch, self.cfg, remat=remat)

    def forward(self, params, tokens, memory=None):
        return T.forward(params, tokens, self.cfg, memory)

    def lm_head(self, params, x):
        return T.lm_head(params, x, self.cfg)

    def prefill(self, params, tokens, memory=None, max_len=None):
        return T.prefill(params, tokens, self.cfg, memory, max_len)

    def decode_step(self, params, cache, tokens):
        return T.decode_step(params, cache, tokens, self.cfg)

    def init_cache(self, batch, max_len, memory_len=0):
        return T.init_cache(self.cfg, batch, max_len, memory_len)

    def cache_shapes(self, batch, max_len, memory_len=0):
        return jax.eval_shape(
            lambda: T.init_cache(self.cfg, batch, max_len, memory_len)
        )


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# input specs per (arch × shape)
# ---------------------------------------------------------------------------


def _memory_spec(cfg: ArchConfig, batch: int, seq_len: int):
    if cfg.vision_tokens:
        return jax.ShapeDtypeStruct(
            (batch, cfg.vision_tokens, cfg.d_model), COMPUTE_DTYPE
        )
    if cfg.n_encoder_layers:
        return jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model), COMPUTE_DTYPE)
    return None


def memory_len_for(cfg: ArchConfig, shape: ShapeConfig) -> int:
    if cfg.vision_tokens:
        return cfg.vision_tokens
    if cfg.n_encoder_layers:
        return shape.seq_len if shape.kind != "decode" else AUDIO_DECODE_MEMORY
    return 0


def shape_supported(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: long_500k skipped (DESIGN.md §5)"
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeConfig | str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        specs = {"tokens": tok, "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        mem = _memory_spec(cfg, B, S)
        if mem is not None:
            specs["memory"] = mem
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": tok}
        mem = _memory_spec(cfg, B, S)
        if mem is not None:
            specs["memory"] = mem
        return specs
    if shape.kind == "decode":
        model = build_model(cfg)
        mem_len = memory_len_for(cfg, shape)
        cache = model.cache_shapes(B, S, mem_len)
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "cache": cache,
        }
    raise ValueError(shape.kind)
