"""Attention mixers: GQA self-attention (full / sliding-window), MLA
(multi-head latent attention, MiniCPM3), and cross-attention (vision /
encoder memory) — with both sequence-form (train/prefill, flash kernel)
and single-token decode (KV cache) entry points.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.models.config import ArchConfig, MLAConfig
from repro.models.layers import _dense_init, apply_rope, init_rmsnorm, rmsnorm


# ------------------------------------------------------------- GQA
def init_attention(key, cfg: ArchConfig):
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": _dense_init(k1, (D, H * hd)),
        "wk": _dense_init(k2, (D, Hkv * hd)),
        "wv": _dense_init(k3, (D, Hkv * hd)),
        "wo": _dense_init(k4, (H * hd, D), scale=(H * hd) ** -0.5),
    }


def attention_seq(params, x, cfg: ArchConfig, *, window=None, positions=None,
                  q_offset: int = 0, causal: bool = True):
    """Sequence-form attention (train / prefill).  Returns (out, (k, v))."""
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    if positions is None:
        positions = q_offset + jnp.arange(S)[None, :]
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(dt)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(dt)).reshape(B, S, Hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(dt)).reshape(B, S, Hkv, hd)
    q = apply_rope(q.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta)
    k = apply_rope(k.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta)
    v = v.swapaxes(1, 2)  # (B, Hkv, S, hd)
    o = flash_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    o = o.swapaxes(1, 2).reshape(B, S, H * hd)
    return jnp.einsum("bsh,hd->bsd", o, params["wo"].astype(dt)), (k, v)


def quantize_kv(x):
    """Per-(batch, head, position) symmetric int8 over the head dim.
    x: (..., hd) -> (int8 (..., hd), f32 scale (...))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attention_decode(params, x, cache, pos, cfg: ArchConfig, *, window=None):
    """Single-token decode.  cache: (k, v) each (B, Hkv, S_cache, hd), or
    the int8 form (kq, ks, vq, vs) when cfg.kv_cache_int8;
    ``pos``: scalar current position.  Returns (out, new_cache).

    For windowed layers the cache is a ring buffer of size ``window``."""
    B, _, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    int8_cache = len(cache) == 4
    if int8_cache:
        k_cache, k_scale, v_cache, v_scale = cache
    else:
        k_cache, v_cache = cache
    S_cache = k_cache.shape[2]

    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(dt)).reshape(B, 1, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(dt)).reshape(B, 1, Hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(dt)).reshape(B, 1, Hkv, hd)
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q.swapaxes(1, 2), posv[:, None, :], cfg.rope_theta)  # (B,H,1,hd)
    k = apply_rope(k.swapaxes(1, 2), posv[:, None, :], cfg.rope_theta)
    v = v.swapaxes(1, 2)

    slot = pos % S_cache if window is not None else pos
    if int8_cache:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, kq, slot, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, vq, slot, axis=2)
        k_scale = jax.lax.dynamic_update_slice_in_dim(k_scale, ks, slot, axis=2)
        v_scale = jax.lax.dynamic_update_slice_in_dim(v_scale, vs, slot, axis=2)
        k_full = dequantize_kv(k_cache, k_scale, jnp.float32)
        v_full = dequantize_kv(v_cache, v_scale, jnp.float32)
        new_cache = (k_cache, k_scale, v_cache, v_scale)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=2)
        k_full, v_full = k_cache, v_cache
        new_cache = (k_cache, v_cache)

    # positions of cache slots (ring-aware) for masking
    idx = jnp.arange(S_cache)
    if window is not None:
        wrap = (pos // S_cache) * S_cache
        slot_pos = jnp.where(idx <= slot, wrap + idx, wrap - S_cache + idx)
        valid = (slot_pos >= jnp.maximum(0, pos - window + 1)) & (slot_pos <= pos)
    else:
        valid = idx <= pos

    n_rep = H // Hkv
    kx = jnp.repeat(k_full, n_rep, axis=1)
    vx = jnp.repeat(v_full, n_rep, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) * (hd ** -0.5)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)).astype(dt)
    o = o.swapaxes(1, 2).reshape(B, 1, H * hd)
    out = jnp.einsum("bsh,hd->bsd", o, params["wo"].astype(dt))
    return out, new_cache


# ------------------------------------------------------------- MLA
def init_mla(key, cfg: ArchConfig):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": _dense_init(ks[0], (D, m.q_lora_rank)),
        "q_norm": init_rmsnorm(m.q_lora_rank),
        "w_uq": _dense_init(ks[1], (m.q_lora_rank, H * qh)),
        "w_dkv": _dense_init(ks[2], (D, m.kv_lora_rank + m.qk_rope_head_dim)),
        "kv_norm": init_rmsnorm(m.kv_lora_rank),
        "w_ukv": _dense_init(
            ks[3], (m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim))
        ),
        "wo": _dense_init(ks[4], (H * m.v_head_dim, D)),
    }


def mla_seq(params, x, cfg: ArchConfig, *, q_offset: int = 0):
    """Multi-head latent attention, sequence form.  The cache is the
    compressed latent (B, S, kv_rank + rope_dim) — the memory win that
    makes MiniCPM3 long-context serving cheap.  Returns (out, latent)."""
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    dt = x.dtype
    positions = q_offset + jnp.arange(S)[None, :]

    cq = rmsnorm(
        jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(dt)), params["q_norm"],
        cfg.norm_eps,
    )
    q = jnp.einsum("bsr,rh->bsh", cq, params["w_uq"].astype(dt)).reshape(
        B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim
    )
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(
        q_rope.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta
    ).swapaxes(1, 2)

    latent = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dt))
    c_kv, k_rope = jnp.split(latent, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, params["kv_norm"], cfg.norm_eps)
    kv = jnp.einsum("bsr,rh->bsh", c_kv, params["w_ukv"].astype(dt)).reshape(
        B, S, H, m.qk_nope_head_dim + m.v_head_dim
    )
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k_rope = apply_rope(
        k_rope[:, :, None, :].swapaxes(1, 2), positions[:, None, :], cfg.rope_theta
    ).swapaxes(1, 2)  # (B, S, 1, rope_dim) shared across heads

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1).swapaxes(1, 2)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (m.qk_rope_head_dim,))],
        axis=-1,
    ).swapaxes(1, 2)
    v = v.swapaxes(1, 2)
    # v head dim may differ from qk head dim -> pad for the kernel
    pad = q_full.shape[-1] - v.shape[-1]
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad else v
    o = flash_attention(q_full, k_full, v_p, causal=True, q_offset=q_offset)
    o = o[..., : m.v_head_dim]
    o = o.swapaxes(1, 2).reshape(B, S, H * m.v_head_dim)
    return jnp.einsum("bsh,hd->bsd", o, params["wo"].astype(dt)), latent


def mla_decode(params, x, latent_cache, pos, cfg: ArchConfig):
    """Single-token MLA decode against the compressed latent cache
    (B, S_cache, kv_rank + rope_dim)."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    dt = x.dtype

    new_latent = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dt))
    latent_cache = jax.lax.dynamic_update_slice_in_dim(
        latent_cache, new_latent, pos, axis=1
    )
    S_cache = latent_cache.shape[1]
    positions = jnp.arange(S_cache)[None, :]

    cq = rmsnorm(
        jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(dt)), params["q_norm"],
        cfg.norm_eps,
    )
    q = jnp.einsum("bsr,rh->bsh", cq, params["w_uq"].astype(dt)).reshape(
        B, 1, H, m.qk_nope_head_dim + m.qk_rope_head_dim
    )
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    posv = jnp.full((B, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope.swapaxes(1, 2), posv[:, None, :], cfg.rope_theta)
    q_nope = q_nope.swapaxes(1, 2)

    c_kv, k_rope = jnp.split(latent_cache, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, params["kv_norm"], cfg.norm_eps)
    kv = jnp.einsum("bsr,rh->bsh", c_kv, params["w_ukv"].astype(dt)).reshape(
        B, S_cache, H, m.qk_nope_head_dim + m.v_head_dim
    )
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k_rope = apply_rope(
        k_rope[:, :, None, :].swapaxes(1, 2), positions[:, None, :], cfg.rope_theta
    )  # (B, 1, S, rope)

    s = (
        jnp.einsum("bhqd,bshd->bhqs", q_nope.astype(jnp.float32),
                   k_nope.astype(jnp.float32))
        + jnp.einsum("bhqd,bzsd->bhqs", q_rope.astype(jnp.float32),
                     k_rope.astype(jnp.float32))
    ) * ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
    valid = jnp.arange(S_cache) <= pos
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bshd->bhqd", p, v.astype(jnp.float32)).astype(dt)
    o = o.swapaxes(1, 2).reshape(B, 1, H * m.v_head_dim)
    return jnp.einsum("bsh,hd->bsd", o, params["wo"].astype(dt)), latent_cache


# ------------------------------------------------------------- cross-attn
def init_cross_attention(key, cfg: ArchConfig):
    p = init_attention(key, cfg)
    p["gate"] = jnp.zeros((), jnp.float32)
    return p


def cross_attention(params, x, memory_kv, cfg: ArchConfig):
    """x attends to a fixed memory (vision patches / encoder output).
    memory_kv: precomputed (k, v) each (B, Hkv, M, hd)."""
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(dt)).reshape(
        B, S, H, hd
    ).swapaxes(1, 2)
    k, v = memory_kv
    o = flash_attention(q, k.astype(dt), v.astype(dt), causal=False)
    o = o.swapaxes(1, 2).reshape(B, S, H * hd)
    out = jnp.einsum("bsh,hd->bsd", o, params["wo"].astype(dt))
    return jnp.tanh(params["gate"]).astype(dt) * out


def cross_memory(params, memory, cfg: ArchConfig):
    """Precompute cross-attention (k, v) from memory embeddings
    (B, M, D) once per sequence (prefill)."""
    B, M, D = memory.shape
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim
    dt = memory.dtype
    k = jnp.einsum("bmd,dh->bmh", memory, params["wk"].astype(dt)).reshape(
        B, M, Hkv, hd
    ).swapaxes(1, 2)
    v = jnp.einsum("bmd,dh->bmh", memory, params["wv"].astype(dt)).reshape(
        B, M, Hkv, hd
    ).swapaxes(1, 2)
    return k, v
