"""Loop classification (Aggify §3: which loops become aggregates).

``classify`` inspects one :class:`~repro.core.ir.While` or
:class:`~repro.core.ir.CursorLoop` and returns a :class:`LoopVerdict`:

* ``rewritable=False`` — the loop has no driving relation (plain WHILE)
  or its body uses constructs the rewrite cannot express (nested loops,
  RETURN, subqueries, UDF calls, non-determinism).  FROID inlining then
  falls back to the per-row interpreter, which carries these natively.
* ``kind="reduce"`` — every statement is an unconditional or
  single-IF-guarded commutative accumulator update (``@a = @a + t`` /
  ``@a = @a * t``) whose term and guard are loop-invariant apart from the
  fetch variables.  Lowered as masked ``sum``/``prod`` reductions — no
  sequential dependence at all.
* ``kind="scan"`` — anything else expressible: order-dependent updates,
  BREAK, extra termination guards, loop-local declares.  Lowered as an
  ordered ``lax.scan`` fold with predicated early exit.
"""
from __future__ import annotations

import dataclasses

from repro.core import ir as IR
from repro.core import relalg as R
from repro.core import scalar as S


@dataclasses.dataclass(frozen=True)
class LoopVerdict:
    rewritable: bool
    kind: str  # "reduce" | "scan" | "" when non-rewritable
    reason: str
    written: tuple[str, ...] = ()  # live-out assigned variables
    locals: tuple[str, ...] = ()  # loop-local declares (not live-out)

    def __str__(self):
        head = f"rewritable ({self.kind})" if self.rewritable else "non-rewritable"
        return f"{head}: {self.reason}"


def _body_statements(stmts):
    for st in stmts:
        yield st
        if isinstance(st, IR.IfElse):
            yield from _body_statements(st.then_body)
            yield from _body_statements(st.else_body)
        elif isinstance(st, (IR.While, IR.CursorLoop)):
            yield from _body_statements(st.body)


def _body_exprs(loop: IR.CursorLoop):
    if loop.guard is not None:
        yield loop.guard
    yield from IR.walk_stmt_exprs(loop.body)


def classify(loop: IR.Statement) -> LoopVerdict:
    if isinstance(loop, IR.While):
        return LoopVerdict(
            False, "", "WHILE without a cursor relation — no driving "
            "relation to fold over")
    assert isinstance(loop, IR.CursorLoop), loop

    assigned: set[str] = set()
    local_decls: set[str] = set()
    has_break = False
    for st in _body_statements(loop.body):
        if isinstance(st, (IR.While, IR.CursorLoop)):
            return LoopVerdict(False, "", "nested loop in cursor loop body")
        if isinstance(st, IR.Return):
            return LoopVerdict(False, "", "RETURN inside cursor loop body")
        if isinstance(st, IR.Fetch):
            return LoopVerdict(False, "", "FETCH inside cursor loop body")
        if isinstance(st, IR.Assign):
            assigned.add(st.name)
        elif isinstance(st, IR.Declare):
            local_decls.add(st.name)
        elif isinstance(st, IR.Break):
            has_break = True

    for e in _body_exprs(loop):
        for n in S.walk(e):
            if isinstance(n, (S.ScalarSubquery, S.Exists)):
                return LoopVerdict(
                    False, "", "subquery inside cursor loop body")
            if isinstance(n, S.UdfCall):
                return LoopVerdict(
                    False, "", "nested UDF call inside cursor loop body")
            if isinstance(n, S.Func) and n.name in S.Func.NON_DETERMINISTIC:
                return LoopVerdict(
                    False, "", f"non-deterministic {n.name}() in loop body")
    for n in R.walk_plan_deep(loop.plan):
        for e in n.exprs():
            for x in S.walk(e):
                if isinstance(x, S.UdfCall):
                    return LoopVerdict(
                        False, "", "UDF call inside cursor-defining query")

    written = tuple(sorted(assigned - local_decls))
    locals_ = tuple(sorted(local_decls))
    if reduce_info(loop, assigned, local_decls) is not None and not has_break:
        return LoopVerdict(
            True, "reduce",
            "commutative accumulator fold — lowered as masked reductions",
            written, locals_)
    return LoopVerdict(
        True, "scan",
        "order-dependent fold — lowered as a predicated lax.scan",
        written, locals_)


def reduce_info(loop: IR.CursorLoop, assigned=None, locals_=None):
    """``{acc: (op, term, pred|None)}`` when the loop is a commutative
    fold, else None.  ``term``/``pred`` still contain raw Var refs (the
    rewrite pass substitutes fetch targets with cursor columns)."""
    if assigned is None or locals_ is None:
        assigned, locals_ = set(), set()
        for st in _body_statements(loop.body):
            if isinstance(st, IR.Assign):
                assigned.add(st.name)
            elif isinstance(st, IR.Declare):
                locals_.add(st.name)
    if loop.guard is not None:
        return None
    fetch_vars = {v for v, _ in loop.targets}
    if assigned & fetch_vars or locals_:
        return None

    def invariant(e):
        # terms/guards may read fetch variables, params, and enclosing
        # scope — but not any variable written in the loop
        return not any(
            isinstance(n, S.Var) and n.name in assigned for n in S.walk(e)
        )

    reds: dict[str, tuple] = {}

    def match(st: IR.Assign, pred):
        e = st.expr
        if not (isinstance(e, S.BinOp) and e.op in ("+", "*")):
            return False
        if isinstance(e.l, S.Var) and e.l.name == st.name:
            term = e.r
        elif isinstance(e.r, S.Var) and e.r.name == st.name:
            term = e.l
        else:
            return False
        if st.name in reds or not invariant(term):
            return False
        reds[st.name] = (e.op, term, pred)
        return True

    for st in loop.body:
        if isinstance(st, IR.Assign):
            if not match(st, None):
                return None
        elif isinstance(st, IR.IfElse):
            if st.else_body or not invariant(st.pred):
                return None
            for inner in st.then_body:
                if not (isinstance(inner, IR.Assign) and match(inner, st.pred)):
                    return None
        elif isinstance(st, IR.Break):
            return None
        else:
            return None
    return reds
