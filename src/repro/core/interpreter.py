"""Iterative UDF evaluation — the baseline Froid replaces (paper §2.2/§2.3).

Two modes, mirroring the paper's Table 5 quadrants:

* ``python`` ("interpreted T-SQL"): the UDF is evaluated **once per
  qualifying tuple**, statement by statement.  Each statement gets a
  compiled plan that is cached on first use (SQL Server's per-statement
  plan cache); control flow (IF/ELSE, early RETURN) is interpreted on the
  host between statements.  Queries inside the body re-execute per
  invocation — the O(N·M) behaviour the paper measures.

* ``scan`` ("natively compiled UDF", Hekaton analogue §8.2.7): the whole
  UDF body is traced once into a single compiled function (branches become
  predication) and driven over rows by ``lax.scan``.  Still one invocation
  per row — native compilation removes interpretation overhead but not the
  iterative execution model, which is exactly the paper's point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algebrizer as A
from repro.core import ir as IR
from repro.core import scalar as S
from repro.core.executor import Executor


class InterpreterError(Exception):
    pass


class _Break(Exception):
    """Internal control-flow sentinel for BREAK (python mode)."""


class _Flow:
    """Mutable per-iteration control state for traced loop bodies: lanes
    with ``broken`` set have hit BREAK and skip the rest of the body."""

    def __init__(self, broken):
        self.broken = broken


class Interpreter:
    def __init__(self, catalog, registry, mode: str = "python",
                 jit_statements: bool = True, max_recursion: int = 32,
                 max_loop_iters: int = 10_000):
        assert mode in ("python", "scan")
        self.catalog = catalog
        self.registry = registry
        self.mode = mode
        self.jit_statements = jit_statements
        self.max_recursion = max_recursion
        self.max_loop_iters = max_loop_iters
        self._stmt_cache: dict[int, callable] = {}
        self._scan_cache: dict[str, callable] = {}
        self.stats = {
            "invocations": 0,
            "statements_executed": 0,
            "bytes_scanned": 0,  # logical reads by per-invocation queries
            "rows_scanned": 0,
        }

    # ------------------------------------------------------------------
    # hook wired into Executor.udf_column_evaluator
    # ------------------------------------------------------------------
    def eval_udf_call(self, expr: S.UdfCall, env, ctx) -> S.Value:
        udf = self.registry.get(expr.name)
        if udf is None:
            raise InterpreterError(f"unknown UDF {expr.name!r}")
        n = ctx.num_rows
        args = [S.eval_scalar(a, env, ctx).broadcast(n) for a in expr.args]
        if self.mode == "scan":
            return self._eval_scan(udf, args, n)
        # the UDF is invoked once per *qualifying* tuple (paper §2.2):
        # skip masked-out rows (also required so recursion terminates)
        mask = getattr(ctx, "row_mask", None)
        host_mask = None
        if mask is not None and not isinstance(
            mask, jax.core.Tracer
        ):
            host_mask = np.asarray(mask)
        return self._eval_python(udf, args, n, host_mask)

    # ------------------------------------------------------------------
    # 'python' mode: per-tuple, statement-at-a-time
    # ------------------------------------------------------------------
    def _eval_python(self, udf: IR.UdfDef, args: list[S.Value], n: int,
                     mask: np.ndarray | None = None) -> S.Value:
        host_args = [
            (np.asarray(a.data), np.asarray(a.validity()), a.dictionary)
            for a in args
        ]
        outs = np.zeros((n,), np.float32)
        valids = np.zeros((n,), bool)
        for i in range(n):
            if mask is not None and not mask[i]:
                continue  # non-qualifying tuple: UDF is never invoked
            params = {
                pname: S.Value(
                    jnp.asarray(d[i]), jnp.asarray(v[i]), dic
                )
                for (pname, _), (d, v, dic) in zip(udf.params, host_args)
            }
            val = self.call_udf(udf, params)
            # nested-call results can carry a (1,)-shaped value
            arr = np.asarray(val.data, np.float32).reshape(-1)
            outs[i] = arr[0] if arr.size else 0.0
            v = np.asarray(val.validity()).reshape(-1)
            valids[i] = bool(v[0]) if v.size else False
        return S.Value(jnp.asarray(outs), jnp.asarray(valids))

    def call_udf(self, udf: IR.UdfDef, params: dict[str, S.Value],
                 depth: int = 0) -> S.Value:
        """One UDF invocation: interpret the statement list."""
        if depth > self.max_recursion:
            raise InterpreterError(f"{udf.name}: recursion limit")
        self.stats["invocations"] += 1
        vars: dict[str, S.Value] = {}
        ret = self._run_block(udf, udf.body, vars, params, depth)
        if ret is None:
            return S.null_value()
        return ret

    def _run_block(self, udf, stmts, vars, params, depth):
        for st in stmts:
            self.stats["statements_executed"] += 1
            if isinstance(st, IR.Declare):
                if st.init is None:
                    vars[st.name] = S.null_value(A._NULL_DTYPES.get(st.dtype))
                else:
                    vars[st.name] = self._eval_stmt_expr(
                        udf, st, st.init, vars, params, depth
                    )
            elif isinstance(st, IR.Assign):
                vars[st.name] = self._eval_stmt_expr(
                    udf, st, st.expr, vars, params, depth
                )
            elif isinstance(st, IR.IfElse):
                p = self._eval_stmt_expr(udf, st, st.pred, vars, params, depth)
                taken = bool(np.asarray(p.data)) and bool(np.asarray(p.validity()))
                body = st.then_body if taken else st.else_body
                ret = self._run_block(udf, body, vars, params, depth)
                if ret is not None:
                    return ret
            elif isinstance(st, IR.Return):
                return self._eval_stmt_expr(udf, st, st.expr, vars, params, depth)
            elif isinstance(st, IR.Break):
                raise _Break()
            elif isinstance(st, IR.While):
                ret = self._run_while(udf, st, vars, params, depth)
                if ret is not None:
                    return ret
            elif isinstance(st, IR.CursorLoop):
                ret = self._run_cursor_loop(udf, st, vars, params, depth)
                if ret is not None:
                    return ret
            elif isinstance(st, IR.Fetch):
                raise InterpreterError(
                    "FETCH outside a recognised cursor WHILE loop")
            else:
                raise InterpreterError(type(st).__name__)
        return None

    def _truthy(self, v: S.Value) -> bool:
        return bool(np.asarray(v.data)) and bool(np.asarray(v.validity()))

    def _run_while(self, udf, st: IR.While, vars, params, depth):
        """Reference WHILE semantics: host-interpreted, per statement."""
        iters = 0
        try:
            while True:
                p = self._eval_stmt_expr(udf, st, st.pred, vars, params, depth)
                if not self._truthy(p):
                    return None
                iters += 1
                if iters > self.max_loop_iters:
                    raise InterpreterError(
                        f"{udf.name}: WHILE exceeded {self.max_loop_iters} "
                        "iterations")
                ret = self._run_block(udf, st.body, vars, params, depth)
                if ret is not None:
                    return ret
        except _Break:
            return None

    def _run_cursor_loop(self, udf, st: IR.CursorLoop, vars, params, depth):
        """Reference cursor-loop semantics (the correctness oracle): run
        the defining query once, then iterate its qualifying rows in order
        — bind fetch variables, check the guard, run the body."""
        executor = Executor(
            self.catalog,
            udf_column_evaluator=functools.partial(self._nested_udf, depth),
        )
        res = executor.execute(st.plan, params=params, vars=vars)
        ex_stats = executor.stats
        self.stats["bytes_scanned"] += ex_stats["bytes_scanned"]
        self.stats["rows_scanned"] += ex_stats["rows_scanned"]
        mask = np.asarray(res.mask)
        cols = {
            c: (np.asarray(col.data), np.asarray(col.validity()),
                col.dictionary)
            for c, col in res.table.columns.items()
        }
        try:
            for i in range(mask.shape[0]):
                if not mask[i]:
                    continue  # masked-out row: not a cursor row
                for v, c in st.targets:
                    d, valid, dic = cols[c]
                    vars[v] = S.Value(
                        jnp.asarray(d[i]), jnp.asarray(valid[i]), dic)
                if st.guard is not None:
                    g = self._eval_stmt_expr(
                        udf, st, st.guard, vars, params, depth)
                    if not self._truthy(g):
                        return None
                ret = self._run_block(udf, st.body, vars, params, depth)
                if ret is not None:
                    return ret
        except _Break:
            pass
        return None

    def _eval_stmt_expr(self, udf, st, expr, vars, params, depth) -> S.Value:
        """Evaluate one statement's expression.  With ``jit_statements`` the
        evaluation is compiled once per (udf, statement) — the per-statement
        plan cache — keyed by the statement's identity."""
        executor = Executor(
            self.catalog,
            udf_column_evaluator=functools.partial(self._nested_udf, depth),
        )
        ctx = S.EvalContext(executor=executor, num_rows=1, params=params,
                            vars=vars)
        has_udf = any(isinstance(x, S.UdfCall) for x in S.walk(expr))
        if not self.jit_statements or has_udf:
            # nested UDF calls interpret on the host — can't stage them
            out = S.eval_scalar(expr, {}, ctx)
            ex_stats = executor.stats
            self.stats["bytes_scanned"] += ex_stats["bytes_scanned"]
            self.stats["rows_scanned"] += ex_stats["rows_scanned"]
            return out
        var_names = sorted(vars)
        par_names = sorted(params)
        # plan-cache key: one compiled plan per (statement, frame layout)
        key = (id(st), tuple(var_names), tuple(par_names))
        cached = self._stmt_cache.get(key)
        if cached is None:
            # first invocation: run un-staged to learn the result's string
            # dictionary (host-side metadata), then compile & cache the plan
            first = S.eval_scalar(expr, {}, ctx)
            ex_stats = executor.stats
            stmt_bytes = ex_stats["bytes_scanned"]
            stmt_rows = ex_stats["rows_scanned"]
            self.stats["bytes_scanned"] += stmt_bytes
            self.stats["rows_scanned"] += stmt_rows
            dicts = {k: vars[k].dictionary for k in var_names}
            pdicts = {k: params[k].dictionary for k in par_names}

            def raw(var_leaves, par_leaves):
                vv = {
                    k: S.Value(d, v, dicts[k])
                    for k, (d, v) in zip(var_names, var_leaves)
                }
                pp = {
                    k: S.Value(d, v, pdicts[k])
                    for k, (d, v) in zip(par_names, par_leaves)
                }
                ex = Executor(self.catalog)
                c = S.EvalContext(executor=ex, num_rows=1, params=pp, vars=vv)
                out = S.eval_scalar(expr, {}, c)
                return out.data, out.validity()

            self._stmt_cache[key] = (
                jax.jit(raw), first.dictionary, stmt_bytes, stmt_rows
            )
            return first
        fn, dic, stmt_bytes, stmt_rows = cached
        # each invocation logically re-reads the statement's inner tables
        self.stats["bytes_scanned"] += stmt_bytes
        self.stats["rows_scanned"] += stmt_rows
        var_leaves = [(vars[k].data, vars[k].validity()) for k in var_names]
        par_leaves = [(params[k].data, params[k].validity()) for k in par_names]
        data, valid = fn(var_leaves, par_leaves)
        return S.Value(data, valid, dic)

    def _nested_udf(self, depth, expr: S.UdfCall, env, ctx) -> S.Value:
        udf = self.registry.get(expr.name)
        if udf is None:
            raise InterpreterError(f"unknown UDF {expr.name!r}")
        n = ctx.num_rows
        args = [S.eval_scalar(a, env, ctx).broadcast(n) for a in expr.args]
        if n == 1 or all(jnp.ndim(a.data) == 0 for a in args):
            params = {
                pname: a for (pname, _), a in zip(udf.params, args)
            }
            return self.call_udf(udf, params, depth + 1)
        return self._eval_python(udf, args, n)

    # ------------------------------------------------------------------
    # 'scan' mode: whole-UDF native compilation, lax.scan over rows
    # ------------------------------------------------------------------
    def _eval_scan(self, udf: IR.UdfDef, args: list[S.Value], n: int) -> S.Value:
        fn = self._scan_cache.get(udf.name)
        dicts = [a.dictionary for a in args]
        if fn is None:
            def row_fn(arg_scalars):
                params = {
                    pname: S.Value(d, v, dic)
                    for (pname, _), (d, v), dic in zip(
                        udf.params, arg_scalars, dicts
                    )
                }
                out = self.traced_call(udf, params)
                return out.data.astype(jnp.float32), out.validity()

            def scan_all(arg_arrays):
                def step(carry, xs):
                    return carry, row_fn(xs)

                _, (data, valid) = jax.lax.scan(step, 0, arg_arrays)
                return data, valid

            fn = jax.jit(scan_all)
            self._scan_cache[udf.name] = fn
        arg_arrays = [
            (a.broadcast(n).data, a.broadcast(n).validity()) for a in args
        ]
        data, valid = fn(arg_arrays)
        return S.Value(data, valid)

    def traced_call(self, udf: IR.UdfDef, params: dict[str, S.Value],
                    depth: int = 0) -> S.Value:
        """Trace the whole UDF body as one function: IF/ELSE becomes
        predication (both branches evaluated, merged by the predicate), and
        early RETURNs thread a (ret, retset) pair — the value-level
        equivalent of the algebrizer's probe/pass-through columns."""
        if depth > self.max_recursion:
            raise InterpreterError(f"{udf.name}: recursion limit")

        executor = Executor(
            self.catalog,
            udf_column_evaluator=functools.partial(self._traced_nested, depth),
        )

        def ev(expr, vars):
            ctx = S.EvalContext(executor=executor, num_rows=1, params=params,
                                vars=vars)
            return S.eval_scalar(expr, {}, ctx)

        def guard_of(live, flow):
            """The combined write-guard at this point: the enclosing branch
            predicate ANDed with not-yet-BROKEN.  None means unguarded (the
            straight-line top-level path, preserved bit-for-bit)."""
            g = live
            if flow is not None:
                nb = ~flow.broken
                g = nb if g is None else g & nb
            return g

        def run(stmts, vars, ret, retset, live=None, flow=None):
            for st in stmts:
                g = guard_of(live, flow)
                if isinstance(st, IR.Declare):
                    v = (
                        S.null_value(A._NULL_DTYPES.get(st.dtype))
                        if st.init is None
                        else ev(st.init, vars)
                    )
                    if g is None:
                        vars[st.name] = v
                    else:
                        old = vars.get(st.name) or S.null_value(v.data.dtype)
                        vars[st.name] = _merge(g, v, old)
                elif isinstance(st, IR.Assign):
                    v = ev(st.expr, vars)
                    if g is None:
                        vars[st.name] = v
                    else:
                        old = vars.get(st.name) or S.null_value(v.data.dtype)
                        vars[st.name] = _merge(g, v, old)
                elif isinstance(st, IR.Return):
                    v = ev(st.expr, vars)
                    if ret is None:
                        if g is None:
                            ret, retset = v, jnp.asarray(True)
                        else:
                            ret, retset = v, jnp.asarray(g).reshape(())
                    else:
                        take = (~retset if g is None
                                else jnp.asarray(g).reshape(()) & ~retset)
                        ret = S.Value(
                            jnp.where(take, v.data.astype(ret.data.dtype),
                                      ret.data),
                            jnp.where(take, v.validity(), ret.validity()),
                            ret.dictionary or v.dictionary,
                        )
                        retset = retset | take
                elif isinstance(st, IR.IfElse):
                    p = ev(st.pred, vars)
                    taken = p.data.astype(bool) & p.validity()
                    tlive = None if g is None else g & taken
                    elive = None if g is None else g & ~taken
                    tvars = dict(vars)
                    tret, tretset = run(st.then_body, tvars, ret, retset,
                                        tlive, flow)
                    evars = dict(vars)
                    eret, eretset = run(st.else_body, evars, ret, retset,
                                        elive, flow)
                    for k in set(tvars) | set(evars):
                        tv = tvars.get(k, vars.get(k))
                        evv = evars.get(k, vars.get(k))
                        if tv is None:
                            tv = S.null_value()
                        if evv is None:
                            evv = S.null_value()
                        vars[k] = _merge(taken, tv, evv)
                    ret, retset = _merge_ret(taken, tret, tretset, eret, eretset)
                elif isinstance(st, IR.Break):
                    if flow is None:
                        raise InterpreterError("BREAK outside a loop")
                    b = jnp.asarray(True) if g is None else g
                    flow.broken = flow.broken | jnp.asarray(b).reshape(())
                elif isinstance(st, IR.While):
                    ret, retset = traced_while(st, vars, ret, retset, g)
                elif isinstance(st, IR.CursorLoop):
                    ret, retset = traced_cursor(st, vars, ret, retset, g)
                elif isinstance(st, IR.Fetch):
                    raise InterpreterError(
                        "FETCH outside a recognised cursor WHILE loop")
            return ret, retset

        def seed_frame(st, vars, ret, retset, extra_nulls=()):
            """Close the loop's carry structure: every name the body may
            write must exist in the frame before tracing starts."""
            for name, dtype in _loop_declares(st.body):
                if name not in vars:
                    vars[name] = S.null_value(A._NULL_DTYPES.get(dtype))
            for name in _loop_assigned([st]):
                if name not in vars:
                    vars[name] = (params[name] if name in params
                                  else S.null_value())
            for name, dtype in extra_nulls:
                if name not in vars:
                    vars[name] = S.null_value(dtype)
            has_ret = _has_return(st.body)
            if has_ret and ret is None:
                ret = S.null_value()
                retset = jnp.asarray(False)
            return ret, retset, has_ret

        def traced_while(st: IR.While, vars, ret, retset, live):
            ret, retset, has_ret = seed_frame(st, vars, ret, retset)
            names = sorted(vars)
            dtypes = {k: jnp.asarray(vars[k].data).dtype for k in names}
            dicts = {k: vars[k].dictionary for k in names}
            rdict = ret.dictionary if ret is not None else None
            base_live = (jnp.asarray(True) if live is None
                         else _sc(jnp.asarray(live)))

            def unpack(leaves):
                return {k: S.Value(d, v, dicts[k])
                        for k, (d, v) in zip(names, leaves)}

            def cond_fn(c):
                it, leaves, rleaf, rs, brk = c
                p = ev(st.pred, unpack(leaves))
                ok = (_sc(p.data).astype(bool) & _sc(p.validity())
                      & (it < self.max_loop_iters) & base_live & ~brk)
                if has_ret:
                    ok = ok & ~rs
                return ok

            def body_fn(c):
                it, leaves, rleaf, rs, brk = c
                vv = unpack(leaves)
                r = (S.Value(rleaf[0], rleaf[1], rdict)
                     if ret is not None else None)
                flow = _Flow(jnp.asarray(False))
                r2, rs2 = run(st.body, vv, r, rs, live=None, flow=flow)
                leaves2 = tuple(
                    (_sc(vv[k].data).astype(dtypes[k]), _sc(vv[k].validity()))
                    for k in names
                )
                rleaf2 = (
                    (_sc(r2.data).astype(rleaf[0].dtype), _sc(r2.validity()))
                    if r2 is not None else rleaf
                )
                return (it + 1, leaves2, rleaf2,
                        _sc(jnp.asarray(rs2)), _sc(flow.broken))

            init = (
                jnp.asarray(0, jnp.int32),
                tuple((_sc(jnp.asarray(vars[k].data)),
                       _sc(jnp.asarray(vars[k].validity()))) for k in names),
                ((_sc(jnp.asarray(ret.data)), _sc(jnp.asarray(ret.validity())))
                 if ret is not None
                 else (jnp.zeros((), jnp.float32), jnp.asarray(False))),
                _sc(jnp.asarray(retset)),
                jnp.asarray(False),
            )
            _, leaves, rleaf, rs, _ = jax.lax.while_loop(
                cond_fn, body_fn, init)
            for k, v in unpack(leaves).items():
                vars[k] = v
            if ret is not None:
                ret = S.Value(rleaf[0], rleaf[1], rdict)
            return ret, rs

        def traced_cursor(st: IR.CursorLoop, vars, ret, retset, live):
            res = executor.execute(st.plan, params=params, vars=vars)
            cols = res.table.columns
            extra = [(v, cols[c].data.dtype) for v, c in st.targets]
            ret, retset, has_ret = seed_frame(st, vars, ret, retset, extra)
            names = sorted(vars)
            dtypes = {k: jnp.asarray(vars[k].data).dtype for k in names}
            dicts = {k: vars[k].dictionary for k in names}
            cdicts = {c: col.dictionary for c, col in cols.items()}
            rdict = ret.dictionary if ret is not None else None
            base_live = (jnp.asarray(True) if live is None
                         else _sc(jnp.asarray(live)))

            def unpack(leaves):
                return {k: S.Value(d, v, dicts[k])
                        for k, (d, v) in zip(names, leaves)}

            def step(carry, x):
                leaves, done, rleaf, rs = carry
                mask_bit, row = x
                vv = unpack(leaves)
                live_row = mask_bit & ~done & base_live
                if has_ret:
                    live_row = live_row & ~rs
                for v, c in st.targets:
                    new = S.Value(row[c][0], row[c][1], cdicts[c])
                    vv[v] = _merge(live_row, new, vv[v])
                done2 = done
                if st.guard is not None:
                    gv = ev(st.guard, vv)
                    gok = _sc(gv.data).astype(bool) & _sc(gv.validity())
                    done2 = done2 | (live_row & ~gok)
                    live_row = live_row & gok
                flow = _Flow(jnp.asarray(False))
                r = (S.Value(rleaf[0], rleaf[1], rdict)
                     if ret is not None else None)
                r2, rs2 = run(st.body, vv, r, rs, live=live_row, flow=flow)
                done2 = done2 | flow.broken
                leaves2 = tuple(
                    (_sc(vv[k].data).astype(dtypes[k]), _sc(vv[k].validity()))
                    for k in names
                )
                rleaf2 = (
                    (_sc(r2.data).astype(rleaf[0].dtype), _sc(r2.validity()))
                    if r2 is not None else rleaf
                )
                return (leaves2, _sc(done2), rleaf2, _sc(jnp.asarray(rs2))), None

            init = (
                tuple((_sc(jnp.asarray(vars[k].data)),
                       _sc(jnp.asarray(vars[k].validity()))) for k in names),
                jnp.asarray(False),
                ((_sc(jnp.asarray(ret.data)), _sc(jnp.asarray(ret.validity())))
                 if ret is not None
                 else (jnp.zeros((), jnp.float32), jnp.asarray(False))),
                _sc(jnp.asarray(retset)),
            )
            xs = (res.mask,
                  {c: (col.data, col.validity()) for c, col in cols.items()})
            (leaves, _, rleaf, rs), _ = jax.lax.scan(step, init, xs)
            for k, v in unpack(leaves).items():
                vars[k] = v
            if ret is not None:
                ret = S.Value(rleaf[0], rleaf[1], rdict)
            return ret, rs

        vars: dict[str, S.Value] = {}
        ret, retset = run(udf.body, vars, None, jnp.asarray(False))
        if ret is None:
            return S.null_value()
        keep = retset if retset is not None else jnp.asarray(True)
        return S.Value(ret.data, ret.validity() & keep, ret.dictionary)

    def _traced_nested(self, depth, expr: S.UdfCall, env, ctx) -> S.Value:
        udf = self.registry.get(expr.name)
        if udf is None:
            raise InterpreterError(f"unknown UDF {expr.name!r}")
        args = [S.eval_scalar(a, env, ctx) for a in expr.args]
        params = {pname: a for (pname, _), a in zip(udf.params, args)}
        return self.traced_call(udf, params, depth + 1)


def _sc(x):
    """Scalarize a traced value to rank-0 (loop carries must be scalars)."""
    return jnp.reshape(jnp.asarray(x), ())


def _loop_declares(stmts):
    """(name, dtype) of every Declare reachable in ``stmts``."""
    for st in stmts:
        if isinstance(st, IR.Declare):
            yield st.name, st.dtype
        elif isinstance(st, IR.IfElse):
            yield from _loop_declares(st.then_body)
            yield from _loop_declares(st.else_body)
        elif isinstance(st, (IR.While, IR.CursorLoop)):
            yield from _loop_declares(st.body)


def _loop_assigned(stmts):
    """Every variable name written (Assign or FETCH target) in ``stmts``."""
    for st in stmts:
        if isinstance(st, IR.Assign):
            yield st.name
        elif isinstance(st, IR.IfElse):
            yield from _loop_assigned(st.then_body)
            yield from _loop_assigned(st.else_body)
        elif isinstance(st, (IR.While, IR.CursorLoop)):
            if isinstance(st, IR.CursorLoop):
                for v, _ in st.targets:
                    yield v
            yield from _loop_assigned(st.body)
        elif isinstance(st, IR.Fetch):
            for v, _ in st.targets:
                yield v


def _has_return(stmts) -> bool:
    for st in stmts:
        if isinstance(st, IR.Return):
            return True
        if isinstance(st, IR.IfElse):
            if _has_return(st.then_body) or _has_return(st.else_body):
                return True
        elif isinstance(st, (IR.While, IR.CursorLoop)):
            if _has_return(st.body):
                return True
    return False


def _merge(pred, tv: S.Value, ev: S.Value) -> S.Value:
    td, ed = tv.data, ev.data
    if td.dtype != ed.dtype:
        common = jnp.result_type(td.dtype, ed.dtype)
        td, ed = td.astype(common), ed.astype(common)
    return S.Value(
        jnp.where(pred, td, ed),
        jnp.where(pred, tv.validity(), ev.validity()),
        tv.dictionary or ev.dictionary,
    )


def _merge_ret(pred, tret, tretset, eret, eretset):
    if tret is None and eret is None:
        return None, jnp.asarray(False)
    if tret is None:
        tret = S.null_value(eret.data.dtype)
        tretset = jnp.asarray(False)
    if eret is None:
        eret = S.null_value(tret.data.dtype)
        eretset = jnp.asarray(False)
    ret = _merge(pred, tret, eret)
    retset = jnp.where(pred, tretset, eretset)
    return ret, retset


