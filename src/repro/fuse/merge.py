"""Plan-merge pass: align fusable plans over a shared table set.

The fusion engine's front half.  Given the bound+optimized plans of the
statements a fused program will carry, this pass finds the work they have
in common so the back half (:mod:`repro.fuse.program`) computes it once:

* every **param-free subtree** (no ``Param``/``Outer``/``Var`` references
  anywhere below it, including inside nested subquery plans) is a candidate
  for sharing — its result depends only on catalog state, which all members
  of a fused program see identically;
* candidates are keyed by :func:`repro.core.session.plan_fingerprint`, so
  two independently-built trees of the same shape dedup (the cross-
  statement version of the executor's per-``node_id`` CSE memo);
* sharing is **maximal**: when a subtree is shared, its descendants are
  subsumed (they execute inside the one shared evaluation).

The output is a :class:`FusedPlan`: the member plans in fusion order, the
distinct shared subtrees (each with a canonical node to execute), and a
``node_id -> fingerprint`` map the fused executor consults to skip straight
to the shared result.  Identical *whole* statements still fuse — their
param-dependent roots simply contribute no shared subtree beyond whatever
catalog-only work they contain.

Deliberately out of scope (ROADMAP open item): common subexpressions that
are *not* identical subtrees — correlated subquery bodies differing only in
their outer binding, and shared sub-subtrees between two distinct shared
roots.  Those need expression-level rewriting, not plan alignment.
"""
from __future__ import annotations

import dataclasses

from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.session import plan_fingerprint

#: every relalg node the executor can run is side-effect free; anything
#: else (a future effectful node, a foreign plan object) blocks fusion
PURE_NODES = (
    R.Scan, R.ConstantScan, R.Compute, R.Project, R.Filter,
    R.Join, R.Apply, R.GroupAgg, R.Sort,
)


def plan_is_pure(plan: R.RelNode) -> bool:
    """True when every node of ``plan`` is a known side-effect-free
    operator — the fusability analysis's safety gate."""
    return all(isinstance(n, PURE_NODES) for n in R.walk_plan(plan))


def subtree_is_constant(node: R.RelNode) -> bool:
    """True when the subtree's result depends only on catalog state: no
    query parameters, no outer-row references, no unbound UDF locals, and
    no non-deterministic intrinsics (``rand()`` must evaluate per
    statement, not once per pool) — anywhere below it, including nested
    subquery plans (``S.walk`` descends into ``ScalarSubquery``/``Exists``
    plans)."""
    for n in R.walk_plan(node):
        for e in n.exprs():
            for s in S.walk(e):
                if isinstance(s, (S.Param, S.Outer, S.Var)):
                    return False
                if isinstance(s, S.Func) and s.name in S.Func.NON_DETERMINISTIC:
                    return False
    return True


@dataclasses.dataclass
class FusedPlan:
    """The merge pass's product (see module docstring)."""

    members: list  # member plans, fusion order
    shared: list  # [(fingerprint, canonical subtree)] — execute-once set
    shared_ids: dict  # node_id -> fingerprint, across every member plan
    stats: dict  # merge-level counters (shared_subtrees, shared_refs, ...)


def merge_plans(plans: list) -> FusedPlan:
    """Merge ``plans`` into one fused-program description.

    Two passes: count occurrences of every constant subtree fingerprint
    across all members (a subtree occurring twice — in two members, or
    twice within one — is worth computing once), then mark shared subtrees
    top-down so only maximal ones survive.
    """
    const_fp: dict[int, tuple | None] = {}  # node_id -> fp | not-shareable
    occurrences: dict[tuple, int] = {}
    canonical: dict[tuple, R.RelNode] = {}
    for plan in plans:
        for n in R.walk_plan(plan):
            fp = const_fp.get(n.node_id, "unseen")
            if fp == "unseen":
                fp = plan_fingerprint(n) if subtree_is_constant(n) else None
                const_fp[n.node_id] = fp
            if fp is not None:
                occurrences[fp] = occurrences.get(fp, 0) + 1
                canonical.setdefault(fp, n)

    shared_fps = {fp for fp, c in occurrences.items() if c >= 2}
    shared: list[tuple[tuple, R.RelNode]] = []
    shared_ids: dict[int, tuple] = {}
    emitted: set = set()

    def mark(n: R.RelNode) -> None:
        fp = const_fp.get(n.node_id)
        if fp is not None and fp in shared_fps:
            shared_ids[n.node_id] = fp
            if fp not in emitted:
                emitted.add(fp)
                shared.append((fp, canonical[fp]))
            return  # maximal: descendants execute inside the shared result
        for c in n.children():
            mark(c)

    for plan in plans:
        mark(plan)

    total_scans = sum(
        1 for p in plans for n in R.walk_plan(p) if isinstance(n, R.Scan)
    )
    shared_scan_nodes = sum(
        1 for _, sub in shared for n in R.walk_plan(sub)
        if isinstance(n, R.Scan)
    )
    stats = {
        "fused_members": len(plans),
        "shared_subtrees": len(shared),
        # marked references across members; refs - subtrees = evaluations
        # the fused program skips relative to the per-statement path
        "shared_refs": len(shared_ids),
        "total_scans": total_scans,
        "shared_scan_nodes": shared_scan_nodes,
    }
    return FusedPlan(list(plans), shared, shared_ids, stats)
