"""Blockwise online-softmax attention (FlashAttention) Pallas TPU kernel.

Supports the attention variants of the assigned architectures:
* causal masking (decoder LMs),
* sliding-window masking (Mixtral SWA, Gemma-3 local layers),
* GQA (kv-head sharing) expressed in the K/V BlockSpec index_map (no
  materialized head repetition — the kv block for query head ``h`` is
  fetched from head ``h // n_rep``),
* ``q_offset`` for chunked prefill (query block at absolute position
  ``q_offset + i``).

Tiling: grid = (batch, q_heads, Sq/BQ, Sk/BK), K innermost (sequential).
Q/O blocks are (BQ, D) in VMEM, K/V blocks (BK, D); the online-softmax
running state (m, l, acc) lives in VMEM scratch persisting across the K
axis.  Fully-masked K blocks are skipped with ``pl.when`` (this is the
structural win of causal/windowed tiling: ~2x fewer MXU passes for causal,
O(W·S) instead of O(S²) for windows).

MXU alignment: BQ=BK=128 blocks, D is the head dim (128 for all assigned
archs) — every matmul is (128, D)x(D, 128) or (128, 128)x(128, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, sm_scale, causal, window, q_offset, bq, bk, nk, kv_len,
):
    i = pl.program_id(2)  # q block
    kk = pl.program_id(3)  # k block

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_first = q_offset + i * bq  # absolute position of first query row
    k_first = kk * bk

    # block-level relevance: skip fully-masked K blocks
    relevant = k_first < kv_len
    if causal:
        relevant = jnp.logical_and(relevant, k_first <= q_first + bq - 1)
    if window is not None:
        relevant = jnp.logical_and(relevant, k_first + bk - 1 > q_first - window)

    @pl.when(relevant)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)

        qpos = q_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...][:, :1]  # (bq, 1)
        l_prev = l_ref[...][:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # rescale of old state
        p = jnp.exp(s - m_new)  # (bq, bk)
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kk == nk - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        safe_l = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hk, Sk, D)
    v: jnp.ndarray,  # (B, Hk, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    sm_scale: float | None = None,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
):
    B, Hq, Sq, D = q.shape
    _, Hk, Sk, _ = k.shape
    assert Hq % Hk == 0, (Hq, Hk)
    n_rep = Hq // Hk
    if sm_scale is None:
        sm_scale = D ** -0.5

    bq = min(bq, Sq)
    bk = min(bk, Sk)
    q_pad = (-Sq) % bq
    k_pad = (-Sk) % bk
    kv_len = Sk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
    nq = (Sq + q_pad) // bq
    nk = (Sk + k_pad) // bk

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale,
        causal=causal,
        window=window,
        q_offset=q_offset,
        bq=bq,
        bk=bk,
        nk=nk,
        kv_len=kv_len,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, kk: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, D), lambda b, h, i, kk, n_rep=n_rep: (b, h // n_rep, kk, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, D), lambda b, h, i, kk, n_rep=n_rep: (b, h // n_rep, kk, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, kk: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq + q_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),  # acc
            pltpu.VMEM((bq, 128), jnp.float32),  # running max (lane-padded)
            pltpu.VMEM((bq, 128), jnp.float32),  # running denom
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
