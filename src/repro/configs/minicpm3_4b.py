"""minicpm3-4b [dense] — 62L d_model=2560 40H d_ff=6400 vocab=73448;
multi-head latent attention (MLA).  [hf:openbmb/MiniCPM3-4B]"""
import dataclasses

from repro.models.config import ArchConfig, LayerSpec, MLAConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b",
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_ff=6400,
        vocab=73448,
        head_dim=96,  # qk_nope 64 + qk_rope 32
        super_block=(LayerSpec(mixer="attn", mlp="dense"),),
        n_repeats=62,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                      qk_rope_head_dim=32, v_head_dim=64),
        tie_embeddings=True,
        max_seq_len=32_768,
    )


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        config(), d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
        head_dim=24, n_repeats=2,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        max_seq_len=128,
    )
