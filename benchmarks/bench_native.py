"""Table 5 (Hekaton native compilation): the 2×2 of
{interpreted, natively compiled} × {froid OFF, froid ON} on an
inner-query UDF (where native compilation alone cannot remove the
iterative O(N·M) work — the paper's point)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_run
from repro.core import (FROID, HEKATON, INTERPRETED, Session, UdfBuilder,
                        col, param, scan, sum_, udf, var)

N = 2_000
M = 20_000
N_INTERP = 200


def run(quick: bool = False):
    db = Session()
    rng = np.random.default_rng(0)
    db.create_table("detail", d_key=rng.integers(0, 500, M),
                    d_val=rng.uniform(0, 100, M).astype(np.float32))
    db.create_table("T", a=rng.integers(0, 500, N))
    u = UdfBuilder("fare_total", [("k", "int32")], "float32")
    u.declare("s", "float32")
    u.select({"s": sum_(col("d_val"))}, frm=scan("detail"),
             where=col("d_key") == param("k"))
    u.return_(var("s"))
    db.create_function(u.build())
    q = scan("T").compute(v=udf("fare_total", col("a")))

    # interpreted + froid OFF (classic)
    sub_q = scan("T").filter(col("a") >= 0).compute(v=udf("fare_total", col("a")))
    r = db.execute(
        scan("T").compute(v=udf("fare_total", col("a"))) if N <= N_INTERP
        else _cap(db, q), INTERPRETED,
    )
    t_interp = r.elapsed_s * (N / min(N, N_INTERP))
    emit("table5/interpreted_froid_off", t_interp * 1e6, "extrapolated")

    # native (compiled) + froid OFF: still iterative
    fn = db.prepare(q, HEKATON)
    t_native_off = time_run(fn, warmup=1, iters=2)
    emit("table5/native_froid_off", t_native_off * 1e6,
         f"vs_interpreted={t_interp/t_native_off:.1f}x")

    # interpreted query + froid ON (plan built each call, no caching)
    t_on_interp = time_run(lambda: db.execute(q, FROID.eager()).masked.mask,
                           warmup=1, iters=2)
    emit("table5/interpreted_froid_on", t_on_interp * 1e6, "")

    # native + froid ON: compiled set-oriented plan
    fn_on = db.prepare(q, FROID)
    t_on = time_run(fn_on)
    emit("table5/native_froid_on", t_on * 1e6,
         f"total_gain={t_interp/t_on:.0f}x")


def _cap(db, q):
    from repro.tables.table import Column, Table

    t = db.catalog["T"]
    db.catalog["T_cap"] = Table(
        {n: Column(c.data[:N_INTERP], None, c.dictionary)
         for n, c in t.columns.items()}
    )
    from repro.core import scan as _scan, udf as _udf, col as _col

    return _scan("T_cap").compute(v=_udf("fare_total", _col("a")))


if __name__ == "__main__":
    run()
