"""TPC-H queries rewritten with scalar UDFs (paper §8.2.4 / §11).

    PYTHONPATH=src:. python examples/tpch_udf_demo.py

Shows: plan for Q6 with the q6conditions UDF inlined (dynamic slicing turns
the imperative date checks into plain predicates), result equivalence with
the original query, and the speedup against iterative evaluation.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.tpch_udfs import QUERIES, register_udfs
from repro.core import FROID, Session
from repro.data.tpch import generate_tpch

db = Session()
print("generating TPC-H data (sf=0.02)…")
generate_tpch(db, sf=0.02)
register_udfs(db)

for name in ("Q6", "Q14", "Q12"):
    q_udf, q_orig = QUERIES[name]
    qu, qo = q_udf(), q_orig()
    stmt_u = db.prepare(qu, FROID)
    stmt_o = db.prepare(qo, FROID)
    if name == "Q6":
        print("\n=== plan for Q6 with q6conditions() inlined ===")
        print(stmt_u.explain())

    ru = stmt_u.execute()                  # cold: bind+optimize+jit
    t_on = stmt_u.execute().elapsed_s      # warm: cached compiled plan
    ro = stmt_o.execute()
    t_orig = stmt_o.execute().elapsed_s

    ra = ru.table
    rb = ro.table
    col0 = [c for c in ra.names() if c in rb.columns][0]
    match = np.allclose(
        np.asarray(ra.columns[col0].data, np.float64),
        np.asarray(rb.columns[col0].data, np.float64), rtol=2e-3, atol=1e-2)
    print(f"{name}: udf+froid {t_on*1e3:7.1f} ms | original {t_orig*1e3:7.1f} ms"
          f" | overhead {t_on/t_orig:4.2f}x | results match: {match}")
print("\nUDFs cost ~nothing when Froid inlines them (paper Fig. 9).")
