"""Batched serving engine: fixed-slot continuous batching over the model's
prefill/decode steps, with Froid-compiled admission (admission.py) and
greedy/temperature sampling.

Slots hold (cache row, remaining budget); finished slots are refilled from
the admitted queue each tick.  Single-process reference implementation —
the decode step itself is the pjit'd ``serve_step`` the dry-run lowers for
the production mesh.

Two intake shapes:

* ``run(requests)`` — the whole wave arrives at once; admission evaluates
  it as one queue table (the tick path).
* ``submit(request)`` + ``drain()`` — requests arrive one at a time (the
  online shape); ``drain`` tickets the whole queued wave on the
  coalescing microbatch scheduler, so admission executes as set-oriented
  ``execute_many`` batches instead of one statement per request, with
  the same queue-depth semantics (and therefore verdicts) as ``run``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.resilience.faults import ResilienceError
from repro.serve.admission import AdmissionPolicy
from repro.serve.scheduler import CoalescingScheduler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    tier: int = 1


@dataclasses.dataclass
class Completed:
    rid: int
    tokens: list
    reason: str  # length | eos | rejected


class ServeEngine:
    def __init__(self, model, params, *, slots: int = 4, max_len: int = 256,
                 eos_id: int | None = None, froid_admission: bool = True,
                 admission_policy=None, seed: int = 0,
                 admission_scheduler: CoalescingScheduler | None = None,
                 admission_mesh=None, admission_fuse: bool = False,
                 admission_adaptive: bool = False,
                 admission_timeout_s: float | None = None,
                 admission_store=None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        # admission_policy: ExecutionPolicy or preset name ("froid",
        # "interpreted", "hekaton"); froid_admission is the legacy switch.
        # admission_mesh shards the online (submit/drain) admission
        # microbatches over a device mesh so intake traffic fills devices.
        # admission_fuse drains mixed-statement admission waves as one
        # fused device program; admission_adaptive tracks the arrival rate
        # with the coalescing window; admission_timeout_s deadlines each
        # admission ticket — an expired or resilience-failed ticket
        # completes as "shed" instead of hanging or crashing the drain.
        # admission_store (PlanStore or path) warm-starts the compiled
        # admission statement across engine restarts.
        self.admission = AdmissionPolicy(
            froid=froid_admission, policy=admission_policy,
            scheduler=admission_scheduler, mesh=admission_mesh,
            fuse=admission_fuse, adaptive=admission_adaptive,
            timeout_s=admission_timeout_s, store=admission_store,
        )
        self.shed: list[Completed] = []  # resilience-shed completions
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)
        # online intake: requests awaiting the next drain()
        self._submitted: list[Request] = []

    # ------------------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Completed]:
        """Serve a request list to completion (batched, slot-filled)."""
        verdict = self.admission.evaluate(
            {
                "tier": np.array([r.tier for r in requests]),
                "prompt_len": np.array([len(r.prompt) for r in requests]),
                "max_new_tokens": np.array([r.max_new_tokens for r in requests]),
                "temperature": np.array([r.temperature for r in requests]),
            }
        )
        queue = []
        done: list[Completed] = []
        for i, r in enumerate(requests):
            if not verdict["admit"][i]:
                done.append(Completed(r.rid, [], "rejected"))
            else:
                queue.append((r, int(verdict["granted"][i]),
                              float(verdict["temp"][i])))

        while queue:
            batch = queue[: self.slots]
            queue = queue[self.slots :]
            done.extend(self._serve_batch(batch))
        return done

    # ------------------------------------------------------------------
    def submit(self, request: Request) -> None:
        """Online intake: queue one request for the next ``drain()``."""
        self._submitted.append(request)

    def drain(self) -> list[Completed]:
        """Admit the queued wave set-oriented (per-request tickets on the
        coalescing scheduler, drained through ``execute_many``), then
        serve every admitted request to completion.  Admission happens at
        drain time so every ticket sees the same queue depth the tick
        path (``run``) would — identical verdicts, including
        load-shedding."""
        submitted, self._submitted = self._submitted, []
        depth = len(submitted)
        tickets = [
            self.admission.submit(
                tier=r.tier,
                prompt_len=len(r.prompt),
                max_new_tokens=r.max_new_tokens,
                temperature=r.temperature,
                depth=depth,
            )
            for r in submitted
        ]
        self.admission.scheduler.flush()
        queue = []
        done: list[Completed] = []
        for r, ticket in zip(submitted, tickets):
            try:
                v = AdmissionPolicy.verdict(ticket.result())
            except ResilienceError:
                # deadline shed / exhausted ladder: the request completes
                # explicitly instead of crashing the whole drain
                c = Completed(r.rid, [], "shed")
                self.shed.append(c)
                done.append(c)
                continue
            if not v["admit"]:
                done.append(Completed(r.rid, [], "rejected"))
            else:
                queue.append((r, v["granted"], v["temp"]))
        while queue:
            batch = queue[: self.slots]
            queue = queue[self.slots :]
            done.extend(self._serve_batch(batch))
        return done

    # ------------------------------------------------------------------
    def _serve_batch(self, batch) -> list[Completed]:
        B = len(batch)
        S = max(len(r.prompt) for r, _, _ in batch)
        toks = np.zeros((B, S), np.int32)
        for i, (r, _, _) in enumerate(batch):
            toks[i, S - len(r.prompt) :] = r.prompt  # left-pad
        budgets = np.array([b for _, b, _ in batch])
        temps = np.array([t for _, _, t in batch], np.float32)

        logits, cache = self.model.prefill(
            self.params, jnp.asarray(toks), max_len=self.max_len
        )
        outs: list[list[int]] = [[] for _ in range(B)]
        finished = np.zeros(B, bool)
        next_tok = self._sample(logits, temps)
        for i in range(B):
            outs[i].append(int(next_tok[i]))

        max_budget = int(budgets.max(initial=0))
        for step in range(1, max_budget):
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(next_tok)[:, None]
            )
            next_tok = self._sample(logits, temps)
            for i in range(B):
                if finished[i]:
                    continue
                if step >= budgets[i]:
                    finished[i] = True
                    continue
                t = int(next_tok[i])
                outs[i].append(t)
                if self.eos_id is not None and t == self.eos_id:
                    finished[i] = True
            if finished.all():
                break

        out = []
        for i, (r, b, _) in enumerate(batch):
            reason = (
                "eos"
                if self.eos_id is not None and outs[i] and outs[i][-1] == self.eos_id
                else "length"
            )
            out.append(Completed(r.rid, outs[i][:b], reason))
        return out

    def _sample(self, logits, temps):
        self.key, sub = jax.random.split(self.key)
        greedy = jnp.argmax(logits, axis=-1)
        scaled = logits / jnp.maximum(jnp.asarray(temps)[:, None], 1e-4)
        sampled = jax.random.categorical(sub, scaled)
        pick = jnp.where(jnp.asarray(temps) > 0, sampled, greedy)
        return np.asarray(pick.astype(jnp.int32))
