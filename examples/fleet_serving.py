"""Fleet serving walkthrough: the persistent plan tier warm-starting a
multi-worker fleet, observable through ``Session.persist_stats`` and
``FleetEngine.stats``.

    PYTHONPATH=src python examples/fleet_serving.py

The PR-9 persistent tier + fleet in four acts:

  1. A cold worker: a fresh ``Session`` over an empty ``PlanStore``
     traces and AOT-compiles every statement on first execute, then
     serializes the compiled executable into the store (atomic rename,
     version-stamped entries).
  2. A warm start: a brand-new session over the now-populated store
     answers its first execute of every statement without re-tracing —
     the serialized executable is loaded and called directly
     (``persist_hits`` covers the whole population).
  3. A fleet: ``FleetEngine`` spins N workers over one shared store;
     round-robin intake, per-worker coalescing drains, results in
     arrival order.  Worker 1 rides worker 0's saves even inside a
     cold fleet.
  4. Corruption is survivable: a truncated entry is rejected with a
     typed ``PlanCacheWarning``, the worker silently recompiles (never
     wrong results), and re-saves a good entry behind it.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile
import warnings

import numpy as np

from repro.core import FROID, Session, col, param, scan
from repro.persist import PlanCacheWarning, PlanStore
from repro.serve import FleetEngine

root = tempfile.mkdtemp(prefix="fleet_demo_")
N_STMTS = 4


def setup(session: Session) -> dict:
    rng = np.random.default_rng(3)
    session.create_table("T", a=rng.integers(0, 100, 256))
    stmts = {}
    for i in range(N_STMTS):
        q = (scan("T").filter(col("a") >= param("lo"))
             .compute(**{f"w{i}": col("a") * param("scale") + float(i)})
             .project("a", f"w{i}"))
        stmts[f"q{i}"] = session.prepare(q, FROID)
    return stmts


# ---------------------------------------------------------------- act 1
print("== act 1: cold worker populates the store ==")
cold = Session(store=root)
stmts = setup(cold)
for i in range(N_STMTS):
    stmts[f"q{i}"].execute(params={"lo": 40, "scale": 2.0})
ps = cold.persist_stats
print(f"  store dir: {root}")
print(f"  persist_stats: saves={ps['saves']} hits={ps['hits']} "
      f"misses={ps['misses']}")
print(f"  on disk: {PlanStore(root).stats()}")

# ---------------------------------------------------------------- act 2
print("== act 2: warm start — a fresh session never re-traces ==")
warm = Session(store=root)
wstmts = setup(warm)
rs = [wstmts[f"q{i}"].execute(params={"lo": 40, "scale": 2.0})
      for i in range(N_STMTS)]
ps = warm.persist_stats
print(f"  first {N_STMTS} executes: persist_hits={ps['hits']} "
      f"misses={ps['misses']} (0 misses = nothing re-traced)")
print(f"  cache_stats persist counters: "
      f"{ {k: v for k, v in warm.cache_stats.items() if 'persist' in k} }")

# ---------------------------------------------------------------- act 3
print("== act 3: fleet drain over the shared store ==")
fleet = FleetEngine(setup, workers=2, store=root)
for j in range(8):
    fleet.submit(f"q{j % N_STMTS}", {"lo": 10 + j, "scale": 1.5})
results = fleet.drain()
st = fleet.stats
print(f"  drained {len(results)} requests in arrival order, "
      f"first row counts: {[r.table.num_rows for r in results[:4]]}")
print(f"  fleet: {st['fleet']}")
for pw in st["workers"]:
    print(f"  worker {pw['wid']}: persist={pw['persist']}")

# ---------------------------------------------------------------- act 4
print("== act 4: a corrupt entry degrades to recompile, never to a "
      "wrong answer ==")
for name in os.listdir(root):
    if name.endswith(".plan"):
        path = os.path.join(root, name)
        blob = open(path, "rb").read()
        open(path, "wb").write(blob[:12])  # truncate mid-header
with warnings.catch_warnings(record=True) as caught:
    warnings.simplefilter("always")
    repaired = Session(store=root)
    rstmts = setup(repaired)
    rs2 = [rstmts[f"q{i}"].execute(params={"lo": 40, "scale": 2.0})
           for i in range(N_STMTS)]
typed = [w for w in caught if issubclass(w.category, PlanCacheWarning)]
ps = repaired.persist_stats
print(f"  PlanCacheWarning raised: {len(typed) >= 1}; "
      f"rejects={ps['rejects']} hits={ps['hits']} saves={ps['saves']}")
for a, b in zip(rs, rs2):
    np.testing.assert_allclose(np.asarray(a.masked.table.columns["a"].data),
                               np.asarray(b.masked.table.columns["a"].data))
print("  results identical to the warm session's — the bad entry was "
      "rejected, recompiled, and re-saved behind")
