"""Table 4 (columnstore / batch-mode execution): the same UDF query with
row-at-a-time iteration vs the sort-based set-oriented group-by vs the
fused relagg Pallas kernel (batch mode) — the TPU analogue of the paper's
row store vs columnstore comparison.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit, time_run
from repro.core import (
    FROID,
    HEKATON,
    Session,
    UdfBuilder,
    col,
    param,
    scan,
    sum_,
    udf,
)
from repro.data.tpch import generate_tpch


def run(quick: bool = False, sf: float = 0.02):
    db = Session()
    generate_tpch(db, sf=sf)

    u = UdfBuilder("discount_price",
                   [("price", "float32"), ("disc", "float32")], "float32")
    u.return_(param("price") * (1.0 - param("disc")))
    db.create_function(u.build())

    q = (
        scan("lineitem")
        .filter(col("l_quantity") > 10)
        .group_by(
            "l_returnflag",
            rev=sum_(udf("discount_price", col("l_extendedprice"),
                         col("l_discount"))),
        )
    )

    fn_sort = db.prepare(q, FROID)
    t_sort = time_run(fn_sort)
    emit("table4/froid_on_rowstore(sort-groupby)", t_sort * 1e6, "")

    batch_mode = dataclasses.replace(FROID, pallas_agg=True, compile_plan=False)

    def run_pallas():
        return db.execute(q, batch_mode).masked.mask

    # NB: pallas interpret-mode on CPU measures dispatch, not MXU speed —
    # the batch-mode win is structural (no sort; one fused pass); we also
    # report the sort cost it eliminates.
    t_pal = time_run(run_pallas, warmup=1, iters=1)
    emit("table4/froid_on_batchmode(relagg)", t_pal * 1e6,
         f"vs_sort={t_sort/t_pal:.2f}x (interpret-mode timing)")

    n = db.catalog["lineitem"].num_rows
    fn_off = db.prepare(q, HEKATON)
    t_off = time_run(fn_off, warmup=1, iters=1)
    emit("table4/froid_off_iterative", t_off * 1e6,
         f"rows={n} slowdown_vs_batch={t_off/t_sort:.1f}x")


if __name__ == "__main__":
    run()
