"""Relational rewrite engine (paper §5 substitution + §6 compiler opts).

Rules (each is a semantics-preserving plan rewrite, unit-tested):

* ``remove_applies``      — Apply(L, single-row derived table) → Compute(L)
                            (apply removal / decorrelation of region DTs)
* ``splice_subqueries``   — ScalarSubquery over a pure single-row region
                            chain inside a Compute → splice its columns into
                            the outer Compute (the paper's *substitution*)
* ``fuse_computes``       — Compute(Compute(X)) → Compute(X)
* ``fold_constants``      — constant folding + CASE pruning (= constant
                            propagation + dynamic slicing, §6.1/§6.2)
* ``propagate_constants`` — within a Compute chain, replace refs to columns
                            that folded to constants
* ``prune_columns``       — projection pushdown == dead-code elimination
                            (§6.3: the @t example)
* ``decorrelate_scalar_agg`` / ``decorrelate_lookup`` / ``decorrelate_exists``
                          — correlated scalar subqueries → GroupAgg + left
                            join / semi-join (the "optimizer infers the
                            joins and group-bys" step that makes plans
                            set-oriented, §5)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import relalg as R
from repro.core import scalar as S
from repro.core.fingerprint import _norm as _fp_norm
from repro.core.fingerprint import plan_fingerprint

# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------

_fresh_counter = [0]


def _fresh(base: str) -> str:
    _fresh_counter[0] += 1
    return f"{base}_x{_fresh_counter[0]}"


def _is_region_chain(plan: R.RelNode) -> bool:
    node = plan
    while isinstance(node, (R.Compute, R.Project)):
        node = node.child
    return isinstance(node, R.ConstantScan)


def _rewrite_exprs(node: R.RelNode, fn) -> R.RelNode:
    """Rebuild ``node`` with every scalar expression passed through ``fn``
    (a Scalar -> Scalar transform)."""
    if isinstance(node, R.Compute):
        return R.Compute(node.child, {k: fn(v) for k, v in node.computed.items()})
    if isinstance(node, R.Filter):
        return R.Filter(node.child, fn(node.pred))
    if isinstance(node, R.GroupAgg):
        aggs = {
            k: R.AggSpec(a.fn, None if a.expr is None else fn(a.expr))
            for k, a in node.aggs.items()
        }
        return R.GroupAgg(node.child, node.keys, aggs, node.capacity,
                                  node.dense_range)
    if isinstance(node, R.Apply) and node.passthrough is not None:
        return R.Apply(node.left, node.right, node.kind, fn(node.passthrough))
    if hasattr(node, "map_exprs"):  # LoopScan & friends
        return node.map_exprs(fn)
    return node


def _expr_outer_refs(e: S.Scalar) -> set[str]:
    """Outer refs of e, including those of embedded subquery plans."""
    out = S.free_outer(e)
    for sub in S.walk(e):
        if isinstance(sub, (S.ScalarSubquery, S.Exists)):
            from repro.core.executor import _plan_outer_refs

            out |= _plan_outer_refs(sub.plan)
    return out


def _expr_col_refs(e: S.Scalar) -> set[str]:
    return S.free_cols(e)


# ---------------------------------------------------------------------------
# rule: apply removal
# ---------------------------------------------------------------------------


def remove_applies(plan: R.RelNode, catalog=None):
    """Apply(L, region-DT) with outer/cross kind → Compute(L, region cols),
    rewriting the region's Outer(c) refs to ColRef(c) (same row now).
    Outer refs *inside* nested subquery plans are left intact — they still
    refer to the (now wider) current row."""
    changed = [False]

    def fix_expr(e: S.Scalar) -> S.Scalar:
        def f(x):
            if isinstance(x, S.Outer):
                return S.ColRef(x.name)
            return None

        return S.transform(e, f)

    def rule(node: R.RelNode):
        if not isinstance(node, R.Apply) or node.kind not in ("outer", "cross"):
            return None
        if node.passthrough is not None:
            return None
        if not _is_region_chain(node.right):
            return None
        # collect the chain bottom-up
        chain = []
        cur = node.right
        while isinstance(cur, (R.Compute, R.Project)):
            chain.append(cur)
            cur = cur.child
        out = node.left
        for nd in reversed(chain):
            if isinstance(nd, R.Compute):
                out = R.Compute(
                    out, {k: fix_expr(v) for k, v in nd.computed.items()}
                )
            else:  # Project inside a region chain: narrow to region cols +
                # everything the left side already had is kept implicitly —
                # skip the narrowing here; prune_columns recovers it.
                continue
        changed[0] = True
        return out

    return R.transform_plan(plan, rule), changed[0]


# ---------------------------------------------------------------------------
# rule: splice single-row subqueries into the enclosing Compute
# ---------------------------------------------------------------------------


def splice_subqueries(plan: R.RelNode, catalog=None):
    """Compute(X, {c: f(ScalarSubquery(region-chain))}) — the shape produced
    by inlining a UDF — becomes Compute(X, {region cols..., c: f(ColRef)}).
    This is the paper's *substitution* step made explicit."""
    changed = [False]

    def rule(node: R.RelNode):
        if not isinstance(node, R.Compute):
            return None
        new_computed: dict[str, S.Scalar] = {}
        did = False
        for name, expr in node.computed.items():

            def fix(e: S.Scalar):
                nonlocal did
                if not isinstance(e, S.ScalarSubquery):
                    return None
                sub = e.plan
                # unwrap Project(Compute(ConstantScan, {...}), [col])
                rename = None
                if isinstance(sub, R.Project) and len(sub.cols) == 1:
                    (out_name, src_name), = sub.cols.items()
                    rename = (e.column or out_name, src_name)
                    sub = sub.child
                if not isinstance(sub, R.Compute) or not isinstance(
                    sub.child, R.ConstantScan
                ):
                    return None
                # splice: region-local columns become outer-row columns
                def o2c(x):
                    if isinstance(x, S.Outer):
                        return S.ColRef(x.name)
                    return None

                for cname, cexpr in sub.computed.items():
                    new_computed[cname] = S.transform(cexpr, o2c)
                did = True
                target = rename[1] if rename else e.column
                if target is None:
                    names = list(sub.computed)
                    target = names[-1]
                return S.ColRef(target)

            new_computed[name] = S.transform(expr, fix)
        if not did:
            return None
        changed[0] = True
        return R.Compute(node.child, new_computed)

    return R.transform_plan(plan, rule), changed[0]


# ---------------------------------------------------------------------------
# rule: fuse consecutive Computes
# ---------------------------------------------------------------------------


def fuse_computes(plan: R.RelNode, catalog=None):
    changed = [False]

    def rule(node: R.RelNode):
        if isinstance(node, R.Compute) and isinstance(node.child, R.Compute):
            inner = node.child
            merged = dict(inner.computed)
            merged.update(node.computed)
            if len(merged) != len(inner.computed) + len(node.computed):
                # name shadowing — only safe when SSA; bail out
                overlap = set(inner.computed) & set(node.computed)
                if overlap:
                    return None
            changed[0] = True
            return R.Compute(inner.child, merged)
        return None

    return R.transform_plan(plan, rule), changed[0]


# ---------------------------------------------------------------------------
# rule: constant folding (+ CASE pruning == dynamic slicing)
# ---------------------------------------------------------------------------


def _try_const(e: S.Scalar):
    """Return python constant if e is Const, else None-marker."""
    if isinstance(e, S.Const):
        return True, e.value
    return False, None


def _fold_expr(e: S.Scalar, changed) -> S.Scalar:
    def f(x: S.Scalar):
        if isinstance(x, (S.BinOp, S.Cmp)):
            lk, lv = _try_const(x.l)
            rk, rv = _try_const(x.r)
            if lk and rk and lv is not None and rv is not None:
                try:
                    out = _eval_const_binop(x, lv, rv)
                except Exception:
                    return None
                changed[0] = True
                return S.Const(out)
            if (lk and lv is None) or (rk and rv is None):
                changed[0] = True
                return S.Const(None)  # NULL propagates through arith/cmp
            return None
        if isinstance(x, S.BoolOp):
            vals = [(_try_const(a)) for a in x.args]
            if x.op == "not" and vals[0][0]:
                changed[0] = True
                v = vals[0][1]
                return S.Const(None if v is None else (not bool(v)))
            if x.op == "and":
                if any(k and v is not None and not v for k, v in vals):
                    changed[0] = True
                    return S.Const(False)
                rest = [a for a, (k, v) in zip(x.args, vals) if not (k and v)]
                if len(rest) < len(x.args):
                    changed[0] = True
                    if not rest:
                        return S.Const(True)
                    return rest[0] if len(rest) == 1 else S.BoolOp("and", rest)
            if x.op == "or":
                if any(k and v is not None and v for k, v in vals):
                    changed[0] = True
                    return S.Const(True)
                rest = [
                    a
                    for a, (k, v) in zip(x.args, vals)
                    if not (k and (v is not None and not v))
                ]
                if len(rest) < len(x.args):
                    changed[0] = True
                    if not rest:
                        return S.Const(False)
                    return rest[0] if len(rest) == 1 else S.BoolOp("or", rest)
            return None
        if isinstance(x, S.Case):
            # dynamic slicing: constant predicates select their branch
            new_whens = []
            for p, v in x.whens:
                k, pv = _try_const(p)
                if k:
                    if pv is not None and bool(pv):
                        changed[0] = True
                        if not new_whens:
                            return v
                        return S.Case(new_whens, v)
                    changed[0] = True  # false/NULL arm: drop it
                    continue
                new_whens.append((p, v))
            if len(new_whens) != len(x.whens):
                changed[0] = True
                if not new_whens:
                    return x.else_
                return S.Case(new_whens, x.else_)
            return None
        if isinstance(x, S.Coalesce):
            args = []
            for a in x.args:
                k, v = _try_const(a)
                if k and v is None:
                    changed[0] = True
                    continue  # NULL constant: drop
                args.append(a)
                if k:  # non-null constant: later args unreachable
                    break
            if len(args) != len(x.args):
                changed[0] = True
                if not args:
                    return S.Const(None)
                return args[0] if len(args) == 1 else S.Coalesce(args)
            return None
        if isinstance(x, S.IsNull):
            k, v = _try_const(x.expr)
            if k:
                changed[0] = True
                return S.Const(v is None)
            return None
        if isinstance(x, S.Cast):
            k, v = _try_const(x.expr)
            if k:
                changed[0] = True
                if v is None:
                    return S.Const(None)
                return S.Const(np.asarray(v).astype(x.dtype).item())
            return None
        if isinstance(x, S.Between):
            ks = [_try_const(a) for a in (x.expr, x.lo, x.hi)]
            if all(k for k, _ in ks):
                vs = [v for _, v in ks]
                if any(v is None for v in vs):
                    changed[0] = True
                    return S.Const(None)
                changed[0] = True
                return S.Const(vs[1] <= vs[0] <= vs[2])
            return None
        if isinstance(x, S.InList):
            k, v = _try_const(x.expr)
            if k:
                changed[0] = True
                return S.Const(None if v is None else v in x.options)
            return None
        if isinstance(x, S.Func) and x.name not in S.Func.NON_DETERMINISTIC:
            consts = [_try_const(a) for a in x.args]
            if all(k for k, _ in consts) and x.args:
                try:
                    vals = {}
                    out = S.eval_scalar(x, vals, S.EvalContext())
                    data = np.asarray(out.data)
                    ok = bool(np.asarray(out.validity()))
                    changed[0] = True
                    return S.Const(data.item() if ok else None)
                except Exception:
                    return None
        return None

    return S.transform(e, f)


def _eval_const_binop(x, lv, rv):
    if isinstance(x, S.Cmp):
        if isinstance(lv, str) or isinstance(rv, str):
            ops = {"==": lv == rv, "!=": lv != rv, "<": lv < rv,
                   "<=": lv <= rv, ">": lv > rv, ">=": lv >= rv}
            return ops[x.op]
        a, b = np.asarray(lv), np.asarray(rv)
        return bool({"==": a == b, "!=": a != b, "<": a < b,
                     "<=": a <= b, ">": a > b, ">=": a >= b}[x.op])
    if isinstance(lv, str) or isinstance(rv, str):
        raise TypeError("no constant string arithmetic")
    a, b = lv, rv
    out = {"+": a + b, "-": a - b, "*": a * b,
           "/": (a / b if b != 0 else None),
           "//": (a // b if b != 0 else None),
           "%": (a % b if b != 0 else None)}[x.op]
    return out


def fold_constants(plan: R.RelNode, catalog=None):
    changed = [False]

    def rule(node: R.RelNode):
        out = _rewrite_exprs(node, lambda e: _fold_expr(e, changed))
        return out if changed[0] else None

    # run expr folding everywhere (including inside subquery plans)
    def deep(node: R.RelNode):
        node2 = _rewrite_exprs(node, lambda e: _fold_and_recurse(e, changed))
        return node2

    def _fold_and_recurse(e, changed):
        def f(x):
            if isinstance(x, S.ScalarSubquery):
                sub, ch = fold_constants(x.plan, catalog)
                if ch:
                    changed[0] = True
                    return S.ScalarSubquery(sub, x.column, x.agg_default)
            if isinstance(x, S.Exists):
                sub, ch = fold_constants(x.plan, catalog)
                if ch:
                    changed[0] = True
                    return S.Exists(sub, x.negated)
            return None

        e = S.transform(e, f)
        return _fold_expr(e, changed)

    return R.transform_plan(plan, deep), changed[0]


# ---------------------------------------------------------------------------
# rule: constant propagation within a Compute
# ---------------------------------------------------------------------------


def propagate_constants(plan: R.RelNode, catalog=None):
    changed = [False]

    def rule(node: R.RelNode):
        if not isinstance(node, R.Compute):
            return None
        consts: dict[str, S.Const] = {}
        new: dict[str, S.Scalar] = {}
        did = False

        def subst(e: S.Scalar) -> S.Scalar:
            def f(x):
                nonlocal did
                if isinstance(x, (S.ColRef, S.Outer)) and x.name in consts:
                    did = True
                    return S.Const(consts[x.name].value)
                if isinstance(x, S.ScalarSubquery):
                    p2 = _subst_plan(x.plan)
                    if p2 is not x.plan:
                        return S.ScalarSubquery(p2, x.column, x.agg_default)
                if isinstance(x, S.Exists):
                    p2 = _subst_plan(x.plan)
                    if p2 is not x.plan:
                        return S.Exists(p2, x.negated)
                return None

            return S.transform(e, f)

        def _subst_plan(p: R.RelNode) -> R.RelNode:
            def fn(nd):
                out = _rewrite_exprs(nd, subst)
                return out

            return R.transform_plan(p, fn)

        for name, expr in node.computed.items():
            e2 = subst(expr)
            new[name] = e2
            if isinstance(e2, S.Const):
                consts[name] = e2
        if not did:
            return None
        changed[0] = True
        return R.Compute(node.child, new)

    return R.transform_plan(plan, rule), changed[0]


# ---------------------------------------------------------------------------
# rule: projection pushdown / dead column elimination
# ---------------------------------------------------------------------------


def prune_columns(plan: R.RelNode, catalog=None, required: set[str] | None = None):
    """Top-down DCE: drop computed columns nothing references (§6.3)."""
    changed = [False]

    def needed_of_expr(e: S.Scalar) -> set[str]:
        return _expr_col_refs(e) | _expr_outer_refs(e)

    def rec(node: R.RelNode, req: set[str] | None) -> R.RelNode:
        # req == None means "keep everything" (unknown consumer)
        if isinstance(node, R.Project):
            child_req = set(node.cols.values())
            return R.Project(rec(node.child, child_req), node.cols)
        if isinstance(node, R.Compute):
            if req is None:
                return R.Compute(rec(node.child, None), node.computed)
            keep: dict[str, S.Scalar] = {}
            needed = set(req)
            for name in reversed(list(node.computed)):
                expr = node.computed[name]
                if name in needed:
                    keep[name] = expr
                    needed |= needed_of_expr(expr)
            if len(keep) != len(node.computed):
                changed[0] = True
            keep = {k: keep[k] for k in node.computed if k in keep}
            child_req = (needed - set(keep)) | {
                r for r in needed if r not in node.computed
            }
            return R.Compute(rec(node.child, child_req), keep)
        if isinstance(node, R.Filter):
            child_req = None if req is None else req | needed_of_expr(node.pred)
            return R.Filter(rec(node.child, child_req), node.pred)
        if isinstance(node, R.Sort):
            child_req = None if req is None else req | {k for k, _ in node.keys}
            return R.Sort(rec(node.child, child_req), node.keys, node.limit)
        if isinstance(node, R.GroupAgg):
            child_req = set(node.keys)
            for a in node.aggs.values():
                if a.expr is not None:
                    child_req |= needed_of_expr(a.expr)
            return R.GroupAgg(
                rec(node.child, child_req), node.keys, dict(node.aggs),
                node.capacity, node.dense_range,
            )
        if isinstance(node, R.Join):
            lk = {l for l, _ in node.on}
            rk = {r for _, r in node.on}
            # redundant-join elimination: a left join against a key-unique
            # build whose columns nothing references preserves left rows
            # exactly — drop it (this is how a dead decorrelated subquery
            # disappears entirely, §6.3)
            if node.kind == "left" and req is not None and catalog is not None:
                try:
                    rcols = set(R.output_columns(node.right, catalog))
                except Exception:
                    rcols = None
                if rcols is not None and not (req & rcols):
                    changed[0] = True
                    return rec(node.left, req)
            lreq = None if req is None else (req | lk)
            rreq = None if req is None else (req | rk)
            return R.Join(
                rec(node.left, lreq), rec(node.right, rreq), node.on, node.kind
            )
        if isinstance(node, R.Apply):
            # conservative: right side's outer refs must stay available
            from repro.core.executor import _plan_outer_refs

            lreq = None if req is None else req | _plan_outer_refs(node.right)
            if node.passthrough is not None and lreq is not None:
                lreq |= needed_of_expr(node.passthrough)
            return R.Apply(
                rec(node.left, lreq), rec(node.right, None), node.kind,
                node.passthrough,
            )
        return node

    return rec(plan, required), changed[0]


# ---------------------------------------------------------------------------
# decorrelation rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _CorrPattern:
    table_plan: R.RelNode  # the uncorrelated (residual) chain, rebuilt
    keys: list  # [(inner key column, outer-row key expression), ...]


def _split_conjuncts(pred: S.Scalar) -> list[S.Scalar]:
    if isinstance(pred, S.BoolOp) and pred.op == "and":
        out = []
        for a in pred.args:
            out += _split_conjuncts(a)
        return out
    return [pred]


def _is_outer_key_expr(e: S.Scalar) -> bool:
    """True if e is an expression over the outer row only (>=1 Outer ref,
    no ColRefs/subqueries) — usable as a join key computed on the left."""
    if not S.free_outer(e):
        return False
    for x in S.walk(e):
        if isinstance(x, (S.ColRef, S.ScalarSubquery, S.Exists, S.UdfCall, S.Var)):
            return False
    return True


def _corr_digest(*parts) -> str:
    """Six-hex-digit content digest naming decorrelated plumbing columns.
    Content-derived (unlike ``_fresh``'s process-global counter), so the
    same query rewrites to byte-identical column names in every process —
    the rewritten plan fingerprints stably into all cache tiers and the
    persistent store."""
    import hashlib

    return hashlib.sha1(repr(parts).encode()).hexdigest()[:6]


def _match_corr_filter(plan: R.RelNode) -> _CorrPattern | None:
    """Match a ``[Filter|Compute|Project]*`` chain over an uncorrelated base
    whose filter conjuncts contain one or more ``ColRef(k) == g(Outer…)``
    equi-correlations (g any pure outer-row expression, e.g. a Cast the
    binder inserted) and whose every other conjunct / interposed
    computation is uncorrelated.

    Returns the chain rebuilt with the correlated conjuncts removed plus
    the (key column, outer expression) pairs.  A correlation key column
    must survive to the chain's output unchanged — not overwritten by a
    Compute nor dropped/renamed by a Project sitting above its Filter —
    else the pattern does not apply (caller keeps the per-row apply)."""
    spine: list[tuple[str, object]] = []  # top-down rebuild recipe
    corr: list[tuple[str, S.Scalar, int]] = []  # (key col, outer expr, depth)
    node = plan
    while True:
        if isinstance(node, R.Filter):
            residual = []
            for p in _split_conjuncts(node.pred):
                if isinstance(p, S.Cmp) and p.op == "==":
                    if isinstance(p.l, S.ColRef) and _is_outer_key_expr(p.r):
                        corr.append((p.l.name, p.r, len(spine)))
                        continue
                    if isinstance(p.r, S.ColRef) and _is_outer_key_expr(p.l):
                        corr.append((p.r.name, p.l, len(spine)))
                        continue
                if _expr_outer_refs(p):
                    return None
                residual.append(p)
            spine.append(("filter", residual))
            node = node.child
            continue
        if isinstance(node, R.Compute):
            if any(_expr_outer_refs(e) for e in node.computed.values()):
                return None
            spine.append(("node", node))
            node = node.child
            continue
        if isinstance(node, R.Project):
            spine.append(("node", node))
            node = node.child
            continue
        break
    from repro.core.executor import _plan_outer_refs

    if not corr or _plan_outer_refs(node):
        return None
    for key, _, depth in corr:
        for kind, nd in spine[:depth]:
            if kind != "node":
                continue
            if isinstance(nd, R.Compute) and key in nd.computed:
                return None
            if isinstance(nd, R.Project) and nd.cols.get(key) != key:
                return None
    inner = node
    for kind, payload in reversed(spine):
        if kind == "filter":
            for p in payload:
                inner = R.Filter(inner, p)
        else:
            inner = payload.with_children([inner])
    # dedupe repeated conjuncts, keeping first-seen (deterministic) order
    keys, seen = [], set()
    for key, expr, _ in corr:
        sig = (key, _fp_norm(expr))
        if sig not in seen:
            seen.add(sig)
            keys.append((key, expr))
    return _CorrPattern(inner, keys)


def _left_key_cols(pat: _CorrPattern, child: R.RelNode, tag: str):
    """Return (child', [key col names]) for joining ``child`` on the
    pattern's outer-key expressions: plain ``Outer(c)`` keys join on the
    column directly, expression keys get computed under a content-derived
    ``__dck`` name."""
    cols: list[str] = []
    computed: dict[str, S.Scalar] = {}
    for j, (_, e) in enumerate(pat.keys):
        if isinstance(e, S.Outer):
            cols.append(e.name)
            continue
        kc = f"__dck{tag}_{j}"
        computed[kc] = S.transform(
            e, lambda x: S.ColRef(x.name) if isinstance(x, S.Outer) else None
        )
        cols.append(kc)
    if computed:
        child = R.Compute(child, computed)
    return child, cols


def _outer_keys_available(pat: _CorrPattern, child: R.RelNode, catalog) -> bool:
    """The correlation may reference a scope further out than ``child``
    (e.g. inside a not-yet-spliced region chain) — only decorrelate when
    every Outer ref resolves to a column ``child`` produces."""
    names: set[str] = set()
    for _, e in pat.keys:
        names |= S.free_outer(e)
    if not names:
        return False
    try:
        cols = set(R.output_columns(child, catalog or {}))
    except Exception:
        return False
    return names <= cols


def _group_key(kind: str, pat: _CorrPattern) -> tuple:
    """Shared-build identity: two occurrences with the same (uncorrelated
    body, key columns, outer key expressions) materialize ONE build joined
    back once — the shared-scan materialization step."""
    return (
        kind,
        plan_fingerprint(pat.table_plan),
        tuple(k for k, _ in pat.keys),
        tuple(_fp_norm(e) for _, e in pat.keys),
    )


def decorrelate_in_computes(plan: R.RelNode, catalog=None):
    """Rewrite correlated ScalarSubquery/Exists inside Compute exprs into
    left joins against grouped/keyed builds — the step that turns iterative
    nested evaluation into set-oriented joins (paper §5, Figure 5).

    The inner scan then runs once per *distinct binding* instead of once
    per outer row.  Handled shapes: multi-aggregate ``GroupAgg`` bodies,
    multi-key equi-correlations, pure Compute/Project chains between the
    correlated filter and the aggregate, correlations on columns computed
    in the *same* Compute (substituted into the join key), EXISTS (as a
    ``count_star`` build), and projection lookups.  Occurrences sharing a
    body+key identity share one materialized build (aggregates merge into
    one keyed GroupAgg); anything that doesn't match keeps today's per-row
    apply — never an error."""
    changed = [False]

    def rule(node: R.RelNode):
        if not isinstance(node, R.Compute):
            return None
        child = node.child

        groups: dict[tuple, dict] = {}
        order: list[tuple] = []
        repl: dict[int, tuple] = {}  # id(expr node) -> (group key, member)
        defined_before: set[str] = set()
        subst: dict[str, S.Scalar] = {}

        def shallow(e: S.Scalar):
            """Walk e without descending into subquery plans (mirrors what
            ``S.transform`` visits, so collection and replacement agree)."""
            stack = [e]
            while stack:
                v = stack.pop()
                yield v
                if not isinstance(v, (S.ScalarSubquery, S.Exists)):
                    stack.extend(v.children())

        def resolve_keys(pat: _CorrPattern) -> _CorrPattern | None:
            """Outer refs naming columns computed earlier in this same
            Compute shadow the child's columns — substitute their (pure)
            definitions into the key expressions, to fixpoint, so the join
            key computes over ``child``.  None when a shadowed name has no
            substitutable definition."""
            out = []
            for key, e in pat.keys:
                for _ in range(8):
                    names = S.free_outer(e) & defined_before
                    if not names:
                        break
                    if not names <= set(subst):
                        return None
                    e = S.transform(
                        e,
                        lambda x: subst[x.name]
                        if isinstance(x, S.Outer) and x.name in subst
                        else None,
                    )
                else:
                    return None
                if not _is_outer_key_expr(e):
                    return None
                out.append((key, e))
            return _CorrPattern(pat.table_plan, out)

        def group_for(kind: str, pat: _CorrPattern) -> dict:
            gk = _group_key(kind, pat)
            g = groups.get(gk)
            if g is None:
                g = groups[gk] = {
                    "key": gk, "pat": pat, "kind": kind,
                    "slots": {}, "sigs": {},
                }
                order.append(gk)
            return g

        def slot_for(g: dict, sig: tuple, payload) -> str:
            """Content-deduped output slot within a shared build (two
            identical aggregates over one body yield one column)."""
            name = g["sigs"].get(sig)
            if name is None:
                name = f"a{len(g['slots'])}"
                g["sigs"][sig] = name
                g["slots"][name] = payload
            return name

        def register(x) -> None:
            if isinstance(x, S.Exists):
                pat = _match_corr_filter(x.plan)
                if pat is not None:
                    pat = resolve_keys(pat)
                if pat is None or not _outer_keys_available(pat, child, catalog):
                    return
                g = group_for("agg", pat)
                name = slot_for(g, ("count_star", None),
                                R.AggSpec("count_star", None))
                repl[id(x)] = (g["key"], ("exists", name, x.negated))
                return
            sub = x.plan
            if isinstance(sub, R.GroupAgg) and not sub.keys and sub.aggs:
                want = x.column
                if want is None and len(sub.aggs) == 1:
                    want = next(iter(sub.aggs))
                if want is None or want not in sub.aggs:
                    return
                if any(_expr_outer_refs_safe(a.expr) for a in sub.aggs.values()):
                    return
                pat = _match_corr_filter(sub.child)
                if pat is not None:
                    pat = resolve_keys(pat)
                if pat is None or not _outer_keys_available(pat, child, catalog):
                    return
                g = group_for("agg", pat)
                spec = sub.aggs[want]
                sig = (spec.fn,
                       None if spec.expr is None else _fp_norm(spec.expr))
                name = slot_for(g, sig, spec)
                repl[id(x)] = (g["key"], ("agg", name, spec.fn))
                return
            if isinstance(sub, R.Compute) and len(sub.computed) == 1:
                (pname, pexpr), = sub.computed.items()
                if (x.column or pname) != pname or _expr_outer_refs_safe(pexpr):
                    return
                pat = _match_corr_filter(sub.child)
                if pat is not None:
                    pat = resolve_keys(pat)
                if pat is None or not _outer_keys_available(pat, child, catalog):
                    return
                g = group_for("lkp", pat)
                name = slot_for(g, (_fp_norm(pexpr),), pexpr)
                repl[id(x)] = (g["key"], ("lkp", name))

        # -- phase 1: collect occurrences, grouped by shared-build identity
        for cname, e in node.computed.items():
            for v in shallow(e):
                if isinstance(v, (S.ScalarSubquery, S.Exists)) and id(v) not in repl:
                    register(v)
            pure = not any(
                isinstance(w, (S.ScalarSubquery, S.Exists, S.UdfCall,
                               S.Var, S.Outer))
                for w in shallow(e)
            )
            if pure:
                subst[cname] = S.transform(
                    e,
                    lambda x: S.Outer(x.name) if isinstance(x, S.ColRef)
                    else None,
                )
            defined_before.add(cname)

        if not repl:
            return None

        # -- phase 2: one materialized build + left join per group
        for gk in order:
            g = groups[gk]
            pat = g["pat"]
            try:
                existing = set(R.output_columns(child, catalog or {}))
            except Exception:
                existing = set()
            salt = 0
            while True:
                tag = _corr_digest(gk) if not salt else _corr_digest(gk, salt)
                named = [f"__dc{tag}_{s}" for s in g["slots"]]
                named += [f"__dgk{tag}_{j}" for j in range(len(pat.keys))]
                named += [f"__dck{tag}_{j}" for j in range(len(pat.keys))]
                if not any(c in existing for c in named):
                    break
                salt += 1
            g["tag"] = tag
            kf = [f"__dgk{tag}_{j}" for j in range(len(pat.keys))]
            proj = {kf[j]: pat.keys[j][0] for j in range(len(pat.keys))}
            if g["kind"] == "agg":
                aggs = {f"__dc{tag}_{s}": spec for s, spec in g["slots"].items()}
                build: R.RelNode = R.GroupAgg(
                    pat.table_plan, [k for k, _ in pat.keys], aggs
                )
                proj.update({c: c for c in aggs})
            else:
                projs = {f"__dc{tag}_{s}": ex for s, ex in g["slots"].items()}
                build = R.Compute(pat.table_plan, projs)
                proj.update({c: c for c in projs})
            rt = R.Project(build, proj)
            child, lks = _left_key_cols(pat, child, tag)
            child = R.Join(child, rt, list(zip(lks, kf)), "left")

        # -- phase 3: swap each occurrence for its build-output reference
        def fix(x):
            hit = repl.get(id(x))
            if hit is None:
                return None
            gk, m = hit
            tag = groups[gk]["tag"]
            if m[0] == "agg":
                _, sname, fn = m
                ref: S.Scalar = S.ColRef(f"__dc{tag}_{sname}")
                if fn in ("count", "count_star"):
                    ref = S.Coalesce([ref, S.Const(0)])
                return ref
            if m[0] == "exists":
                _, sname, negated = m
                has = S.Coalesce(
                    [S.ColRef(f"__dc{tag}_{sname}"), S.Const(0)]
                ) > S.Const(0)
                return S.BoolOp("not", [has]) if negated else has
            return S.ColRef(f"__dc{tag}_{m[1]}")

        changed[0] = True
        return R.Compute(
            child, {k: S.transform(e, fix) for k, e in node.computed.items()}
        )

    return R.transform_plan(plan, rule), changed[0]


def _expr_outer_refs_safe(e: S.Scalar | None) -> set[str]:
    if e is None:
        return set()
    return _expr_outer_refs(e)


def decorrelate_filters(plan: R.RelNode, catalog=None):
    """Filter(X, Exists(corr)) → semi-join; NOT Exists → anti-join."""
    changed = [False]

    def rule(node: R.RelNode):
        if not isinstance(node, R.Filter):
            return None
        pred = node.pred
        if isinstance(pred, S.Exists):
            pat = _match_corr_filter(pred.plan)
            if pat is None or not _outer_keys_available(pat, node.child, catalog):
                return None
            tag = _corr_digest(_group_key("semi", pat))
            kf = [f"__dgk{tag}_{j}" for j in range(len(pat.keys))]
            rt = R.Project(
                pat.table_plan,
                {kf[j]: pat.keys[j][0] for j in range(len(pat.keys))},
            )
            changed[0] = True
            kind = "anti" if pred.negated else "semi"
            child, lks = _left_key_cols(pat, node.child, tag)
            return R.Join(child, rt, list(zip(lks, kf)), kind)
        return None

    return R.transform_plan(plan, rule), changed[0]


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def annotate_group_stats(plan: R.RelNode, catalog=None):
    """§Perf (Froid engine): statistics-driven group-by planning.

    For a single-int-key GroupAgg whose key column traces to a base-table
    scan through Filter/Compute (key untouched), attach the table's
    (distinct, min, max) stats: ``capacity`` bounds the segment arrays and
    a dense key range switches the executor to direct ``gid = key - lo``
    segmenting — no sort.  This is what a cost-based optimizer gets from
    histograms; UDFs used to hide it (paper §2.3 'lack of costing')."""
    if not catalog:
        return plan, False
    changed = [False]

    def source_stats(node: R.RelNode, col: str):
        while isinstance(node, (R.Filter, R.Compute, R.Project)):
            if isinstance(node, R.Compute) and col in node.computed:
                return None
            if isinstance(node, R.Project):
                if col not in node.cols:
                    return None
                col = node.cols[col]
            node = node.child
        if isinstance(node, R.Scan):
            t = catalog.get(node.table)
            if t is not None and col in getattr(t, "stats", {}):
                return t.stats[col]
        return None

    def rule(node: R.RelNode):
        if (
            not isinstance(node, R.GroupAgg)
            or len(node.keys) != 1
            or node.dense_range is not None
        ):
            return None
        st = source_stats(node.child, node.keys[0])
        if st is None:
            return None
        distinct, lo, hi = st
        span = hi - lo + 1
        if span <= 0 or span > 4 * distinct or span > 1_000_000:
            cap = node.capacity or distinct
            if node.capacity is None:
                changed[0] = True
                return R.GroupAgg(node.child, node.keys, dict(node.aggs),
                                  distinct, None)
            return None
        changed[0] = True
        return R.GroupAgg(node.child, node.keys, dict(node.aggs),
                          node.capacity or span, (lo, hi))

    return R.transform_plan(plan, rule), changed[0]


DEFAULT_RULES = (
    remove_applies,
    splice_subqueries,
    fuse_computes,
    fold_constants,
    propagate_constants,
    decorrelate_in_computes,
    decorrelate_filters,
    annotate_group_stats,
)


def _deep(rule):
    """Lift a plan rule so it also rewrites subquery plans embedded in
    scalar expressions (ScalarSubquery / Exists), recursively."""

    def run(plan: R.RelNode, catalog=None):
        changed = [False]

        def fix_expr(e: S.Scalar) -> S.Scalar:
            def f(x):
                if isinstance(x, S.ScalarSubquery):
                    p2, ch = run(x.plan, catalog)
                    if ch:
                        changed[0] = True
                        return S.ScalarSubquery(p2, x.column, x.agg_default)
                if isinstance(x, S.Exists):
                    p2, ch = run(x.plan, catalog)
                    if ch:
                        changed[0] = True
                        return S.Exists(p2, x.negated)
                return None

            return S.transform(e, f)

        def node_fn(node: R.RelNode):
            out = _rewrite_exprs(node, fix_expr)
            return out

        plan = R.transform_plan(plan, node_fn)
        plan, ch = rule(plan, catalog)
        return plan, changed[0] or ch

    return run


def deep_prune(plan: R.RelNode, catalog=None, required: set[str] | None = None):
    """prune_columns, recursing into subquery plans with their own
    required-sets (a ScalarSubquery needs only its output column; an Exists
    needs none)."""
    changed = [False]

    def fix_expr(e: S.Scalar) -> S.Scalar:
        def f(x):
            if isinstance(x, S.ScalarSubquery):
                req = {x.column} if x.column else None
                p2, ch = deep_prune(x.plan, catalog, req)
                if ch:
                    changed[0] = True
                    return S.ScalarSubquery(p2, x.column, x.agg_default)
            if isinstance(x, S.Exists):
                p2, ch = deep_prune(x.plan, catalog, set())
                if ch:
                    changed[0] = True
                    return S.Exists(p2, x.negated)
            return None

        return S.transform(e, f)

    plan = R.transform_plan(plan, lambda nd: _rewrite_exprs(nd, fix_expr))
    plan, ch = prune_columns(plan, catalog, required)
    return plan, changed[0] or ch


def optimize(
    plan: R.RelNode,
    catalog=None,
    required: set[str] | None = None,
    rules=DEFAULT_RULES,
    max_passes: int = 12,
) -> R.RelNode:
    """Run the rewrite rules to fixpoint (recursing into subquery plans),
    pruning dead columns first in every pass so dead subqueries disappear
    before decorrelation turns them into joins (§6.3)."""
    deep_rules = [_deep(r) for r in rules]

    def prune_rule(p, c):
        return deep_prune(p, c, required)

    all_rules = [prune_rule] + deep_rules
    for _ in range(max_passes):
        any_change = False
        for rule in all_rules:
            plan, ch = rule(plan, catalog)
            any_change = any_change or ch
        if not any_change:
            break
    plan, _ = deep_prune(plan, catalog, required)
    return plan


# ---------------------------------------------------------------------------
# plan pretty-printer (EXPLAIN)
# ---------------------------------------------------------------------------


def explain(plan: R.RelNode, indent: int = 0) -> str:
    pad = "  " * indent
    out = []
    n = plan
    if isinstance(n, R.Scan):
        out.append(f"{pad}Scan {n.table}")
    elif isinstance(n, R.ConstantScan):
        out.append(f"{pad}ConstantScan")
    elif isinstance(n, R.Compute):
        out.append(f"{pad}Compute {list(n.computed)}")
        for name, e in n.computed.items():
            for sub in S.walk(e):
                if isinstance(sub, (S.ScalarSubquery, S.Exists)):
                    out.append(f"{pad}  [subquery of {name}]")
                    out.append(explain(sub.plan, indent + 2))
        out.append(explain(n.child, indent + 1))
    elif isinstance(n, R.Project):
        out.append(f"{pad}Project {list(n.cols)}")
        out.append(explain(n.child, indent + 1))
    elif isinstance(n, R.Filter):
        out.append(f"{pad}Filter {n.pred!r}")
        out.append(explain(n.child, indent + 1))
    elif isinstance(n, R.Join):
        out.append(f"{pad}Join[{n.kind}] on {n.on}")
        out.append(explain(n.left, indent + 1))
        out.append(explain(n.right, indent + 1))
    elif isinstance(n, R.Apply):
        out.append(f"{pad}Apply[{n.kind}]")
        out.append(explain(n.left, indent + 1))
        out.append(explain(n.right, indent + 1))
    elif isinstance(n, R.GroupAgg):
        out.append(f"{pad}GroupAgg keys={n.keys} aggs={list(n.aggs)}")
        out.append(explain(n.child, indent + 1))
    elif isinstance(n, R.Sort):
        out.append(f"{pad}Sort {n.keys} limit={n.limit}")
        out.append(explain(n.child, indent + 1))
    elif isinstance(n, R.LoopScan):
        out.append(f"{pad}LoopScan[{n.kind}] outputs={n.outputs} "
                   f"carry={list(n.carry)} steps={len(n.steps)}")
        out.append(explain(n.child, indent + 1))
    else:
        out.append(f"{pad}{type(n).__name__}")
    return "\n".join(out)
