"""Stable cache-key machinery for the persistent plan tier.

A persistent cache key must mean the same thing in every process that opens
the store, so it may contain only value-like primitives: ``str``, ``bytes``,
``int``, ``float``, ``bool``, ``None`` and (nested) tuples of those.
Anything process-local — ``id()``-derived integers, monotonic stamp counters,
dict-order-dependent sequences, live objects — would make two identical
statements in two workers miss (or worse, alias) each other.

:func:`assert_stable_key` is the enforcement point: the session routes every
persistent key through it, and the round-trip test in
``tests/test_persist.py`` asserts ``parse_key(repr(key)) == key`` for every
tier so a regression that smuggles a process-local value into a key fails
loudly instead of silently degrading hit rates.
"""
from __future__ import annotations

import ast
import hashlib
import re

_SCALARS = (str, bytes, bool, int, float, type(None))

#: the pre-PR-10 reserved slot-parameter spelling embedded the occurrence's
#: process-local ``node_id`` (``__cse_slot_<digits>``) — a value that can
#: never mean the same thing in two processes.  The canonical spelling is
#: ordinal-based (``__cse_slot_o<digits>``, see ``repro.fuse.merge``) and
#: deliberately does not match this shape.
_ID_SHAPED = re.compile(r"^__cse_slot_\d+$")


def assert_stable_key(obj: object, path: str = "key") -> None:
    """Raise ``TypeError`` naming the offending path unless *obj* is built
    purely from persistable primitives (scalars and nested tuples), none of
    which spell a process-local identity (id()-shaped slot-parameter
    names)."""
    if isinstance(obj, str):
        if _ID_SHAPED.match(obj):
            raise TypeError(
                f"unstable cache-key component at {path}: {obj!r} embeds a "
                "process-local node id — use the canonical ordinal slot "
                "spelling (repro.fuse.merge.slot_param)"
            )
        return
    if isinstance(obj, _SCALARS):
        return
    if isinstance(obj, tuple):
        for i, item in enumerate(obj):
            assert_stable_key(item, f"{path}[{i}]")
        return
    raise TypeError(
        f"unstable cache-key component at {path}: {type(obj).__name__} "
        f"({obj!r}) — persistent keys may only contain "
        "str/bytes/int/float/bool/None and tuples thereof"
    )


def key_digest(key: tuple) -> str:
    """Content-addressed filename for *key* (hex sha256 of its repr)."""
    assert_stable_key(key)
    return hashlib.sha256(repr(key).encode("utf-8")).hexdigest()


def parse_key(text: str) -> tuple:
    """Inverse of ``repr`` for stable keys (strict literal parse)."""
    key = ast.literal_eval(text)
    assert_stable_key(key)
    return key
